# vqt — build, test, and artifact pipeline.
#
# Tier-1 verification (ROADMAP.md):  make build test
# Full three-layer path:             make artifacts build test
#
# Layers (see docs/ARCHITECTURE.md):
#   L3  rust/            serving coordinator + incremental engine (cargo)
#   L2  python/compile/  JAX model lowered to HLO-text artifacts (make artifacts)
#   L1  python/compile/kernels/  Pallas kernels validated against jnp refs

CARGO  ?= cargo
PYTHON ?= python3
ARTIFACTS := rust/artifacts

.PHONY: all build test artifacts train bench doc fmt clippy py-test clean distclean

all: build

## Rust -----------------------------------------------------------------

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

bench:
	$(CARGO) bench

doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

fmt:
	$(CARGO) fmt --all -- --check

clippy:
	$(CARGO) clippy --workspace --all-targets -- -D warnings

## Python build path (L2/L1) --------------------------------------------

# Lower the JAX model (+ Pallas kernels) to HLO-text artifacts and export
# VQTB weights under rust/artifacts/ — consumed by rust/src/runtime/.
# Requires JAX. When JAX is absent this prints a clear SKIP and exits 0 so
# the pure-Rust tier stays usable: the artifact-dependent Rust tests
# (rust/tests/integration_runtime.rs, examples/classification_e2e.rs)
# detect the missing artifacts/ and skip cleanly.
artifacts:
	@if $(PYTHON) -c "import jax" >/dev/null 2>&1; then \
		cd python && $(PYTHON) -m compile.aot --out ../$(ARTIFACTS) \
			--weights ../$(ARTIFACTS)/weights_trained_serve.bin; \
	else \
		echo "SKIP: jax is not importable — $(ARTIFACTS)/ not built."; \
		echo "      Rust artifact-dependent tests will print SKIP and pass."; \
	fi

# Train the Table-1 variants + the serving checkpoint (slow; optional —
# everything runs on deterministic random init without it).
train:
	cd python && $(PYTHON) -m compile.train --out ../$(ARTIFACTS) \
		--variants serve,opt,distil,vq_h2,vq_h4

py-test:
	cd python && $(PYTHON) -m pytest tests/ -q

## Housekeeping ----------------------------------------------------------

clean:
	$(CARGO) clean

distclean: clean
	rm -rf $(ARTIFACTS)
