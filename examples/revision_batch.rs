//! Offline batch processing (the paper's second scenario, §1/§3): a queue
//! of document revisions waits for processing; revisions of the same
//! document share a base, so the coordinator processes the base once and
//! each revision incrementally, storing activations in the compressed
//! (P, C) form of §3.1. Reports FLOP savings and measured storage
//! compression.
//!
//! Run: `cargo run --release --example revision_batch`

use std::sync::Arc;
use vqt::bench::serving_weights;
use vqt::config::{ModelConfig, ServeConfig};
use vqt::coordinator::{Backend, Coordinator, Request, Response};
use vqt::edits::trace::{RevisionTrace, TraceConfig};
use vqt::incremental::EngineOptions;
use vqt::util::Rng;

fn main() -> anyhow::Result<()> {
    vqt::util::logging::init();
    let cfg = ModelConfig::vqt_mini();
    let (weights, trained) = serving_weights(&cfg, "weights_trained_serve.bin");
    let coordinator = Coordinator::start(
        Backend {
            weights: Arc::clone(&weights),
            artifacts_dir: None,
            engine_opts: EngineOptions::default(),
        },
        ServeConfig::default(),
    );
    let client = coordinator.client();
    let mut rng = Rng::new(77);

    // Build a revision queue: 4 documents × 6 revisions each.
    let mut tcfg = TraceConfig::mini();
    tcfg.min_len = 256;
    tcfg.max_len = 384;
    println!(
        "offline revision queue: 4 documents × 6 revisions ({} weights)\n",
        if trained { "trained" } else { "random-init" }
    );

    let (mut total_flops, mut total_dense) = (0u64, 0u64);
    for doc_id in 0..4 {
        let trace = RevisionTrace::generate(&tcfg, 7, &mut rng);
        let base = trace.revisions[0].clone();
        let revisions: Vec<Vec<u32>> = trace.revisions[1..].to_vec();
        let resp = client.request(Request::BatchRevisions {
            base: base.clone(),
            revisions: revisions.clone(),
        })?;
        match resp {
            Response::BatchLogits {
                each,
                flops,
                dense_equiv_flops,
                storage,
            } => {
                total_flops += flops;
                total_dense += dense_equiv_flops;
                println!(
                    "doc {doc_id}: base {} tokens, {} revisions → {:.1}× fewer ops; \
                     activation storage {:.1}× smaller ({} vs {} floats)",
                    base.len(),
                    each.len(),
                    dense_equiv_flops as f64 / flops as f64,
                    storage.1 as f64 / storage.0.max(1) as f64,
                    storage.0,
                    storage.1
                );
            }
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }
    println!(
        "\nqueue total: {:.1}× fewer arithmetic operations than from-scratch processing",
        total_dense as f64 / total_flops as f64
    );
    if let Response::Stats(stats) = client.request(Request::Stats)? {
        println!("coordinator stats: {}", stats.to_string());
    }
    Ok(())
}
