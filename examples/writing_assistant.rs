//! Writing assistant (the paper's motivating ONLINE scenario, §1): a user
//! edits a document word by word while the model keeps its classification
//! fresh after every keystroke-level change. Reports per-edit latency,
//! FLOP savings, and positional-defrag events.
//!
//! Run: `cargo run --release --example writing_assistant`

use std::sync::Arc;
use vqt::bench::serving_weights;
use vqt::config::ModelConfig;
use vqt::edits::trace::{next_revision, sample_atomic, TraceConfig};
use vqt::edits::Edit;
use vqt::flops::dense_forward_flops;
use vqt::incremental::{EngineOptions, IncrementalEngine};
use vqt::util::{median, Rng};

fn main() -> anyhow::Result<()> {
    vqt::util::logging::init();
    let cfg = ModelConfig::vqt_mini();
    let (weights, trained) = serving_weights(&cfg, "weights_trained_serve.bin");
    let mut rng = Rng::new(2026);

    // Simulate a long editing session: a document under continuous
    // word-by-word revision (the atomic-edit stream of Fig. 4).
    let tcfg = TraceConfig::mini();
    let mut doc = vqt::edits::trace::generate_document(&tcfg, &mut rng);
    doc.truncate(448);
    println!(
        "writing assistant on a {}-token document ({} weights)\n",
        doc.len(),
        if trained { "trained" } else { "random-init" }
    );

    let mut engine = IncrementalEngine::new(Arc::clone(&weights), &doc, EngineOptions::default());
    let session_edits = 120;
    let mut latencies_ms = Vec::new();
    let mut speedups = Vec::new();
    let mut label_flips = 0;
    let mut last_pred = engine.predict();

    for step in 0..session_edits {
        // Draw the next atomic edit from a simulated revision.
        let target = next_revision(&tcfg, engine.tokens(), &mut rng);
        let Some(sample) = sample_atomic(engine.tokens(), &target, None, &mut rng) else {
            continue;
        };
        // (apply_edit on the live engine, not the sample's base — we're
        // streaming single edits)
        let edit = clamp_edit(sample.edit, engine.len(), cfg.max_seq);
        let t0 = std::time::Instant::now();
        let rep = engine.apply_edit(edit);
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        latencies_ms.push(ms);
        speedups.push(dense_forward_flops(&cfg, engine.len()) as f64 / rep.flops as f64);
        let pred = engine.predict();
        if pred != last_pred {
            label_flips += 1;
            last_pred = pred;
        }
        if step % 30 == 0 {
            println!(
                "  step {step:>3}: {edit:?} → {ms:.2} ms, {:.0}× fewer ops, sentiment={}",
                speedups.last().unwrap(),
                if pred == 1 { "positive" } else { "negative" }
            );
        }
    }

    println!(
        "\nsession summary: {} edits | median latency {:.2} ms | median op-saving {:.0}× | \
         {} defrags | {} label changes",
        latencies_ms.len(),
        median(&latencies_ms),
        median(&speedups),
        engine.stats.defrags,
        label_flips
    );
    println!(
        "engine stats: {} corrections, {} full row recomputes, {} code flips, {} output recomputes",
        engine.stats.corrections,
        engine.stats.rows_recomputed,
        engine.stats.code_flips,
        engine.stats.outputs_recomputed
    );
    let rep = engine.verify();
    println!(
        "state verification after the whole session: {} code mismatches, max logit diff {:.2e}",
        rep.code_mismatches, rep.max_logit_diff
    );

    // The assistant's other job: next-token suggestions, fresh after every
    // edit at O(vocab·d) — independent of document length.
    let top = engine.suggest_topk(3);
    println!(
        "next-token suggestions after the session: {:?}",
        top.iter().map(|(t, s)| format!("{t}:{s:.2}")).collect::<Vec<_>>()
    );
    Ok(())
}

/// Keep sampled edits valid against the LIVE document (lengths drift).
fn clamp_edit(e: Edit, len: usize, max_seq: usize) -> Edit {
    match e {
        Edit::Replace { at, tok } => Edit::Replace { at: at.min(len - 1), tok },
        Edit::Insert { at, tok } if len < max_seq => Edit::Insert { at: at.min(len), tok },
        Edit::Insert { at, tok } => Edit::Replace { at: at.min(len - 1), tok },
        Edit::Delete { at } if len > 1 => Edit::Delete { at: at.min(len - 1) },
        Edit::Delete { .. } => Edit::Replace { at: 0, tok: 0 },
    }
}
