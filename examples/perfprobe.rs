//! Minimal latency probe for the early-edit worst case (all later rows
//! receive corrections). Uses trained serving weights when `make train`
//! ran, deterministic random init otherwise.
//!
//! Run: `cargo run --release --example perfprobe`

use vqt::bench::serving_weights;
use vqt::config::ModelConfig;
use vqt::edits::Edit;
use vqt::incremental::{EngineOptions, IncrementalEngine};

fn main() {
    let cfg = ModelConfig::vqt_mini();
    let (w, _trained) = serving_weights(&cfg, "weights_trained_serve.bin");
    let tokens: Vec<u32> = (0..512).map(|i| (i * 37 % 256) as u32).collect();
    let mut eng = IncrementalEngine::new(w, &tokens, EngineOptions::default());
    let mut best = f64::INFINITY;
    for round in 0..5 {
        let t0 = std::time::Instant::now();
        for i in 0..20 {
            eng.apply_edit(Edit::Replace { at: 51, tok: ((round * 20 + i) % 255) as u32 });
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / 20.0;
        best = best.min(ms);
    }
    println!("early-edit p-best: {best:.2} ms/edit");
}
