use std::sync::Arc;
use vqt::config::ModelConfig;
use vqt::edits::Edit;
use vqt::incremental::{EngineOptions, IncrementalEngine};
use vqt::model::ModelWeights;
fn main() {
    let cfg = ModelConfig::vqt_mini();
    let w = Arc::new(ModelWeights::load("artifacts/weights_trained_serve.bin", &cfg).unwrap_or_else(|_| ModelWeights::random(&cfg, 7)));
    let tokens: Vec<u32> = (0..512).map(|i| (i * 37 % 256) as u32).collect();
    let mut eng = IncrementalEngine::new(w, &tokens, EngineOptions::default());
    let mut best = f64::INFINITY;
    for round in 0..5 {
        let t0 = std::time::Instant::now();
        for i in 0..20 {
            eng.apply_edit(Edit::Replace { at: 51, tok: ((round * 20 + i) % 255) as u32 });
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / 20.0;
        best = best.min(ms);
    }
    println!("early-edit p-best: {best:.2} ms/edit");
}
