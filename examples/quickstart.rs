//! Quickstart: open an editing session, apply edits, observe that each
//! edit costs a small fraction of a dense forward pass while producing
//! identical classifications.
//!
//! Run: `cargo run --release --example quickstart`
//! (works without artifacts; uses trained weights when `make train` ran)

use std::sync::Arc;
use vqt::bench::serving_weights;
use vqt::config::ModelConfig;
use vqt::edits::Edit;
use vqt::flops::dense_forward_flops;
use vqt::incremental::{EngineOptions, IncrementalEngine};

fn main() -> anyhow::Result<()> {
    vqt::util::logging::init();
    let cfg = ModelConfig::vqt_mini();
    let (weights, trained) = serving_weights(&cfg, "weights_trained_serve.bin");
    println!(
        "VQT-mini: {} params, {} layers, {} VQ heads × {} codes ({} weights)",
        cfg.param_count(),
        cfg.n_layers,
        cfg.vq_heads,
        cfg.vq_codes,
        if trained { "trained" } else { "random-init" }
    );

    // A "document": byte tokens. Pretend it is review text.
    let document: Vec<u32> = "this movie was absolutely wonderful, a joy to watch"
        .bytes()
        .map(u32::from)
        .collect();

    // Opening a session costs one full forward pass...
    let mut engine = IncrementalEngine::new(Arc::clone(&weights), &document, EngineOptions::default());
    let full_cost = engine.ledger.total();
    println!(
        "\nopened session: {} tokens, initial pass {:.1}M ops, logits {:?}",
        engine.len(),
        full_cost as f64 / 1e6,
        engine.logits()
    );

    // ...but edits are incremental.
    let edits = [
        Edit::Replace { at: 20, tok: b't' as u32 },  // wonderful -> t...
        Edit::Insert { at: 0, tok: b'!' as u32 },
        Edit::Delete { at: 5 },
    ];
    let dense = dense_forward_flops(&cfg, engine.len());
    for e in edits {
        let rep = engine.apply_edit(e);
        println!(
            "{e:?}: {:.2}M ops — {:.1}× fewer than a dense pass",
            rep.flops as f64 / 1e6,
            dense as f64 / rep.flops as f64
        );
    }

    // The exactness claim: the incremental state matches a from-scratch
    // dense recompute.
    let report = engine.verify();
    println!(
        "\nverify vs dense recompute: {} / {} VQ codes match, max logit diff {:.2e}",
        report.total_codes - report.code_mismatches,
        report.total_codes,
        report.max_logit_diff
    );
    assert!(report.is_exact(1e-3));
    println!("exactness holds ✓");
    Ok(())
}
