//! END-TO-END driver (the repo's full-system validation): load the trained
//! serving model, start the coordinator (with the AOT L2 artifacts as the
//! dense path), and serve a realistic multi-session editing workload —
//! live sentiment classification over documents under edit. Reports
//! accuracy, latency percentiles, throughput, and the aggregate FLOP
//! saving.
//!
//! Run: `make artifacts && cargo run --release --example classification_e2e`

use std::sync::Arc;
use std::time::Instant;
use vqt::config::{ModelConfig, ServeConfig};
use vqt::coordinator::{Backend, Coordinator, Request, Response};
use vqt::edits::Edit;
use vqt::incremental::EngineOptions;
use vqt::model::ModelWeights;
use vqt::runtime::ArtifactManifest;
use vqt::util::{percentile, Rng};

/// Synthetic sentiment document (mirrors python/compile/datagen.py: the
/// corpus the serving model was trained on).
fn sentiment_doc(rng: &mut Rng, min_len: usize, max_len: usize) -> (Vec<u32>, usize) {
    let n = rng.range(min_len, max_len);
    let label = rng.below(2);
    let mut doc: Vec<u32> = (0..n).map(|_| rng.below(200) as u32).collect();
    let k = rng.range(4, 16).min(n);
    let slots = rng.sorted_subset(n, k);
    for s in slots {
        let agree = rng.chance(0.8);
        let positive = (label == 1) == agree;
        let lex = if positive { 200..216 } else { 216..232 };
        doc[s] = rng.range(lex.start, lex.end - 1) as u32;
    }
    (doc, label)
}

/// An edit that *preserves* the document's sentiment (touches filler).
fn neutral_edit(rng: &mut Rng, len: usize, max_seq: usize) -> Edit {
    let tok = rng.below(200) as u32;
    match rng.below(3) {
        0 => Edit::Replace { at: rng.below(len), tok },
        1 if len < max_seq => Edit::Insert { at: rng.below(len + 1), tok },
        _ if len > 8 => Edit::Delete { at: rng.below(len) },
        _ => Edit::Replace { at: rng.below(len), tok },
    }
}

fn main() -> anyhow::Result<()> {
    vqt::util::logging::init();
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let (cfg, weights, use_artifacts) = if dir.join("manifest.json").exists() {
        // Weights + config come straight from the bundle; the coordinator
        // probes PJRT itself and falls back to the oracle if unavailable.
        let manifest = ArtifactManifest::load(&dir)?;
        let cfg = manifest.config.clone();
        let w = ModelWeights::load(ArtifactManifest::weights_path(&dir), &cfg)?;
        (cfg, w, true)
    } else {
        eprintln!("NOTE: no artifacts/ — run `make artifacts` for the full three-layer path");
        let cfg = ModelConfig::vqt_mini();
        let w = ModelWeights::random(&cfg, 7);
        (cfg, w, false)
    };
    println!(
        "e2e: serving VQT-mini ({} params, artifacts={})",
        cfg.param_count(),
        use_artifacts
    );

    let coordinator = Coordinator::start(
        Backend {
            weights: Arc::new(weights),
            artifacts_dir: use_artifacts.then(|| dir.clone()),
            engine_opts: EngineOptions::default(),
        },
        ServeConfig {
            max_sessions: 32,
            ..ServeConfig::default()
        },
    );
    let client = coordinator.client();
    let mut rng = Rng::new(42);

    // --- workload: 16 sessions, ~40 edits each ---------------------------
    let sessions = 16usize;
    let edits_per_session = 40usize;
    let mut labels = Vec::new();
    println!("\nopening {sessions} sessions (documents 192–448 tokens)…");
    let t_open = Instant::now();
    for s in 0..sessions {
        let (doc, label) = sentiment_doc(&mut rng, 192, 448);
        labels.push(label);
        client.request(Request::Open {
            session: format!("doc{s}"),
            tokens: doc,
        })?.logits()?;
    }
    let open_s = t_open.elapsed().as_secs_f64();

    println!("streaming {} edits round-robin…", sessions * edits_per_session);
    let mut lat_ms = Vec::new();
    let mut correct = 0usize;
    let mut total_preds = 0usize;
    let mut flops_inc = 0u64;
    let mut flops_dense = 0u64;
    let t_serve = Instant::now();
    for round in 0..edits_per_session {
        for s in 0..sessions {
            let sid = format!("doc{s}");
            // Track current length via a stats-free approach: ask for a
            // neutral replace at a safe position.
            let e = neutral_edit(&mut rng, 64, cfg.max_seq); // positions < 64 always valid
            let t0 = Instant::now();
            let resp = client.request(Request::Edit {
                session: sid,
                edit: e,
            })?;
            lat_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            match resp {
                Response::Logits {
                    predicted,
                    flops,
                    dense_equiv_flops,
                    ..
                } => {
                    flops_inc += flops;
                    flops_dense += dense_equiv_flops;
                    if round == edits_per_session - 1 {
                        total_preds += 1;
                        correct += (predicted == labels[s]) as usize;
                    }
                }
                other => anyhow::bail!("{other:?}"),
            }
        }
    }
    let serve_s = t_serve.elapsed().as_secs_f64();
    let n_edits = lat_ms.len();

    // --- dense-path check (L2 artifacts through PJRT) ---------------------
    if use_artifacts {
        let (doc, _) = sentiment_doc(&mut rng, 128, 256);
        let t0 = Instant::now();
        client.request(Request::Dense { tokens: doc })?.logits()?;
        println!(
            "\ndense path (AOT/PJRT when available, oracle otherwise): {:.1} ms",
            t0.elapsed().as_secs_f64() * 1e3
        );
    }

    // --- report ------------------------------------------------------------
    println!("\n=== e2e report ===");
    println!("session opens : {sessions} in {open_s:.2}s ({:.1}/s)", sessions as f64 / open_s);
    println!(
        "edit requests : {n_edits} in {serve_s:.2}s → {:.0} req/s sustained",
        n_edits as f64 / serve_s
    );
    println!(
        "latency       : p50 {:.2} ms · p90 {:.2} ms · p99 {:.2} ms",
        percentile(&lat_ms, 50.0),
        percentile(&lat_ms, 90.0),
        percentile(&lat_ms, 99.0)
    );
    println!(
        "FLOP saving   : {:.1}× fewer arithmetic ops than dense re-processing",
        flops_dense as f64 / flops_inc as f64
    );
    println!(
        "accuracy      : {}/{} final classifications correct (sentiment preserved under neutral edits)",
        correct, total_preds
    );
    if let Response::Stats(stats) = client.request(Request::Stats)? {
        println!("coordinator   : {}", stats.to_string());
    }
    Ok(())
}
