//! Server demo: run a scripted client against the TCP JSON wire protocol
//! and print the exchange (newline-delimited JSON, one object per line).
//!
//! For readability this demo drives `vqt::server::handle_conn` directly —
//! the blocking thread-per-connection reference handler. The production
//! deploy shape is the readiness-driven async front end (`serve_async`,
//! ARCHITECTURE.md §10): a fixed pool of IO threads with admission
//! control (defaults: `max_connections = 4096`, `max_inflight_per_conn =
//! 32`) and typed `Busy` load shedding. Both front ends speak the wire
//! protocol shown here and produce bit-identical replies, so everything
//! this demo prints applies to both.
//!
//! Run: `cargo run --release --example server_demo`

use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;
use vqt::bench::serving_weights;
use vqt::config::{ModelConfig, ServeConfig};
use vqt::coordinator::{Backend, Coordinator};
use vqt::incremental::EngineOptions;

fn main() -> anyhow::Result<()> {
    vqt::util::logging::init();
    let cfg = ModelConfig::vqt_mini();
    let (weights, _) = serving_weights(&cfg, "weights_trained_serve.bin");
    let coordinator = Coordinator::start(
        Backend {
            weights: Arc::clone(&weights),
            artifacts_dir: None,
            engine_opts: EngineOptions::default(),
        },
        ServeConfig::default(),
    );

    // Bind an ephemeral port and serve one connection in the background.
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let client = coordinator.client();
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let Ok(stream) = conn else { continue };
            let c = client.clone();
            std::thread::spawn(move || {
                let _ = vqt::server::handle_conn(stream, c);
            });
        }
    });
    println!("server listening on {addr}\n");

    let mut conn = std::net::TcpStream::connect(addr)?;
    let mut reader = BufReader::new(conn.try_clone()?);
    let mut rpc = |line: &str| -> anyhow::Result<String> {
        println!("→ {line}");
        conn.write_all(line.as_bytes())?;
        conn.write_all(b"\n")?;
        let mut resp = String::new();
        reader.read_line(&mut resp)?;
        // Truncate long logit arrays for display.
        let disp = if resp.len() > 160 {
            format!("{}…", &resp[..160])
        } else {
            resp.trim().to_string()
        };
        println!("← {disp}\n");
        Ok(resp)
    };

    let doc: Vec<String> = "what a delightful and moving film"
        .bytes()
        .map(|b| b.to_string())
        .collect();
    rpc(&format!(
        r#"{{"op":"open","session":"rev1","tokens":[{}]}}"#,
        doc.join(",")
    ))?;
    rpc(r#"{"op":"edit","session":"rev1","kind":"replace","at":7,"tok":100}"#)?;
    rpc(r#"{"op":"edit","session":"rev1","kind":"insert","at":0,"tok":33}"#)?;
    rpc(r#"{"op":"edit","session":"rev1","kind":"delete","at":3}"#)?;
    rpc(r#"{"op":"stats"}"#)?;
    rpc(r#"{"op":"close","session":"rev1"}"#)?;
    println!("server demo complete");
    // The accept-loop thread holds a coordinator client forever; exit the
    // process rather than joining the worker (which would never drain).
    std::process::exit(0);
}
