#!/usr/bin/env python3
"""Docs link-and-anchor checker (stdlib only — runs in the CI lint lane).

Scans every tracked Markdown file for inline links `[text](target)` and
validates the ones this repo can actually break:

  * relative file links must resolve (relative to the linking file);
  * fragment links (`#anchor`, `file.md#anchor`) must name a heading that
    exists in the target file, using GitHub's slug rules (lowercase,
    spaces to hyphens, punctuation stripped, duplicate slugs suffixed
    -1, -2, ...);
  * absolute URLs (http/https/mailto) are skipped — external liveness is
    not this check's job, and hitting the network in CI is flaky.

Exit status 0 when every link resolves; 1 with one line per broken link
otherwise. Run from anywhere: paths are anchored at the repo root
(this script's grandparent directory).
"""

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# Inline markdown links, skipping images; code spans are stripped first.
LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def md_files():
    skip_parts = {".git", "target", "node_modules"}
    for p in sorted(ROOT.rglob("*.md")):
        if not skip_parts.intersection(p.relative_to(ROOT).parts):
            yield p


def github_slug(heading, seen):
    """GitHub's anchor slug: strip markdown emphasis/code/links, lowercase,
    drop punctuation, hyphenate spaces, dedupe with -N suffixes."""
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", heading)  # [t](u) -> t
    text = re.sub(r"[`*_]", "", text)
    slug = re.sub(r"[^\w\- ]", "", text.lower(), flags=re.UNICODE)
    slug = slug.replace(" ", "-")
    if slug in seen:
        seen[slug] += 1
        return f"{slug}-{seen[slug]}"
    seen[slug] = 0
    return slug


def anchors_of(path, cache={}):
    if path not in cache:
        seen = {}
        anchors = set()
        in_fence = False
        for line in path.read_text(encoding="utf-8").splitlines():
            if CODE_FENCE_RE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = HEADING_RE.match(line)
            if m:
                anchors.add(github_slug(m.group(2), seen))
            # Explicit <a name="..."> / id="..." anchors also count.
            for a in re.findall(r'(?:name|id)="([^"]+)"', line):
                anchors.add(a)
        cache[path] = anchors
    return cache[path]


def links_of(path):
    in_fence = False
    for lineno, line in enumerate(
        path.read_text(encoding="utf-8").splitlines(), start=1
    ):
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        stripped = re.sub(r"`[^`]*`", "``", line)  # links in code spans don't count
        for m in LINK_RE.finditer(stripped):
            yield lineno, m.group(1)


def main():
    errors = []
    for md in md_files():
        for lineno, target in links_of(md):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, https:, mailto:
                continue
            where = f"{md.relative_to(ROOT)}:{lineno}"
            path_part, _, frag = target.partition("#")
            if path_part:
                dest = (md.parent / path_part).resolve()
                if not dest.exists():
                    errors.append(f"{where}: broken link '{target}' (no such file)")
                    continue
            else:
                dest = md
            if frag and dest.suffix == ".md":
                if frag not in anchors_of(dest):
                    errors.append(
                        f"{where}: broken anchor '{target}' "
                        f"(no heading slugs to '#{frag}' in {dest.relative_to(ROOT)})"
                    )
    for e in errors:
        print(e)
    if errors:
        print(f"\n{len(errors)} broken doc link(s)")
        return 1
    print(f"doc links OK across {sum(1 for _ in md_files())} markdown files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
