//! OPT-125M-scale projection — connects the mini-scale measurements to the
//! paper's reported regime.
//!
//! The paper's Table 2 is measured on a 12-layer, d=768 model over
//! 1536–2048-token documents. This host cannot execute that densely, but
//! the incremental cost model is fully determined by (a) analytic
//! per-component FLOP formulas and (b) the *dirty-propagation statistics*
//! the VQ filtering produces. We measure (b) on the mini model — per-layer
//! corrected-row counts, full-recompute rows, code flips, output
//! recomputes per edit — normalize them to rates, and replay them through
//! the analytic formulas at OPT-125M dimensions.
//!
//! Assumption stated plainly: code-flip rates transfer across scale. The
//! paper's own measurements (12.1× atomic) imply HIGHER flip rates at
//! scale than our trained mini model exhibits; we therefore report a
//! sweep over flip-rate multipliers rather than a single point.

use std::sync::Arc;
use vqt::bench::{bench_pairs, gen_pairs, print_table, serving_weights};
use vqt::config::ModelConfig;
use vqt::edits::trace::{sample_atomic, TraceConfig};
use vqt::flops::{self, MULADD, TRANSCENDENTAL};
use vqt::incremental::{EngineOptions, IncrementalEngine};
use vqt::util::Rng;

struct Rates {
    /// Corrections applied per (edit, layer), normalized by document length.
    corrections_per_n: f64,
    /// Full-recompute rows per (edit, layer).
    rows_recomputed: f64,
    /// Output recomputes per (edit, layer), i.e. dirty+flipped rows.
    outputs: f64,
}

fn measure_rates(pairs: &[(Vec<u32>, Vec<u32>)], w: &Arc<vqt::model::ModelWeights>) -> (Rates, f64) {
    let mut rng = Rng::new(5);
    let mut corr = 0f64;
    let mut rows = 0f64;
    let mut outs = 0f64;
    let mut n_sum = 0f64;
    let mut edits = 0f64;
    for (a, b) in pairs {
        let Some(s) = sample_atomic(a, b, None, &mut rng) else { continue };
        if s.base.len() >= w.cfg.max_seq {
            continue;
        }
        let mut eng = IncrementalEngine::new(w.clone(), &s.base, EngineOptions::default());
        eng.stats = Default::default();
        eng.apply_edit(s.edit);
        corr += eng.stats.corrections as f64;
        rows += eng.stats.rows_recomputed as f64;
        outs += eng.stats.outputs_recomputed as f64;
        n_sum += eng.len() as f64;
        edits += 1.0;
    }
    let layers = w.cfg.n_layers as f64;
    (
        Rates {
            corrections_per_n: corr / edits / layers / (n_sum / edits),
            rows_recomputed: rows / edits / layers,
            outputs: outs / edits / layers,
        },
        edits,
    )
}

/// Analytic incremental cost of one atomic edit at config `cfg`, given
/// propagation rates.
fn projected_edit_cost(cfg: &ModelConfig, n: usize, r: &Rates, flip_mult: f64) -> f64 {
    let d = cfg.d_model as f64;
    let nh = cfg.n_heads as f64;
    let hq = (cfg.n_heads * cfg.vq_codes) as f64;
    let layers = cfg.n_layers as f64;
    // Per correction: 2 coeff computations (d muladds + nh σ) + score acc.
    let per_corr = 2.0 * (MULADD as f64 * d + nh * (1 + TRANSCENDENTAL) as f64)
        + MULADD as f64 * hq;
    // Per full row: ctx/2 average visible columns.
    let per_row = (n as f64 / 2.0) * (MULADD as f64 * d + nh * (1 + TRANSCENDENTAL) as f64 + MULADD as f64 * hq);
    // Per output recompute: the per-location bundle.
    let per_out = flops::per_location_cost(cfg) as f64;
    // Re-assignment across touched rows ~ n · 3hq.
    let reassign = n as f64 * 3.0 * hq;
    layers
        * (r.corrections_per_n * n as f64 * per_corr
            + r.rows_recomputed * per_row
            + r.outputs * flip_mult * per_out
            + reassign)
}

fn main() {
    let bench_t0 = std::time::Instant::now();
    let n_pairs = bench_pairs().min(150);
    let tcfg = TraceConfig::mini();
    let pairs = gen_pairs(&tcfg, n_pairs, 9);
    let mini = ModelConfig::vqt_mini();
    let (w, trained) = serving_weights(&mini, "weights_trained_serve.bin");
    let (rates, edits) = measure_rates(&pairs, &w);
    println!(
        "# scale projection — rates measured on vqt_mini over {edits} atomic edits ({})",
        if trained { "trained" } else { "random-init" }
    );
    println!(
        "  corrections/(n·layer) = {:.3}, full rows/layer = {:.2}, outputs/layer = {:.2}",
        rates.corrections_per_n, rates.rows_recomputed, rates.outputs
    );

    // Sanity: projected speedup at MINI scale should be near the measured
    // Table-2 atomic median.
    let mini_n = 448;
    let mini_cost = projected_edit_cost(&mini, mini_n, &rates, 1.0);
    let mini_dense = flops::dense_forward_flops(&mini, mini_n) as f64;
    println!(
        "\nself-check at mini scale (n={mini_n}): projected {:.1}× (measured Table-2 atomic median should be nearby)",
        mini_dense / mini_cost
    );

    let opt = ModelConfig::opt_125m_scale();
    let n = 1792; // middle of the paper's 1536–2048 window
    let dense = flops::dense_forward_flops(&opt, n) as f64;
    let mut rows = Vec::new();
    for flip_mult in [1.0, 2.0, 4.0, 8.0] {
        let cost = projected_edit_cost(&opt, n, &rates, flip_mult);
        rows.push(vec![
            format!("{flip_mult}×"),
            format!("{:.1}×", dense / cost),
        ]);
    }
    print_table(
        "Projected OPT-125M-scale atomic-edit speedup vs code-flip-rate multiplier",
        &["flip-rate vs mini", "projected speedup"],
        &rows,
    );
    println!("\npaper's measured value at this scale: 12.1× (median)");

    // ---- open-loop tail-latency projection ---------------------------
    // Serving tail at OPT-125M scale: convert the projected per-edit FLOP
    // cost into a service time using the arithmetic throughput this host
    // actually achieves on the incremental path (measured, not assumed),
    // then push a Poisson arrival curve through a single-shard queue
    // (Lindley recursion, deterministic service — the per-session shard is
    // serial by design) and read exact p50/p99/p999 off the sample.
    let smoke = std::env::var("VQT_BENCH_SMOKE").is_ok();
    let mut rng = Rng::new(77);
    let doc: Vec<u32> = (0..448).map(|_| rng.below(mini.vocab_size - 1) as u32).collect();
    let mut eng = IncrementalEngine::new(w.clone(), &doc, EngineOptions::default());
    let timed_edits = if smoke { 8 } else { 64 };
    let ledger0 = eng.ledger.total();
    let t = std::time::Instant::now();
    for _ in 0..timed_edits {
        let at = rng.below(eng.len());
        let tok = rng.below(mini.vocab_size - 1) as u32;
        eng.apply_edit(vqt::edits::Edit::Replace { at, tok });
    }
    let wall_per_edit_ns = t.elapsed().as_nanos() as f64 / timed_edits as f64;
    let flops_per_edit = (eng.ledger.total() - ledger0) as f64 / timed_edits as f64;
    let flops_per_ns = flops_per_edit / wall_per_edit_ns;
    let service_ns = projected_edit_cost(&opt, n, &rates, 1.0) / flops_per_ns;
    println!(
        "\nmeasured incremental throughput: {flops_per_ns:.2} flops/ns ⇒ projected OPT-125M service time {:.2}ms/edit",
        service_ns / 1e6
    );

    let arrivals = 50_000usize;
    let mut tail_rows = Vec::new();
    let mut emitted: Option<(f64, f64, f64)> = None;
    for rho in [0.3, 0.6, 0.9] {
        let mean_gap_ns = service_ns / rho;
        let mut wait_ns = 0f64; // Lindley: W_{k+1} = max(0, W_k + S − A_k)
        let mut lat = Vec::with_capacity(arrivals);
        for _ in 0..arrivals {
            lat.push(wait_ns + service_ns);
            let u = (rng.below(1 << 20) + 1) as f64 / (1u64 << 20) as f64;
            let gap_ns = -u.ln() * mean_gap_ns;
            wait_ns = (wait_ns + service_ns - gap_ns).max(0.0);
        }
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| lat[(((p / 100.0) * (lat.len() - 1) as f64).round() as usize).min(lat.len() - 1)];
        let (p50, p99, p999) = (pct(50.0), pct(99.0), pct(99.9));
        tail_rows.push(vec![
            format!("{rho:.1}"),
            format!("{:.2}ms", p50 / 1e6),
            format!("{:.2}ms", p99 / 1e6),
            format!("{:.2}ms", p999 / 1e6),
        ]);
        if rho == 0.6 {
            emitted = Some((p50, p99, p999));
        }
    }
    print_table(
        "Projected OPT-125M open-loop tail latency (Poisson arrivals, one shard)",
        &["utilization ρ", "p50", "p99", "p999"],
        &tail_rows,
    );
    let (p50, p99, p999) = emitted.expect("ρ=0.6 row");

    vqt::bench::emit_json(
        "scale_projection",
        &[
            ("total_wall_ns", bench_t0.elapsed().as_nanos() as f64),
            (
                "projected_speedup_1x_ratio",
                dense / projected_edit_cost(&opt, n, &rates, 1.0),
            ),
            ("projected_tail_p50_wall_ns", p50),
            ("projected_tail_p99_wall_ns", p99),
            ("projected_tail_p999_wall_ns", p999),
        ],
    );
}
