//! **Table 1** — accuracy/F1 on document classification for the four model
//! variants. Training happens in Python (`make train`); this bench
//! re-evaluates every trained checkpoint IN RUST on the exported eval set,
//! cross-checking against the Python-reported numbers AND (for VQ
//! variants) checking that incremental classification after an edit
//! session matches the dense evaluation.
//!
//! Paper reference (IMDB): RoBERTa 95.3/95.0, OPT-125M 94.4/94.5,
//! DistilOPT 92.4/92.3, VQ-OPT h=2 90.3/90.4, VQ-OPT h=4 91.6/91.6.

use std::sync::Arc;
use vqt::bench::print_table;
use vqt::config::ModelConfig;
use vqt::flops::FlopLedger;
use vqt::incremental::{EngineOptions, IncrementalEngine};
use vqt::model::{dense_forward, ModelWeights};
use vqt::util::TensorFile;

fn artifacts() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn spread_positions(len: usize, seq: usize, pool: usize) -> Vec<u32> {
    (0..len)
        .map(|i| (((2 * i + 1) * pool) / (2 * seq)) as u32)
        .collect()
}

fn main() {
    let bench_t0 = std::time::Instant::now();
    let dir = artifacts();
    let eval_path = dir.join("table1_eval.bin");
    if !eval_path.exists() {
        println!("Table 1 requires trained checkpoints: run `make train` first.");
        // Emit anyway (with a skip marker): BENCH_*.json presence proves the
        // bench runs, and CI's bench-smoke job never has trained checkpoints.
        vqt::bench::emit_json(
            "table1_accuracy",
            &[
                ("skipped_ops", 1.0),
                ("total_wall_ns", bench_t0.elapsed().as_nanos() as f64),
            ],
        );
        return;
    }
    let eval = TensorFile::load(&eval_path).expect("eval set");
    let (tdims, tokens) = eval.get("tokens").unwrap().as_i32().unwrap();
    let (_, lengths) = eval.get("lengths").unwrap().as_i32().unwrap();
    let (_, labels) = eval.get("labels").unwrap().as_i32().unwrap();
    let (n_eval, seq) = (tdims[0], tdims[1]);
    println!("# Table 1 — synthetic-sentiment classification ({n_eval} eval docs)");

    let mut rows = Vec::new();
    for (label, variant, file) in [
        ("OPT-mini (softmax)", "opt", "weights_trained_opt.bin"),
        ("DistilOPT-mini", "distil", "weights_trained_distil.bin"),
        ("VQ-OPT-mini (h=2)", "vq_h2", "weights_trained_vq_h2.bin"),
        ("VQ-OPT-mini (h=4)", "vq_h4", "weights_trained_vq_h4.bin"),
    ] {
        let path = dir.join(file);
        if !path.exists() {
            eprintln!("skipping {label}: {file} missing (run `make train`)");
            continue;
        }
        let cfg = ModelConfig::table1(variant).unwrap();
        let w = ModelWeights::load(&path, &cfg).expect("load weights");
        let (mut correct, mut tp, mut fp, mut fnn) = (0usize, 0usize, 0usize, 0usize);
        let mut led = FlopLedger::new();
        for i in 0..n_eval {
            let len = lengths[i] as usize;
            let doc: Vec<u32> = tokens[i * seq..i * seq + len]
                .iter()
                .map(|&t| t as u32)
                .collect();
            let pos = spread_positions(len, seq, cfg.pos_pool);
            let out = dense_forward(&w, &doc, &pos, &mut led);
            let pred = vqt::model::predict(&out) as i32;
            let y = labels[i];
            correct += (pred == y) as usize;
            tp += (pred == 1 && y == 1) as usize;
            fp += (pred == 1 && y == 0) as usize;
            fnn += (pred == 0 && y == 1) as usize;
        }
        let acc = correct as f64 / n_eval as f64;
        let prec = tp as f64 / (tp + fp).max(1) as f64;
        let rec = tp as f64 / (tp + fnn).max(1) as f64;
        let f1 = if prec + rec > 0.0 {
            2.0 * prec * rec / (prec + rec)
        } else {
            0.0
        };
        rows.push(vec![
            label.to_string(),
            format!("{:.1}", acc * 100.0),
            format!("{:.1}", f1 * 100.0),
        ]);
    }
    print_table("Table 1 (reproduced, Rust re-eval)", &["Model", "Accuracy", "F1"], &rows);
    println!("\nPaper: OPT-125M 94.4/94.5, DistilOPT 92.4/92.3, VQ h=2 90.3/90.4, VQ h=4 91.6/91.6");

    // Parity leg: for the h=2 VQ variant, run 32 docs through an edit
    // session (build char by char from a prefix) and check incremental
    // classification equals the dense one.
    let path = dir.join("weights_trained_vq_h2.bin");
    if path.exists() {
        let cfg = ModelConfig::table1("vq_h2").unwrap();
        let w = Arc::new(ModelWeights::load(&path, &cfg).unwrap());
        let mut mismatches = 0;
        for i in 0..32.min(n_eval) {
            let len = lengths[i] as usize;
            let doc: Vec<u32> = tokens[i * seq..i * seq + len]
                .iter()
                .map(|&t| t as u32)
                .collect();
            // Start from the first half, then insert the rest one by one.
            let half = len / 2;
            let mut eng =
                IncrementalEngine::new(w.clone(), &doc[..half], EngineOptions::default());
            for (j, &t) in doc[half..].iter().enumerate() {
                eng.apply_edit(vqt::edits::Edit::Insert {
                    at: half + j,
                    tok: t,
                });
            }
            let rep = eng.verify();
            if rep.code_mismatches != 0 || rep.max_logit_diff > 1e-3 {
                mismatches += 1;
            }
        }
        println!(
            "\nincremental-vs-dense classification parity over 32 edit sessions: {} mismatches",
            mismatches
        );
    }

    vqt::bench::emit_json(
        "table1_accuracy",
        &[("total_wall_ns", bench_t0.elapsed().as_nanos() as f64)],
    );
}
