//! **Table 2** — theoretical speedups for processing edit sequences.
//!
//! Paper protocol: 500 revision pairs scraped from Wikipedia (we use the
//! synthetic trace generator, docs/ARCHITECTURE.md), three measurements:
//!   Atomic          — one sampled atomic edit per pair (online),
//!   Entire Revision — the whole diff applied at once (offline),
//!   First 5 %       — atomic edits restricted to the first 5 % of tokens.
//! Rows: OPT (1×, by definition), DistilOPT (from-scratch with half the
//! layers — FLOP-formula ratio), VQ-OPT h=2 and h=4 (measured on the
//! incremental engine with trained weights when available).
//!
//! Paper reference (OPT-125M scale): Distil 2×; VQ h=2: 12.1× / 4.7× /
//! 4.8×; VQ h=4: 5.2× / 2.5× / 2.2×.

use vqt::bench::*;
use vqt::config::ModelConfig;
use vqt::edits::trace::TraceConfig;
use vqt::incremental::EngineOptions;
use vqt::util::Rng;

fn main() {
    let bench_t0 = std::time::Instant::now();
    let n_pairs = bench_pairs();
    let tcfg = TraceConfig::mini();
    let pairs = gen_pairs(&tcfg, n_pairs, 20260710);
    println!(
        "# Table 2 — theoretical speedups ({n_pairs} synthetic revision pairs, {}–{} tokens)",
        tcfg.min_len, tcfg.max_len
    );

    let opt_cfg = {
        // OPT-mini analog at serving scale: same dims, softmax, no VQ.
        let mut c = ModelConfig::vqt_mini();
        c.attention = vqt::config::AttentionKind::Softmax;
        c.vq_heads = 0;
        c
    };
    let distil_cfg = {
        let mut c = opt_cfg.clone();
        c.n_layers /= 2;
        c
    };
    let mid_len = (tcfg.min_len + tcfg.max_len) / 2;
    let distil_x = baseline_speedup(&opt_cfg, &distil_cfg, mid_len);

    let mut rows: Vec<Vec<String>> = vec![
        vec!["OPT-mini".into(), "1.0×".into(), "1.0×".into(), "1.0×".into()],
        vec![
            "DistilOPT-mini".into(),
            format!("{distil_x:.1}×"),
            format!("{distil_x:.1}×"),
            format!("{distil_x:.1}×"),
        ],
    ];

    for (label, cfg, weights_file) in [
        (
            "VQ-OPT-mini (h=2)",
            ModelConfig::vqt_mini(),
            "weights_trained_serve.bin",
        ),
        (
            "VQ-OPT-mini (h=4)",
            ModelConfig::vqt_mini_h4(),
            "weights_trained_serve_h4.bin",
        ),
    ] {
        let (w, trained) = serving_weights(&cfg, weights_file);
        let opts = EngineOptions::default();
        let mut rng = Rng::new(99);

        let atomic: Vec<Measured> = pairs
            .iter()
            .filter_map(|(a, b)| measure_atomic(&w, opts, a, b, None, &mut rng))
            .collect();
        let offline: Vec<Measured> = pairs
            .iter()
            .map(|(a, b)| measure_offline_pair(&w, opts, a, b))
            .collect();
        let first5: Vec<Measured> = pairs
            .iter()
            .filter_map(|(a, b)| measure_atomic(&w, opts, a, b, Some((0.0, 0.05)), &mut rng))
            .collect();

        eprintln!(
            "[{label}] {} atomic, {} offline, {} first-5% samples ({})",
            atomic.len(),
            offline.len(),
            first5.len(),
            if trained { "trained weights" } else { "random-init weights" }
        );
        rows.push(vec![
            format!("{label}{}", if trained { "" } else { " (rand)" }),
            format!("{:.1}×", median_speedup(&atomic)),
            format!("{:.1}×", median_speedup(&offline)),
            format!("{:.1}×", median_speedup(&first5)),
        ]);
    }

    print_table(
        "Table 2 (reproduced)",
        &["Model", "Atomic", "Entire Revision", "First 5%"],
        &rows,
    );
    println!(
        "\nPaper (OPT-125M scale): Distil 2×; VQ h=2 12.1×/4.7×/4.8×; VQ h=4 5.2×/2.5×/2.2×.\n\
         Expected to hold in *shape* (VQ ≫ Distil on atomic; offline < atomic;\n\
         h=2 > h=4): absolute factors scale with depth/width (see docs/ARCHITECTURE.md §3)."
    );

    vqt::bench::emit_json(
        "table2_speedups",
        &[("total_wall_ns", bench_t0.elapsed().as_nanos() as f64)],
    );
}
