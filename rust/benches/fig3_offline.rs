//! **Figure 3** — relative reduction in arithmetic operations for OFFLINE
//! processing of two complete revisions, vs the fraction of modified
//! tokens. The paper's claim: speedup is inversely proportional to the
//! fraction modified; median 4.7× at OPT-125M scale.
//!
//! Emits the scatter series as CSV (`fig3_offline.csv`) plus summary
//! statistics and a correlation check.

use vqt::bench::*;
use vqt::config::ModelConfig;
use vqt::edits::trace::TraceConfig;
use vqt::incremental::EngineOptions;

fn main() {
    let bench_t0 = std::time::Instant::now();
    let n_pairs = bench_pairs();
    let tcfg = TraceConfig::mini();
    let pairs = gen_pairs(&tcfg, n_pairs, 3);
    let cfg = ModelConfig::vqt_mini();
    let (w, trained) = serving_weights(&cfg, "weights_trained_serve.bin");
    println!(
        "# Fig 3 — offline speedup vs fraction modified ({n_pairs} pairs, {})",
        if trained { "trained weights" } else { "random-init weights" }
    );

    let opts = EngineOptions::default();
    let mut series: Vec<(f64, f64)> = Vec::new();
    for (i, (a, b)) in pairs.iter().enumerate() {
        let m = measure_offline_pair(&w, opts, a, b);
        series.push((m.x, m.speedup()));
        if (i + 1) % 25 == 0 {
            eprintln!("  {}/{n_pairs}", i + 1);
        }
    }
    write_csv(
        "fig3_offline.csv",
        "fraction_modified,speedup",
        &series,
    );

    let speedups: Vec<f64> = series.iter().map(|p| p.1).collect();
    let med = vqt::util::median(&speedups);
    println!("median speedup: {med:.1}×   (paper: 4.7× at OPT-125M scale)");

    // The paper's claim: speedup ∝ 1/fraction. Verify the rank correlation
    // between log(1/x) and log(speedup) is strongly positive.
    let logx: Vec<f64> = series.iter().map(|p| -(p.0.max(1e-4)).ln()).collect();
    let logy: Vec<f64> = series.iter().map(|p| p.1.max(1e-9).ln()).collect();
    let corr = pearson(&logx, &logy);
    println!("log-log correlation(1/fraction, speedup) = {corr:.3} (expect ≫ 0)");

    // Bucketed summary so the trend is visible without plotting.
    let mut rows = Vec::new();
    for (lo, hi) in [(0.0, 0.01), (0.01, 0.03), (0.03, 0.1), (0.1, 0.3), (0.3, 1.0)] {
        let bucket: Vec<f64> = series
            .iter()
            .filter(|p| p.0 >= lo && p.0 < hi)
            .map(|p| p.1)
            .collect();
        if !bucket.is_empty() {
            rows.push(vec![
                format!("{lo:.2}–{hi:.2}"),
                format!("{}", bucket.len()),
                format!("{:.1}×", vqt::util::median(&bucket)),
            ]);
        }
    }
    print_table(
        "Fig 3 (bucketed): speedup by fraction modified",
        &["fraction", "pairs", "median speedup"],
        &rows,
    );
    vqt::bench::emit_json(
        "fig3_offline",
        &[
            ("total_wall_ns", bench_t0.elapsed().as_nanos() as f64),
            ("median_speedup_ratio", vqt::util::median(&speedups)),
        ],
    );
}

fn pearson(x: &[f64], y: &[f64]) -> f64 {
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let cov: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let vx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    let vy: f64 = y.iter().map(|b| (b - my) * (b - my)).sum();
    cov / (vx.sqrt() * vy.sqrt()).max(1e-12)
}
