//! Micro-benchmarks over the serving hot paths (wallclock — the §Perf
//! layer-3 profile targets). Reports the tiled kernels against the exact
//! pre-tiling kernels they replaced (the regression guard for
//! `tensor/ops.rs`), per-edit latency by document length and edit
//! position, engine rebuild cost, the AOT dense path, and sustained
//! online throughput.
//!
//! Set `VQT_BENCH_SMOKE=1` for a one-iteration smoke run (CI): every
//! section executes, nothing is timed long enough to matter.

use std::sync::Arc;
use vqt::bench::{emit_json, print_table, serving_weights, time_it};
use vqt::config::ModelConfig;
use vqt::edits::Edit;
use vqt::incremental::{
    apply_scripts_batched, CacheHandle, CodeCache, EngineOptions, IncrementalEngine,
};
use vqt::runtime::ArtifactRuntime;
use vqt::tensor::{self, Matrix};
use vqt::util::Rng;

/// The exact pre-tiling `matmul_into` (i-k-j, unit stride, zero-row
/// skip) — the honest baseline the tiled kernel must beat, NOT a
/// cache-hostile strawman.
fn baseline_matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    c.data.iter_mut().for_each(|x| *x = 0.0);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut c.data[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// The exact pre-tiling `vec_matmul_into` (two-row unroll) for the GEMV
/// hot path — same honesty argument as above.
fn baseline_vec_matmul_into(x: &[f32], w: &Matrix, y: &mut [f32]) {
    y.iter_mut().for_each(|v| *v = 0.0);
    let cols = w.cols;
    let pairs = x.len() / 2;
    for pp in 0..pairs {
        let p = pp * 2;
        let (x0, x1) = (x[p], x[p + 1]);
        let w0 = &w.data[p * cols..(p + 1) * cols];
        let w1 = &w.data[(p + 1) * cols..(p + 2) * cols];
        for ((yv, &a), &b) in y.iter_mut().zip(w0).zip(w1) {
            *yv += x0 * a + x1 * b;
        }
    }
    if x.len() % 2 == 1 {
        let p = x.len() - 1;
        let xv = x[p];
        let wrow = &w.data[p * cols..(p + 1) * cols];
        for (yv, &wv) in y.iter_mut().zip(wrow) {
            *yv += xv * wv;
        }
    }
}

fn main() {
    let smoke = std::env::var("VQT_BENCH_SMOKE").is_ok();
    let cfg = ModelConfig::vqt_mini();
    let (w, trained) = serving_weights(&cfg, "weights_trained_serve.bin");
    println!(
        "# micro_hotpath ({}{}) — vqt_mini d={} L={} seq≤{}",
        if trained { "trained" } else { "random-init" },
        if smoke { ", smoke" } else { "" },
        cfg.d_model,
        cfg.n_layers,
        cfg.max_seq
    );
    let mut rng = Rng::new(1);

    // --- tiled kernels vs the pre-tiling kernels ------------------------
    // Regression guard: the tiled implementations must not lose to the
    // kernels they replaced at any shape here.
    let (kw, ki) = if smoke { (0, 1) } else { (1, 5) };
    let mut rows = Vec::new();
    for &(m, k, n) in &[
        (8usize, 128usize, 128usize),
        (64, 128, 512),
        (16, 768, 768),
        (64, 768, 768),
    ] {
        let a = Matrix::from_fn(m, k, |_, _| rng.normal());
        let b = Matrix::from_fn(k, n, |_, _| rng.normal());
        let mut c = Matrix::zeros(m, n);
        let tn = time_it(kw, ki, || baseline_matmul_into(&a, &b, &mut c));
        std::hint::black_box(c.data[0]);
        let tt = time_it(kw, ki, || tensor::matmul_into(&a, &b, &mut c));
        std::hint::black_box(c.data[0]);
        rows.push(vec![
            format!("matmul {m}x{k}x{n}"),
            format!("{:.3}", tn.p50.as_secs_f64() * 1e3),
            format!("{:.3}", tt.p50.as_secs_f64() * 1e3),
            format!("{:.2}x", tn.p50.as_secs_f64() / tt.p50.as_secs_f64().max(1e-9)),
        ]);
    }
    for &(k, n) in &[(128usize, 512usize), (768, 768), (768, 3072)] {
        let wmat = Matrix::from_fn(k, n, |_, _| rng.normal());
        let x: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; n];
        let tn = time_it(kw, ki, || baseline_vec_matmul_into(&x, &wmat, &mut y));
        std::hint::black_box(y[0]);
        let tt = time_it(kw, ki, || tensor::vec_matmul_into(&x, &wmat, &mut y));
        std::hint::black_box(y[0]);
        rows.push(vec![
            format!("vec_matmul {k}x{n}"),
            format!("{:.3}", tn.p50.as_secs_f64() * 1e3),
            format!("{:.3}", tt.p50.as_secs_f64() * 1e3),
            format!("{:.2}x", tn.p50.as_secs_f64() / tt.p50.as_secs_f64().max(1e-9)),
        ]);
    }
    print_table(
        "tiled kernels vs pre-tiling kernels (speedup must be ≥1.0)",
        &["shape", "baseline p50 (ms)", "tiled p50 (ms)", "speedup"],
        &rows,
    );

    // --- scalar vs explicit-SIMD backend ---------------------------------
    // The PR-7 lever: the same tiled core with dispatch pinned per phase
    // (the `_with` entry points ignore the global selector and any
    // VQT_KERNEL_BACKEND override, so both columns measure what they
    // claim). The backends are bit-identical by contract — this table is
    // pure wall-clock. On a CPU without AVX2/NEON the "simd" column runs
    // the scalar fallback and the ratio honestly prints ~1.0×.
    let simd_backend = {
        let auto = tensor::active_backend();
        if auto == tensor::ResolvedBackend::Scalar {
            println!("(no AVX2/NEON detected — SIMD column falls back to scalar)");
        }
        auto
    };
    let mut rows = Vec::new();
    let mut simd_speedup = 1.0f64;
    let mut simd_gemm_speedup = 1.0f64;
    for &(k, n) in &[(128usize, 512usize), (768, 768), (768, 3072)] {
        let wmat = Matrix::from_fn(k, n, |_, _| rng.normal());
        let x: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; n];
        let ts = time_it(kw, ki, || {
            tensor::vec_matmul_into_with(tensor::ResolvedBackend::Scalar, &x, &wmat, &mut y)
        });
        std::hint::black_box(y[0]);
        let tv = time_it(kw, ki, || {
            tensor::vec_matmul_into_with(simd_backend, &x, &wmat, &mut y)
        });
        std::hint::black_box(y[0]);
        let ratio = ts.p50.as_secs_f64() / tv.p50.as_secs_f64().max(1e-9);
        if (k, n) == (768, 3072) {
            simd_speedup = ratio;
        }
        rows.push(vec![
            format!("vec_matmul {k}x{n}"),
            format!("{:.3}", ts.p50.as_secs_f64() * 1e3),
            format!("{:.3}", tv.p50.as_secs_f64() * 1e3),
            format!("{:.2}x", ratio),
        ]);
    }
    for &(m, k, n) in &[(16usize, 768usize, 768usize), (64, 768, 768)] {
        let a = Matrix::from_fn(m, k, |_, _| rng.normal());
        let b = Matrix::from_fn(k, n, |_, _| rng.normal());
        let mut c = Matrix::zeros(m, n);
        let ts = time_it(kw, ki, || {
            tensor::matmul_into_with(tensor::ResolvedBackend::Scalar, &a, &b, &mut c)
        });
        std::hint::black_box(c.data[0]);
        let tv = time_it(kw, ki, || tensor::matmul_into_with(simd_backend, &a, &b, &mut c));
        std::hint::black_box(c.data[0]);
        let ratio = ts.p50.as_secs_f64() / tv.p50.as_secs_f64().max(1e-9);
        if (m, k, n) == (64, 768, 768) {
            simd_gemm_speedup = ratio;
        }
        rows.push(vec![
            format!("matmul {m}x{k}x{n}"),
            format!("{:.3}", ts.p50.as_secs_f64() * 1e3),
            format!("{:.3}", tv.p50.as_secs_f64() * 1e3),
            format!("{:.2}x", ratio),
        ]);
    }
    print_table(
        &format!(
            "scalar vs SIMD backend (simd resolves to: {})",
            simd_backend.name()
        ),
        &["shape", "scalar p50 (ms)", "simd p50 (ms)", "speedup"],
        &rows,
    );

    // --- per-edit latency by length × position --------------------------
    let (ew, ei) = if smoke { (0, 1) } else { (2, 12) };
    let mut rows = Vec::new();
    for &n in &[64usize, 128, 256, 512] {
        let tokens: Vec<u32> = (0..n).map(|_| rng.below(256) as u32).collect();
        for (pos_label, frac) in [("early(10%)", 0.1), ("mid(50%)", 0.5), ("late(90%)", 0.9)] {
            let at = ((n as f64 * frac) as usize).min(n - 1);
            let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
            let mut tok = 0u32;
            let mut flops = 0u64;
            let t = time_it(ew, ei, || {
                tok = (tok + 1) % 255;
                flops = eng.apply_edit(Edit::Replace { at, tok }).flops;
            });
            rows.push(vec![
                format!("replace n={n} {pos_label}"),
                format!("{:.2}", t.p50.as_secs_f64() * 1e3),
                format!("{:.2}", t.mean.as_secs_f64() * 1e3),
                format!("{:.1}M", flops as f64 / 1e6),
            ]);
        }
    }
    // Insert/delete cycle at mid-document.
    {
        let n = 256;
        let tokens: Vec<u32> = (0..n).map(|_| rng.below(256) as u32).collect();
        let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let t = time_it(ew, ei, || {
            eng.apply_edit(Edit::Insert { at: 128, tok: 7 });
            eng.apply_edit(Edit::Delete { at: 128 });
        });
        rows.push(vec![
            "insert+delete n=256 mid".into(),
            format!("{:.2}", t.p50.as_secs_f64() * 1e3),
            format!("{:.2}", t.mean.as_secs_f64() * 1e3),
            "-".into(),
        ]);
    }
    // Full rebuild (defrag worst case).
    for &n in &[128usize, 512] {
        let tokens: Vec<u32> = (0..n).map(|_| rng.below(256) as u32).collect();
        let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let t = time_it(if smoke { 0 } else { 1 }, if smoke { 1 } else { 5 }, || {
            eng.rebuild()
        });
        rows.push(vec![
            format!("full rebuild n={n}"),
            format!("{:.2}", t.p50.as_secs_f64() * 1e3),
            format!("{:.2}", t.mean.as_secs_f64() * 1e3),
            "-".into(),
        ]);
    }
    print_table(
        "L3 engine latencies",
        &["op", "p50 (ms)", "mean (ms)", "flops"],
        &rows,
    );

    // --- AOT dense path (L2 through PJRT) --------------------------------
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let runtime = if dir.join("manifest.json").exists() {
        match ArtifactRuntime::open(&dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                println!("(artifact runtime unavailable — {e:#})");
                None
            }
        }
    } else {
        println!("(no artifacts/ — run `make artifacts` for the L2 rows)");
        None
    };
    if let Some(rt) = runtime {
        rt.warmup().expect("warmup");
        let mut rows = Vec::new();
        for &n in &[32usize, 128, 512] {
            let tokens: Vec<u32> = (0..n).map(|_| rng.below(256) as u32).collect();
            let pool = rt.manifest.config.pos_pool;
            let pos: Vec<u32> = (0..n).map(|i| (((2 * i + 1) * pool) / (2 * n)) as u32).collect();
            let t = time_it(ew, ei.min(10), || {
                rt.dense_logits(&tokens, &pos).expect("dense");
            });
            rows.push(vec![
                format!("AOT dense fwd n={n}"),
                format!("{:.2}", t.p50.as_secs_f64() * 1e3),
                format!("{:.2}", t.mean.as_secs_f64() * 1e3),
            ]);
        }
        print_table("L2 AOT path (PJRT CPU)", &["op", "p50 (ms)", "mean (ms)"], &rows);
    }

    // --- cross-session batched vs per-session execution -------------------
    // The PR-5 serving lever: S sessions each apply one mid-document
    // replace; unbatched walks the layer weights once per session, the
    // batched path pools every session's block-tail rows into stacked
    // GEMMs and streams each weight matrix once per wave. Bit-exact by
    // construction (differential_batch.rs); this table shows the
    // amortization is also a wall-clock win that grows with S.
    let (bw, bi) = if smoke { (0, 1) } else { (1, 8) };
    let mut rows = Vec::new();
    let mut amortized_ratio_s8 = 1.0f64;
    let base_doc: Vec<u32> = (0..256).map(|_| rng.below(256) as u32).collect();
    for &s in &[2usize, 4, 8, 16] {
        let mk = |count: usize| -> Vec<IncrementalEngine> {
            (0..count)
                .map(|i| {
                    let mut d = base_doc.clone();
                    d[i % d.len()] = (i % 251) as u32; // distinct docs
                    IncrementalEngine::new(w.clone(), &d, EngineOptions::default())
                })
                .collect()
        };
        let mut unb = mk(s);
        let mut tok = 1u32;
        let tu = time_it(bw, bi, || {
            tok = (tok + 1) % 255;
            for e in unb.iter_mut() {
                e.apply_edit(Edit::Replace { at: 128, tok });
            }
        });
        let mut bat = mk(s);
        let mut tok2 = 1u32;
        let tb = time_it(bw, bi, || {
            tok2 = (tok2 + 1) % 255;
            let scripts: Vec<[Edit; 1]> =
                (0..s).map(|_| [Edit::Replace { at: 128, tok: tok2 }]).collect();
            let script_refs: Vec<&[Edit]> = scripts.iter().map(|a| a.as_slice()).collect();
            let mut refs: Vec<&mut IncrementalEngine> = bat.iter_mut().collect();
            apply_scripts_batched(&mut refs, &script_refs, 1024);
        });
        let ratio = tu.p50.as_secs_f64() / tb.p50.as_secs_f64().max(1e-9);
        if s == 8 {
            amortized_ratio_s8 = ratio;
        }
        rows.push(vec![
            format!("replace ×{s} sessions (n=256)"),
            format!("{:.2}", tu.p50.as_secs_f64() * 1e3),
            format!("{:.2}", tb.p50.as_secs_f64() * 1e3),
            format!("{:.2}x", ratio),
            format!("{:.3}", tb.p50.as_secs_f64() * 1e3 / s as f64),
        ]);
    }
    print_table(
        "cross-session batched block tails vs per-session execution",
        &[
            "workload",
            "unbatched p50 (ms)",
            "batched p50 (ms)",
            "speedup",
            "batched ms/session",
        ],
        &rows,
    );

    // --- codebook-product cache: miss, warm hit, wave dedup ----------------
    // The PR-6 lever: block tails keyed by (layer, code tuple) skip the
    // decode→mix GEMV on a hit. Three regimes, each against an uncached
    // peer running the SAME edit pattern (edit cost varies with the token
    // stream, so every comparison keeps its own honest baseline):
    //   warm  — an A→B→A token toggle; every tail after warmup hits;
    //   cold  — a fresh token every edit; every tail misses AND pays the
    //           insert, bounding the overhead the cache can ever add;
    //   wave  — 8 identical sessions per pooled wave; dedup collapses the
    //           wave's repeated code to ONE product before the stacked GEMM.
    let (cw, ci) = if smoke { (0, 1) } else { (2, 12) };
    let cache_doc: Vec<u32> = (0..256).map(|_| rng.below(256) as u32).collect();
    let mk_cache = || CacheHandle::new(Arc::new(CodeCache::new(64 << 20)), &w);
    let mk_eng = |cache: Option<CacheHandle>| {
        let mut e = IncrementalEngine::new(w.clone(), &cache_doc, EngineOptions::default());
        e.set_code_cache(cache);
        e
    };
    let mut rows = Vec::new();
    // Warm regime (vs uncached toggle).
    let mut plain_t = mk_eng(None);
    let mut i1 = 0u32;
    let tpt = time_it(cw, ci, || {
        i1 += 1;
        plain_t.apply_edit(Edit::Replace { at: 128, tok: 1 + (i1 & 1) });
    });
    let mut warm = mk_eng(Some(mk_cache()));
    let mut i2 = 0u32;
    let twm = time_it(cw, ci, || {
        i2 += 1;
        warm.apply_edit(Edit::Replace { at: 128, tok: 1 + (i2 & 1) });
    });
    let warm_ratio = tpt.p50.as_secs_f64() / twm.p50.as_secs_f64().max(1e-9);
    rows.push(vec![
        "warm (A↔B toggle, all hits)".into(),
        format!("{:.3}", tpt.p50.as_secs_f64() * 1e3),
        format!("{:.3}", twm.p50.as_secs_f64() * 1e3),
        format!("{:.2}x", warm_ratio),
    ]);
    // Cold regime (vs uncached cycle).
    let mut plain_c = mk_eng(None);
    let mut i3 = 0u32;
    let tpc = time_it(cw, ci, || {
        i3 = (i3 + 1) % 251;
        plain_c.apply_edit(Edit::Replace { at: 128, tok: i3 });
    });
    let mut cold = mk_eng(Some(mk_cache()));
    let mut i4 = 0u32;
    let tcd = time_it(cw, ci, || {
        i4 = (i4 + 1) % 251;
        cold.apply_edit(Edit::Replace { at: 128, tok: i4 });
    });
    let cold_ratio = tpc.p50.as_secs_f64() / tcd.p50.as_secs_f64().max(1e-9);
    rows.push(vec![
        "cold (fresh token, all misses)".into(),
        format!("{:.3}", tpc.p50.as_secs_f64() * 1e3),
        format!("{:.3}", tcd.p50.as_secs_f64() * 1e3),
        format!("{:.2}x", cold_ratio),
    ]);
    // Wave-dedup regime: 8 sessions pooled, identical edits per wave.
    let s = 8usize;
    let mk_wave = |cache: Option<CacheHandle>| -> Vec<IncrementalEngine> {
        (0..s).map(|_| mk_eng(cache.clone())).collect()
    };
    let mut unc_wave = mk_wave(None);
    let mut k1 = 0u32;
    let tbu = time_it(cw, ci, || {
        k1 = (k1 + 1) % 251;
        let script = [Edit::Replace { at: 128, tok: k1 }];
        let refs: Vec<&[Edit]> = (0..s).map(|_| script.as_slice()).collect();
        let mut er: Vec<&mut IncrementalEngine> = unc_wave.iter_mut().collect();
        apply_scripts_batched(&mut er, &refs, 1024);
    });
    let mut ded_wave = mk_wave(Some(mk_cache()));
    let mut k2 = 0u32;
    let tbd = time_it(cw, ci, || {
        k2 = (k2 + 1) % 251;
        let script = [Edit::Replace { at: 128, tok: k2 }];
        let refs: Vec<&[Edit]> = (0..s).map(|_| script.as_slice()).collect();
        let mut er: Vec<&mut IncrementalEngine> = ded_wave.iter_mut().collect();
        apply_scripts_batched(&mut er, &refs, 1024);
    });
    let dedup_ratio = tbu.p50.as_secs_f64() / tbd.p50.as_secs_f64().max(1e-9);
    rows.push(vec![
        format!("wave ×{s} (same token, deduped)"),
        format!("{:.3}", tbu.p50.as_secs_f64() * 1e3),
        format!("{:.3}", tbd.p50.as_secs_f64() * 1e3),
        format!("{:.2}x", dedup_ratio),
    ]);
    print_table(
        "codebook-product cache: block-tail edits, cached vs uncached (n=256)",
        &["regime", "uncached p50 (ms)", "cached p50 (ms)", "speedup"],
        &rows,
    );
    println!(
        "(warm engine: {} hits / {} misses; wave cache deduped {} hits)",
        warm.stats.cache_hits,
        warm.stats.cache_misses,
        ded_wave.iter().map(|e| e.stats.cache_hits).sum::<u64>(),
    );

    // --- sustained online throughput --------------------------------------
    let n = 384;
    let tokens: Vec<u32> = (0..n).map(|_| rng.below(256) as u32).collect();
    let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
    let edits = if smoke { 20 } else { 300 };
    let t0 = std::time::Instant::now();
    for i in 0..edits {
        let at = rng.below(eng.len());
        match i % 3 {
            0 => {
                eng.apply_edit(Edit::Replace {
                    at,
                    tok: rng.below(256) as u32,
                });
            }
            1 if eng.len() < cfg.max_seq => {
                eng.apply_edit(Edit::Insert {
                    at,
                    tok: rng.below(256) as u32,
                });
            }
            _ if eng.len() > 64 => {
                eng.apply_edit(Edit::Delete { at });
            }
            _ => {}
        }
    }
    let dt = t0.elapsed();
    println!(
        "\nsustained online editing: {edits} mixed edits on n≈{n} in {:.2}s → {:.0} edits/s \
         ({} defrags, speedup ledger {:.1}×)",
        dt.as_secs_f64(),
        edits as f64 / dt.as_secs_f64(),
        eng.stats.defrags,
        vqt::flops::dense_forward_flops(&cfg, n) as f64 * edits as f64
            / eng.ledger.total() as f64
    );

    // --- tracing overhead: the disabled guard on the edit hot path ---------
    // The §11 observability contract: with tracing off, a stage guard is
    // one thread-local load — and CI gates the derived per-edit overhead
    // at ≤2%. Three measurements: (a) the raw disabled-guard cost in a
    // tight loop, (b) guard activations per edit (counted by an actual
    // traced edit — the same guards that fire inert when tracing is off),
    // (c) edit p50 with tracing off vs begin/finish around every edit.
    use vqt::util::trace;
    trace::ensure_off();
    let guard_iters: u32 = if smoke { 10_000 } else { 2_000_000 };
    let tg0 = std::time::Instant::now();
    for _ in 0..guard_iters {
        std::hint::black_box(trace::stage("bench_guard"));
    }
    let guard_ns = tg0.elapsed().as_nanos() as f64 / guard_iters as f64;

    let trace_doc: Vec<u32> = (0..256).map(|_| rng.below(256) as u32).collect();
    let mut probe = IncrementalEngine::new(w.clone(), &trace_doc, EngineOptions::default());
    trace::begin(std::time::Instant::now());
    probe.apply_edit(Edit::Replace { at: 128, tok: 1 });
    let guards_per_edit = trace::finish()
        .map(|r| r.stages.iter().map(|s| s.count).sum::<u64>())
        .unwrap_or(1)
        .max(1) as f64;

    let (tw, ti) = if smoke { (0, 1) } else { (2, 12) };
    let mut eng_off = IncrementalEngine::new(w.clone(), &trace_doc, EngineOptions::default());
    let mut tk = 0u32;
    let t_off = time_it(tw, ti, || {
        tk = (tk + 1) % 251;
        eng_off.apply_edit(Edit::Replace { at: 128, tok: tk });
    });
    let mut eng_on = IncrementalEngine::new(w.clone(), &trace_doc, EngineOptions::default());
    let mut tk2 = 0u32;
    let t_on = time_it(tw, ti, || {
        tk2 = (tk2 + 1) % 251;
        trace::begin(std::time::Instant::now());
        eng_on.apply_edit(Edit::Replace { at: 128, tok: tk2 });
        std::hint::black_box(trace::finish());
    });
    let edit_off_ns = t_off.p50.as_secs_f64() * 1e9;
    let trace_off_overhead_ratio = guard_ns * guards_per_edit / edit_off_ns.max(1.0);
    let trace_on_overhead_ratio =
        t_on.p50.as_secs_f64() / t_off.p50.as_secs_f64().max(1e-12) - 1.0;
    print_table(
        "tracing overhead on the edit hot path (n=256 replace)",
        &["measurement", "value"],
        &[
            vec!["disabled guard (ns)".into(), format!("{guard_ns:.2}")],
            vec!["guard activations / edit".into(), format!("{guards_per_edit:.0}")],
            vec!["edit p50, tracing off (ms)".into(), format!("{:.3}", edit_off_ns / 1e6)],
            vec![
                "edit p50, traced (ms)".into(),
                format!("{:.3}", t_on.p50.as_secs_f64() * 1e3),
            ],
            vec![
                "derived off-overhead".into(),
                format!("{:.4}% (gate: ≤2%)", trace_off_overhead_ratio * 100.0),
            ],
            vec![
                "measured on-overhead".into(),
                format!("{:.2}%", trace_on_overhead_ratio * 100.0),
            ],
        ],
    );

    emit_json(
        "micro_hotpath",
        &[
            (
                "sustained_edit_wall_ns",
                dt.as_nanos() as f64 / edits as f64,
            ),
            ("sustained_edits_per_s_ops", edits as f64 / dt.as_secs_f64()),
            (
                "ledger_speedup_ratio",
                vqt::flops::dense_forward_flops(&cfg, n) as f64 * edits as f64
                    / eng.ledger.total() as f64,
            ),
            ("batched_x8_speedup_ratio", amortized_ratio_s8),
            ("engine_flops", eng.ledger.total() as f64),
            (
                "cache_warm_edit_p50_ns",
                twm.p50.as_secs_f64() * 1e9,
            ),
            (
                "cache_uncached_edit_p50_ns",
                tpt.p50.as_secs_f64() * 1e9,
            ),
            ("cache_warm_speedup_ratio", warm_ratio),
            ("cache_cold_speedup_ratio", cold_ratio),
            ("cache_wave_dedup_speedup_ratio", dedup_ratio),
            // Scalar-vs-SIMD on the widest GEMV (768×3072, the FFN row)
            // and the largest stacked GEMM — ~1.0 on CPUs without
            // AVX2/NEON, where "simd" resolves to the scalar fallback.
            ("simd_speedup_ratio", simd_speedup),
            ("simd_gemm_speedup_ratio", simd_gemm_speedup),
            // Observability cost contract (§11): the disabled-guard cost
            // per edit as a fraction of the edit itself — CI fails >2%.
            ("trace_off_guard_wall_ns", guard_ns),
            ("trace_off_overhead_ratio", trace_off_overhead_ratio),
            ("trace_on_overhead_ratio", trace_on_overhead_ratio),
        ],
    );

    let _ = Arc::strong_count(&w);
}
