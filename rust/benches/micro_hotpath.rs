//! Micro-benchmarks over the serving hot paths (wallclock — the §Perf
//! layer-3 profile targets). Reports per-edit latency by document length
//! and edit position, engine rebuild cost, the AOT dense path, and
//! sustained online throughput.

use std::sync::Arc;
use vqt::bench::{print_table, serving_weights, time_it};
use vqt::config::ModelConfig;
use vqt::edits::Edit;
use vqt::incremental::{EngineOptions, IncrementalEngine};
use vqt::runtime::ArtifactRuntime;
use vqt::util::Rng;

fn main() {
    let cfg = ModelConfig::vqt_mini();
    let (w, trained) = serving_weights(&cfg, "weights_trained_serve.bin");
    println!(
        "# micro_hotpath ({}) — vqt_mini d={} L={} seq≤{}",
        if trained { "trained" } else { "random-init" },
        cfg.d_model,
        cfg.n_layers,
        cfg.max_seq
    );
    let mut rng = Rng::new(1);

    // --- per-edit latency by length × position --------------------------
    let mut rows = Vec::new();
    for &n in &[64usize, 128, 256, 512] {
        let tokens: Vec<u32> = (0..n).map(|_| rng.below(256) as u32).collect();
        for (pos_label, frac) in [("early(10%)", 0.1), ("mid(50%)", 0.5), ("late(90%)", 0.9)] {
            let at = ((n as f64 * frac) as usize).min(n - 1);
            let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
            let mut tok = 0u32;
            let mut flops = 0u64;
            let t = time_it(2, 12, || {
                tok = (tok + 1) % 255;
                flops = eng.apply_edit(Edit::Replace { at, tok }).flops;
            });
            rows.push(vec![
                format!("replace n={n} {pos_label}"),
                format!("{:.2}", t.p50.as_secs_f64() * 1e3),
                format!("{:.2}", t.mean.as_secs_f64() * 1e3),
                format!("{:.1}M", flops as f64 / 1e6),
            ]);
        }
    }
    // Insert/delete cycle at mid-document.
    {
        let n = 256;
        let tokens: Vec<u32> = (0..n).map(|_| rng.below(256) as u32).collect();
        let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let t = time_it(2, 12, || {
            eng.apply_edit(Edit::Insert { at: 128, tok: 7 });
            eng.apply_edit(Edit::Delete { at: 128 });
        });
        rows.push(vec![
            "insert+delete n=256 mid".into(),
            format!("{:.2}", t.p50.as_secs_f64() * 1e3),
            format!("{:.2}", t.mean.as_secs_f64() * 1e3),
            "-".into(),
        ]);
    }
    // Full rebuild (defrag worst case).
    for &n in &[128usize, 512] {
        let tokens: Vec<u32> = (0..n).map(|_| rng.below(256) as u32).collect();
        let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let t = time_it(1, 5, || eng.rebuild());
        rows.push(vec![
            format!("full rebuild n={n}"),
            format!("{:.2}", t.p50.as_secs_f64() * 1e3),
            format!("{:.2}", t.mean.as_secs_f64() * 1e3),
            "-".into(),
        ]);
    }
    print_table(
        "L3 engine latencies",
        &["op", "p50 (ms)", "mean (ms)", "flops"],
        &rows,
    );

    // --- AOT dense path (L2 through PJRT) --------------------------------
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let runtime = if dir.join("manifest.json").exists() {
        match ArtifactRuntime::open(&dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                println!("(artifact runtime unavailable — {e:#})");
                None
            }
        }
    } else {
        println!("(no artifacts/ — run `make artifacts` for the L2 rows)");
        None
    };
    if let Some(rt) = runtime {
        rt.warmup().expect("warmup");
        let mut rows = Vec::new();
        for &n in &[32usize, 128, 512] {
            let tokens: Vec<u32> = (0..n).map(|_| rng.below(256) as u32).collect();
            let pool = rt.manifest.config.pos_pool;
            let pos: Vec<u32> = (0..n).map(|i| (((2 * i + 1) * pool) / (2 * n)) as u32).collect();
            let t = time_it(2, 10, || {
                rt.dense_logits(&tokens, &pos).expect("dense");
            });
            rows.push(vec![
                format!("AOT dense fwd n={n}"),
                format!("{:.2}", t.p50.as_secs_f64() * 1e3),
                format!("{:.2}", t.mean.as_secs_f64() * 1e3),
            ]);
        }
        print_table("L2 AOT path (PJRT CPU)", &["op", "p50 (ms)", "mean (ms)"], &rows);
    }

    // --- sustained online throughput --------------------------------------
    let n = 384;
    let tokens: Vec<u32> = (0..n).map(|_| rng.below(256) as u32).collect();
    let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
    let edits = 300;
    let t0 = std::time::Instant::now();
    for i in 0..edits {
        let at = rng.below(eng.len());
        match i % 3 {
            0 => {
                eng.apply_edit(Edit::Replace {
                    at,
                    tok: rng.below(256) as u32,
                });
            }
            1 if eng.len() < cfg.max_seq => {
                eng.apply_edit(Edit::Insert {
                    at,
                    tok: rng.below(256) as u32,
                });
            }
            _ if eng.len() > 64 => {
                eng.apply_edit(Edit::Delete { at });
            }
            _ => {}
        }
    }
    let dt = t0.elapsed();
    println!(
        "\nsustained online editing: {edits} mixed edits on n≈{n} in {:.2}s → {:.0} edits/s \
         ({} defrags, speedup ledger {:.1}×)",
        dt.as_secs_f64(),
        edits as f64 / dt.as_secs_f64(),
        eng.stats.defrags,
        vqt::flops::dense_forward_flops(&cfg, n) as f64 * edits as f64
            / eng.ledger.total() as f64
    );

    let _ = Arc::strong_count(&w);
}
