//! Ablations over the design choices docs/ARCHITECTURE.md calls out:
//!   A. App-A.2 score trick ON vs OFF — FLOPs and wallclock per edit.
//!   B. VQ codebook size (q = 16 / 64 / 256) — speedup vs code-flip rate.
//!   C. Position-pool gap factor — defrag rate under insertion workloads
//!      (§3.3 / App. B's "use a very large pool" recommendation).
//!   D. Softmax vs GELU attention — why the paper swaps softmax out
//!      (dense-forward cost is equal; softmax admits no *exact* value-space
//!      deltas, only the semi-naive aggregate recompute measured in E).
//!   E. Semi-naive softmax recompute — attention ops saved by the
//!      per-row delta path on a long-document edit stream
//!      (ARCHITECTURE.md §12; emits `attn_delta_ops_ratio`).

use std::sync::Arc;
use vqt::bench::{print_table, time_it};
use vqt::config::ModelConfig;
use vqt::edits::Edit;
use vqt::flops::dense_forward_flops;
use vqt::incremental::{EngineOptions, IncrementalEngine};
use vqt::model::ModelWeights;
use vqt::util::Rng;

fn mini_with(q: usize, heads: usize) -> ModelConfig {
    let mut c = ModelConfig::vqt_mini();
    c.vq_codes = q;
    c.vq_heads = heads;
    c
}

fn main() {
    let bench_t0 = std::time::Instant::now();
    println!("# ablations (vqt_mini scale, deterministic random weights)");
    let mut rng = Rng::new(31);
    let n = 256;
    let tokens: Vec<u32> = (0..n).map(|_| rng.below(256) as u32).collect();

    // --- A: score trick ---------------------------------------------------
    let cfg = ModelConfig::vqt_mini();
    let w = Arc::new(ModelWeights::random(&cfg, 7));
    let mut rows = Vec::new();
    for (label, trick) in [("score trick ON (App A.2)", true), ("score trick OFF", false)] {
        let opts = EngineOptions {
            score_trick: trick,
            verify_every: 0,
            ..EngineOptions::default()
        };
        let mut eng = IncrementalEngine::new(w.clone(), &tokens, opts);
        let mut flops = 0u64;
        let mut tok = 1u32;
        let t = time_it(2, 10, || {
            tok = (tok + 3) % 255;
            flops = eng.apply_edit(Edit::Replace { at: 64, tok }).flops;
        });
        rows.push(vec![
            label.to_string(),
            format!("{:.2}", t.p50.as_secs_f64() * 1e3),
            format!("{:.1}M", flops as f64 / 1e6),
            format!(
                "{:.1}×",
                dense_forward_flops(&cfg, n) as f64 / flops as f64
            ),
        ]);
    }
    print_table(
        "A. VQ-score-space corrections (App. A.2)",
        &["variant", "p50/edit (ms)", "flops/edit", "speedup"],
        &rows,
    );

    // --- B: codebook size --------------------------------------------------
    let mut rows = Vec::new();
    for q in [16usize, 64, 256] {
        let cfg = mini_with(q, 2);
        let w = Arc::new(ModelWeights::random(&cfg, 7));
        let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let mut flops = 0u64;
        for i in 0..20 {
            let at = rng.below(eng.len());
            flops += eng
                .apply_edit(Edit::Replace {
                    at,
                    tok: (i * 13 % 255) as u32,
                })
                .flops;
        }
        let flips = eng.stats.code_flips as f64
            / (eng.stats.edits_applied as f64 * cfg.n_layers as f64 * n as f64);
        rows.push(vec![
            format!("q = {q}"),
            format!(
                "{:.1}×",
                20.0 * dense_forward_flops(&cfg, n) as f64 / flops as f64
            ),
            format!("{:.3}%", flips * 100.0),
        ]);
    }
    print_table(
        "B. codebook size vs speedup / code-flip rate",
        &["codebook", "median-ish speedup", "row code-flip rate"],
        &rows,
    );

    // --- C: gap factor vs defrag rate --------------------------------------
    let mut rows = Vec::new();
    for gap in [1usize, 2, 4, 8, 16] {
        let mut cfg = ModelConfig::vqt_tiny();
        cfg.pos_pool = cfg.max_seq * gap;
        let w = Arc::new(ModelWeights::random(&cfg, 7));
        let start: Vec<u32> = (0..16).map(|_| rng.below(60) as u32).collect();
        let mut eng = IncrementalEngine::new(w.clone(), &start, EngineOptions::default());
        let mut inserts = 0u64;
        while eng.len() < cfg.max_seq - 1 {
            let at = rng.below(eng.len() + 1);
            eng.apply_edit(Edit::Insert {
                at,
                tok: rng.below(60) as u32,
            });
            inserts += 1;
            if eng.len() > 40 && rng.chance(0.3) {
                eng.apply_edit(Edit::Delete {
                    at: rng.below(eng.len()),
                });
            }
        }
        rows.push(vec![
            format!("{gap}×"),
            format!("{inserts}"),
            format!("{}", eng.stats.defrags),
            format!(
                "{:.2}%",
                eng.stats.defrags as f64 / inserts as f64 * 100.0
            ),
        ]);
    }
    print_table(
        "C. position-pool gap factor vs defragmentation (§3.3)",
        &["pool/max_seq", "inserts", "defrags", "defrag rate"],
        &rows,
    );
    println!("(paper/App. B recommends a large pool — rate should fall sharply with the factor)");

    // --- D: softmax vs gelu dense cost ------------------------------------
    let gelu = ModelConfig::vqt_mini();
    let mut softmax = ModelConfig::vqt_mini();
    softmax.attention = vqt::config::AttentionKind::Softmax;
    println!(
        "\nD. dense-forward cost at n=512: gelu {:.0}M ops vs softmax {:.0}M ops ({:+.1}% — \
         the swap is ~free; its value is enabling exact incremental deltas)",
        dense_forward_flops(&gelu, 512) as f64 / 1e6,
        dense_forward_flops(&softmax, 512) as f64 / 1e6,
        (dense_forward_flops(&softmax, 512) as f64 / dense_forward_flops(&gelu, 512) as f64
            - 1.0)
            * 100.0
    );

    // --- E: semi-naive softmax recompute ----------------------------------
    // The long-document scenario the delta path exists for: one changed
    // column against hundreds of clean query rows, repeated across a
    // scattered edit stream. `attn_delta_ops_ratio` is (attention ops a
    // forced-full engine would have charged) / (ops actually charged) =
    // (flops + saved) / flops, so > 1.0 means the cost rule paid off.
    let mut sm_cfg = ModelConfig::vqt_mini();
    sm_cfg.attention = vqt::config::AttentionKind::Softmax;
    let sm_w = Arc::new(ModelWeights::random(&sm_cfg, 7));
    let doc: Vec<u32> = (0..448).map(|_| rng.below(256) as u32).collect();
    let mut eng = IncrementalEngine::new(sm_w.clone(), &doc, EngineOptions::default());
    // The initial build is full attention by construction; the ratio below
    // measures edits only, where the decision rule actually runs.
    let mut edit_flops = 0u64;
    for i in 0..32 {
        let at = rng.below(eng.len());
        edit_flops += eng
            .apply_edit(Edit::Replace {
                at,
                tok: (i * 29 % 255) as u32,
            })
            .flops;
    }
    let saved = eng.stats.attn_delta_saved_flops;
    let ops_ratio = (edit_flops + saved) as f64 / edit_flops as f64;
    print_table(
        "E. semi-naive softmax recompute (§12), 448-token doc, 32 scattered replaces",
        &["metric", "value"],
        &[
            vec!["delta rows".into(), format!("{}", eng.stats.attn_delta_rows)],
            vec!["full rows".into(), format!("{}", eng.stats.attn_full_rows)],
            vec!["drift refreshes".into(), format!("{}", eng.stats.attn_refreshes)],
            vec!["ops saved".into(), format!("{:.1}M", saved as f64 / 1e6)],
            vec!["attn_delta_ops_ratio".into(), format!("{ops_ratio:.2}×")],
        ],
    );
    vqt::bench::emit_json(
        "ablations",
        &[
            ("total_wall_ns", bench_t0.elapsed().as_nanos() as f64),
            ("attn_delta_ops_ratio", ops_ratio),
            ("attn_delta_saved_flops", saved as f64),
        ],
    );
}
