//! **Figure 4** — relative reduction in arithmetic operations for ONLINE
//! processing of atomic edits (log scale), vs the edit's normalized
//! location. The paper: median 12.1×, with later edits cheaper (causal
//! attention ⇒ fewer affected rows).
//!
//! Emits the scatter series as CSV (`fig4_online.csv`) plus summary stats.
//!
//! Second half (Linux): an **open-loop arrival-curve driver** against the
//! readiness-driven async server — requests fire on a fixed schedule
//! regardless of completions (no coordinated omission: latency is measured
//! from the *scheduled* arrival), and the client-side tail is reported as
//! exact p50/p99/p999 percentiles plus the typed-busy shed ratio.

use std::sync::Arc;
use vqt::bench::*;
use vqt::config::ModelConfig;
use vqt::edits::trace::TraceConfig;
use vqt::incremental::EngineOptions;
use vqt::model::ModelWeights;
use vqt::util::Rng;

/// Client-side tail of one open-loop run.
struct OpenLoop {
    p50_ns: f64,
    p99_ns: f64,
    p999_ns: f64,
    shed_ratio: f64,
}

/// Exact percentile from a sorted sample (nearest-rank on the inclusive
/// scale — same convention as `coordinator::metrics::Histogram`).
fn percentile(sorted_ns: &[f64], p: f64) -> f64 {
    if sorted_ns.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted_ns.len() - 1) as f64).round() as usize;
    sorted_ns[idx.min(sorted_ns.len() - 1)]
}

/// Drive the async front end open-loop: `n_requests` atomic edits across
/// `conns` pipelined connections at `rate` requests/s. Returns `None` off
/// Linux (the event-loop front end is epoll-based).
#[cfg(target_os = "linux")]
fn openloop_tail(w: &Arc<ModelWeights>, n_requests: usize, rate: f64) -> Option<OpenLoop> {
    use std::collections::VecDeque;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::Mutex;
    use std::time::{Duration, Instant};
    use vqt::config::ServeConfig;
    use vqt::coordinator::{Backend, Coordinator};
    use vqt::server::{AsyncServer, FrontendOptions};

    const CONNS: usize = 8;
    let mut sc = ServeConfig::default();
    sc.workers = 2;
    sc.queue_capacity = 512;
    let coord = Coordinator::start(
        Backend {
            weights: w.clone(),
            artifacts_dir: None,
            engine_opts: EngineOptions::default(),
        },
        sc,
    );
    let server = AsyncServer::start(
        "127.0.0.1:0",
        coord.client(),
        FrontendOptions {
            io_threads: 2,
            max_connections: 0,
            max_inflight_per_conn: 64,
            trace_buffer: 0,
        },
    )
    .ok()?;
    let addr = server.local_addr();

    // One session per connection, opened in lockstep before the clock
    // starts; the open-loop phase then measures steady-state edits only.
    let mut rng = Rng::new(911);
    let doc_len = w.cfg.max_seq * 3 / 4;
    let mut writers = Vec::with_capacity(CONNS);
    let mut readers = Vec::with_capacity(CONNS);
    for i in 0..CONNS {
        let mut conn = TcpStream::connect(addr).ok()?;
        conn.set_nodelay(true).ok()?;
        let mut reader = BufReader::new(conn.try_clone().ok()?);
        let tokens: Vec<String> = (0..doc_len)
            .map(|_| (rng.below(w.cfg.vocab_size - 1)).to_string())
            .collect();
        let line = format!(
            "{{\"op\":\"open\",\"session\":\"ol{i}\",\"tokens\":[{}]}}\n",
            tokens.join(",")
        );
        conn.write_all(line.as_bytes()).ok()?;
        let mut resp = String::new();
        reader.read_line(&mut resp).ok()?;
        writers.push(conn);
        readers.push(reader);
    }

    // Reader threads: match replies FIFO against the scheduled arrival
    // stamps (per-connection ordering is the server's contract).
    let stamps: Vec<Arc<Mutex<VecDeque<Instant>>>> =
        (0..CONNS).map(|_| Arc::new(Mutex::new(VecDeque::new()))).collect();
    let per_conn: Vec<usize> = (0..CONNS)
        .map(|c| n_requests / CONNS + usize::from(c < n_requests % CONNS))
        .collect();
    let mut handles = Vec::with_capacity(CONNS);
    for (c, mut reader) in readers.into_iter().enumerate() {
        let stamps = stamps[c].clone();
        let expect = per_conn[c];
        handles.push(std::thread::spawn(move || {
            let mut lat_ns = Vec::with_capacity(expect);
            let mut shed = 0usize;
            let mut line = String::new();
            for _ in 0..expect {
                line.clear();
                if reader.read_line(&mut line).unwrap_or(0) == 0 {
                    break;
                }
                let scheduled = stamps.lock().unwrap().pop_front().expect("stamp per reply");
                lat_ns.push(scheduled.elapsed().as_nanos() as f64);
                if line.contains("\"busy\":true") {
                    shed += 1;
                }
            }
            (lat_ns, shed)
        }));
    }

    // Open-loop writer: requests fire at t0 + k/rate whether or not
    // earlier ones completed; the stamp is the SCHEDULED time, so client
    // slip (a late write) counts against the tail instead of hiding.
    let t0 = Instant::now();
    let mut sent = vec![0usize; CONNS];
    for k in 0..n_requests {
        let c = k % CONNS;
        if sent[c] >= per_conn[c] {
            continue;
        }
        let target = t0 + Duration::from_secs_f64(k as f64 / rate);
        while Instant::now() < target {
            std::thread::sleep(Duration::from_micros(50));
        }
        let at = rng.below(doc_len);
        let tok = rng.below(w.cfg.vocab_size - 1);
        let line = format!(
            "{{\"op\":\"edit\",\"session\":\"ol{c}\",\"kind\":\"replace\",\"at\":{at},\"tok\":{tok}}}\n"
        );
        stamps[c].lock().unwrap().push_back(target);
        if writers[c].write_all(line.as_bytes()).is_err() {
            break;
        }
        sent[c] += 1;
    }

    let mut lat_ns = Vec::with_capacity(n_requests);
    let mut shed = 0usize;
    for h in handles {
        let (l, s) = h.join().ok()?;
        lat_ns.extend(l);
        shed += s;
    }
    server.shutdown();
    coord.shutdown();
    if lat_ns.is_empty() {
        return None;
    }
    lat_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(OpenLoop {
        p50_ns: percentile(&lat_ns, 50.0),
        p99_ns: percentile(&lat_ns, 99.0),
        p999_ns: percentile(&lat_ns, 99.9),
        shed_ratio: shed as f64 / lat_ns.len() as f64,
    })
}

#[cfg(not(target_os = "linux"))]
fn openloop_tail(_w: &Arc<ModelWeights>, _n_requests: usize, _rate: f64) -> Option<OpenLoop> {
    None
}

fn main() {
    let bench_t0 = std::time::Instant::now();
    let n_pairs = bench_pairs();
    let tcfg = TraceConfig::mini();
    let pairs = gen_pairs(&tcfg, n_pairs, 4);
    let cfg = ModelConfig::vqt_mini();
    let (w, trained) = serving_weights(&cfg, "weights_trained_serve.bin");
    println!(
        "# Fig 4 — online atomic-edit speedup vs normalized location ({n_pairs} pairs, {})",
        if trained { "trained weights" } else { "random-init weights" }
    );

    let opts = EngineOptions::default();
    let mut rng = Rng::new(44);
    let mut series: Vec<(f64, f64)> = Vec::new();
    for (i, (a, b)) in pairs.iter().enumerate() {
        if let Some(m) = measure_atomic(&w, opts, a, b, None, &mut rng) {
            series.push((m.x, m.speedup()));
        }
        if (i + 1) % 25 == 0 {
            eprintln!("  {}/{n_pairs}", i + 1);
        }
    }
    write_csv("fig4_online.csv", "normalized_location,speedup", &series);

    let speedups: Vec<f64> = series.iter().map(|p| p.1).collect();
    println!(
        "median speedup: {:.1}×   (paper: 12.1× at OPT-125M scale)",
        vqt::util::median(&speedups)
    );

    // Later edits must be cheaper: median speedup in the last third vs the
    // first third of the document.
    let early: Vec<f64> = series.iter().filter(|p| p.0 < 0.33).map(|p| p.1).collect();
    let late: Vec<f64> = series.iter().filter(|p| p.0 > 0.67).map(|p| p.1).collect();
    let mut rows = Vec::new();
    for (label, bucket) in [("0.00–0.33", &early), ("0.67–1.00", &late)] {
        if !bucket.is_empty() {
            rows.push(vec![
                label.to_string(),
                format!("{}", bucket.len()),
                format!("{:.1}×", vqt::util::median(bucket)),
            ]);
        }
    }
    print_table(
        "Fig 4 (bucketed): speedup by edit location",
        &["location", "edits", "median speedup"],
        &rows,
    );
    if !(early.is_empty() || late.is_empty()) {
        let e = vqt::util::median(&early);
        let l = vqt::util::median(&late);
        println!(
            "location correlation: late/early = {:.2} (expect > 1 — later edits cheaper)",
            l / e
        );
    }

    let mut metrics = vec![("total_wall_ns", bench_t0.elapsed().as_nanos() as f64)];
    let late_over_early = if early.is_empty() || late.is_empty() {
        0.0
    } else {
        vqt::util::median(&late) / vqt::util::median(&early)
    };
    metrics.push(("late_over_early_ratio", late_over_early));

    // Open-loop tail latency against the async front end: a fixed arrival
    // curve (requests/s), client-measured from the scheduled arrival time.
    let smoke = std::env::var("VQT_BENCH_SMOKE").is_ok();
    let (n_requests, rate) = if smoke { (160, 400.0) } else { (4000, 1000.0) };
    match openloop_tail(&w, n_requests, rate) {
        Some(ol) => {
            println!(
                "\nopen-loop tail ({n_requests} req @ {rate:.0}/s): p50 {:.2}ms  p99 {:.2}ms  p999 {:.2}ms  shed {:.2}%",
                ol.p50_ns / 1e6,
                ol.p99_ns / 1e6,
                ol.p999_ns / 1e6,
                ol.shed_ratio * 100.0
            );
            metrics.push(("openloop_p50_wall_ns", ol.p50_ns));
            metrics.push(("openloop_p99_wall_ns", ol.p99_ns));
            metrics.push(("openloop_p999_wall_ns", ol.p999_ns));
            metrics.push(("openloop_shed_ratio", ol.shed_ratio));
        }
        None => println!("\n(open-loop driver skipped: async front end unavailable here)"),
    }
    vqt::bench::emit_json("fig4_online", &metrics);
    // Say where the consolidated JSON landed (or how to get one), so a CI
    // log reader can find the artifact without opening the workflow file.
    match std::env::var("VQT_BENCH_JSON") {
        Ok(p) => println!("\nbench JSON appended to {p}"),
        Err(_) => println!("\n(set VQT_BENCH_JSON=<path> to append these metrics as JSON)"),
    }
}
