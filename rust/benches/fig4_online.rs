//! **Figure 4** — relative reduction in arithmetic operations for ONLINE
//! processing of atomic edits (log scale), vs the edit's normalized
//! location. The paper: median 12.1×, with later edits cheaper (causal
//! attention ⇒ fewer affected rows).
//!
//! Emits the scatter series as CSV (`fig4_online.csv`) plus summary stats.

use vqt::bench::*;
use vqt::config::ModelConfig;
use vqt::edits::trace::TraceConfig;
use vqt::incremental::EngineOptions;
use vqt::util::Rng;

fn main() {
    let bench_t0 = std::time::Instant::now();
    let n_pairs = bench_pairs();
    let tcfg = TraceConfig::mini();
    let pairs = gen_pairs(&tcfg, n_pairs, 4);
    let cfg = ModelConfig::vqt_mini();
    let (w, trained) = serving_weights(&cfg, "weights_trained_serve.bin");
    println!(
        "# Fig 4 — online atomic-edit speedup vs normalized location ({n_pairs} pairs, {})",
        if trained { "trained weights" } else { "random-init weights" }
    );

    let opts = EngineOptions::default();
    let mut rng = Rng::new(44);
    let mut series: Vec<(f64, f64)> = Vec::new();
    for (i, (a, b)) in pairs.iter().enumerate() {
        if let Some(m) = measure_atomic(&w, opts, a, b, None, &mut rng) {
            series.push((m.x, m.speedup()));
        }
        if (i + 1) % 25 == 0 {
            eprintln!("  {}/{n_pairs}", i + 1);
        }
    }
    write_csv("fig4_online.csv", "normalized_location,speedup", &series);

    let speedups: Vec<f64> = series.iter().map(|p| p.1).collect();
    println!(
        "median speedup: {:.1}×   (paper: 12.1× at OPT-125M scale)",
        vqt::util::median(&speedups)
    );

    // Later edits must be cheaper: median speedup in the last third vs the
    // first third of the document.
    let early: Vec<f64> = series.iter().filter(|p| p.0 < 0.33).map(|p| p.1).collect();
    let late: Vec<f64> = series.iter().filter(|p| p.0 > 0.67).map(|p| p.1).collect();
    let mut rows = Vec::new();
    for (label, bucket) in [("0.00–0.33", &early), ("0.67–1.00", &late)] {
        if !bucket.is_empty() {
            rows.push(vec![
                label.to_string(),
                format!("{}", bucket.len()),
                format!("{:.1}×", vqt::util::median(bucket)),
            ]);
        }
    }
    print_table(
        "Fig 4 (bucketed): speedup by edit location",
        &["location", "edits", "median speedup"],
        &rows,
    );
    if !(early.is_empty() || late.is_empty()) {
        let e = vqt::util::median(&early);
        let l = vqt::util::median(&late);
        println!(
            "location correlation: late/early = {:.2} (expect > 1 — later edits cheaper)",
            l / e
        );
    }

    let mut metrics = vec![("total_wall_ns", bench_t0.elapsed().as_nanos() as f64)];
    let late_over_early = if early.is_empty() || late.is_empty() {
        0.0
    } else {
        vqt::util::median(&late) / vqt::util::median(&early)
    };
    metrics.push(("late_over_early_ratio", late_over_early));
    vqt::bench::emit_json("fig4_online", &metrics);
}
