//! Golden-trace regression lock: a pinned edit trace (committed under
//! `tests/data/golden_trace.json`) is replayed on a fixed-seed model, and
//! the per-step FLOP counts, logits (as exact f32 bit patterns), reuse
//! statistics, and final ledger are compared against
//! `tests/data/golden_expected.json`.
//!
//! Any kernel or engine refactor that silently changes numerics — a
//! reordered accumulation, a different tile width, a miscounted ledger
//! tick — fails this test loudly with the first diverging step.
//!
//! Blessing protocol: when the expected file is ABSENT the test computes
//! it, writes it next to the trace, prints a notice, and passes — commit
//! the generated file to lock the numerics. (Bless-on-absence rather than
//! an env-var flag so the lock bootstraps on machines where the repo
//! author cannot run cargo; regeneration after an *intentional* numerics
//! change is `rm tests/data/golden_expected.json && cargo test --test
//! golden_trace`.) When the file exists, the comparison is exact — no
//! tolerances anywhere.
//!
//! Independent of the golden file, every replay is cross-checked against
//! the dense from-scratch oracle, so even an unblessed first run verifies
//! exactness. With `VQT_BENCH_SMOKE=1` (the CI smoke job) the oracle
//! cross-check runs only at the end, keeping the job well under a minute.

use std::sync::Arc;
use vqt::config::ModelConfig;
use vqt::edits::Edit;
use vqt::incremental::{EngineOptions, IncrementalEngine};
use vqt::model::ModelWeights;
use vqt::util::Json;

fn data_path(name: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/data")
        .join(name)
}

fn load_trace() -> (ModelConfig, u64, Vec<u32>, Vec<Edit>) {
    let text = std::fs::read_to_string(data_path("golden_trace.json")).expect("trace file");
    let j = Json::parse(&text).expect("trace JSON");
    let cfg = ModelConfig::from_json(j.get("model")).expect("trace model config");
    let seed = j.get("weights_seed").as_usize().expect("weights_seed") as u64;
    let initial: Vec<u32> = j
        .get("initial")
        .as_arr()
        .expect("initial")
        .iter()
        .map(|v| v.as_usize().unwrap() as u32)
        .collect();
    let edits: Vec<Edit> = j
        .get("edits")
        .as_arr()
        .expect("edits")
        .iter()
        .map(|e| {
            let at = e.get("at").as_usize().unwrap();
            match e.get("kind").as_str().unwrap() {
                "replace" => Edit::Replace {
                    at,
                    tok: e.get("tok").as_usize().unwrap() as u32,
                },
                "insert" => Edit::Insert {
                    at,
                    tok: e.get("tok").as_usize().unwrap() as u32,
                },
                "delete" => Edit::Delete { at },
                k => panic!("unknown kind {k}"),
            }
        })
        .collect();
    (cfg, seed, initial, edits)
}

/// One replay step's observable outputs, exactly.
struct Step {
    flops: u64,
    logit_bits: Vec<u32>,
}

fn replay() -> (Vec<Step>, IncrementalEngine) {
    let (cfg, seed, initial, edits) = load_trace();
    let smoke = std::env::var("VQT_BENCH_SMOKE").is_ok();
    let w = Arc::new(ModelWeights::random(&cfg, seed));
    let mut eng = IncrementalEngine::new(w, &initial, EngineOptions::default());
    let mut steps = Vec::with_capacity(edits.len());
    for (i, &e) in edits.iter().enumerate() {
        let rep = eng.apply_edit(e);
        steps.push(Step {
            flops: rep.flops,
            logit_bits: rep.logits.iter().map(|x| x.to_bits()).collect(),
        });
        // The oracle cross-check keeps even an unblessed run honest.
        if !smoke || i + 1 == edits.len() {
            let v = eng.verify();
            assert!(v.is_exact(1e-3), "step {i}: dense divergence {v:?}");
        }
    }
    (steps, eng)
}

fn expected_json(steps: &[Step], eng: &IncrementalEngine) -> Json {
    let s = &eng.stats;
    let led = &eng.ledger;
    Json::obj(vec![
        (
            "steps",
            Json::Arr(
                steps
                    .iter()
                    .map(|st| {
                        Json::obj(vec![
                            ("flops", Json::num(st.flops as f64)),
                            (
                                "logit_bits",
                                Json::Arr(
                                    st.logit_bits
                                        .iter()
                                        .map(|&b| Json::num(b as f64))
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "final_stats",
            Json::obj(vec![
                ("edits_applied", Json::num(s.edits_applied as f64)),
                ("defrags", Json::num(s.defrags as f64)),
                ("full_rebuilds", Json::num(s.full_rebuilds as f64)),
                ("rows_recomputed", Json::num(s.rows_recomputed as f64)),
                ("corrections", Json::num(s.corrections as f64)),
                ("code_flips", Json::num(s.code_flips as f64)),
                ("outputs_recomputed", Json::num(s.outputs_recomputed as f64)),
            ]),
        ),
        (
            "final_ledger",
            Json::obj(vec![
                ("linear", Json::num(led.linear as f64)),
                ("attention", Json::num(led.attention as f64)),
                ("vq", Json::num(led.vq as f64)),
                ("elementwise", Json::num(led.elementwise as f64)),
                ("embed", Json::num(led.embed as f64)),
                ("bookkeeping", Json::num(led.bookkeeping as f64)),
            ]),
        ),
    ])
}

#[test]
fn golden_trace_replay_matches_expected() {
    let (steps, eng) = replay();
    let computed = expected_json(&steps, &eng);
    let expected_path = data_path("golden_expected.json");
    if !expected_path.exists() {
        std::fs::write(&expected_path, format!("{computed}\n")).expect("bless golden file");
        eprintln!(
            "golden_trace: blessed {} — commit this file to lock engine numerics",
            expected_path.display()
        );
        return;
    }
    let text = std::fs::read_to_string(&expected_path).expect("expected file");
    let expected = Json::parse(&text).expect("expected JSON");
    // Compare step-by-step for a pinpointed failure before the full check.
    let exp_steps = expected.get("steps").as_arr().expect("steps");
    assert_eq!(exp_steps.len(), steps.len(), "trace length changed");
    for (i, (exp, got)) in exp_steps.iter().zip(&steps).enumerate() {
        assert_eq!(
            exp.get("flops").as_usize(),
            Some(got.flops as usize),
            "step {i}: FLOP count changed"
        );
        let exp_bits: Vec<u32> = exp
            .get("logit_bits")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap() as u32)
            .collect();
        assert_eq!(
            exp_bits, got.logit_bits,
            "step {i}: logits changed at the bit level"
        );
    }
    assert_eq!(
        expected, computed,
        "reuse statistics or ledger categories changed"
    );
}

/// The trace itself must stay structurally valid (lengths in bounds at
/// every step) — guards against hand-edits to the JSON breaking the lock
/// silently.
#[test]
fn golden_trace_is_well_formed() {
    let (cfg, _, initial, edits) = load_trace();
    let mut len = initial.len();
    assert!(len > 0 && len <= cfg.max_seq);
    for (i, e) in edits.iter().enumerate() {
        match *e {
            Edit::Replace { at, tok } => {
                assert!(at < len && (tok as usize) < cfg.vocab_size, "edit {i}")
            }
            Edit::Insert { at, tok } => {
                assert!(at <= len && (tok as usize) < cfg.vocab_size, "edit {i}");
                len += 1;
            }
            Edit::Delete { at } => {
                assert!(at < len && len > 1, "edit {i}");
                len -= 1;
            }
        }
        assert!(len <= cfg.max_seq, "edit {i} overflows max_seq");
    }
}
