//! Differential framing tests: the event loop's incremental [`LineFramer`]
//! must frame any byte stream bit-identically to the blocking server's
//! `take(limit).read_until(b'\n')` loop — at EVERY chunk boundary, since
//! readiness-sized reads can split the stream anywhere.

use vqt::server::framer::{Frame, LineFramer};
use vqt::util::Rng;

/// What a framing pass says about a stream: the complete lines (newline
/// stripped), whether it ended oversized, and the trailing unterminated
/// line at EOF, if any.
#[derive(Debug, PartialEq, Eq)]
struct Framing {
    lines: Vec<Vec<u8>>,
    oversized: bool,
    remainder: Option<Vec<u8>>,
}

/// Reference: the blocking server's exact loop (`handle_conn`), run over an
/// in-memory stream. A line is oversized iff `read_until` fills the whole
/// `take(limit)` window without finding a newline; a final partial line at
/// EOF is returned (and processed) as-is.
fn blocking_framing(input: &[u8], limit: usize) -> Framing {
    use std::io::{BufRead, BufReader, Read};
    let mut reader = BufReader::new(std::io::Cursor::new(input));
    let mut out = Framing {
        lines: Vec::new(),
        oversized: false,
        remainder: None,
    };
    let mut buf = Vec::new();
    loop {
        buf.clear();
        let n = Read::by_ref(&mut reader)
            .take(limit as u64)
            .read_until(b'\n', &mut buf)
            .unwrap();
        if n == 0 {
            return out;
        }
        if buf.last() != Some(&b'\n') && n == limit {
            out.oversized = true;
            return out; // connection dropped: the rest is never read
        }
        if buf.last() == Some(&b'\n') {
            buf.pop();
            out.lines.push(buf.clone());
        } else {
            out.remainder = Some(buf.clone()); // partial line at EOF
            return out;
        }
    }
}

/// Run the incremental framer over `input` split at the given chunk sizes
/// (the tail after the last boundary is pushed too), then signal EOF.
fn incremental_framing(input: &[u8], limit: usize, chunks: &[usize]) -> Framing {
    let mut f = LineFramer::new(limit);
    let mut out = Framing {
        lines: Vec::new(),
        oversized: false,
        remainder: None,
    };
    let mut drain = |f: &mut LineFramer, out: &mut Framing| {
        while let Some(frame) = f.next() {
            match frame {
                Frame::Line(l) => out.lines.push(l),
                Frame::Oversized => out.oversized = true,
            }
        }
    };
    let mut at = 0;
    for &sz in chunks {
        let end = (at + sz).min(input.len());
        f.push(&input[at..end]);
        drain(&mut f, &mut out);
        at = end;
    }
    f.push(&input[at..]);
    drain(&mut f, &mut out);
    out.remainder = f.take_remainder();
    out
}

const LIMIT: usize = 16;

/// A corpus that exercises every boundary the rule has: empty lines, lines
/// at limit-1/limit/limit+1 content bytes, and interleaved normal traffic.
fn corpus() -> Vec<Vec<u8>> {
    vec![
        b"a\nbb\nccc\n".to_vec(),
        b"\n\n\n".to_vec(),
        b"123456789012345\n".to_vec(),   // limit-1 content + '\n': fits exactly
        b"1234567890123456\n".to_vec(),  // limit content bytes: oversized
        b"12345678901234567".to_vec(),   // oversized, no newline at all
        b"ok\n1234567890123456\nnever\n".to_vec(), // oversized mid-stream
        b"trailing-partial".to_vec(),    // EOF without newline
        b"full\ntrailing".to_vec(),
        b"".to_vec(),
        b"exact-window-lin\nx\n".to_vec(),
    ]
}

#[test]
fn every_two_chunk_split_matches_the_blocking_reference() {
    for input in corpus() {
        let want = blocking_framing(&input, LIMIT);
        for split in 0..=input.len() {
            let got = incremental_framing(&input, LIMIT, &[split]);
            assert_eq!(got, want, "input {input:?} split at {split}");
        }
    }
}

#[test]
fn byte_at_a_time_matches_the_blocking_reference() {
    for input in corpus() {
        let want = blocking_framing(&input, LIMIT);
        let ones = vec![1usize; input.len()];
        let got = incremental_framing(&input, LIMIT, &ones);
        assert_eq!(got, want, "input {input:?} byte-at-a-time");
    }
}

#[test]
fn random_chunk_schedules_match_the_blocking_reference() {
    let mut rng = Rng::new(0xF4A3);
    // One long adversarial stream: random lines whose lengths cluster
    // around the limit boundary, plus occasional blanks.
    let mut input = Vec::new();
    for _ in 0..200 {
        let len = rng.below(LIMIT + 4);
        for _ in 0..len {
            input.push(b'a' + (rng.below(26) as u8));
        }
        input.push(b'\n');
    }
    input.extend_from_slice(b"tail-without-newline");
    let want = blocking_framing(&input, LIMIT);
    for _ in 0..50 {
        let mut chunks = Vec::new();
        let mut total = 0;
        while total < input.len() {
            let c = 1 + rng.below(32);
            chunks.push(c);
            total += c;
        }
        let got = incremental_framing(&input, LIMIT, &chunks);
        assert_eq!(got, want);
    }
}

/// Interleaved connections: many framers fed round-robin in small chunks
/// (as one IO thread does across its sockets) frame independently — one
/// connection's partial line never bleeds into another's.
#[test]
fn interleaved_framers_keep_streams_independent() {
    let streams: Vec<Vec<u8>> = (0..8)
        .map(|i| {
            let mut rng = Rng::new(100 + i as u64);
            let mut s = Vec::new();
            for _ in 0..40 {
                let len = rng.below(LIMIT - 1);
                for _ in 0..len {
                    s.push(b'0' + (i as u8));
                }
                s.push(b'\n');
            }
            s
        })
        .collect();
    let mut framers: Vec<LineFramer> = (0..8).map(|_| LineFramer::new(LIMIT)).collect();
    let mut got: Vec<Vec<Vec<u8>>> = vec![Vec::new(); 8];
    let mut offsets = vec![0usize; 8];
    let mut rng = Rng::new(7);
    while offsets.iter().zip(&streams).any(|(&o, s)| o < s.len()) {
        for i in 0..8 {
            let (o, s) = (offsets[i], &streams[i]);
            if o >= s.len() {
                continue;
            }
            let end = (o + 1 + rng.below(5)).min(s.len());
            framers[i].push(&s[o..end]);
            offsets[i] = end;
            while let Some(Frame::Line(l)) = framers[i].next() {
                got[i].push(l);
            }
        }
    }
    for i in 0..8 {
        let want = blocking_framing(&streams[i], LIMIT);
        assert_eq!(got[i], want.lines, "stream {i}");
        // Every line of stream i is made of stream i's own byte.
        for l in &got[i] {
            assert!(l.iter().all(|&b| b == b'0' + i as u8));
        }
    }
}

/// The server-facing limit: the framer is constructed with the same
/// `READ_LIMIT_BYTES` window the blocking reader uses, so a line of
/// exactly `MAX_REQUEST_BYTES` bytes plus newline still frames, and the
/// parser (not the framer) is what rejects it from there on up.
#[test]
fn server_limit_admits_exactly_what_the_blocking_reader_admits() {
    let limit = vqt::server::MAX_REQUEST_BYTES + 2;
    let mut line = vec![b'x'; vqt::server::MAX_REQUEST_BYTES + 1];
    line.push(b'\n');
    let mut f = LineFramer::new(limit);
    f.push(&line);
    match f.next() {
        Some(Frame::Line(l)) => assert_eq!(l.len(), vqt::server::MAX_REQUEST_BYTES + 1),
        other => panic!("{other:?}"),
    }
    assert_eq!(
        blocking_framing(&line, limit).lines.len(),
        1,
        "blocking reader admits the same line"
    );
}
