//! End-to-end observability: a traced request driven through the
//! readiness-driven async front end must come back with a span breakdown
//! covering (at least) queue wait, engine work, and the reply write —
//! with monotonic timestamps — and the three surfacing paths (`trace`
//! verb, `metrics` verb, plain-HTTP `GET /metrics`) must all serve.
#![cfg(target_os = "linux")]

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use vqt::config::{ModelConfig, ServeConfig};
use vqt::coordinator::{Backend, Coordinator};
use vqt::incremental::EngineOptions;
use vqt::model::ModelWeights;
use vqt::server::{AsyncServer, FrontendOptions};
use vqt::util::Json;

fn serve(tag: &str, cfg_mut: impl FnOnce(&mut ServeConfig)) -> (Coordinator, AsyncServer) {
    let cfg = ModelConfig::vqt_tiny();
    let w = Arc::new(ModelWeights::random(&cfg, 17));
    let mut sc = ServeConfig::default();
    sc.workers = 2;
    sc.trace_buffer = 64;
    sc.spill_dir = std::env::temp_dir()
        .join(format!("vqt_trace_it_{tag}_{}", std::process::id()))
        .to_str()
        .unwrap()
        .to_string();
    cfg_mut(&mut sc);
    let trace_buffer = sc.trace_buffer;
    let c = Coordinator::start(
        Backend {
            weights: w,
            artifacts_dir: None,
            engine_opts: EngineOptions::default(),
        },
        sc,
    );
    let server = AsyncServer::start(
        "127.0.0.1:0",
        c.client(),
        FrontendOptions {
            io_threads: 1,
            max_connections: 0,
            max_inflight_per_conn: 8,
            trace_buffer,
        },
    )
    .unwrap();
    (c, server)
}

fn roundtrip(conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> Json {
    conn.write_all(req.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut line = String::new();
    assert!(reader.read_line(&mut line).unwrap() > 0, "server hung up");
    Json::parse(&line).unwrap_or_else(|e| panic!("{e}: {line}"))
}

/// Stage lookup by name in a trace record's `stages` array.
fn stage<'a>(trace: &'a Json, name: &str) -> Option<&'a Json> {
    trace
        .get("stages")
        .as_arr()?
        .iter()
        .find(|s| s.get("name").as_str() == Some(name))
}

#[test]
fn traced_request_breakdown_spans_the_pipeline() {
    let (c, server) = serve("breakdown", |_| {});
    let mut conn = TcpStream::connect(server.local_addr()).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    // Untraced requests never grow a trace field, even with the rings armed.
    let j = roundtrip(
        &mut conn,
        &mut reader,
        r#"{"op":"open","session":"t1","tokens":[1,2,3,4,5,6]}"#,
    );
    assert_eq!(j.get("ok").as_bool(), Some(true), "{j}");
    assert!(matches!(j.get("trace"), Json::Null), "opt-in only: {j}");

    // Per-request opt-in: the reply carries the span breakdown inline.
    let j = roundtrip(
        &mut conn,
        &mut reader,
        r#"{"op":"edit","session":"t1","kind":"replace","at":1,"tok":9,"trace":true}"#,
    );
    assert_eq!(j.get("ok").as_bool(), Some(true), "{j}");
    let trace = j.get("trace");
    assert_eq!(trace.get("kind").as_str(), Some("edit"), "{j}");
    assert_eq!(trace.get("session").as_str(), Some("t1"));
    let total = trace.get("total_us").as_usize().expect("total_us");

    // The breakdown covers queue wait and engine work, timestamps are
    // monotonic per stage, and every stage fits inside the total.
    let qw = stage(trace, "queue_wait").expect("queue_wait stage");
    let eng = stage(trace, "engine").expect("engine stage");
    for s in trace.get("stages").as_arr().unwrap() {
        let start = s.get("start_us").as_usize().unwrap();
        let end = s.get("end_us").as_usize().unwrap();
        assert!(start <= end, "stage ends before it starts: {s}");
        assert!(end <= total, "stage past total_us: {s} vs {total}");
        assert!(s.get("busy_us").as_usize().unwrap() <= end - start + 1, "{s}");
    }
    // The epoch is the enqueue instant, so queue wait opens the timeline
    // and the engine runs strictly after dequeue.
    assert_eq!(qw.get("start_us").as_usize(), Some(0), "{trace}");
    assert!(
        eng.get("start_us").as_usize().unwrap() >= qw.get("end_us").as_usize().unwrap(),
        "engine before dequeue: {trace}"
    );
    // The inline copy is attached BEFORE the bytes hit the socket — the
    // reply-write stage can only exist in the retained ring.
    assert!(stage(trace, "reply_write").is_none(), "{trace}");

    // The `trace` verb serves the retained rings; the async front end's
    // copy of the edit's record has the appended reply_write stage.
    let j = roundtrip(&mut conn, &mut reader, r#"{"op":"trace"}"#);
    assert_eq!(j.get("ok").as_bool(), Some(true), "{j}");
    let traces = j.get("traces").as_arr().expect("traces array");
    assert!(!traces.is_empty());
    let with_reply = traces
        .iter()
        .find(|t| t.get("kind").as_str() == Some("edit") && stage(t, "reply_write").is_some())
        .expect("an edit trace retired through the front end with reply_write");
    let rw = stage(with_reply, "reply_write").unwrap();
    let eng = stage(with_reply, "engine").expect("engine stage in retained record");
    assert!(
        rw.get("start_us").as_usize().unwrap() >= eng.get("end_us").as_usize().unwrap(),
        "reply written before the engine finished: {with_reply}"
    );
    assert!(
        with_reply.get("total_us").as_usize().unwrap()
            >= rw.get("end_us").as_usize().unwrap(),
        "{with_reply}"
    );

    server.shutdown();
    c.shutdown();
}

#[test]
fn metrics_verb_and_http_scrape_serve_the_exposition() {
    let (c, server) = serve("metrics", |_| {});
    let mut conn = TcpStream::connect(server.local_addr()).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    roundtrip(
        &mut conn,
        &mut reader,
        r#"{"op":"open","session":"m1","tokens":[4,5,6,7]}"#,
    );
    roundtrip(
        &mut conn,
        &mut reader,
        r#"{"op":"edit","session":"m1","kind":"replace","at":0,"tok":2}"#,
    );

    // Line-protocol verb: the exposition rides inside the JSON reply.
    let j = roundtrip(&mut conn, &mut reader, r#"{"op":"metrics"}"#);
    assert_eq!(j.get("ok").as_bool(), Some(true), "{j}");
    let text = j.get("metrics").as_str().expect("metrics text").to_string();
    assert!(text.contains("# TYPE vqt_edits_total counter"), "{text}");
    assert!(text.contains("vqt_edits_total 1"), "{text}");
    assert!(text.contains("# TYPE vqt_queue_wait_us histogram"), "{text}");
    assert!(text.contains("vqt_queue_wait_us_bucket{le=\"+Inf\"}"), "{text}");
    assert!(text.contains("vqt_live_sessions 1"), "{text}");
    // The async front end appends its own series to the pool's.
    assert!(text.contains("vqt_frontend_connections 1"), "{text}");
    assert!(
        text.contains("vqt_frontend_thread_connections{io_thread=\"0\"} 1"),
        "{text}"
    );

    // Plain-HTTP scrape: one HTTP/1.0 response carrying the same body
    // shape, then close.
    let mut scrape = TcpStream::connect(server.local_addr()).unwrap();
    scrape.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
    let mut resp = String::new();
    scrape.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"), "{resp}");
    assert!(resp.contains("Content-Type: text/plain; version=0.0.4"), "{resp}");
    let body = resp.split_once("\r\n\r\n").expect("header/body split").1;
    assert!(body.contains("# TYPE vqt_edits_total counter"), "{body}");
    assert!(body.contains("vqt_frontend_connections"), "{body}");

    server.shutdown();
    c.shutdown();
}

#[test]
fn slow_request_sampling_counts_over_threshold_requests() {
    // A 1µs bar everything trips: every request is sampled as slow.
    let (c, server) = serve("slow", |sc| {
        sc.trace_buffer = 0;
        sc.slow_request_us = 1;
    });
    let mut conn = TcpStream::connect(server.local_addr()).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    roundtrip(
        &mut conn,
        &mut reader,
        r#"{"op":"open","session":"sl","tokens":[1,2,3]}"#,
    );
    roundtrip(
        &mut conn,
        &mut reader,
        r#"{"op":"edit","session":"sl","kind":"replace","at":0,"tok":7}"#,
    );
    let j = roundtrip(&mut conn, &mut reader, r#"{"op":"stats"}"#);
    let shards = j.get("stats").get("per_shard").as_arr().expect("per_shard");
    let slow: usize = shards
        .iter()
        .map(|s| s.get("slow_requests").as_usize().unwrap())
        .sum();
    let traced: usize = shards
        .iter()
        .map(|s| s.get("traces_recorded").as_usize().unwrap())
        .sum();
    assert!(slow >= 1, "an edit request is always over a 1µs bar, got {slow}");
    assert!(traced >= 2, "sampling requires tracing: {traced}");
    server.shutdown();
    c.shutdown();
}
