//! Differential suite for the session lifecycle subsystem: the invariant
//! it locks is that **a restored engine is indistinguishable from one that
//! never left memory**.
//!
//! Engine tier: every randomized edit stream is driven through a pair of
//! engines — one always resident, one forked through a snapshot→restore
//! cycle at random points (sometimes via an on-disk spill file). After
//! EVERY edit the pair must agree on:
//!   - logits, **bit for bit** (`f32::to_bits` equality, not a tolerance),
//!   - `EditReport::flops` (exact arithmetic-op counts),
//!   - the cumulative FLOP ledger and reuse statistics,
//! and both must stay exact against the dense from-scratch oracle.
//!
//! Coordinator tier: a 64-session load test under a deliberately tiny
//! memory budget proves byte-accounted LRU spilling keeps the measured
//! resident bytes under the configured budget while every session keeps
//! serving bit-exact results through suspend/resume cycles it never sees.

use std::sync::Arc;
use vqt::config::{ModelConfig, ServeConfig};
use vqt::coordinator::{Backend, Coordinator, Request, Response};
use vqt::edits::Edit;
use vqt::incremental::{EngineOptions, IncrementalEngine};
use vqt::model::ModelWeights;
use vqt::testutil::gen_edit;
use vqt::util::Rng;

/// Distinct depths, widths, and VQ-head layouts (mirrors the engine
/// differential suite).
fn configs() -> Vec<(&'static str, ModelConfig)> {
    let tiny = ModelConfig::vqt_tiny();
    let deep = ModelConfig {
        n_layers: 3,
        d_ff: 48,
        ..ModelConfig::vqt_tiny()
    };
    let single_head = ModelConfig {
        vq_heads: 1,
        ..ModelConfig::vqt_tiny()
    };
    let out = vec![("tiny", tiny), ("tiny-3layer", deep), ("tiny-vq1", single_head)];
    for (name, cfg) in &out {
        cfg.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
    }
    out
}

/// Assert the cycled engine is indistinguishable from the resident one.
fn assert_indistinguishable(
    ctx: &str,
    resident: &IncrementalEngine,
    cycled: &IncrementalEngine,
) {
    assert_eq!(cycled.tokens(), resident.tokens(), "{ctx}: tokens");
    assert_eq!(
        cycled.position_ids(),
        resident.position_ids(),
        "{ctx}: position ids"
    );
    for (i, (a, b)) in resident.logits().iter().zip(cycled.logits()).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{ctx}: logit {i} not bit-exact ({a} vs {b})"
        );
    }
    assert_eq!(cycled.ledger, resident.ledger, "{ctx}: FLOP ledger");
    assert_eq!(cycled.stats, resident.stats, "{ctx}: reuse statistics");
}

fn drive(name: &str, cfg: &ModelConfig, seed: u64, n_edits: usize) {
    let w = Arc::new(ModelWeights::random(cfg, seed));
    let mut rng = Rng::new(seed ^ 0x11FE_C0DE);
    let n0 = rng.range(8, cfg.max_seq.min(26));
    let tokens: Vec<u32> = (0..n0).map(|_| rng.below(cfg.vocab_size) as u32).collect();
    let mut resident = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
    // The cycled peer starts from one snapshot of the resident engine, so
    // the pair begins with identical state AND identical counters.
    let mut cycled =
        IncrementalEngine::restore(w.clone(), &resident.snapshot(), EngineOptions::default())
            .unwrap();
    let spill_path = std::env::temp_dir().join(format!(
        "vqt_lifecycle_{name}_{seed}_{}.vqss",
        std::process::id()
    ));
    let mut cycles = 0u32;
    for step in 0..n_edits {
        let ctx = format!("{name} seed {seed} step {step}");
        // Suspend/resume the cycled engine at random points (plus one
        // forced mid-stream cycle so every stream exercises it),
        // alternating in-memory and on-disk round trips.
        if step == n_edits / 2 || rng.chance(0.34) {
            cycles += 1;
            cycled = if rng.chance(0.5) {
                cycled.snapshot_to_file(&spill_path).unwrap();
                IncrementalEngine::restore_from_file(
                    w.clone(),
                    &spill_path,
                    EngineOptions::default(),
                )
                .unwrap_or_else(|e| panic!("{ctx}: resume from file: {e:#}"))
            } else {
                IncrementalEngine::restore(
                    w.clone(),
                    &cycled.snapshot(),
                    EngineOptions::default(),
                )
                .unwrap_or_else(|e| panic!("{ctx}: resume from bytes: {e:#}"))
            };
        }
        let e = gen_edit(&mut rng, resident.len(), cfg.vocab_size, cfg.max_seq);
        let rep_r = resident.apply_edit(e);
        let rep_c = cycled.apply_edit(e);
        assert_eq!(
            rep_r.flops, rep_c.flops,
            "{ctx}: per-edit FLOP count diverged after a suspend/resume cycle"
        );
        assert_eq!(rep_r.defragged, rep_c.defragged, "{ctx}: defrag divergence");
        assert_indistinguishable(&ctx, &resident, &cycled);
        if (step + 1) % 5 == 0 || step + 1 == n_edits {
            // Both sides must also stay exact against the dense oracle.
            let v = cycled.verify();
            assert!(v.is_exact(1e-3), "{ctx}: cycled engine drifted: {v:?}");
            assert_eq!(v.code_mismatches, 0, "{ctx}");
        }
    }
    assert!(cycles > 0, "{name} seed {seed}: stream never cycled");
    let _ = std::fs::remove_file(spill_path);
}

#[test]
fn suspend_resume_streams_are_bit_exact() {
    for (name, cfg) in configs() {
        for seed in [61u64, 62, 63] {
            drive(name, &cfg, seed, 12);
        }
    }
}

#[test]
fn suspend_resume_survives_defrag_boundary() {
    // Cycle immediately after a defragmentation (full rebuild) — the
    // worst-case structural path — and keep going.
    let cfg = ModelConfig::vqt_tiny();
    let w = Arc::new(ModelWeights::random(&cfg, 91));
    let mut rng = Rng::new(92);
    let tokens: Vec<u32> = (0..12).map(|_| rng.below(cfg.vocab_size) as u32).collect();
    let mut resident = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
    let mut cycled =
        IncrementalEngine::restore(w.clone(), &resident.snapshot(), EngineOptions::default())
            .unwrap();
    let mut defrags = 0u32;
    for step in 0..40 {
        if resident.len() >= cfg.max_seq {
            break;
        }
        let e = Edit::Insert {
            at: 6,
            tok: rng.below(cfg.vocab_size) as u32,
        };
        let rep_r = resident.apply_edit(e);
        let rep_c = cycled.apply_edit(e);
        assert_eq!(rep_r.flops, rep_c.flops, "step {step}");
        if rep_r.defragged {
            defrags += 1;
            // Cycle right on the defrag boundary.
            cycled = IncrementalEngine::restore(
                w.clone(),
                &cycled.snapshot(),
                EngineOptions::default(),
            )
            .unwrap();
            assert_indistinguishable(&format!("post-defrag step {step}"), &resident, &cycled);
        }
    }
    assert!(defrags > 0, "stream never defragged — workload too gentle");
    assert_indistinguishable("final", &resident, &cycled);
    assert!(cycled.verify().is_exact(1e-3));
}

// ---------------------------------------------------------------------------
// Coordinator tier: eviction under a byte budget, 64 sessions.
// ---------------------------------------------------------------------------

const LOAD_SESSIONS: usize = 64;
const LOAD_WAVES: usize = 3;
const BUDGET_MB: usize = 1;

fn load_test_spill_dir() -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("vqt_load_spill_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn sixty_four_session_load_stays_under_memory_budget() {
    let cfg = ModelConfig::vqt_tiny();
    let w = Arc::new(ModelWeights::random(&cfg, 7));
    let spill = load_test_spill_dir();
    let budget_bytes = BUDGET_MB << 20;
    let sc = ServeConfig {
        workers: 4,
        max_sessions: 256, // total cap never drops a session in this test
        max_resident_sessions: 0,
        memory_budget_mb: BUDGET_MB,
        spill_dir: spill.to_str().unwrap().to_string(),
        ..ServeConfig::default()
    };
    let coordinator = Coordinator::start(
        Backend {
            weights: w.clone(),
            artifacts_dir: None,
            engine_opts: EngineOptions::default(),
        },
        sc,
    );
    let client = coordinator.client();

    // Open 64 sessions and keep a serial reference script per session.
    let mut docs: Vec<Vec<u32>> = Vec::new();
    let mut scripts: Vec<Vec<Edit>> = vec![Vec::new(); LOAD_SESSIONS];
    for i in 0..LOAD_SESSIONS {
        let mut r = Rng::new(4000 + i as u64);
        let n = r.range(10, 20);
        let doc: Vec<u32> = (0..n).map(|_| r.below(cfg.vocab_size) as u32).collect();
        client
            .request(Request::Open {
                session: format!("load-{i}"),
                tokens: doc.clone(),
            })
            .unwrap()
            .logits()
            .unwrap();
        docs.push(doc);
    }

    let budget_gauge = |client: &vqt::coordinator::Client| -> (usize, usize, usize, u64, u64) {
        match client.request(Request::Stats).unwrap() {
            Response::Stats(j) => (
                j.get("resident_bytes").as_usize().unwrap(),
                j.get("live_sessions").as_usize().unwrap(),
                j.get("spilled_sessions").as_usize().unwrap(),
                j.get("suspends").as_usize().unwrap() as u64,
                j.get("resumes").as_usize().unwrap() as u64,
            ),
            other => panic!("{other:?}"),
        }
    };

    // The budget must hold from the very first snapshot on.
    let (bytes, live, spilled, suspends, _) = budget_gauge(&client);
    assert!(
        bytes <= budget_bytes,
        "resident bytes {bytes} over budget {budget_bytes} after opens"
    );
    assert_eq!(live + spilled, LOAD_SESSIONS, "no session may be lost");
    assert!(suspends > 0, "64 tiny sessions must overflow a 1 MiB budget");

    // Waves of edits touch every session in turn — each touch of a cold
    // session transparently resumes it (and pushes another one out).
    let mut rng = Rng::new(31337);
    let mut lens: Vec<usize> = docs.iter().map(Vec::len).collect();
    for wave in 0..LOAD_WAVES {
        for i in 0..LOAD_SESSIONS {
            let e = gen_edit(&mut rng, lens[i], cfg.vocab_size, cfg.max_seq);
            lens[i] = (lens[i] as isize + e.len_delta()) as usize;
            scripts[i].push(e);
            let r = client
                .request(Request::Edit {
                    session: format!("load-{i}"),
                    edit: e,
                })
                .unwrap();
            assert!(r.logits().is_ok(), "wave {wave} session {i}: {r:?}");
        }
        let (bytes, live, spilled, _, resumes) = budget_gauge(&client);
        assert!(
            bytes <= budget_bytes,
            "wave {wave}: resident bytes {bytes} over budget {budget_bytes}"
        );
        assert_eq!(live + spilled, LOAD_SESSIONS, "wave {wave}: session lost");
        assert!(resumes > 0, "wave {wave}: cold sessions must have resumed");
    }

    // Every session's final logits must be bit-identical to a serial
    // replay on an always-resident engine — suspension was invisible.
    for i in 0..LOAD_SESSIONS {
        let served = client
            .request(Request::EditScript {
                session: format!("load-{i}"),
                edits: Vec::new(),
            })
            .unwrap()
            .logits()
            .unwrap()
            .to_vec();
        let mut reference =
            IncrementalEngine::new(w.clone(), &docs[i], EngineOptions::default());
        reference.apply_edits(&scripts[i]);
        assert_eq!(reference.logits().len(), served.len());
        for (k, (a, b)) in reference.logits().iter().zip(&served).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "session {i} logit {k}: resident {a} vs served-through-spill {b}"
            );
        }
    }

    drop(client);
    coordinator.shutdown();
    let _ = std::fs::remove_dir_all(spill);
}

/// Serving-scale lifecycle tier, run by CI as `cargo test --release --
/// --ignored` alongside the engine differential tier: the vqt_mini presets
/// with longer documents, cycling through snapshot/restore mid-stream.
#[test]
#[ignore = "release-mode lifecycle tier (CI runs with --ignored)"]
fn suspend_resume_streams_serving_scale() {
    for (name, cfg) in [
        ("vqt_mini", ModelConfig::vqt_mini()),
        ("vqt_mini_h4", ModelConfig::vqt_mini_h4()),
    ] {
        cfg.validate().unwrap();
        for seed in [71u64, 72, 73] {
            let w = Arc::new(ModelWeights::random(&cfg, seed));
            let mut rng = Rng::new(seed ^ 0xFACE);
            let n0 = rng.range(96, 160);
            let tokens: Vec<u32> =
                (0..n0).map(|_| rng.below(cfg.vocab_size) as u32).collect();
            let mut resident =
                IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
            let mut cycled = IncrementalEngine::restore(
                w.clone(),
                &resident.snapshot(),
                EngineOptions::default(),
            )
            .unwrap();
            for step in 0..30 {
                if rng.chance(0.25) {
                    cycled = IncrementalEngine::restore(
                        w.clone(),
                        &cycled.snapshot(),
                        EngineOptions::default(),
                    )
                    .unwrap();
                }
                let e = gen_edit(&mut rng, resident.len(), cfg.vocab_size, cfg.max_seq);
                let rep_r = resident.apply_edit(e);
                let rep_c = cycled.apply_edit(e);
                assert_eq!(rep_r.flops, rep_c.flops, "{name} seed {seed} step {step}");
                if step % 10 == 9 {
                    assert_indistinguishable(
                        &format!("{name} seed {seed} step {step}"),
                        &resident,
                        &cycled,
                    );
                    assert!(cycled.verify().is_exact(1e-3));
                }
            }
        }
    }
}
