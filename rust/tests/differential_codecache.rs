//! Differential suite for the shared codebook-product cache
//! (`incremental::codecache` — the `code → decode·w_mix` products behind
//! the block-tail seam).
//!
//! The claim under test is strict BIT-exactness plus honest accounting:
//! for randomized edit streams, a cache-attached engine must produce,
//! per script and in final state,
//!   - identical logits (f32 bit patterns) to an uncached peer,
//!   - identical reuse statistics apart from the cache counters
//!     themselves,
//!   - a FLOP ledger that undercuts the uncached peer by EXACTLY
//!     `hits × (MULADD·d² − d)` — every hit skips one d×d GEMV (charging
//!     a d-float copy instead), and nothing else may change,
//! and must match the dense from-scratch oracle (`verify()`), across
//! ≥3 model configs × seeds, under eviction pressure, across
//! snapshot/restore (which excludes the cache by design), and across a
//! weights-fingerprint mismatch (which must flush, never serve stale).

use std::sync::Arc;
use vqt::config::ModelConfig;
use vqt::edits::Edit;
use vqt::flops::MULADD;
use vqt::incremental::{CacheHandle, CodeCache, EngineOptions, IncrementalEngine};
use vqt::model::ModelWeights;
use vqt::testutil::gen_edit;
use vqt::util::Rng;

/// The config axis: three genuinely different geometries (head count and
/// depth both change the code-tuple shape and the per-layer key stream).
fn configs() -> Vec<(&'static str, ModelConfig)> {
    vec![
        ("vqt_tiny", ModelConfig::vqt_tiny()),
        (
            "vqt_tiny_h4",
            ModelConfig {
                vq_heads: 4,
                ..ModelConfig::vqt_tiny()
            },
        ),
        (
            "vqt_tiny_3l",
            ModelConfig {
                n_layers: 3,
                ..ModelConfig::vqt_tiny()
            },
        ),
    ]
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// What one hit saves in the ledger: the skipped d×d mix GEMV
/// (`MULADD·d²`) minus the d-float copy a hit charges instead.
fn hit_saving(cfg: &ModelConfig) -> u64 {
    let d = cfg.d_model as u64;
    MULADD * d * d - d
}

/// The cache counters, zeroed — masking them makes a cached engine's
/// stats comparable to an uncached peer's.
fn mask_cache_counters(stats: &vqt::incremental::EngineStats) -> vqt::incremental::EngineStats {
    let mut s = stats.clone();
    s.cache_hits = 0;
    s.cache_misses = 0;
    s.cache_evictions = 0;
    s.cache_bytes_inserted = 0;
    s
}

/// Drive one randomized edit stream through a cache-attached engine and
/// an uncached peer; assert bit-exactness and exact FLOP attribution per
/// script and in final state.
fn run_stream(
    label: &str,
    cfg: &ModelConfig,
    seed: u64,
    scripts: usize,
    cache_bytes: usize,
) -> vqt::incremental::CodeCacheStats {
    let w = Arc::new(ModelWeights::random(cfg, seed));
    let handle = CacheHandle::new(Arc::new(CodeCache::new(cache_bytes)), &w);
    let mut r = Rng::new(seed ^ 0xCAC4E);
    let n0 = r.range(10, 20);
    let doc: Vec<u32> = (0..n0).map(|_| r.below(cfg.vocab_size) as u32).collect();
    let opts = EngineOptions::default();
    let mut cached = IncrementalEngine::new(w.clone(), &doc, opts);
    cached.set_code_cache(Some(handle.clone()));
    let mut plain = IncrementalEngine::new(w.clone(), &doc, opts);
    let mut len = doc.len();
    for script_no in 0..scripts {
        let k = r.range(1, 4);
        let script: Vec<Edit> = (0..k)
            .map(|_| {
                let e = gen_edit(&mut r, len, cfg.vocab_size, cfg.max_seq);
                len = (len as isize + e.len_delta()) as usize;
                e
            })
            .collect();
        let hits_before = cached.stats.cache_hits;
        let rep_on = cached.apply_edits(&script);
        let rep_off = plain.apply_edits(&script);
        let hits = cached.stats.cache_hits - hits_before;
        assert_eq!(
            bits(&rep_on.logits),
            bits(&rep_off.logits),
            "{label} seed {seed} script {script_no}: logits bits"
        );
        assert_eq!(
            rep_off.flops - rep_on.flops,
            hits * hit_saving(cfg),
            "{label} seed {seed} script {script_no}: per-script FLOP attribution \
             (hits this script: {hits})"
        );
        assert_eq!(
            rep_on.defragged, rep_off.defragged,
            "{label} seed {seed} script {script_no}: defrag flag"
        );
    }
    // Deterministic A→B→A toggle on row 0: returning a row to a prior
    // content state reproduces the same code tuple (codes are content-
    // determined — the oracle check below proves it), so the third edit
    // MUST hit what the first inserted. Guarantees the stream exercises
    // the hit path regardless of how the random phase landed.
    let t0 = cached.tokens()[0];
    let x = (t0 + 1) % cfg.vocab_size as u32;
    let y = (t0 + 2) % cfg.vocab_size as u32;
    for tok in [x, y, x] {
        let e = [Edit::Replace { at: 0, tok }];
        let a = cached.apply_edits(&e);
        let b = plain.apply_edits(&e);
        assert_eq!(bits(&a.logits), bits(&b.logits), "{label} toggle logits");
    }
    assert!(
        cached.stats.cache_hits > 0,
        "{label}: the A→B→A toggle must hit"
    );
    // Final state: the cached engine is indistinguishable apart from the
    // cache counters, its ledger shortfall is exactly its hits' savings,
    // and it matches the dense oracle.
    assert_eq!(cached.tokens(), plain.tokens(), "{label} tokens");
    assert_eq!(
        cached.position_ids(),
        plain.position_ids(),
        "{label} positions"
    );
    assert_eq!(
        bits(cached.logits()),
        bits(plain.logits()),
        "{label} final logits bits"
    );
    assert_eq!(
        mask_cache_counters(&cached.stats),
        plain.stats,
        "{label} non-cache statistics"
    );
    assert_eq!(
        plain.ledger.total() - cached.ledger.total(),
        cached.stats.cache_hits * hit_saving(cfg),
        "{label} ledger attribution over the whole stream"
    );
    let v = cached.verify();
    assert_eq!(v.code_mismatches, 0, "{label}: dense oracle code parity");
    assert!(
        v.max_logit_diff < 1e-3,
        "{label}: oracle logit diff {}",
        v.max_logit_diff
    );
    // Engine-side counters and the shared cache's own counters must agree
    // (one engine, one cache: no other writers).
    let cs = handle.cache.stats();
    assert_eq!(cs.hits, cached.stats.cache_hits, "{label} hit parity");
    assert_eq!(cs.misses, cached.stats.cache_misses, "{label} miss parity");
    assert_eq!(
        cs.evictions, cached.stats.cache_evictions,
        "{label} eviction parity"
    );
    assert_eq!(
        cs.bytes_inserted, cached.stats.cache_bytes_inserted,
        "{label} byte parity"
    );
    cs
}

#[test]
fn cached_streams_bit_exact_across_configs_and_seeds() {
    for (label, cfg) in configs() {
        for seed in 0..3u64 {
            let cs = run_stream(label, &cfg, 300 + seed, 5, 4 << 20);
            assert!(cs.hits > 0, "{label} seed {seed}: stream never hit");
            assert!(cs.misses > 0, "{label} seed {seed}: stream never missed");
        }
    }
}

/// A byte budget small enough to evict constantly must stay bit-exact:
/// eviction changes WHAT is resident, never what a hit returns.
#[test]
fn eviction_pressure_stays_bit_exact() {
    let cfg = ModelConfig::vqt_tiny();
    let w = Arc::new(ModelWeights::random(&cfg, 41));
    // Capacity of ONE ~192-byte entry (32·4 payload + 64 overhead) per
    // shard: any two keys landing in the same shard displace each other.
    let handle = CacheHandle::new(Arc::new(CodeCache::new(4096)), &w);
    let doc: Vec<u32> = (0..20).map(|i| (i * 7 % 50) as u32).collect();
    let opts = EngineOptions::default();
    let mut cached = IncrementalEngine::new(w.clone(), &doc, opts);
    cached.set_code_cache(Some(handle.clone()));
    let mut plain = IncrementalEngine::new(w.clone(), &doc, opts);
    // Three full replace sweeps: every row's tail recomputes with fresh
    // content each time, streaming far more distinct (layer, code) keys
    // through the cache than it can hold.
    for sweep in 0..3u32 {
        for at in 0..20usize {
            let e = [Edit::Replace {
                at,
                tok: (sweep * 20 + at as u32) * 13 % 50,
            }];
            let a = cached.apply_edits(&e);
            let b = plain.apply_edits(&e);
            assert_eq!(bits(&a.logits), bits(&b.logits), "sweep {sweep} at {at}");
        }
    }
    let cs = handle.cache.stats();
    assert!(
        cs.evictions > 0,
        "budget must actually evict (misses: {})",
        cs.misses
    );
    assert!(handle.cache.resident_bytes() <= 4096, "budget respected");
    assert_eq!(
        plain.ledger.total() - cached.ledger.total(),
        cached.stats.cache_hits * hit_saving(&cfg),
        "attribution stays exact under eviction"
    );
}

/// VQSS snapshots exclude the cache: a restored engine comes back
/// detached with zeroed cache counters, and after re-attaching it runs
/// bit-identically to an always-resident peer — rewarming from the still-
/// shared cache rather than re-serializing it.
#[test]
fn snapshot_restore_excludes_cache_and_stays_exact() {
    let cfg = ModelConfig::vqt_tiny();
    let w = Arc::new(ModelWeights::random(&cfg, 71));
    let handle = CacheHandle::new(Arc::new(CodeCache::new(1 << 20)), &w);
    let mut r = Rng::new(71);
    let doc: Vec<u32> = (0..14).map(|_| r.below(cfg.vocab_size) as u32).collect();
    let opts = EngineOptions::default();
    let mut resident = IncrementalEngine::new(w.clone(), &doc, opts);
    resident.set_code_cache(Some(handle.clone()));
    // Warm phase: some edits populate the cache and the counters.
    let mut len = doc.len();
    let mut warm: Vec<Edit> = Vec::new();
    for _ in 0..4 {
        let e = gen_edit(&mut r, len, cfg.vocab_size, cfg.max_seq);
        len = (len as isize + e.len_delta()) as usize;
        warm.push(e);
    }
    resident.apply_edits(&warm);
    assert!(handle.cache.len() > 0, "warm phase populated the cache");
    let bytes = resident.snapshot();
    let mut restored = IncrementalEngine::restore(w.clone(), &bytes, opts).unwrap();
    assert!(
        restored.code_cache().is_none(),
        "snapshot must not carry the cache attachment"
    );
    assert_eq!(
        (restored.stats.cache_hits, restored.stats.cache_misses),
        (0, 0),
        "cache counters restart at zero after restore"
    );
    restored.set_code_cache(Some(handle.clone()));
    // Identical follow-up stream on both engines, sharing the still-warm
    // cache: bit-identical logits, identical counter deltas.
    let res_hits0 = resident.stats.cache_hits;
    for _ in 0..3 {
        let e = gen_edit(&mut r, len, cfg.vocab_size, cfg.max_seq);
        len = (len as isize + e.len_delta()) as usize;
        let a = resident.apply_edits(&[e]);
        let b = restored.apply_edits(&[e]);
        assert_eq!(bits(&a.logits), bits(&b.logits), "post-restore logits");
        assert_eq!(a.flops, b.flops, "post-restore flops");
    }
    assert_eq!(bits(resident.logits()), bits(restored.logits()));
    assert_eq!(
        restored.stats.cache_hits,
        resident.stats.cache_hits - res_hits0,
        "restored engine's counters are exactly the post-restore delta"
    );
}

/// Attaching a handle fingerprinted for DIFFERENT weights must flush the
/// shared cache rather than serve another model's products — and the
/// flushed engine must still be bit-exact against an uncached peer.
#[test]
fn fingerprint_mismatch_flushes_not_serves_stale() {
    let cfg = ModelConfig::vqt_tiny();
    let w1 = Arc::new(ModelWeights::random(&cfg, 11));
    let w2 = Arc::new(ModelWeights::random(&cfg, 12));
    let cache = Arc::new(CodeCache::new(1 << 20));
    let h1 = CacheHandle::new(cache.clone(), &w1);
    let h2 = CacheHandle::new(cache.clone(), &w2);
    assert_ne!(h1.fp, h2.fp, "different weights, different fingerprints");
    let doc: Vec<u32> = (0..12).map(|i| (i * 3 % 50) as u32).collect();
    let opts = EngineOptions::default();
    let mut a = IncrementalEngine::new(w1, &doc, opts);
    a.set_code_cache(Some(h1));
    a.apply_edits(&[Edit::Replace { at: 3, tok: 7 }, Edit::Insert { at: 5, tok: 9 }]);
    assert!(cache.len() > 0, "w1 products resident");
    // Same document, same edits, other weights: w2's engine must not see
    // a single w1 product.
    let mut b_cached = IncrementalEngine::new(w2.clone(), &doc, opts);
    b_cached.set_code_cache(Some(h2));
    let mut b_plain = IncrementalEngine::new(w2, &doc, opts);
    let script = [Edit::Replace { at: 3, tok: 7 }, Edit::Insert { at: 5, tok: 9 }];
    let rb = b_cached.apply_edits(&script);
    let rp = b_plain.apply_edits(&script);
    assert_eq!(bits(&rb.logits), bits(&rp.logits), "post-flush bit-exact");
    assert_eq!(cache.stats().flushes, 1, "exactly one flush");
}

/// Serving-scale tier (release-mode CI: `cargo test --release -- --ignored`):
/// the vqt_mini geometry under longer streams and realistic budgets.
#[test]
#[ignore = "serving-scale differential tier; run with --release -- --ignored"]
fn cached_streams_bit_exact_at_serving_scale() {
    for (label, cfg) in [
        ("vqt_mini", ModelConfig::vqt_mini()),
        ("vqt_mini_h4", ModelConfig::vqt_mini_h4()),
    ] {
        let cs = run_stream(label, &cfg, 999, 10, 32 << 20);
        assert!(cs.hits > 0, "{label}: serving-scale stream must hit");
    }
}
