//! Differential suite for cross-session batched execution
//! (`incremental::batch::apply_scripts_batched` — the pooled block-tail
//! GEMM path the coordinator shards run under load).
//!
//! The claim under test is strict BIT-exactness, not tolerance-level
//! agreement: for randomized multi-session edit streams, the batched path
//! must produce, per session,
//!   - identical logits (f32 bit patterns),
//!   - identical per-script FLOP reports and final ledgers,
//!   - identical reuse statistics (corrections, code flips, recomputes),
//!   - identical tokens/positions,
//! compared against (a) an unbatched `apply_edits` peer engine and (b) the
//! dense from-scratch oracle (`verify()`), across ≥3 model configs × seeds
//! × several concurrent sessions, including defrags mid-stream and the
//! degenerate chunk caps.

use std::sync::Arc;
use vqt::config::ModelConfig;
use vqt::edits::Edit;
use vqt::incremental::{apply_scripts_batched, EngineOptions, IncrementalEngine};
use vqt::model::ModelWeights;
use vqt::testutil::gen_edit;
use vqt::util::Rng;

/// The config axis: three genuinely different geometries.
fn configs() -> Vec<(&'static str, ModelConfig, EngineOptions)> {
    let trick_off = EngineOptions {
        score_trick: false,
        ..EngineOptions::default()
    };
    vec![
        ("vqt_tiny", ModelConfig::vqt_tiny(), EngineOptions::default()),
        (
            "table1_vq_h4",
            ModelConfig::table1("vq_h4").unwrap(),
            EngineOptions::default(),
        ),
        ("vqt_tiny_naive", ModelConfig::vqt_tiny(), trick_off),
    ]
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Run one multi-session stream batched and unbatched; assert exhaustive
/// equality plus dense-oracle parity.
fn run_stream(
    label: &str,
    cfg: &ModelConfig,
    opts: EngineOptions,
    seed: u64,
    sessions: usize,
    waves: usize,
    max_batch_rows: usize,
) {
    let w = Arc::new(ModelWeights::random(cfg, seed));
    let mut r = Rng::new(seed ^ 0xD1FF);
    let docs: Vec<Vec<u32>> = (0..sessions)
        .map(|i| {
            let n = r.range(8, 16 + i);
            (0..n).map(|_| r.below(cfg.vocab_size) as u32).collect()
        })
        .collect();
    let mut batched: Vec<IncrementalEngine> = docs
        .iter()
        .map(|d| IncrementalEngine::new(w.clone(), d, opts))
        .collect();
    let mut serial: Vec<IncrementalEngine> = docs
        .iter()
        .map(|d| IncrementalEngine::new(w.clone(), d, opts))
        .collect();
    let mut lens: Vec<usize> = docs.iter().map(Vec::len).collect();
    for wave in 0..waves {
        // Random per-session scripts (some empty — sessions idle in and
        // out of waves, like real queues).
        let scripts: Vec<Vec<Edit>> = (0..sessions)
            .map(|i| {
                let k = r.below(4); // 0..=3 edits this wave
                (0..k)
                    .map(|_| {
                        let e = gen_edit(&mut r, lens[i], cfg.vocab_size, cfg.max_seq);
                        lens[i] = (lens[i] as isize + e.len_delta()) as usize;
                        e
                    })
                    .collect()
            })
            .collect();
        let script_refs: Vec<&[Edit]> = scripts.iter().map(|s| s.as_slice()).collect();
        let outcome = {
            let mut refs: Vec<&mut IncrementalEngine> = batched.iter_mut().collect();
            apply_scripts_batched(&mut refs, &script_refs, max_batch_rows)
        };
        assert!(
            outcome.gemm_fills.iter().all(|&f| f <= max_batch_rows),
            "{label} seed {seed} wave {wave}: fill over cap"
        );
        for i in 0..sessions {
            let rep = serial[i].apply_edits(&scripts[i]);
            assert_eq!(
                outcome.reports[i].flops, rep.flops,
                "{label} seed {seed} wave {wave} session {i}: per-script FLOPs"
            );
            assert_eq!(
                outcome.reports[i].defragged, rep.defragged,
                "{label} seed {seed} wave {wave} session {i}: defrag flag"
            );
            assert_eq!(
                bits(&outcome.reports[i].logits),
                bits(&rep.logits),
                "{label} seed {seed} wave {wave} session {i}: report logits bits"
            );
        }
    }
    // Final-state equality: the two engine populations are
    // indistinguishable, and both exactly match the dense oracle.
    for i in 0..sessions {
        let (b, s) = (&batched[i], &serial[i]);
        assert_eq!(b.tokens(), s.tokens(), "{label} session {i} tokens");
        assert_eq!(
            b.position_ids(),
            s.position_ids(),
            "{label} session {i} positions"
        );
        assert_eq!(
            bits(b.logits()),
            bits(s.logits()),
            "{label} session {i} final logits bits"
        );
        assert_eq!(
            b.ledger.total(),
            s.ledger.total(),
            "{label} session {i} ledger total"
        );
        assert_eq!(b.stats, s.stats, "{label} session {i} reuse statistics");
        let v = batched[i].verify();
        assert_eq!(
            v.code_mismatches, 0,
            "{label} session {i}: dense oracle code parity"
        );
        assert!(
            v.max_logit_diff < 1e-3,
            "{label} session {i}: oracle logit diff {}",
            v.max_logit_diff
        );
    }
}

#[test]
fn batched_streams_bit_exact_across_configs_and_seeds() {
    for (label, cfg, opts) in configs() {
        for seed in 0..3u64 {
            run_stream(label, &cfg, opts, 100 + seed, 4, 4, 8);
        }
    }
}

/// Degenerate and adversarial chunk caps: 1-row GEMMs (pure overhead, no
/// pooling) and an effectively unbounded cap must both be bit-identical.
#[test]
fn chunk_cap_extremes_are_bit_exact() {
    let cfg = ModelConfig::vqt_tiny();
    for cap in [1usize, 3, 4096] {
        run_stream("tiny_cap", &cfg, EngineOptions::default(), 77, 3, 3, cap);
    }
}

/// Defrags forced mid-stream (zero position-pool slack): the batched path
/// must absorb full rebuilds inside a wave and stay exact.
#[test]
fn defrag_inside_batched_wave_stays_exact() {
    let mut cfg = ModelConfig::vqt_tiny();
    cfg.pos_pool = cfg.max_seq; // zero slack ⇒ inserts defrag often
    let w = Arc::new(ModelWeights::random(&cfg, 5));
    let mut r = Rng::new(21);
    let docs: Vec<Vec<u32>> = (0..3)
        .map(|_| (0..10).map(|_| r.below(cfg.vocab_size) as u32).collect())
        .collect();
    let mut batched: Vec<IncrementalEngine> = docs
        .iter()
        .map(|d| IncrementalEngine::new(w.clone(), d, EngineOptions::default()))
        .collect();
    let mut serial: Vec<IncrementalEngine> = docs
        .iter()
        .map(|d| IncrementalEngine::new(w.clone(), d, EngineOptions::default()))
        .collect();
    // Insert-heavy scripts at one position force defrags.
    let scripts: Vec<Vec<Edit>> = (0..3)
        .map(|s| {
            (0..6)
                .map(|i| Edit::Insert {
                    at: (s + i) % 5,
                    tok: ((7 * i + s) % 50) as u32,
                })
                .collect()
        })
        .collect();
    let script_refs: Vec<&[Edit]> = scripts.iter().map(|s| s.as_slice()).collect();
    let outcome = {
        let mut refs: Vec<&mut IncrementalEngine> = batched.iter_mut().collect();
        apply_scripts_batched(&mut refs, &script_refs, 8)
    };
    let mut any_defrag = false;
    for i in 0..3 {
        let rep = serial[i].apply_edits(&scripts[i]);
        any_defrag |= rep.defragged;
        assert_eq!(outcome.reports[i].flops, rep.flops, "session {i}");
        assert_eq!(outcome.reports[i].defragged, rep.defragged, "session {i}");
        assert_eq!(bits(&outcome.reports[i].logits), bits(&rep.logits));
        assert_eq!(batched[i].stats, serial[i].stats, "session {i} stats");
        let v = batched[i].verify();
        assert_eq!(v.code_mismatches, 0, "session {i}");
        assert!(v.max_logit_diff < 1e-3, "session {i}");
    }
    assert!(any_defrag, "zero-slack pool must defrag at least once");
}

/// Serving-scale tier (release-mode CI: `cargo test --release -- --ignored`):
/// the vqt_mini geometries under longer concurrent streams.
#[test]
#[ignore = "serving-scale differential tier; run with --release -- --ignored"]
fn batched_streams_bit_exact_at_serving_scale() {
    for (label, cfg) in [
        ("vqt_mini", ModelConfig::vqt_mini()),
        ("vqt_mini_h4", ModelConfig::vqt_mini_h4()),
    ] {
        let w = Arc::new(ModelWeights::random(&cfg, 777));
        let mut r = Rng::new(31337);
        let sessions = 6;
        let docs: Vec<Vec<u32>> = (0..sessions)
            .map(|_| {
                let n = r.range(64, 160);
                (0..n).map(|_| r.below(cfg.vocab_size) as u32).collect()
            })
            .collect();
        let mut batched: Vec<IncrementalEngine> = docs
            .iter()
            .map(|d| IncrementalEngine::new(w.clone(), d, EngineOptions::default()))
            .collect();
        let mut serial: Vec<IncrementalEngine> = docs
            .iter()
            .map(|d| IncrementalEngine::new(w.clone(), d, EngineOptions::default()))
            .collect();
        let mut lens: Vec<usize> = docs.iter().map(Vec::len).collect();
        for _wave in 0..6 {
            let scripts: Vec<Vec<Edit>> = (0..sessions)
                .map(|i| {
                    (0..r.range(1, 4))
                        .map(|_| {
                            let e = gen_edit(&mut r, lens[i], cfg.vocab_size, cfg.max_seq);
                            lens[i] = (lens[i] as isize + e.len_delta()) as usize;
                            e
                        })
                        .collect()
                })
                .collect();
            let script_refs: Vec<&[Edit]> = scripts.iter().map(|s| s.as_slice()).collect();
            let outcome = {
                let mut refs: Vec<&mut IncrementalEngine> = batched.iter_mut().collect();
                apply_scripts_batched(&mut refs, &script_refs, 128)
            };
            for i in 0..sessions {
                let rep = serial[i].apply_edits(&scripts[i]);
                assert_eq!(outcome.reports[i].flops, rep.flops, "{label} session {i}");
                assert_eq!(bits(&outcome.reports[i].logits), bits(&rep.logits), "{label}");
            }
        }
        for i in 0..sessions {
            assert_eq!(batched[i].stats, serial[i].stats, "{label} session {i}");
            let v = batched[i].verify();
            assert_eq!(v.code_mismatches, 0, "{label} session {i}");
            assert!(v.max_logit_diff < 1e-2, "{label} session {i}");
        }
    }
}
