//! Golden schema lock for the `stats` snapshot: dashboards and scrapers
//! key off these field names, so adding/renaming/dropping one must be a
//! conscious, test-visible act. Checked at one shard and at three (the
//! merge path and the per-shard breakdown must agree on shape).

use std::sync::Arc;
use vqt::config::{ModelConfig, ServeConfig};
use vqt::coordinator::{Backend, Coordinator, Request, Response};
use vqt::incremental::EngineOptions;
use vqt::model::ModelWeights;
use vqt::util::Json;

/// Every key the merged (pool-level) stats object carries.
const MERGED_KEYS: &[&str] = &[
    "attn_delta_rows",
    "attn_full_rows",
    "attn_refreshes",
    "attn_saved_flops",
    "batch_fill",
    "batched_rows",
    "cache_bytes",
    "cache_evictions",
    "cache_hits",
    "cache_misses",
    "defrags",
    "dense_calls",
    "edits",
    "errors",
    "flops_dense_equiv",
    "flops_incremental",
    "kernel_backend",
    "lat_dense_us",
    "lat_edit_us",
    "lat_revision_us",
    "live_sessions",
    "panics",
    "per_shard",
    "queue_wait_us",
    "rejected_backpressure",
    "resident_bytes",
    "resumes",
    "revisions",
    "sessions_evicted",
    "sessions_opened",
    "sessions_restored",
    "shards",
    "slow_requests",
    "speedup",
    "spilled_sessions",
    "suspends",
    "traces_recorded",
];

/// Every key each `per_shard` entry carries.
const PER_SHARD_KEYS: &[&str] = &[
    "attn_delta_rows",
    "attn_full_rows",
    "attn_refreshes",
    "attn_saved_flops",
    "batched_rows",
    "cache_bytes",
    "cache_evictions",
    "cache_hits",
    "cache_misses",
    "dense_calls",
    "edits",
    "errors",
    "live_sessions",
    "panics",
    "queue_wait_p99_us",
    "resident_bytes",
    "slow_requests",
    "spilled_sessions",
    "traces_recorded",
];

/// Every key a histogram summary carries.
const HISTOGRAM_KEYS: &[&str] = &["count", "max", "mean", "p50", "p99", "p999"];

fn keys(j: &Json) -> Vec<String> {
    j.as_obj()
        .unwrap_or_else(|| panic!("expected object, got {j}"))
        .keys()
        .cloned()
        .collect()
}

fn stats_snapshot(workers: usize) -> Json {
    let cfg = ModelConfig::vqt_tiny();
    let w = Arc::new(ModelWeights::random(&cfg, 23));
    let mut sc = ServeConfig::default();
    sc.workers = workers;
    let c = Coordinator::start(
        Backend {
            weights: w,
            artifacts_dir: None,
            engine_opts: EngineOptions::default(),
        },
        sc,
    );
    let client = c.client();
    // A little traffic so the snapshot reflects real counters, not just
    // zero-init defaults.
    client
        .request(Request::Open {
            session: "g".into(),
            tokens: vec![1, 2, 3, 4],
        })
        .unwrap();
    let resp = client.request(Request::Stats).unwrap();
    let j = match resp {
        Response::Stats(j) => j,
        other => panic!("{other:?}"),
    };
    c.shutdown();
    j
}

#[test]
fn stats_schema_is_locked_at_one_and_three_shards() {
    for workers in [1usize, 3] {
        let j = stats_snapshot(workers);
        assert_eq!(keys(&j), MERGED_KEYS, "merged keys at {workers} shards");
        for h in ["lat_edit_us", "lat_revision_us", "lat_dense_us", "queue_wait_us", "batch_fill"]
        {
            assert_eq!(keys(j.get(h)), HISTOGRAM_KEYS, "{h} at {workers} shards");
        }
        let shards = j.get("per_shard").as_arr().expect("per_shard array");
        assert_eq!(shards.len(), workers);
        for (i, s) in shards.iter().enumerate() {
            assert_eq!(keys(s), PER_SHARD_KEYS, "shard {i} of {workers}");
        }
        assert_eq!(j.get("shards").as_usize(), Some(workers));
        // The breakdown reconciles with the merged gauges.
        let live: usize = shards
            .iter()
            .map(|s| s.get("live_sessions").as_usize().unwrap())
            .sum();
        assert_eq!(Some(live), j.get("live_sessions").as_usize());
    }
}

/// The async front end's grafted `frontend` object (Linux only — the
/// blocking server's stats reply has no front end).
#[cfg(target_os = "linux")]
#[test]
fn frontend_stats_schema_is_locked() {
    use vqt::server::FrontendStats;
    let fs = FrontendStats::new(3);
    let j = fs.to_json();
    assert_eq!(
        keys(&j),
        [
            "connections",
            "connections_accepted",
            "connections_rejected",
            "per_io_thread",
            "requests_shed",
        ],
        "frontend keys"
    );
    assert_eq!(j.get("per_io_thread").as_arr().map(<[Json]>::len), Some(3));
}
