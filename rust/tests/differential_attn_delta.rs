//! Differential suite for the semi-naive softmax attention path
//! (`incremental::attn_delta` + the engine's `attn_sm_*` methods).
//!
//! Three claims, per ISSUE 10's acceptance gate:
//!
//! 1. **Tolerance-level agreement, not bit-exactness.** A delta-enabled
//!    engine and a forced-full peer (`attn_delta: false`) walk identical
//!    edit streams and must agree on logits within the documented 1e-3,
//!    and BOTH must match the dense from-scratch oracle (`verify()`) with
//!    zero VQ code mismatches. Code parity is load-bearing: it proves the
//!    two engines propagated the *same* changed-column sets through every
//!    layer, which is what makes claim 2 an exact identity.
//! 2. **Exact FLOP ledger identity.** With identical propagation,
//!    `flops_full − flops_delta == Σ per-row savings` holds as u64
//!    equality — the decision rule only ever swaps a full-row charge for a
//!    delta-row charge plus a recorded saving, never changes anything
//!    else.
//! 3. **Drift refresh is a real bound.** A tight `attn_refresh_every`
//!    forces refreshes and keeps error at the documented tolerance; even
//!    `attn_refresh_every: 0` (never refresh) stays bounded at test scale.
//!
//! Configs cross the interesting boundaries: the defaults, a deeper
//! narrow-codebook geometry, and a zero-slack position pool that defrags
//! mid-stream (aggregates must survive `rebuild()` and batch reindexing).

use std::sync::Arc;
use vqt::config::{AttentionKind, ModelConfig};
use vqt::edits::{apply_edits as apply_to_doc, diff_tokens, Edit};
use vqt::incremental::{EngineOptions, IncrementalEngine};
use vqt::model::ModelWeights;
use vqt::testutil::gen_edit;
use vqt::util::Rng;

/// Documented delta-vs-full / dense-oracle tolerance (ARCHITECTURE §12).
const TOL: f32 = 1e-3;

/// The config axis: three genuinely different softmax geometries.
fn configs() -> Vec<(&'static str, ModelConfig)> {
    let mut base = ModelConfig::vqt_tiny();
    base.attention = AttentionKind::Softmax;
    let mut deep = base.clone();
    deep.n_layers = 3;
    deep.vq_codes = 8;
    let mut defrag = base.clone();
    // Zero position-pool slack: inserts force defrags (full rebuilds), so
    // the aggregate store's clear/rebuild path runs mid-stream.
    defrag.pos_pool = defrag.max_seq;
    vec![
        ("tiny_sm", base),
        ("tiny_sm_deep", deep),
        ("tiny_sm_defrag", defrag),
    ]
}

fn delta_opts() -> EngineOptions {
    EngineOptions::default()
}

fn full_opts() -> EngineOptions {
    EngineOptions {
        attn_delta: false,
        ..EngineOptions::default()
    }
}

fn max_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

/// Walk one randomized edit stream through a delta engine and a
/// forced-full peer; assert tolerance agreement, dense-oracle code parity
/// for both, and the exact ledger identity.
fn run_stream(label: &str, cfg: &ModelConfig, seed: u64, doc_len: usize, edits: usize) {
    let w = Arc::new(ModelWeights::random(cfg, seed));
    let mut r = Rng::new(seed ^ 0xA77D);
    let doc: Vec<u32> = (0..doc_len)
        .map(|_| r.below(cfg.vocab_size) as u32)
        .collect();
    let mut delta = IncrementalEngine::new(w.clone(), &doc, delta_opts());
    let mut full = IncrementalEngine::new(w.clone(), &doc, full_opts());
    let mut len = doc.len();
    for step in 0..edits {
        let e = gen_edit(&mut r, len, cfg.vocab_size, cfg.max_seq);
        len = (len as isize + e.len_delta()) as usize;
        let rd = delta.apply_edits(std::slice::from_ref(&e));
        let rf = full.apply_edits(std::slice::from_ref(&e));
        let d = max_diff(&rd.logits, &rf.logits);
        assert!(
            d < TOL,
            "{label} seed {seed} step {step}: delta-vs-full logit diff {d}"
        );
        // Code parity against the dense oracle EVERY step, for BOTH
        // engines: this is what guarantees identical changed-column
        // propagation, the precondition for the exact ledger identity.
        for (name, eng) in [("delta", &delta), ("full", &full)] {
            let v = eng.verify();
            assert_eq!(
                v.code_mismatches, 0,
                "{label} seed {seed} step {step}: {name} code parity"
            );
            assert!(
                v.max_logit_diff < TOL,
                "{label} seed {seed} step {step}: {name} oracle diff {}",
                v.max_logit_diff
            );
        }
    }
    // The forced-full peer must never have taken the delta path, and the
    // delta engine must have actually used it (streams are long enough
    // that at least one clean row wins the cost rule).
    assert_eq!(full.stats.attn_delta_rows, 0, "{label}: peer took deltas");
    assert!(
        delta.stats.attn_delta_rows > 0,
        "{label} seed {seed}: delta path never taken"
    );
    // Exact ledger identity: the only divergence between the two ledgers
    // is full-row charges swapped for delta-row charges, and the engine
    // records exactly that difference in `attn_delta_saved_flops`.
    let (lf, ld) = (full.ledger.total(), delta.ledger.total());
    assert_eq!(
        lf - ld,
        delta.stats.attn_delta_saved_flops,
        "{label} seed {seed}: flops_full({lf}) - flops_delta({ld}) != saved"
    );
}

#[test]
fn delta_matches_forced_full_and_dense_across_configs_and_seeds() {
    for (label, cfg) in configs() {
        for seed in 0..3u64 {
            run_stream(label, &cfg, 200 + seed, 24, 8);
        }
    }
}

/// Wide fan-out: one substitution at row 0 of a long document leaves every
/// later row clean-but-affected — the semi-naive sweet spot. The delta
/// path must dominate and still match the oracle.
#[test]
fn wide_fanout_early_edit_prefers_delta_and_stays_exact() {
    let mut cfg = ModelConfig::vqt_tiny();
    cfg.attention = AttentionKind::Softmax;
    let w = Arc::new(ModelWeights::random(&cfg, 7));
    let mut r = Rng::new(77);
    let doc: Vec<u32> = (0..48).map(|_| r.below(cfg.vocab_size) as u32).collect();
    let mut delta = IncrementalEngine::new(w.clone(), &doc, delta_opts());
    let mut full = IncrementalEngine::new(w, &doc, full_opts());
    let e = Edit::Replace { at: 0, tok: 3 };
    let rd = delta.apply_edits(&[e]);
    let rf = full.apply_edits(&[e]);
    assert!(max_diff(&rd.logits, &rf.logits) < TOL);
    for eng in [&delta, &full] {
        let v = eng.verify();
        assert_eq!(v.code_mismatches, 0);
        assert!(v.max_logit_diff < TOL, "oracle diff {}", v.max_logit_diff);
    }
    // A single changed column against a 48-row context: the cost rule
    // picks delta for (nearly) every clean row, and the saving is real.
    assert!(
        delta.stats.attn_delta_rows > delta.stats.attn_full_rows,
        "delta rows {} should dominate full rows {}",
        delta.stats.attn_delta_rows,
        delta.stats.attn_full_rows
    );
    assert!(delta.stats.attn_delta_saved_flops > 0);
    assert_eq!(
        full.ledger.total() - delta.ledger.total(),
        delta.stats.attn_delta_saved_flops,
        "ledger identity on the fan-out edit"
    );
}

/// Degenerate boundaries: a 1-token document (no clean rows at all — the
/// delta machinery must simply stay out of the way) and a near-total
/// turnover revision (random redraw of every position: most rows are
/// dirty, and the few clean rows see sides approaching ctx, driving the
/// cost rule toward refusing the delta — turnover must stay exact).
#[test]
fn boundary_docs_and_full_turnover_revisions() {
    let mut cfg = ModelConfig::vqt_tiny();
    cfg.attention = AttentionKind::Softmax;
    let w = Arc::new(ModelWeights::random(&cfg, 9));
    // 1-token doc: substitute the only row.
    let mut one = IncrementalEngine::new(w.clone(), &[5], delta_opts());
    one.apply_edits(&[Edit::Replace { at: 0, tok: 9 }]);
    let v = one.verify();
    assert_eq!(v.code_mismatches, 0, "1-token doc");
    assert!(v.max_logit_diff < TOL);
    assert_eq!(one.stats.attn_delta_rows, 0, "no clean rows to delta");
    // Full-turnover revision: replace every token at once.
    let mut r = Rng::new(91);
    let a: Vec<u32> = (0..16).map(|_| r.below(cfg.vocab_size) as u32).collect();
    let b: Vec<u32> = (0..16).map(|_| r.below(cfg.vocab_size) as u32).collect();
    let mut delta = IncrementalEngine::new(w.clone(), &a, delta_opts());
    let mut full = IncrementalEngine::new(w, &a, full_opts());
    let script = diff_tokens(&a, &b);
    assert_eq!(apply_to_doc(&a, &script), b, "diff sanity");
    let rd = delta.apply_revision(&script);
    let rf = full.apply_revision(&script);
    assert!(max_diff(&rd.logits, &rf.logits) < TOL);
    for eng in [&delta, &full] {
        let v = eng.verify();
        assert_eq!(v.code_mismatches, 0, "full turnover");
        assert!(v.max_logit_diff < TOL);
    }
    assert_eq!(
        full.ledger.total() - delta.ledger.total(),
        delta.stats.attn_delta_saved_flops,
        "ledger identity under full turnover"
    );
}

/// Drift refresh: a refresh interval of 2 forces frequent full recomputes
/// of delta-updated rows and must keep the documented tolerance; interval
/// 0 (never refresh) is still bounded at test scale, just without the
/// refresh counter moving.
#[test]
fn drift_refresh_bounds_accumulated_error() {
    let mut cfg = ModelConfig::vqt_tiny();
    cfg.attention = AttentionKind::Softmax;
    let w = Arc::new(ModelWeights::random(&cfg, 13));
    let mut r = Rng::new(131);
    let doc: Vec<u32> = (0..32).map(|_| r.below(cfg.vocab_size) as u32).collect();
    let tight = EngineOptions {
        attn_refresh_every: 2,
        ..EngineOptions::default()
    };
    let never = EngineOptions {
        attn_refresh_every: 0,
        ..EngineOptions::default()
    };
    let mut eng_tight = IncrementalEngine::new(w.clone(), &doc, tight);
    let mut eng_never = IncrementalEngine::new(w, &doc, never);
    // A long stream of same-position substitutions hammers the same clean
    // rows' aggregates over and over — worst case for drift.
    for step in 0..24 {
        let e = Edit::Replace {
            at: (step * 5) % 30,
            tok: (r.below(cfg.vocab_size)) as u32,
        };
        eng_tight.apply_edits(std::slice::from_ref(&e));
        eng_never.apply_edits(std::slice::from_ref(&e));
    }
    let vt = eng_tight.verify();
    assert_eq!(vt.code_mismatches, 0, "tight-refresh code parity");
    assert!(
        vt.max_logit_diff < TOL,
        "tight refresh must hold the documented tolerance, got {}",
        vt.max_logit_diff
    );
    assert!(
        eng_tight.stats.attn_refreshes > 0,
        "interval 2 over 24 edits must trigger drift refreshes"
    );
    let vn = eng_never.verify();
    assert_eq!(vn.code_mismatches, 0, "never-refresh code parity");
    // Never refreshing forfeits the hard bound but stays sane at this
    // scale (f32 drift per delta update is ~ulp-level).
    assert!(
        vn.max_logit_diff < 1e-2,
        "unrefreshed drift blew up: {}",
        vn.max_logit_diff
    );
    assert_eq!(eng_never.stats.attn_refreshes, 0);
}
