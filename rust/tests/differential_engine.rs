//! Differential test suite for the incremental engine: randomized edit
//! streams (insert/delete/replace at random positions) driven through
//! `IncrementalEngine::apply_edits`, checked after every edit against
//! BOTH exactness oracles:
//!
//! 1. `verify()` — the dense from-scratch forward pass over the same
//!    tokens/positions (logits, final hidden states, every per-layer VQ
//!    code);
//! 2. a `rebuild()` peer — a fork of the engine whose state is recomputed
//!    from scratch, row stores and all, which must agree on codes exactly
//!    and on logits within fp-accumulation slack.
//!
//! This is the lock that lets the kernel/coordinator refactors move fast:
//! any divergence between the tiled kernels, the incremental update path,
//! and the dense oracle fails here with the offending (config, seed,
//! step) triple.

use std::sync::Arc;
use vqt::config::ModelConfig;
use vqt::incremental::{EngineOptions, IncrementalEngine};
use vqt::model::ModelWeights;
use vqt::testutil::gen_edit;
use vqt::util::Rng;

/// Model configs exercised by the fast suite — distinct depths, widths,
/// and VQ-head layouts.
fn configs() -> Vec<(&'static str, ModelConfig)> {
    let tiny = ModelConfig::vqt_tiny();
    let deep = ModelConfig {
        n_layers: 3,
        d_ff: 48,
        ..ModelConfig::vqt_tiny()
    };
    let single_head = ModelConfig {
        vq_heads: 1,
        ..ModelConfig::vqt_tiny()
    };
    let wide = ModelConfig::table1("vq_h2").unwrap();
    let out = vec![
        ("tiny", tiny),
        ("tiny-3layer", deep),
        ("tiny-vq1", single_head),
        ("table1-vq_h2", wide),
    ];
    for (name, cfg) in &out {
        cfg.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
    }
    out
}

/// Drive one randomized edit stream, checking both oracles every
/// `check_every` edits and at the end.
fn drive(name: &str, cfg: &ModelConfig, seed: u64, n_edits: usize, check_every: usize) {
    let w = Arc::new(ModelWeights::random(cfg, seed));
    let mut rng = Rng::new(seed ^ 0xD1FF_E4E2);
    let n0 = rng.range(8, cfg.max_seq.min(26));
    let tokens: Vec<u32> = (0..n0).map(|_| rng.below(cfg.vocab_size) as u32).collect();
    let mut eng = IncrementalEngine::new(w, &tokens, EngineOptions::default());
    for step in 0..n_edits {
        let e = gen_edit(&mut rng, eng.len(), cfg.vocab_size, cfg.max_seq);
        eng.apply_edits(&[e]);
        if (step + 1) % check_every == 0 || step + 1 == n_edits {
            check_exact(name, &eng, cfg, seed, step);
        }
    }
}

fn check_exact(name: &str, eng: &IncrementalEngine, cfg: &ModelConfig, seed: u64, step: usize) {
    let ctx = format!("{name} seed {seed} step {step}");
    // Oracle 1: dense from-scratch forward pass.
    let rep = eng.verify();
    assert!(
        rep.is_exact(1e-3),
        "{ctx}: dense divergence {rep:?} after {} edits",
        step + 1
    );
    assert_eq!(rep.code_mismatches, 0, "{ctx}: code drift {rep:?}");
    // Oracle 2: a from-scratch rebuild peer over the same tokens and
    // positions (fork shares both; rebuild recomputes all cached state).
    let mut peer = eng.fork();
    peer.rebuild();
    assert_eq!(peer.tokens(), eng.tokens(), "{ctx}: token divergence");
    for li in 0..cfg.n_layers {
        assert_eq!(
            peer.layer_codes(li),
            eng.layer_codes(li),
            "{ctx}: layer {li} codes diverge from rebuild peer"
        );
    }
    for (i, (a, b)) in eng.logits().iter().zip(peer.logits()).enumerate() {
        assert!(
            (a - b).abs() < 1e-3,
            "{ctx}: logit {i} {a} vs rebuilt {b}"
        );
    }
}

#[test]
fn differential_edit_streams_stay_exact() {
    for (name, cfg) in configs() {
        for seed in [41u64, 42, 43] {
            drive(name, &cfg, seed, 10, 1);
        }
    }
}

#[test]
fn differential_streams_survive_defrag() {
    // Hammer inserts at one position so the positional gap pool exhausts
    // and the engine defragments (full rebuild) mid-stream — the
    // worst-case structural path must stay exact too.
    let cfg = ModelConfig::vqt_tiny();
    let w = Arc::new(ModelWeights::random(&cfg, 77));
    let mut rng = Rng::new(78);
    let tokens: Vec<u32> = (0..12).map(|_| rng.below(cfg.vocab_size) as u32).collect();
    let mut eng = IncrementalEngine::new(w, &tokens, EngineOptions::default());
    let mut defrags = 0u32;
    for step in 0..40 {
        if eng.len() >= cfg.max_seq {
            break;
        }
        let rep = eng.apply_edits(&[vqt::edits::Edit::Insert {
            at: 6,
            tok: rng.below(cfg.vocab_size) as u32,
        }]);
        defrags += rep.defragged as u32;
        if rep.defragged || step % 8 == 7 {
            check_exact("defrag-stream", &eng, &cfg, 77, step);
        }
    }
    assert!(defrags > 0, "stream never defragged — workload too gentle");
}

/// Larger-config tier, run by CI as `cargo test --release -- --ignored`:
/// the serving-scale presets with longer documents and streams.
#[test]
#[ignore = "release-mode differential tier (CI runs with --ignored)"]
fn differential_edit_streams_serving_scale() {
    for (name, cfg) in [
        ("vqt_mini", ModelConfig::vqt_mini()),
        ("vqt_mini_h4", ModelConfig::vqt_mini_h4()),
    ] {
        cfg.validate().unwrap();
        for seed in [7u64, 8, 9] {
            let w = Arc::new(ModelWeights::random(&cfg, seed));
            let mut rng = Rng::new(seed ^ 0xBEEF);
            let n0 = rng.range(96, 160);
            let tokens: Vec<u32> =
                (0..n0).map(|_| rng.below(cfg.vocab_size) as u32).collect();
            let mut eng = IncrementalEngine::new(w, &tokens, EngineOptions::default());
            for step in 0..40 {
                let e = gen_edit(&mut rng, eng.len(), cfg.vocab_size, cfg.max_seq);
                eng.apply_edits(&[e]);
                if step % 8 == 7 || step == 39 {
                    check_exact(name, &eng, &cfg, seed, step);
                }
            }
        }
    }
}
