//! Engine-level backend-equivalence lock: the whole incremental serving
//! stack — rebuild, edits, codebook products, logits — must produce
//! bit-identical results on every kernel backend (scalar, explicit SIMD,
//! auto). This is the end-to-end counterpart of the per-kernel
//! equivalence suite in `src/tensor/simd.rs`; if it fails, a SIMD core
//! diverged from the scalar reference somewhere a microkernel test
//! didn't reach.
//!
//! Single test function on purpose: the kernel backend selector is
//! process-global, and integration tests within one binary run on
//! multiple threads — toggling the selector from parallel tests would
//! race. (An explicit `Scalar`/`Simd` request overrides the
//! `VQT_KERNEL_BACKEND` env var, so the forced phases hold even under
//! the CI leg that pins the env to `simd`.)

use std::sync::Arc;
use vqt::config::ModelConfig;
use vqt::edits::Edit;
use vqt::incremental::{EngineOptions, IncrementalEngine};
use vqt::model::ModelWeights;
use vqt::tensor::{set_kernel_backend, KernelBackend};
use vqt::util::Rng;

fn logits_bits(eng: &IncrementalEngine) -> Vec<u32> {
    eng.logits().iter().map(|v| v.to_bits()).collect()
}

#[test]
fn engine_logits_bitwise_identical_across_backends() {
    let cfg = ModelConfig::vqt_tiny();
    let w = Arc::new(ModelWeights::random(&cfg, 41));
    let mut r = Rng::new(0xBACC);
    let tokens: Vec<u32> = (0..24).map(|_| r.below(cfg.vocab_size) as u32).collect();
    let edits: Vec<Edit> = vec![
        Edit::Replace { at: 3, tok: 7 },
        Edit::Insert { at: 10, tok: 11 },
        Edit::Delete { at: 0 },
        Edit::Replace { at: 20, tok: 1 },
        Edit::Insert { at: 0, tok: 2 },
    ];
    let run = |kb: KernelBackend| -> Vec<Vec<u32>> {
        set_kernel_backend(kb);
        let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let mut traces = vec![logits_bits(&eng)];
        for e in &edits {
            eng.apply_edit(*e);
            traces.push(logits_bits(&eng));
        }
        traces
    };
    let scalar = run(KernelBackend::Scalar);
    let simd = run(KernelBackend::Simd);
    let auto = run(KernelBackend::Auto);
    set_kernel_backend(KernelBackend::Auto);
    assert_eq!(scalar, simd, "forced SIMD diverged from scalar");
    assert_eq!(scalar, auto, "auto dispatch diverged from scalar");
}
