//! Fuzz-style robustness suite for `server/protocol.rs::parse_request`:
//! truncated lines, malformed JSON, wrong-typed fields, hostile nesting,
//! and oversized payloads must all return `Err` — never panic, never
//! silently mis-parse. (A panic inside a shard is survivable — the guard
//! catches it — but the *parser* runs on the connection thread, so it must
//! be panic-free on arbitrary bytes.)

use vqt::server::parse_request;
use vqt::testutil::check;
use vqt::util::Rng;

/// Canonical well-formed lines, one per protocol op — the fuzz corpus.
fn corpus() -> Vec<String> {
    vec![
        r#"{"op":"open","session":"s1","tokens":[1,2,3,4]}"#.into(),
        r#"{"op":"edit","session":"s1","kind":"replace","at":1,"tok":9}"#.into(),
        r#"{"op":"edit","session":"s1","kind":"insert","at":0,"tok":5}"#.into(),
        r#"{"op":"edit","session":"s1","kind":"delete","at":2}"#.into(),
        r#"{"op":"revision","session":"s1","tokens":[4,5,6]}"#.into(),
        r#"{"op":"batch_revisions","base":[1,2],"revisions":[[1,3],[2,2]]}"#.into(),
        r#"{"op":"dense","tokens":[7,8]}"#.into(),
        r#"{"op":"suggest","session":"s1","k":3}"#.into(),
        r#"{"op":"checkpoint","session":"s1","path":"x.vqss"}"#.into(),
        r#"{"op":"restore","session":"s1","path":"x.vqss"}"#.into(),
        r#"{"op":"suspend","session":"s1"}"#.into(),
        r#"{"op":"resume","session":"s1"}"#.into(),
        r#"{"op":"session_info","session":"s1"}"#.into(),
        r#"{"op":"close","session":"s1"}"#.into(),
        r#"{"op":"stats"}"#.into(),
    ]
}

/// Every canonical line parses (the corpus itself must be green, or the
/// truncation property below tests nothing).
#[test]
fn corpus_parses() {
    for line in corpus() {
        parse_request(&line).unwrap_or_else(|e| panic!("{line}: {e:#}"));
    }
}

/// Every proper prefix of a valid line is invalid JSON (the closing brace
/// is missing) and must yield a clean `Err`.
#[test]
fn truncated_lines_error_cleanly() {
    for line in corpus() {
        for cut in 0..line.len() {
            let prefix = &line[..cut];
            assert!(
                parse_request(prefix).is_err(),
                "prefix {prefix:?} unexpectedly parsed"
            );
        }
    }
}

/// Random single-byte corruptions never panic. (They may still parse —
/// flipping one digit keeps a line valid — so only panic-freedom and
/// error-display safety are asserted.)
#[test]
fn random_mutations_never_panic() {
    let corpus = corpus();
    check(
        "mutated lines",
        500,
        |r: &mut Rng| {
            let line = corpus[r.below(corpus.len())].clone();
            let pos = r.below(line.len());
            let byte = r.below(256) as u8;
            (line, pos, byte)
        },
        |(line, pos, byte)| {
            let mut bytes = line.clone().into_bytes();
            bytes[*pos] = *byte;
            // Corruption may break UTF-8; the wire layer only hands the
            // parser &str, so mirror that here.
            if let Ok(s) = std::str::from_utf8(&bytes) {
                if let Err(e) = parse_request(s) {
                    let _ = format!("{e:#}"); // error display must not panic either
                }
            }
        },
    );
}

/// Random garbage (not derived from valid lines) never panics.
#[test]
fn random_garbage_never_panics() {
    check(
        "garbage lines",
        500,
        |r: &mut Rng| {
            let n = r.below(120);
            // Bias toward structural bytes so we reach deep parser paths.
            let structural = b"{}[]\",:0123456789.eE+-tfn\\u";
            (0..n)
                .map(|_| {
                    if r.chance(0.7) {
                        structural[r.below(structural.len())]
                    } else {
                        r.below(128) as u8
                    }
                })
                .collect::<Vec<u8>>()
        },
        |bytes| {
            if let Ok(s) = std::str::from_utf8(bytes) {
                let _ = parse_request(s);
            }
        },
    );
}

/// Wrong-typed fields are rejected, not coerced.
#[test]
fn wrong_typed_fields_error() {
    let bad = [
        // session must be a string
        r#"{"op":"open","session":5,"tokens":[1]}"#,
        r#"{"op":"close","session":null}"#,
        r#"{"op":"suspend","session":[1]}"#,
        // tokens must be an array of u32-range integers
        r#"{"op":"open","session":"s","tokens":"abc"}"#,
        r#"{"op":"open","session":"s","tokens":[1.5]}"#,
        r#"{"op":"open","session":"s","tokens":[-1]}"#,
        r#"{"op":"open","session":"s","tokens":[true]}"#,
        r#"{"op":"open","session":"s","tokens":[[1]]}"#,
        r#"{"op":"open","session":"s","tokens":[4294967296]}"#,
        r#"{"op":"dense","tokens":{"a":1}}"#,
        // edit fields
        r#"{"op":"edit","session":"s","kind":"replace","at":"x","tok":1}"#,
        r#"{"op":"edit","session":"s","kind":"replace","at":0,"tok":"y"}"#,
        r#"{"op":"edit","session":"s","kind":"replace","at":0,"tok":1e18}"#,
        r#"{"op":"edit","session":"s","kind":5,"at":0,"tok":1}"#,
        r#"{"op":"edit","session":"s","kind":"replace","at":-2,"tok":1}"#,
        // batch shapes
        r#"{"op":"batch_revisions","base":[1],"revisions":[5]}"#,
        r#"{"op":"batch_revisions","base":[1],"revisions":[["x"]]}"#,
        r#"{"op":"batch_revisions","base":"nope","revisions":[]}"#,
        // op itself
        r#"{"op":7}"#,
        r#"{"op":null}"#,
        r#"{}"#,
        r#"[]"#,
        r#"null"#,
        r#""open""#,
    ];
    for line in bad {
        assert!(parse_request(line).is_err(), "{line} unexpectedly parsed");
    }
}

/// Oversized payloads: a line past the protocol cap is rejected by length
/// before JSON parsing; pathological nesting inside the cap is rejected by
/// the parser's depth limit. Neither panics or overflows the stack.
#[test]
fn oversized_and_hostile_payloads_error() {
    // Over the line cap.
    let huge = format!(
        r#"{{"op":"dense","tokens":[{}1]}}"#,
        "1,".repeat(1 << 20)
    );
    let err = parse_request(&huge).unwrap_err().to_string();
    assert!(err.contains("oversized"), "{err}");
    // Deep nesting under the cap: the recursive-descent parser must bail
    // at its depth limit, not blow the stack.
    let deep = format!(r#"{{"op":"open","session":"s","tokens":{}1{}}}"#,
        "[".repeat(200_000), "]".repeat(200_000));
    assert!(deep.len() <= 1 << 20, "test line accidentally over the cap");
    // `{:#}` prints the full context chain (the depth error is the cause
    // under the parser's "invalid JSON" context).
    let err = format!("{:#}", parse_request(&deep).unwrap_err());
    assert!(err.contains("nesting"), "{err}");
    // A large-but-legal line parses fine (the coordinator, not the parser,
    // enforces document-length limits).
    let big_ok = format!(
        r#"{{"op":"dense","tokens":[{}1]}}"#,
        "1,".repeat(10_000)
    );
    assert!(parse_request(&big_ok).is_ok());
}
