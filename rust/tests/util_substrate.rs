//! Integration coverage of the `util/` substrate through the PUBLIC API:
//! the in-crate replacements for `serde_json` (`util::Json`), the tensor
//! interchange format (`util::binfmt`), and the deterministic PRNG
//! (`util::Rng`). The in-module unit tests cover internals; these tests
//! pin the externally-visible contracts that the Python build path and
//! the wire protocol depend on.

use vqt::util::{Json, Rng, Tensor, TensorFile};

// --- util::json ----------------------------------------------------------

#[test]
fn json_parse_serialize_roundtrip() {
    let src = r#"{"op":"open","session":"s1","tokens":[1,2,3],"nested":{"x":null,"y":true,"z":-2.5}}"#;
    let v = Json::parse(src).unwrap();
    assert_eq!(v.get("op").as_str(), Some("open"));
    assert_eq!(v.get("tokens").as_arr().unwrap().len(), 3);
    assert_eq!(v.get("nested").get("y").as_bool(), Some(true));
    assert_eq!(v.get("nested").get("z").as_f64(), Some(-2.5));
    // Serialize → reparse is the identity.
    let round = Json::parse(&v.to_string()).unwrap();
    assert_eq!(round, v);
}

#[test]
fn json_serialization_is_deterministic_and_compact() {
    // Key order is canonical (BTreeMap) regardless of input order — the
    // property golden tests and reproducible manifests rely on.
    let a = Json::parse(r#"{"z":1,"m":{"b":2,"a":3},"a":[1,2]}"#).unwrap();
    let b = Json::parse(r#"{"a":[1,2],"m":{"a":3,"b":2},"z":1}"#).unwrap();
    assert_eq!(a, b);
    assert_eq!(a.to_string(), r#"{"a":[1,2],"m":{"a":3,"b":2},"z":1}"#);
    assert_eq!(a.to_string(), b.to_string());
}

#[test]
fn json_unicode_and_escape_roundtrip() {
    let s = "tabs\tquotes\" backslash\\ newline\n π 🦀";
    let j = Json::obj(vec![("text", Json::str(s))]);
    let back = Json::parse(&j.to_string()).unwrap();
    assert_eq!(back.get("text").as_str(), Some(s));
}

#[test]
fn json_rejects_malformed_input() {
    for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1 2", "\"unterminated"] {
        assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
    }
}

// --- util::binfmt --------------------------------------------------------

#[test]
fn tensor_file_roundtrips_through_disk() {
    let mut tf = TensorFile::new();
    tf.insert("w", Tensor::f32(vec![3, 2], vec![0.5, -1.5, 2.0, 3.25, -4.0, 1e-7]));
    tf.insert("ids", Tensor::i32(vec![5], vec![-2, -1, 0, 1, i32::MAX]));
    tf.insert("scalar", Tensor::f32(vec![], vec![42.0]));
    let path = std::env::temp_dir().join(format!("vqt_util_substrate_{}.bin", std::process::id()));
    tf.save(&path).unwrap();
    let back = TensorFile::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(back, tf);
    // Shape-checked access through the typed accessor.
    assert_eq!(back.f32_shaped("w", &[3, 2]).unwrap()[3], 3.25);
    assert!(back.f32_shaped("w", &[2, 3]).is_err());
    assert!(back.get("missing").is_err());
}

#[test]
fn tensor_file_bytes_are_deterministic() {
    // Two files with the same logical content serialize identically —
    // BTreeMap entry order makes artifacts reproducible byte-for-byte.
    let build = |order_flipped: bool| {
        let mut tf = TensorFile::new();
        let names = if order_flipped { ["b", "a"] } else { ["a", "b"] };
        for n in names {
            tf.insert(n, Tensor::i32(vec![2], vec![1, 2]));
        }
        let mut buf = Vec::new();
        tf.write_to(&mut buf).unwrap();
        buf
    };
    assert_eq!(build(false), build(true));
}

#[test]
fn tensor_file_rejects_truncated_stream() {
    let mut tf = TensorFile::new();
    tf.insert("w", Tensor::f32(vec![4], vec![1.0; 4]));
    let mut buf = Vec::new();
    tf.write_to(&mut buf).unwrap();
    let cut = buf.len() - 3;
    assert!(TensorFile::read_from(&mut &buf[..cut]).is_err());
}

// --- util::rng -----------------------------------------------------------

#[test]
fn rng_streams_are_deterministic_per_seed() {
    let draw = |seed: u64| -> Vec<u64> {
        let mut r = Rng::new(seed);
        (0..64).map(|_| r.next_u64()).collect()
    };
    assert_eq!(draw(2026), draw(2026), "same seed ⇒ same stream");
    assert_ne!(draw(2026), draw(2027), "different seed ⇒ different stream");
}

#[test]
fn rng_forked_streams_are_reproducible_and_independent() {
    let mut a = Rng::new(9);
    let mut b = Rng::new(9);
    let fa: Vec<u64> = {
        let mut f = a.fork(1);
        (0..16).map(|_| f.next_u64()).collect()
    };
    let fb: Vec<u64> = {
        let mut f = b.fork(1);
        (0..16).map(|_| f.next_u64()).collect()
    };
    assert_eq!(fa, fb, "forking is part of the deterministic protocol");
    let other: Vec<u64> = {
        let mut f = a.fork(2);
        (0..16).map(|_| f.next_u64()).collect()
    };
    assert_ne!(fa, other, "different fork tags diverge");
}

#[test]
fn rng_derived_draws_stay_in_contract() {
    let mut r = Rng::new(5);
    for _ in 0..2_000 {
        let n = r.range(1, 97);
        assert!(r.below(n) < n);
        let x = r.f64();
        assert!((0.0..1.0).contains(&x));
    }
    let subset = r.sorted_subset(100, 40);
    assert_eq!(subset.len(), 40);
    assert!(subset.windows(2).all(|w| w[0] < w[1]));
}
