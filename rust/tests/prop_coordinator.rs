//! Property tests over coordinator + engine invariants using the in-repo
//! mini property-testing framework (`testutil`).

use std::sync::Arc;
use vqt::config::{ModelConfig, ServeConfig};
use vqt::coordinator::{Backend, Coordinator, Request, Response};
use vqt::incremental::EngineOptions;
use vqt::model::ModelWeights;
use vqt::testutil::{check, gen_doc, gen_edit};
use vqt::util::Rng;

/// Invariant 1: for ANY edit script driven through the coordinator, the
/// session's final logits equal a dense recompute of the final document
/// (routing/batching/state management never corrupt engine state).
#[test]
fn prop_session_state_matches_dense_recompute() {
    let cfg = ModelConfig::vqt_tiny();
    let w = Arc::new(ModelWeights::random(&cfg, 11));
    let coordinator = Coordinator::start(
        Backend {
            weights: w.clone(),
            artifacts_dir: None,
            engine_opts: EngineOptions::default(),
        },
        ServeConfig::default(),
    );
    let client = coordinator.client();
    check(
        "session-matches-dense",
        6,
        |rng| {
            let doc = gen_doc(rng, 8, 24, cfg.vocab_size);
            let k = rng.range(1, 8);
            (doc, k, rng.next_u64())
        },
        |(doc, k, seed)| {
            let mut rng = Rng::new(*seed);
            let sid = format!("p{seed}");
            client
                .request(Request::Open {
                    session: sid.clone(),
                    tokens: doc.clone(),
                })
                .unwrap();
            let mut tracked = doc.clone();
            for _ in 0..*k {
                let e = gen_edit(&mut rng, tracked.len(), cfg.vocab_size, cfg.max_seq);
                tracked = vqt::edits::apply_edits(&tracked, &[e]);
                let r = client
                    .request(Request::Edit {
                        session: sid.clone(),
                        edit: e,
                    })
                    .unwrap();
                assert!(r.logits().is_ok(), "{r:?}");
            }
            // Submit the SAME document as a revision: the diff must be
            // empty and the request near-free.
            let r = client
                .request(Request::Revision {
                    session: sid.clone(),
                    tokens: tracked.clone(),
                })
                .unwrap();
            match r {
                Response::Logits { flops, .. } => {
                    assert!(flops < 100_000, "no-op revision cost {flops}")
                }
                other => panic!("{other:?}"),
            }
            client.request(Request::Close { session: sid }).unwrap();
        },
    );
}

/// Invariant 2: revision requests converge — after a Revision{tokens},
/// the session document equals `tokens` exactly, for arbitrary pairs.
#[test]
fn prop_revision_converges_to_target() {
    let cfg = ModelConfig::vqt_tiny();
    let w = Arc::new(ModelWeights::random(&cfg, 13));
    let coordinator = Coordinator::start(
        Backend {
            weights: w.clone(),
            artifacts_dir: None,
            engine_opts: EngineOptions {
                score_trick: true,
                // Self-verification each revision: any state corruption
                // inside diff-apply would be caught and logged here.
                verify_every: 1,
                ..EngineOptions::default()
            },
        },
        ServeConfig::default(),
    );
    let client = coordinator.client();
    check(
        "revision-converges",
        6,
        |rng| {
            let a = gen_doc(rng, 6, 20, cfg.vocab_size);
            let b = gen_doc(rng, 6, 20, cfg.vocab_size);
            (a, b, rng.next_u64())
        },
        |(a, b, seed)| {
            let sid = format!("rc{seed}");
            client
                .request(Request::Open {
                    session: sid.clone(),
                    tokens: a.clone(),
                })
                .unwrap();
            let r = client
                .request(Request::Revision {
                    session: sid.clone(),
                    tokens: b.clone(),
                })
                .unwrap();
            assert!(r.logits().is_ok(), "{r:?}");
            // A second identical revision must be a no-op.
            let r2 = client
                .request(Request::Revision {
                    session: sid.clone(),
                    tokens: b.clone(),
                })
                .unwrap();
            match r2 {
                Response::Logits { flops, .. } => {
                    assert!(flops < 100_000, "second revision not a no-op: {flops}")
                }
                other => panic!("{other:?}"),
            }
            client.request(Request::Close { session: sid }).unwrap();
        },
    );
}

/// Invariant 3: batch revisions give the same logits as processing each
/// revision in its own session.
#[test]
fn prop_batch_matches_individual_sessions() {
    let cfg = ModelConfig::vqt_tiny();
    let w = Arc::new(ModelWeights::random(&cfg, 17));
    let coordinator = Coordinator::start(
        Backend {
            weights: w.clone(),
            artifacts_dir: None,
            engine_opts: EngineOptions::default(),
        },
        ServeConfig::default(),
    );
    let client = coordinator.client();
    check(
        "batch-matches-individual",
        4,
        |rng| {
            let base = gen_doc(rng, 10, 20, cfg.vocab_size);
            let revisions: Vec<Vec<u32>> = (0..3)
                .map(|_| {
                    let mut r = base.clone();
                    let e = gen_edit(rng, r.len(), cfg.vocab_size, cfg.max_seq);
                    r = vqt::edits::apply_edits(&r, &[e]);
                    r
                })
                .collect();
            (base, revisions)
        },
        |(base, revisions)| {
            let resp = client
                .request(Request::BatchRevisions {
                    base: base.clone(),
                    revisions: revisions.clone(),
                })
                .unwrap();
            let batch_logits = match resp {
                Response::BatchLogits { each, .. } => each,
                other => panic!("{other:?}"),
            };
            for (i, rev) in revisions.iter().enumerate() {
                let sid = format!("ind{i}");
                client
                    .request(Request::Open {
                        session: sid.clone(),
                        tokens: base.clone(),
                    })
                    .unwrap();
                let r = client
                    .request(Request::Revision {
                        session: sid.clone(),
                        tokens: rev.clone(),
                    })
                    .unwrap();
                let ind = r.logits().unwrap();
                for (a, b) in batch_logits[i].iter().zip(ind) {
                    assert!(
                        (a - b).abs() < 1e-4,
                        "batch {a} vs individual {b} (rev {i})"
                    );
                }
                client.request(Request::Close { session: sid }).unwrap();
            }
        },
    );
}

/// Invariant 4: backpressure — with a tiny queue and a stalled worker, the
/// non-blocking path rejects rather than buffering unboundedly.
#[test]
fn prop_backpressure_rejects_when_full() {
    let cfg = ModelConfig::vqt_tiny();
    let w = Arc::new(ModelWeights::random(&cfg, 19));
    let mut sc = ServeConfig::default();
    sc.queue_capacity = 1;
    sc.max_batch = 1;
    sc.batch_deadline_ms = 0;
    let coordinator = Coordinator::start(
        Backend {
            weights: w.clone(),
            artifacts_dir: None,
            engine_opts: EngineOptions::default(),
        },
        sc,
    );
    let client = coordinator.client();
    // Saturate with big Opens from another thread (blocking path), then
    // observe at least one try_request rejection.
    let c2 = client.clone();
    let t = std::thread::spawn(move || {
        for i in 0..8 {
            let tokens: Vec<u32> = (0..60).map(|j| ((i + j) % 60) as u32).collect();
            let _ = c2.request(Request::Open {
                session: format!("bp{i}"),
                tokens,
            });
        }
    });
    let mut rejected = 0;
    for _ in 0..200 {
        if client.try_request(Request::Stats).is_err() {
            rejected += 1;
        }
    }
    t.join().unwrap();
    assert!(rejected > 0, "expected at least one backpressure rejection");
}
