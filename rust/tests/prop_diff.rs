//! Property tests for `edits/diff.rs` (the offline revision-alignment
//! path): on random token sequences, applying the diff always reproduces
//! the target, and for single-splice edits the script is minimal.
//!
//! Uses the in-crate seeded property harness (`vqt::testutil::check`), so
//! every failure reports the generating seed and reproduces exactly.

use vqt::edits::{apply_edits, diff_tokens, edit_distance, Edit};
use vqt::testutil::{check, gen_doc};
use vqt::util::Rng;

/// `apply(a, diff(a, b)) == b` for arbitrary (a, b), including empty and
/// wildly different lengths.
#[test]
fn prop_apply_diff_roundtrips() {
    check(
        "apply∘diff = id",
        300,
        |r: &mut Rng| {
            let a = gen_doc(r, 0, 48, 12); // small vocab ⇒ many repeats
            let b = gen_doc(r, 0, 48, 12);
            (a, b)
        },
        |(a, b)| {
            let script = diff_tokens(a, b);
            assert_eq!(&apply_edits(a, &script), b, "script {script:?}");
        },
    );
}

/// Identical sequences produce the empty script, and the script length is
/// always sandwiched by the LCS distance: `dist/2 ≤ len ≤ dist`
/// (replacements count 2 in the distance but 1 in the script).
#[test]
fn prop_script_length_tracks_distance() {
    check(
        "len vs distance",
        300,
        |r: &mut Rng| {
            let a = gen_doc(r, 0, 40, 8);
            let b = gen_doc(r, 0, 40, 8);
            (a, b)
        },
        |(a, b)| {
            let dist = edit_distance(a, b);
            let len = diff_tokens(a, b).len();
            assert!(len <= dist, "script {len} > distance {dist}");
            assert!(2 * len >= dist, "script {len} impossibly short for {dist}");
            if a == b {
                assert_eq!(len, 0);
            }
        },
    );
}

/// Single-splice minimality, insertion flavor: splicing `m` fresh tokens
/// (disjoint vocab, so nothing accidentally matches) into `a` yields
/// exactly `m` inserts — no spurious deletes, no detours.
#[test]
fn prop_single_splice_insert_is_minimal() {
    check(
        "splice-insert minimal",
        200,
        |r: &mut Rng| {
            let a = gen_doc(r, 1, 40, 30);
            let at = r.below(a.len() + 1);
            let m = r.range(1, 6);
            // Fresh tokens from a disjoint range: a uses [0,30), these use
            // [100,130).
            let fresh: Vec<u32> = (0..m).map(|_| 100 + r.below(30) as u32).collect();
            (a, at, fresh)
        },
        |(a, at, fresh)| {
            let mut b = a.clone();
            for (k, &t) in fresh.iter().enumerate() {
                b.insert(at + k, t);
            }
            assert_eq!(edit_distance(a, &b), fresh.len(), "distance must be m");
            let script = diff_tokens(a, &b);
            assert_eq!(script.len(), fresh.len(), "minimal script is m inserts");
            assert!(
                script.iter().all(|e| matches!(e, Edit::Insert { .. })),
                "{script:?}"
            );
            assert_eq!(&apply_edits(a, &script), &b);
        },
    );
}

/// Single-splice minimality, deletion flavor: removing a contiguous run of
/// `k` tokens yields exactly `k` deletes.
#[test]
fn prop_single_splice_delete_is_minimal() {
    check(
        "splice-delete minimal",
        200,
        |r: &mut Rng| {
            let a = gen_doc(r, 2, 40, 30);
            let k = r.range(1, a.len().min(6));
            let at = r.below(a.len() - k + 1);
            (a, at, k)
        },
        |(a, at, k)| {
            let (at, k) = (*at, *k);
            let mut b = a.clone();
            b.drain(at..at + k);
            // The run's tokens may also occur elsewhere, so distance is at
            // MOST k — and a length difference of k means at LEAST k.
            assert_eq!(edit_distance(a, &b), k);
            let script = diff_tokens(a, &b);
            assert_eq!(script.len(), k, "minimal script is k deletes: {script:?}");
            assert!(script.iter().all(|e| matches!(e, Edit::Delete { .. })));
            assert_eq!(&apply_edits(a, &script), &b);
        },
    );
}

/// A document of distinct tokens (values < 80, disjoint from the fresh
/// range [100, 130)). Distinctness makes the optimal LCS alignment unique,
/// which is what makes the exact-fusion claims below provable; with
/// repeated neighbors the diff is still correct and minimal in *distance*,
/// but may legitimately choose a non-fused del+ins pair.
fn gen_distinct(r: &mut Rng, min_len: usize, max_len: usize) -> Vec<u32> {
    let n = r.range(min_len, max_len);
    let off = r.below(40) as u32;
    (0..n as u32).map(|i| off + i).collect()
}

/// Single-token replacement with a fresh value fuses into exactly one
/// `Replace` (the engine-cheap form — no position-pool traffic).
#[test]
fn prop_single_replace_fuses() {
    check(
        "replace fuses",
        200,
        |r: &mut Rng| {
            let a = gen_distinct(r, 1, 40);
            let at = r.below(a.len());
            let tok = 100 + r.below(30) as u32;
            (a, at, tok)
        },
        |(a, at, tok)| {
            let (at, tok) = (*at, *tok);
            let mut b = a.clone();
            b[at] = tok;
            let script = diff_tokens(a, &b);
            assert_eq!(script, vec![Edit::Replace { at, tok }], "exact fusion");
            assert_eq!(edit_distance(a, &b), 2, "LCS counts replace as del+ins");
        },
    );
}

/// Replacing a contiguous run of k distinct tokens with k fresh tokens:
/// distance is exactly 2k, and the boundary Replace fusion brings the
/// script to at most 2k−1 edits (k of them at least).
#[test]
fn prop_block_replace_bounds() {
    check(
        "block replace bounds",
        200,
        |r: &mut Rng| {
            let a = gen_distinct(r, 2, 40);
            let k = r.range(1, a.len().min(5));
            let at = r.below(a.len() - k + 1);
            let fresh: Vec<u32> = (0..k).map(|_| 100 + r.below(30) as u32).collect();
            (a, at, fresh)
        },
        |(a, at, fresh)| {
            let k = fresh.len();
            let mut b = a.clone();
            b[*at..*at + k].copy_from_slice(fresh);
            assert_eq!(edit_distance(a, &b), 2 * k);
            let script = diff_tokens(a, &b);
            assert!(
                script.len() >= k && script.len() < 2 * k,
                "k={k}: script {script:?}"
            );
            assert_eq!(&apply_edits(a, &script), &b);
        },
    );
}
