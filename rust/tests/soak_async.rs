//! Soak smoke for the readiness-driven front end: ≥1k truly concurrent
//! connections served on a fixed, small thread count. Ignored by default
//! (CI runs it in the `--ignored` tier with `--release`).
#![cfg(target_os = "linux")]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use vqt::config::{ModelConfig, ServeConfig};
use vqt::coordinator::{Backend, Coordinator};
use vqt::incremental::EngineOptions;
use vqt::model::ModelWeights;
use vqt::server::{AsyncServer, FrontendOptions};
use vqt::util::Json;

const CONNS: usize = 1000;

/// Current thread count of this process (server + test harness combined),
/// from `/proc/self/status` — the soak's whole point is that this number
/// stays O(io_threads + workers), not O(connections).
fn process_threads() -> usize {
    let status = std::fs::read_to_string("/proc/self/status").unwrap();
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
        .unwrap()
}

/// Best-effort `RLIMIT_NOFILE` bump: 1k client + 1k server sockets need
/// ~2k fds, and some CI soft limits sit at 1024. Declared directly against
/// the libc `std` links (same zero-dep approach as `server::poll`).
fn raise_fd_limit() {
    #[repr(C)]
    struct Rlimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    let mut lim = Rlimit { cur: 0, max: 0 };
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } == 0 && lim.cur < lim.max {
        lim.cur = lim.max;
        unsafe { setrlimit(RLIMIT_NOFILE, &lim) };
    }
}

#[test]
#[ignore = "soak: 1k concurrent connections; run with --ignored"]
fn thousand_concurrent_connections_on_a_fixed_thread_budget() {
    raise_fd_limit();
    let cfg = ModelConfig::vqt_tiny();
    let w = Arc::new(ModelWeights::random(&cfg, 5));
    let mut sc = ServeConfig::default();
    sc.workers = 2;
    // Size the shard queues for the full burst: this soak measures thread
    // scaling, not load shedding (shedding has its own differential test).
    sc.queue_capacity = 4 * CONNS;
    let c = Coordinator::start(
        Backend {
            weights: w,
            artifacts_dir: None,
            engine_opts: EngineOptions::default(),
        },
        sc,
    );
    let server = AsyncServer::start(
        "127.0.0.1:0",
        c.client(),
        FrontendOptions {
            io_threads: 2,
            max_connections: 0,
            max_inflight_per_conn: 4,
            trace_buffer: 0,
        },
    )
    .unwrap();
    let baseline_threads = process_threads();

    // Establish every connection and put one request on each wire before
    // reading any reply: all CONNS connections are concurrently open and
    // concurrently in flight.
    let mut conns = Vec::with_capacity(CONNS);
    for i in 0..CONNS {
        let mut s = TcpStream::connect(server.local_addr())
            .unwrap_or_else(|e| panic!("connect {i}: {e}"));
        s.set_read_timeout(Some(std::time::Duration::from_secs(60)))
            .unwrap();
        let t = i % 60;
        s.write_all(format!("{{\"op\":\"dense\",\"tokens\":[{t},1,2,3]}}\n").as_bytes())
            .unwrap();
        conns.push(s);
    }

    // The thread count is a budget, not a function of load: with every
    // connection open, the process grew by ZERO threads per connection.
    let peak_threads = process_threads();
    assert!(
        peak_threads <= baseline_threads + 4,
        "thread count grew with connections: {baseline_threads} -> {peak_threads}"
    );

    let mut ok = 0usize;
    for (i, s) in conns.iter_mut().enumerate() {
        let mut reader = BufReader::new(s);
        let mut line = String::new();
        assert!(
            reader.read_line(&mut line).unwrap_or_else(|e| panic!("read {i}: {e}")) > 0,
            "conn {i}: server hung up"
        );
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("ok").as_bool(), Some(true), "conn {i}: {line}");
        assert!(j.get("logits").as_arr().is_some(), "conn {i}: {line}");
        ok += 1;
    }
    assert_eq!(ok, CONNS);

    let stats = server.stats();
    assert_eq!(
        stats.connections_accepted.load(Ordering::Relaxed) as usize,
        CONNS
    );
    assert_eq!(
        stats.connections.load(Ordering::Relaxed) as usize,
        CONNS,
        "every connection still concurrently open"
    );
    assert_eq!(stats.connections_rejected.load(Ordering::Relaxed), 0);

    drop(conns);
    server.shutdown();
    c.shutdown();
}
