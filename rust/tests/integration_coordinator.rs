//! Coordinator integration: sessions, edits, revisions, batch processing,
//! backpressure, eviction, and the TCP server end-to-end.

use std::sync::Arc;
use vqt::config::{ModelConfig, ServeConfig};
use vqt::coordinator::{Backend, Coordinator, Request, Response};
use vqt::edits::Edit;
use vqt::incremental::EngineOptions;
use vqt::model::ModelWeights;
use vqt::util::Rng;

fn start(cfg_mut: impl FnOnce(&mut ServeConfig)) -> Coordinator {
    let cfg = ModelConfig::vqt_tiny();
    let w = Arc::new(ModelWeights::random(&cfg, 5));
    let mut sc = ServeConfig::default();
    cfg_mut(&mut sc);
    Coordinator::start(
        Backend {
            weights: w,
            artifacts_dir: None,
            engine_opts: EngineOptions::default(),
        },
        sc,
    )
}

fn temp_spill_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("vqt_itest_spill_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn doc(seed: u64, n: usize) -> Vec<u32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.below(60) as u32).collect()
}

#[test]
fn open_edit_close_lifecycle() {
    let c = start(|_| {});
    let client = c.client();
    let r = client
        .request(Request::Open {
            session: "s1".into(),
            tokens: doc(1, 20),
        })
        .unwrap();
    assert!(r.logits().is_ok());
    let r = client
        .request(Request::Edit {
            session: "s1".into(),
            edit: Edit::Replace { at: 2, tok: 9 },
        })
        .unwrap();
    match &r {
        Response::Logits {
            flops,
            dense_equiv_flops,
            ..
        } => assert!(flops < dense_equiv_flops),
        other => panic!("{other:?}"),
    }
    match client
        .request(Request::Close {
            session: "s1".into(),
        })
        .unwrap()
    {
        Response::Closed { existed } => assert!(existed),
        other => panic!("{other:?}"),
    }
    let r = client
        .request(Request::Edit {
            session: "s1".into(),
            edit: Edit::Delete { at: 0 },
        })
        .unwrap();
    assert!(matches!(r, Response::Err(_)));
}

#[test]
fn revision_request_diffs_and_saves_flops() {
    let c = start(|_| {});
    let client = c.client();
    let base = doc(2, 24);
    client
        .request(Request::Open {
            session: "r".into(),
            tokens: base.clone(),
        })
        .unwrap();
    let mut rev = base.clone();
    rev[3] = 59;
    rev.insert(10, 7);
    rev.remove(20);
    let r = client
        .request(Request::Revision {
            session: "r".into(),
            tokens: rev.clone(),
        })
        .unwrap();
    let incr_logits = r.logits().unwrap().to_vec();
    assert!(incr_logits.iter().all(|x| x.is_finite()));
    match r {
        Response::Logits {
            flops,
            dense_equiv_flops,
            ..
        } => assert!(flops < dense_equiv_flops, "{flops} !< {dense_equiv_flops}"),
        _ => unreachable!(),
    }
    // Dense path still works alongside.
    let d = client.request(Request::Dense { tokens: rev }).unwrap();
    assert_eq!(d.logits().unwrap().len(), incr_logits.len());
}

#[test]
fn batch_revisions_storage_compression() {
    let c = start(|_| {});
    let client = c.client();
    let base = doc(3, 32);
    let mut rng = Rng::new(9);
    let revisions: Vec<Vec<u32>> = (0..6)
        .map(|_| {
            let mut r = base.clone();
            let at = rng.below(r.len());
            r[at] = rng.below(60) as u32;
            r
        })
        .collect();
    let resp = client
        .request(Request::BatchRevisions {
            base: base.clone(),
            revisions: revisions.clone(),
        })
        .unwrap();
    match resp {
        Response::BatchLogits {
            each,
            flops,
            dense_equiv_flops,
            storage,
        } => {
            assert_eq!(each.len(), 6);
            assert!(flops < dense_equiv_flops);
            // §3.1: compressed storage ≪ dense for a revision batch.
            assert!(
                storage.0 * 2 < storage.1,
                "storage {} vs dense {}",
                storage.0,
                storage.1
            );
        }
        other => panic!("{other:?}"),
    }
}

#[test]
fn lru_eviction_under_session_pressure() {
    let c = start(|sc| sc.max_sessions = 2);
    let client = c.client();
    for i in 0..4 {
        client
            .request(Request::Open {
                session: format!("s{i}"),
                tokens: doc(i as u64, 12),
            })
            .unwrap();
    }
    let r = client
        .request(Request::Edit {
            session: "s0".into(),
            edit: Edit::Replace { at: 0, tok: 1 },
        })
        .unwrap();
    assert!(matches!(r, Response::Err(_)), "s0 must be evicted");
    let r = client
        .request(Request::Edit {
            session: "s3".into(),
            edit: Edit::Replace { at: 0, tok: 1 },
        })
        .unwrap();
    assert!(r.logits().is_ok(), "s3 must be live");
}

#[test]
fn malformed_edit_is_typed_error_session_survives() {
    let c = start(|sc| sc.workers = 2);
    let client = c.client();
    client
        .request(Request::Open {
            session: "a".into(),
            tokens: doc(1, 16),
        })
        .unwrap();
    client
        .request(Request::Open {
            session: "b".into(),
            tokens: doc(2, 16),
        })
        .unwrap();
    // An out-of-bounds edit is rejected by typed pre-validation BEFORE it
    // can trip the engine's asserts: the caller gets a descriptive error,
    // the session keeps its state, and no panic is recorded.
    let r = client
        .request(Request::Edit {
            session: "a".into(),
            edit: Edit::Replace { at: 10_000, tok: 1 },
        })
        .unwrap();
    match &r {
        Response::Err(e) => assert!(e.contains("out of bounds"), "error lacks cause: {e}"),
        other => panic!("expected Err, got {other:?}"),
    }
    // The rejected session is still alive and serviceable...
    let r = client
        .request(Request::Edit {
            session: "a".into(),
            edit: Edit::Replace { at: 0, tok: 1 },
        })
        .unwrap();
    assert!(r.logits().is_ok(), "rejected edit must not cost the session: {r:?}");
    // ...as is everyone else.
    let r = client
        .request(Request::Edit {
            session: "b".into(),
            edit: Edit::Replace { at: 0, tok: 1 },
        })
        .unwrap();
    assert!(r.logits().is_ok(), "{r:?}");
    // The merged snapshot shows a typed error, zero panics, both sessions.
    match client.request(Request::Stats).unwrap() {
        Response::Stats(j) => {
            assert_eq!(j.get("panics").as_usize(), Some(0));
            assert!(j.get("errors").as_usize().unwrap() >= 1);
            assert_eq!(j.get("live_sessions").as_usize(), Some(2));
        }
        other => panic!("{other:?}"),
    }
}

/// The empty-document sweep: every verb a client can point at an empty or
/// emptied document returns a typed error (or a well-defined empty reply),
/// with zero worker panics across the whole sweep.
#[test]
fn empty_document_paths_are_typed_errors_not_panics() {
    let c = start(|_| {});
    let client = c.client();
    // open with [] → typed error (already covered; re-checked in-sweep).
    let r = client
        .request(Request::Open {
            session: "e".into(),
            tokens: vec![],
        })
        .unwrap();
    assert!(matches!(r, Response::Err(_)));
    // A real session, edited down to one token: the delete that would
    // empty it is refused, so a document can never become empty.
    client
        .request(Request::Open {
            session: "e".into(),
            tokens: vec![5, 6],
        })
        .unwrap();
    let r = client
        .request(Request::EditScript {
            session: "e".into(),
            edits: vec![Edit::Delete { at: 0 }, Edit::Delete { at: 0 }],
        })
        .unwrap();
    match &r {
        Response::Err(e) => assert!(e.contains("cannot delete the last token"), "{e}"),
        other => panic!("{other:?}"),
    }
    // revision to [] → typed error; suggest still works after all this.
    let r = client
        .request(Request::Revision {
            session: "e".into(),
            tokens: vec![],
        })
        .unwrap();
    match &r {
        Response::Err(e) => assert!(e.contains("empty revision"), "{e}"),
        other => panic!("{other:?}"),
    }
    match client
        .request(Request::Suggest {
            session: "e".into(),
            k: 3,
        })
        .unwrap()
    {
        Response::Suggestions(top) => assert_eq!(top.len(), 3),
        other => panic!("{other:?}"),
    }
    // dense with [] and batch_revisions with an empty member → typed.
    let r = client.request(Request::Dense { tokens: vec![] }).unwrap();
    assert!(matches!(r, Response::Err(_)));
    let r = client
        .request(Request::BatchRevisions {
            base: vec![1, 2, 3],
            revisions: vec![vec![1, 2], vec![]],
        })
        .unwrap();
    match &r {
        Response::Err(e) => assert!(e.contains("empty revision"), "{e}"),
        other => panic!("{other:?}"),
    }
    // The whole sweep cost zero panics.
    match client.request(Request::Stats).unwrap() {
        Response::Stats(j) => assert_eq!(j.get("panics").as_usize(), Some(0)),
        other => panic!("{other:?}"),
    }
}

#[test]
fn invalid_requests_surface_errors_not_panics() {
    let c = start(|_| {});
    let client = c.client();
    let r = client
        .request(Request::Open {
            session: "x".into(),
            tokens: vec![],
        })
        .unwrap();
    assert!(matches!(r, Response::Err(_)));
    let r = client
        .request(Request::Revision {
            session: "nope".into(),
            tokens: doc(1, 5),
        })
        .unwrap();
    assert!(matches!(r, Response::Err(_)));
    let r = client
        .request(Request::Open {
            session: "y".into(),
            tokens: doc(2, ModelConfig::vqt_tiny().max_seq + 1),
        })
        .unwrap();
    assert!(matches!(r, Response::Err(_)));
}

#[test]
fn vq_less_weights_error_instead_of_panicking_the_worker() {
    // A weights file whose config promises VQ but whose layer carries no
    // codebooks must surface as a typed request error ("layer N has no VQ
    // config"), not a worker panic (regression: `vq.as_ref().unwrap()`).
    let cfg = ModelConfig::vqt_tiny();
    let mut w = ModelWeights::random(&cfg, 5);
    w.layers[1].vq = None;
    let c = Coordinator::start(
        Backend {
            weights: Arc::new(w),
            artifacts_dir: None,
            engine_opts: EngineOptions::default(),
        },
        ServeConfig::default(),
    );
    let client = c.client();
    let r = client
        .request(Request::Open {
            session: "s".into(),
            tokens: doc(1, 12),
        })
        .unwrap();
    match r {
        Response::Err(e) => assert!(e.contains("layer 1 has no VQ config"), "{e}"),
        other => panic!("expected typed error, got {other:?}"),
    }
    let r = client
        .request(Request::BatchRevisions {
            base: doc(2, 10),
            revisions: vec![doc(3, 10)],
        })
        .unwrap();
    assert!(matches!(r, Response::Err(_)));
    // The shard survived both failures: typed errors, zero panics.
    match client.request(Request::Stats).unwrap() {
        Response::Stats(j) => {
            assert_eq!(j.get("panics").as_usize(), Some(0));
            assert!(j.get("errors").as_usize().unwrap() >= 2);
            // And the resolved kernel backend is reported for operators.
            let kb = j.get("kernel_backend").as_str().unwrap();
            assert!(["scalar", "avx2", "neon"].contains(&kb), "{kb}");
        }
        other => panic!("{other:?}"),
    }
}

#[test]
fn stats_track_speedup() {
    let c = start(|_| {});
    let client = c.client();
    client
        .request(Request::Open {
            session: "m".into(),
            tokens: doc(4, 40),
        })
        .unwrap();
    for i in 0..5 {
        client
            .request(Request::Edit {
                session: "m".into(),
                edit: Edit::Replace {
                    at: 30 + i,
                    tok: i as u32,
                },
            })
            .unwrap();
    }
    match client.request(Request::Stats).unwrap() {
        Response::Stats(j) => {
            let speedup = j.get("speedup").as_f64().unwrap();
            assert!(speedup > 1.0, "aggregate speedup {speedup}");
            assert_eq!(j.get("edits").as_usize(), Some(5));
            assert_eq!(j.get("live_sessions").as_usize(), Some(1));
        }
        other => panic!("{other:?}"),
    }
}

#[test]
fn tcp_server_end_to_end() {
    use std::io::{BufRead, BufReader, Write};
    let c = start(|_| {});
    let client = c.client();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let _ = vqt::server::handle_conn(stream, client);
    });
    let mut conn = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut send = |line: &str| -> vqt::util::Json {
        conn.write_all(line.as_bytes()).unwrap();
        conn.write_all(b"\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        vqt::util::Json::parse(&resp).unwrap()
    };
    let j = send(r#"{"op":"open","session":"t","tokens":[1,2,3,4,5,6,7,8]}"#);
    assert_eq!(j.get("ok").as_bool(), Some(true));
    let j = send(r#"{"op":"edit","session":"t","kind":"replace","at":2,"tok":40}"#);
    assert_eq!(j.get("ok").as_bool(), Some(true));
    assert!(j.get("speedup").as_f64().unwrap() > 1.0);
    let j = send(r#"{"op":"edit","session":"t","kind":"insert","at":0,"tok":1}"#);
    assert_eq!(j.get("ok").as_bool(), Some(true));
    let j = send(r#"{"op":"stats"}"#);
    assert_eq!(j.get("stats").get("edits").as_usize(), Some(2));
    let j = send(r#"{"op":"oops"}"#);
    assert_eq!(j.get("ok").as_bool(), Some(false));
}

#[test]
fn suggest_checkpoint_restore_cycle() {
    let ckpt_dir = std::env::temp_dir().join(format!("vqt_itest_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let c = start(|sc| sc.checkpoint_dir = ckpt_dir.to_str().unwrap().to_string());
    let client = c.client();
    let tokens = doc(20, 24);
    client
        .request(Request::Open {
            session: "cp".into(),
            tokens: tokens.clone(),
        })
        .unwrap();
    // Suggestions come back sorted.
    match client
        .request(Request::Suggest {
            session: "cp".into(),
            k: 4,
        })
        .unwrap()
    {
        Response::Suggestions(top) => {
            assert_eq!(top.len(), 4);
            assert!(top.windows(2).all(|p| p[0].1 >= p[1].1));
        }
        other => panic!("{other:?}"),
    }
    // Edit, checkpoint, close, restore, and verify state carried over.
    let r = client
        .request(Request::Edit {
            session: "cp".into(),
            edit: Edit::Replace { at: 3, tok: 7 },
        })
        .unwrap();
    let logits_before = r.logits().unwrap().to_vec();
    // Checkpoint names are bare filenames, confined to checkpoint_dir.
    assert!(matches!(
        client
            .request(Request::Checkpoint {
                session: "cp".into(),
                path: "cp.vqss".into(),
            })
            .unwrap(),
        Response::Done
    ));
    assert!(ckpt_dir.join("cp.vqss").exists(), "checkpoint lands in checkpoint_dir");
    client
        .request(Request::Close {
            session: "cp".into(),
        })
        .unwrap();
    assert!(matches!(
        client
            .request(Request::Restore {
                session: "cp2".into(),
                path: "cp.vqss".into(),
            })
            .unwrap(),
        Response::Done
    ));
    // The restored session continues from the same state.
    let r = client
        .request(Request::Edit {
            session: "cp2".into(),
            edit: Edit::Replace { at: 3, tok: 7 }, // no-op value change? same token: engine treats as modified
        })
        .unwrap();
    let logits_after = r.logits().unwrap();
    for (a, b) in logits_before.iter().zip(logits_after) {
        assert!((a - b).abs() < 1e-4, "restored state diverged: {a} vs {b}");
    }
    // Escapes are typed errors, not filesystem writes: traversal,
    // absolute paths, and any separator-bearing name are all refused.
    for evil in ["../evil.bin", "/tmp/evil.bin", "sub/dir.bin", "..", ""] {
        let r = client
            .request(Request::Checkpoint {
                session: "cp2".into(),
                path: evil.into(),
            })
            .unwrap();
        match &r {
            Response::Err(_) => {}
            other => panic!("checkpoint {evil:?} must be rejected, got {other:?}"),
        }
        let r = client
            .request(Request::Restore {
                session: "cp3".into(),
                path: evil.into(),
            })
            .unwrap();
        assert!(matches!(r, Response::Err(_)), "restore {evil:?} must be rejected");
    }
    let _ = std::fs::remove_dir_all(&ckpt_dir);
}

/// With no `checkpoint_dir` configured, the checkpoint/restore verbs are
/// disabled outright — a typed error, never a write relative to the
/// server's cwd.
#[test]
fn checkpoint_disabled_without_configured_dir() {
    let c = start(|_| {});
    let client = c.client();
    client
        .request(Request::Open {
            session: "nd".into(),
            tokens: doc(21, 12),
        })
        .unwrap();
    let r = client
        .request(Request::Checkpoint {
            session: "nd".into(),
            path: "cp.vqss".into(),
        })
        .unwrap();
    match &r {
        Response::Err(e) => assert!(e.contains("no checkpoint_dir"), "{e}"),
        other => panic!("{other:?}"),
    }
}

/// Restoring on top of an existing session replaces the old incarnation
/// cleanly: the resident engine (or its spill file) is released, the
/// restore is counted under `sessions_restored` — not double-counted as a
/// fresh `sessions_opened` — and the gauges stay truthful.
#[test]
fn restore_over_existing_session_replaces_cleanly() {
    let ckpt_dir = std::env::temp_dir().join(format!("vqt_itest_ckptover_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let spill = temp_spill_dir("ckptover");
    let c = start(|sc| {
        sc.checkpoint_dir = ckpt_dir.to_str().unwrap().to_string();
        sc.spill_dir = spill.to_str().unwrap().to_string();
        sc.workers = 1; // same shard for both sessions: deterministic stats
    });
    let client = c.client();
    let stats = |client: &vqt::coordinator::Client| match client.request(Request::Stats).unwrap() {
        Response::Stats(j) => j,
        other => panic!("{other:?}"),
    };
    client
        .request(Request::Open {
            session: "a".into(),
            tokens: doc(40, 16),
        })
        .unwrap();
    client
        .request(Request::Checkpoint {
            session: "a".into(),
            path: "a.vqss".into(),
        })
        .unwrap();
    let opened_before = stats(&client).get("sessions_opened").as_usize().unwrap();

    // Restore over the RESIDENT incarnation of "a".
    client
        .request(Request::Edit {
            session: "a".into(),
            edit: Edit::Replace { at: 0, tok: 3 },
        })
        .unwrap();
    assert!(matches!(
        client
            .request(Request::Restore {
                session: "a".into(),
                path: "a.vqss".into(),
            })
            .unwrap(),
        Response::Done
    ));
    let j = stats(&client);
    assert_eq!(j.get("sessions_restored").as_usize(), Some(1));
    assert_eq!(
        j.get("sessions_opened").as_usize(),
        Some(opened_before),
        "restore must not inflate sessions_opened"
    );
    assert_eq!(j.get("live_sessions").as_usize(), Some(1));

    // Restore over a SUSPENDED incarnation: the old spill file must not
    // leak — the replaced incarnation's state is released with it.
    assert!(matches!(
        client
            .request(Request::Suspend {
                session: "a".into(),
            })
            .unwrap(),
        Response::Done
    ));
    let spilled = |dir: &std::path::Path| -> usize {
        std::fs::read_dir(dir)
            .map(|rd| rd.filter_map(|e| e.ok()).count())
            .unwrap_or(0)
    };
    assert_eq!(spilled(&spill), 1, "suspend writes exactly one spill file");
    assert!(matches!(
        client
            .request(Request::Restore {
                session: "a".into(),
                path: "a.vqss".into(),
            })
            .unwrap(),
        Response::Done
    ));
    let j = stats(&client);
    assert_eq!(j.get("sessions_restored").as_usize(), Some(2));
    assert_eq!(j.get("spilled_sessions").as_usize(), Some(0), "old spill must be released");
    assert_eq!(spilled(&spill), 0, "restore-over-suspended leaks a spill file");
    assert_eq!(j.get("live_sessions").as_usize(), Some(1));
    // And the surviving incarnation serves.
    let r = client
        .request(Request::Edit {
            session: "a".into(),
            edit: Edit::Replace { at: 1, tok: 4 },
        })
        .unwrap();
    assert!(r.logits().is_ok(), "{r:?}");
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let _ = std::fs::remove_dir_all(&spill);
}

#[test]
fn suspend_resume_and_session_info_verbs() {
    let spill = temp_spill_dir("verbs");
    let c = start(|sc| {
        sc.spill_dir = spill.to_str().unwrap().to_string();
        sc.workers = 2;
    });
    let client = c.client();
    let tokens = doc(30, 20);
    client
        .request(Request::Open {
            session: "lv".into(),
            tokens: tokens.clone(),
        })
        .unwrap()
        .logits()
        .unwrap();
    let r = client
        .request(Request::Edit {
            session: "lv".into(),
            edit: Edit::Replace { at: 4, tok: 11 },
        })
        .unwrap();
    let logits_resident: Vec<u32> = r.logits().unwrap().iter().map(|x| x.to_bits()).collect();

    // Resident info reports measured bytes and the edit count.
    match client
        .request(Request::SessionInfo { session: "lv".into() })
        .unwrap()
    {
        Response::SessionInfo {
            state,
            resident_bytes,
            edits,
            doc_len,
            ..
        } => {
            assert_eq!(state, "resident");
            assert!(resident_bytes > 0);
            assert_eq!(edits, 1);
            assert_eq!(doc_len, tokens.len());
        }
        other => panic!("{other:?}"),
    }

    // Suspend (idempotent), observe the state flip and the spill file.
    assert!(matches!(
        client.request(Request::Suspend { session: "lv".into() }).unwrap(),
        Response::Done
    ));
    assert!(matches!(
        client.request(Request::Suspend { session: "lv".into() }).unwrap(),
        Response::Done
    ));
    match client
        .request(Request::SessionInfo { session: "lv".into() })
        .unwrap()
    {
        Response::SessionInfo {
            state,
            resident_bytes,
            spill_bytes,
            ..
        } => {
            assert_eq!(state, "suspended");
            assert_eq!(resident_bytes, 0);
            assert!(spill_bytes > 0);
        }
        other => panic!("{other:?}"),
    }
    match client.request(Request::Stats).unwrap() {
        Response::Stats(j) => {
            assert_eq!(j.get("suspends").as_usize(), Some(1));
            assert_eq!(j.get("spilled_sessions").as_usize(), Some(1));
            assert_eq!(j.get("live_sessions").as_usize(), Some(0));
        }
        other => panic!("{other:?}"),
    }

    // An edit on a suspended session transparently resumes it — and the
    // result is bit-identical to an always-resident engine replaying the
    // same edit sequence (same weights seed as `start()` uses).
    let r = client
        .request(Request::Edit {
            session: "lv".into(),
            edit: Edit::Replace { at: 9, tok: 3 },
        })
        .unwrap();
    let logits_resumed: Vec<u32> = r.logits().unwrap().iter().map(|x| x.to_bits()).collect();
    let w = Arc::new(ModelWeights::random(&ModelConfig::vqt_tiny(), 5));
    let mut reference =
        vqt::incremental::IncrementalEngine::new(w, &tokens, EngineOptions::default());
    reference.apply_edits(&[Edit::Replace { at: 4, tok: 11 }]);
    let ref_after_first: Vec<u32> = reference.logits().iter().map(|x| x.to_bits()).collect();
    assert_eq!(ref_after_first, logits_resident, "pre-suspend determinism");
    reference.apply_edits(&[Edit::Replace { at: 9, tok: 3 }]);
    let ref_after_second: Vec<u32> = reference.logits().iter().map(|x| x.to_bits()).collect();
    assert_eq!(
        logits_resumed, ref_after_second,
        "suspend/resume must be invisible at the bit level"
    );
    match client.request(Request::Stats).unwrap() {
        Response::Stats(j) => {
            assert_eq!(j.get("resumes").as_usize(), Some(1));
            assert_eq!(j.get("spilled_sessions").as_usize(), Some(0));
            assert_eq!(j.get("live_sessions").as_usize(), Some(1));
            assert!(j.get("resident_bytes").as_usize().unwrap() > 0);
        }
        other => panic!("{other:?}"),
    }

    // Explicit Resume on a resident session is a cheap no-op; on an
    // unknown session it errors.
    assert!(matches!(
        client.request(Request::Resume { session: "lv".into() }).unwrap(),
        Response::Done
    ));
    assert!(matches!(
        client.request(Request::Resume { session: "ghost".into() }).unwrap(),
        Response::Err(_)
    ));
    assert!(matches!(
        client.request(Request::SessionInfo { session: "ghost".into() }).unwrap(),
        Response::Err(_)
    ));

    // Without a spill dir, Suspend is a clean error.
    let c2 = start(|_| {});
    let cl2 = c2.client();
    cl2.request(Request::Open {
        session: "nospill".into(),
        tokens: doc(1, 8),
    })
    .unwrap();
    match cl2
        .request(Request::Suspend { session: "nospill".into() })
        .unwrap()
    {
        Response::Err(e) => assert!(e.contains("spill_dir"), "{e}"),
        other => panic!("{other:?}"),
    }

    // Closing a suspended session removes its spill file.
    client
        .request(Request::Suspend { session: "lv".into() })
        .unwrap();
    match client.request(Request::Close { session: "lv".into() }).unwrap() {
        Response::Closed { existed } => assert!(existed),
        other => panic!("{other:?}"),
    }
    // No snapshot may be left anywhere under the spill root (the
    // coordinator spills into a per-instance subdirectory).
    fn vqss_files(dir: &std::path::Path) -> Vec<std::path::PathBuf> {
        let mut out = Vec::new();
        if let Ok(rd) = std::fs::read_dir(dir) {
            for e in rd.flatten() {
                let p = e.path();
                if p.is_dir() {
                    out.extend(vqss_files(&p));
                } else if p.extension().is_some_and(|x| x == "vqss") {
                    out.push(p);
                }
            }
        }
        out
    }
    let leftovers = vqss_files(&spill);
    assert!(leftovers.is_empty(), "spill files leaked: {leftovers:?}");
    let _ = std::fs::remove_dir_all(spill);
}
