//! Cross-layer integration: the AOT-compiled L2 JAX model (executed via
//! PJRT) must agree with the in-process L3 dense oracle AND the L3
//! incremental engine, all on the same weights.
//!
//! Requires `make artifacts` (skips with a message when absent, so plain
//! `cargo test` works in a fresh checkout).

use std::sync::Arc;

use vqt::flops::FlopLedger;
use vqt::incremental::{EngineOptions, IncrementalEngine};
use vqt::model::{dense_forward, ModelWeights};
use vqt::runtime::ArtifactRuntime;
use vqt::util::Rng;

/// Open the artifact runtime, or explain why this test is skipped: the
/// artifacts are built by `make artifacts` (absent in a fresh checkout),
/// and executing them additionally needs a live PJRT backend (the default
/// build ships the `runtime::xla` stub, which reports unavailable).
fn open_runtime() -> Option<ArtifactRuntime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    match ArtifactRuntime::open(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: artifact runtime unavailable ({e:#})");
            None
        }
    }
}

#[test]
fn l2_artifact_matches_l3_dense_oracle() {
    let Some(rt) = open_runtime() else { return };
    let cfg = rt.manifest.config.clone();
    let w = ModelWeights::load(rt.weights_path(), &cfg).unwrap();
    let mut rng = Rng::new(42);
    for &n in &[17usize, 32, 100] {
        let tokens: Vec<u32> = (0..n).map(|_| rng.below(cfg.vocab_size - 1) as u32).collect();
        let pos: Vec<u32> = rng
            .sorted_subset(cfg.pos_pool / 2, n)
            .into_iter()
            .map(|p| p as u32)
            .collect();
        let l2 = rt.dense_logits(&tokens, &pos).unwrap();
        let mut led = FlopLedger::new();
        let l3 = dense_forward(&w, &tokens, &pos, &mut led);
        assert_eq!(l2.len(), l3.logits.len());
        for (a, b) in l2.iter().zip(&l3.logits) {
            assert!(
                (a - b).abs() < 2e-3,
                "n={n}: L2 {a} vs L3 {b} (diff {})",
                (a - b).abs()
            );
        }
    }
}

#[test]
fn l2_artifact_matches_incremental_engine_after_edits() {
    let Some(rt) = open_runtime() else { return };
    let cfg = rt.manifest.config.clone();
    let w = Arc::new(ModelWeights::load(rt.weights_path(), &cfg).unwrap());
    let mut rng = Rng::new(7);
    let n = 48;
    let tokens: Vec<u32> = (0..n).map(|_| rng.below(cfg.vocab_size - 1) as u32).collect();
    let mut eng = IncrementalEngine::new(w, &tokens, EngineOptions::default());
    for _ in 0..5 {
        let at = rng.below(eng.len());
        let tok = rng.below(cfg.vocab_size - 1) as u32;
        eng.apply_edit(vqt::edits::Edit::Replace { at, tok });
    }
    let l2 = rt.dense_logits(eng.tokens(), eng.position_ids()).unwrap();
    for (a, b) in l2.iter().zip(eng.logits()) {
        assert!(
            (a - b).abs() < 2e-3,
            "L2 {a} vs incremental {b} after edits"
        );
    }
}

#[test]
fn l1_vq_assign_artifact_matches_l3_codebooks() {
    let Some(rt) = open_runtime() else { return };
    let cfg = rt.manifest.config.clone();
    if cfg.vq_heads == 0 {
        return;
    }
    let w = ModelWeights::load(rt.weights_path(), &cfg).unwrap();
    let vq = w.layers[0].vq.as_ref().unwrap();
    let n = rt.manifest.buckets.last().copied().unwrap();
    let mut rng = Rng::new(3);
    let x = vqt::tensor::Matrix::from_fn(n, cfg.d_model, |_, _| rng.normal());
    let codes = rt.vq_assign(&x).unwrap();
    assert_eq!(codes.len(), n * cfg.vq_heads);
    let mut led = FlopLedger::new();
    for i in 0..n {
        let want = vq.assign(x.row(i), &mut led);
        for (h, &c) in want.as_slice().iter().enumerate() {
            assert_eq!(
                codes[i * cfg.vq_heads + h],
                c as i32,
                "row {i} head {h}"
            );
        }
    }
}

#[test]
fn bucket_padding_is_exact() {
    // Same document through two different buckets must give identical
    // logits (mask correctness end-to-end).
    let Some(rt) = open_runtime() else { return };
    let cfg = rt.manifest.config.clone();
    let mut rng = Rng::new(11);
    let n = 30; // fits the 32-bucket
    let tokens: Vec<u32> = (0..n).map(|_| rng.below(cfg.vocab_size - 1) as u32).collect();
    let pos: Vec<u32> = rng
        .sorted_subset(cfg.pos_pool / 4, n)
        .into_iter()
        .map(|p| p as u32)
        .collect();
    let small = rt.dense_logits(&tokens, &pos).unwrap();
    // Force the next bucket by asking for a longer doc padded manually:
    // re-run with the same doc plus no-op — emulate by checking against
    // the L3 oracle instead (bucket 32 vs direct computation).
    let w = ModelWeights::load(rt.weights_path(), &cfg).unwrap();
    let mut led = FlopLedger::new();
    let oracle = dense_forward(&w, &tokens, &pos, &mut led);
    for (a, b) in small.iter().zip(&oracle.logits) {
        assert!((a - b).abs() < 2e-3);
    }
}
