//! Concurrency suite for the sharded coordinator pool: many client
//! threads hammering many sessions must leave every session in exactly
//! the state a serial replay of that session's edits produces, and
//! shutdown must drain cleanly.
//!
//! Determinism argument: each session is owned by one client thread
//! (blocking request/reply, so one in-flight op per session) and routed
//! to one fixed shard, whose queue is FIFO and whose batch planner
//! preserves intra-session order. The engine is deterministic, so the
//! coordinator's logits must equal a single-threaded replay bit-for-bit
//! (asserted with a 1e-6 slack for paranoia).

use std::sync::Arc;
use vqt::config::{ModelConfig, ServeConfig};
use vqt::coordinator::{Backend, Coordinator, Request, Response};
use vqt::edits::Edit;
use vqt::incremental::{EngineOptions, IncrementalEngine};
use vqt::model::ModelWeights;
use vqt::testutil::gen_edit;
use vqt::util::Rng;

const THREADS: usize = 8;
const SESSIONS_PER_THREAD: usize = 4; // 32 sessions total
const EDITS_PER_THREAD: usize = 24;

fn sid(thread: usize, s: usize) -> String {
    format!("t{thread}-doc{s}")
}

fn make_doc(thread: usize, s: usize, vocab: usize) -> Vec<u32> {
    let mut rng = Rng::new(1000 + (thread * SESSIONS_PER_THREAD + s) as u64);
    let n = rng.range(10, 24);
    (0..n).map(|_| rng.below(vocab) as u32).collect()
}

#[test]
fn sharded_pool_matches_serial_replay_per_session() {
    let cfg = ModelConfig::vqt_tiny();
    let w = Arc::new(ModelWeights::random(&cfg, 23));
    let sc = ServeConfig {
        workers: 4,
        max_sessions: 128, // no eviction even if hashing clusters sessions
        ..ServeConfig::default()
    };
    let coordinator = Coordinator::start(
        Backend {
            weights: w.clone(),
            artifacts_dir: None,
            engine_opts: EngineOptions::default(),
        },
        sc,
    );
    let client = coordinator.client();
    assert_eq!(client.shards(), 4);

    let mut joins = Vec::new();
    for t in 0..THREADS {
        let c = client.clone();
        let cfg = cfg.clone();
        joins.push(std::thread::spawn(move || {
            let docs: Vec<Vec<u32>> = (0..SESSIONS_PER_THREAD)
                .map(|s| make_doc(t, s, cfg.vocab_size))
                .collect();
            for (s, doc) in docs.iter().enumerate() {
                c.request(Request::Open {
                    session: sid(t, s),
                    tokens: doc.clone(),
                })
                .unwrap()
                .logits()
                .unwrap();
            }
            // Interleave edits across this thread's sessions, recording
            // the per-session script for the serial replay.
            let mut rng = Rng::new(5000 + t as u64);
            let mut lens: Vec<usize> = docs.iter().map(Vec::len).collect();
            let mut scripts: Vec<Vec<Edit>> = vec![Vec::new(); SESSIONS_PER_THREAD];
            for _ in 0..EDITS_PER_THREAD {
                let s = rng.below(SESSIONS_PER_THREAD);
                let e = gen_edit(&mut rng, lens[s], cfg.vocab_size, cfg.max_seq);
                lens[s] = (lens[s] as isize + e.len_delta()) as usize;
                scripts[s].push(e);
                let r = c
                    .request(Request::Edit {
                        session: sid(t, s),
                        edit: e,
                    })
                    .unwrap();
                assert!(r.logits().is_ok(), "t{t} s{s}: {r:?}");
            }
            // Final logits via an empty edit script (a read, in effect).
            let finals: Vec<Vec<f32>> = (0..SESSIONS_PER_THREAD)
                .map(|s| {
                    c.request(Request::EditScript {
                        session: sid(t, s),
                        edits: Vec::new(),
                    })
                    .unwrap()
                    .logits()
                    .unwrap()
                    .to_vec()
                })
                .collect();
            (t, docs, scripts, finals)
        }));
    }

    let results: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();

    // Serial replay: one fresh engine per session, same doc, same script,
    // single-threaded.
    for (t, docs, scripts, finals) in &results {
        for s in 0..SESSIONS_PER_THREAD {
            let mut eng =
                IncrementalEngine::new(w.clone(), &docs[s], EngineOptions::default());
            eng.apply_edits(&scripts[s]);
            assert_eq!(eng.logits().len(), finals[s].len());
            for (i, (a, b)) in eng.logits().iter().zip(&finals[s]).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-6,
                    "t{t} session {s} logit {i}: serial {a} vs pool {b}"
                );
            }
        }
    }

    // Pool-wide stats merged across shards: every session and edit
    // accounted for exactly once.
    match client.request(Request::Stats).unwrap() {
        Response::Stats(j) => {
            assert_eq!(j.get("shards").as_usize(), Some(4));
            assert_eq!(
                j.get("live_sessions").as_usize(),
                Some(THREADS * SESSIONS_PER_THREAD)
            );
            assert_eq!(
                j.get("edits").as_usize(),
                Some(THREADS * EDITS_PER_THREAD)
            );
            assert_eq!(
                j.get("sessions_opened").as_usize(),
                Some(THREADS * SESSIONS_PER_THREAD)
            );
            assert_eq!(j.get("errors").as_usize(), Some(0));
        }
        other => panic!("{other:?}"),
    }

    // Drain/shutdown: all clients dropped, every shard must exit cleanly
    // (shutdown joins all shard threads; a hang here is a test timeout).
    drop(client);
    coordinator.shutdown();
}

/// Satellite coverage: `Metrics::merge` / `per_shard` accounting at worker
/// counts 1 and 3 with a pool-wide `queue_capacity` (and `max_sessions`)
/// that does NOT divide evenly across shards — the ceil-split must not
/// lose or double-count anything, and the merged snapshot must equal the
/// per-shard sum exactly.
#[test]
fn stats_merge_matches_per_shard_sum_at_awkward_splits() {
    let cfg = ModelConfig::vqt_tiny();
    for &workers in &[1usize, 3] {
        let w = Arc::new(ModelWeights::random(&cfg, 61));
        let sc = ServeConfig {
            workers,
            queue_capacity: 7, // ceil(7/3)=3 per shard — non-divisible
            max_sessions: 10,  // ceil(10/3)=4 per shard — non-divisible
            code_cache_mb: 16, // cache on: its counters must merge too
            ..ServeConfig::default()
        };
        let coordinator = Coordinator::start(
            Backend {
                weights: w.clone(),
                artifacts_dir: None,
                engine_opts: EngineOptions::default(),
            },
            sc,
        );
        let client = coordinator.client();
        let mut rng = Rng::new(71);
        let n_sessions = 6;
        let mut lens = Vec::new();
        for s in 0..n_sessions {
            let doc: Vec<u32> = (0..10).map(|_| rng.below(cfg.vocab_size) as u32).collect();
            lens.push(doc.len());
            client
                .request(Request::Open {
                    session: format!("m{s}"),
                    tokens: doc,
                })
                .unwrap()
                .logits()
                .unwrap();
        }
        let mut edits_sent = 0u64;
        for _round in 0..3 {
            for s in 0..n_sessions {
                let e = gen_edit(&mut rng, lens[s], cfg.vocab_size, cfg.max_seq);
                lens[s] = (lens[s] as isize + e.len_delta()) as usize;
                client
                    .request(Request::Edit {
                        session: format!("m{s}"),
                        edit: e,
                    })
                    .unwrap()
                    .logits()
                    .unwrap();
                edits_sent += 1;
            }
        }
        for _ in 0..4 {
            client
                .request(Request::Dense {
                    tokens: (0..8).map(|i| (i % 50) as u32).collect(),
                })
                .unwrap()
                .logits()
                .unwrap();
        }
        match client.request(Request::Stats).unwrap() {
            Response::Stats(j) => {
                assert_eq!(j.get("shards").as_usize(), Some(workers));
                let per_shard = j.get("per_shard").as_arr().expect("per_shard");
                assert_eq!(per_shard.len(), workers, "one entry per shard");
                // The merged counters equal the per-shard sums EXACTLY.
                for key in [
                    "edits",
                    "dense_calls",
                    "live_sessions",
                    "errors",
                    "batched_rows",
                    "cache_hits",
                    "cache_misses",
                    "cache_evictions",
                    "cache_bytes",
                ] {
                    let sum: usize = per_shard
                        .iter()
                        .map(|sj| sj.get(key).as_usize().unwrap_or(0))
                        .sum();
                    assert_eq!(
                        j.get(key).as_usize(),
                        Some(sum),
                        "workers={workers}: merged '{key}' != per-shard sum"
                    );
                }
                assert_eq!(j.get("edits").as_usize(), Some(edits_sent as usize));
                assert_eq!(j.get("dense_calls").as_usize(), Some(4));
                assert_eq!(j.get("live_sessions").as_usize(), Some(n_sessions));
                assert_eq!(j.get("errors").as_usize(), Some(0));
                // The batch-occupancy histogram is present and coherent
                // (count may be 0 when no waves overlapped).
                assert!(j.get("batch_fill").get("count").as_f64().is_some());
                // Every edit recomputes at least one block tail, so the
                // cache saw traffic — and it landed in the merged stats.
                let hits = j.get("cache_hits").as_usize().unwrap();
                let misses = j.get("cache_misses").as_usize().unwrap();
                assert!(
                    hits + misses > 0,
                    "workers={workers}: cache-on pool recorded no cache traffic"
                );
            }
            other => panic!("workers={workers}: {other:?}"),
        }
        drop(client);
        coordinator.shutdown();
    }
}

/// The cross-session payoff the cache exists for: many sessions typing
/// the same token into the same document share ONE product. The first
/// session's edit misses and warms the process-global cache; every later
/// session hits — including sessions hash-routed to OTHER shards, which
/// is what distinguishes a process-global cache from a per-shard one.
#[test]
fn many_sessions_same_token_hit_cross_session() {
    let cfg = ModelConfig::vqt_tiny();
    let w = Arc::new(ModelWeights::random(&cfg, 83));
    let sc = ServeConfig {
        workers: 2,
        code_cache_mb: 8,
        ..ServeConfig::default()
    };
    let coordinator = Coordinator::start(
        Backend {
            weights: w,
            artifacts_dir: None,
            engine_opts: EngineOptions::default(),
        },
        sc,
    );
    let client = coordinator.client();
    let doc: Vec<u32> = (0..12).map(|i| (i * 5 % 50) as u32).collect();
    let n_sessions = 6;
    for s in 0..n_sessions {
        client
            .request(Request::Open {
                session: format!("same{s}"),
                tokens: doc.clone(),
            })
            .unwrap()
            .logits()
            .unwrap();
    }
    // Everyone types the same token at the same position.
    let mut finals: Vec<Vec<u32>> = Vec::new();
    for s in 0..n_sessions {
        let r = client
            .request(Request::Edit {
                session: format!("same{s}"),
                edit: Edit::Replace { at: 4, tok: 49 },
            })
            .unwrap();
        finals.push(r.logits().unwrap().iter().map(|x| x.to_bits()).collect());
    }
    // Identical sessions, identical edits: identical logits bits — the
    // cached fast path did not perturb a single bit for any session.
    for (s, f) in finals.iter().enumerate().skip(1) {
        assert_eq!(&finals[0], f, "session {s} diverged");
    }
    match client.request(Request::Stats).unwrap() {
        Response::Stats(j) => {
            let hits = j.get("cache_hits").as_usize().unwrap();
            let misses = j.get("cache_misses").as_usize().unwrap();
            assert!(misses > 0, "the first session must warm the cache");
            assert!(
                hits > 0,
                "later sessions must hit cross-session (hits {hits}, misses {misses})"
            );
            // The per-shard breakdown carries the cache keys and sums to
            // the merged view.
            let per_shard = j.get("per_shard").as_arr().expect("per_shard");
            let sum: usize = per_shard
                .iter()
                .map(|sj| sj.get("cache_hits").as_usize().unwrap())
                .sum();
            assert_eq!(sum, hits, "per-shard hits must sum to the merged total");
        }
        other => panic!("{other:?}"),
    }
    drop(client);
    coordinator.shutdown();
}

#[test]
fn round_robin_spreads_sessionless_work_and_stats_merge() {
    let cfg = ModelConfig::vqt_tiny();
    let w = Arc::new(ModelWeights::random(&cfg, 29));
    let sc = ServeConfig {
        workers: 3,
        ..ServeConfig::default()
    };
    let coordinator = Coordinator::start(
        Backend {
            weights: w,
            artifacts_dir: None,
            engine_opts: EngineOptions::default(),
        },
        sc,
    );
    let client = coordinator.client();
    let tokens: Vec<u32> = (0..12).map(|i| (i % 60) as u32).collect();
    // 6 session-less dense calls from one client round-robin across 3
    // shards deterministically: each shard must serve exactly 2.
    for _ in 0..6 {
        client
            .request(Request::Dense {
                tokens: tokens.clone(),
            })
            .unwrap()
            .logits()
            .unwrap();
    }
    match client.request(Request::Stats).unwrap() {
        Response::Stats(j) => {
            assert_eq!(j.get("dense_calls").as_usize(), Some(6));
            assert_eq!(j.get("shards").as_usize(), Some(3));
            let per_shard = j.get("per_shard").as_arr().expect("per_shard array");
            assert_eq!(per_shard.len(), 3);
            for (i, sj) in per_shard.iter().enumerate() {
                assert_eq!(
                    sj.get("dense_calls").as_usize(),
                    Some(2),
                    "shard {i} did not get its round-robin share"
                );
            }
        }
        other => panic!("{other:?}"),
    }
}
