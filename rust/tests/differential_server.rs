//! Differential test: the readiness-driven async front end must be
//! bit-identical on the wire to the blocking thread-per-connection server
//! — same multi-session script in, byte-for-byte same reply lines out —
//! plus admission-control and typed load-shedding behavior that only the
//! async front end has.
#![cfg(target_os = "linux")]

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use vqt::config::{ModelConfig, ServeConfig};
use vqt::coordinator::{Backend, Coordinator};
use vqt::incremental::EngineOptions;
use vqt::model::ModelWeights;
use vqt::server::{AsyncServer, FrontendOptions};
use vqt::util::Json;

fn coordinator(tag: &str, cfg_mut: impl FnOnce(&mut ServeConfig)) -> Coordinator {
    let cfg = ModelConfig::vqt_tiny();
    // Same seed for both coordinators: identical weights ⇒ identical
    // logits ⇒ the replies can be compared as raw bytes.
    let w = Arc::new(ModelWeights::random(&cfg, 5));
    let mut sc = ServeConfig::default();
    sc.workers = 2;
    sc.spill_dir = std::env::temp_dir()
        .join(format!("vqt_diff_spill_{tag}_{}", std::process::id()))
        .to_str()
        .unwrap()
        .to_string();
    cfg_mut(&mut sc);
    Coordinator::start(
        Backend {
            weights: w,
            artifacts_dir: None,
            engine_opts: EngineOptions::default(),
        },
        sc,
    )
}

/// One scripted exchange: raw bytes to write, number of reply lines owed.
/// (Blank/whitespace lines owe none — both servers skip them silently.)
struct Step(Vec<u8>, usize);

fn step(line: &str, replies: usize) -> Step {
    let mut b = line.as_bytes().to_vec();
    b.push(b'\n');
    Step(b, replies)
}

/// A multi-session script touching every differential-safe verb (no
/// `stats`: the async server grafts its own `frontend` counters into that
/// one reply by design) plus the error paths panic-proofed in this series.
fn script() -> Vec<Step> {
    vec![
        step(r#"{"op":"open","session":"s1","tokens":[1,2,3,4,5,6,7,8]}"#, 1),
        step(r#"{"op":"open","session":"s2","tokens":[9,8,7,6,5,4,3,2,1]}"#, 1),
        step(r#"{"op":"open","session":"s3","tokens":[11,12,13,14,15,16]}"#, 1),
        // Blank and whitespace-only lines produce no reply on either server.
        Step(b"\n   \n".to_vec(), 0),
        step(r#"{"op":"edit","session":"s1","kind":"replace","at":2,"tok":40}"#, 1),
        step(r#"{"op":"edit","session":"s2","kind":"insert","at":0,"tok":7}"#, 1),
        step(r#"{"op":"edit","session":"s3","kind":"delete","at":5}"#, 1),
        step(r#"{"op":"revision","session":"s1","tokens":[1,2,3,9,9,6,7,8,10]}"#, 1),
        step(r#"{"op":"suggest","session":"s2","k":4}"#, 1),
        step(r#"{"op":"dense","tokens":[3,1,4,1,5]}"#, 1),
        step(r#"{"op":"batch_revisions","base":[1,2,3,4],"revisions":[[1,2,3,5],[1,2,4]]}"#, 1),
        step(r#"{"op":"session_info","session":"s3"}"#, 1),
        step(r#"{"op":"suspend","session":"s3"}"#, 1),
        step(r#"{"op":"session_info","session":"s3"}"#, 1),
        step(r#"{"op":"resume","session":"s3"}"#, 1),
        step(r#"{"op":"edit","session":"s3","kind":"replace","at":0,"tok":2}"#, 1),
        // Typed errors — the panic-proofed paths, byte-identical too.
        step(r#"{"op":"edit","session":"s1","kind":"replace","at":9999,"tok":1}"#, 1),
        step(r#"{"op":"revision","session":"s1","tokens":[]}"#, 1),
        step(r#"{"op":"open","session":"s4","tokens":[]}"#, 1),
        step(r#"{"op":"dense","tokens":[]}"#, 1),
        step(r#"{"op":"suggest","session":"nope","k":2}"#, 1),
        step(r#"{"op":"oops"}"#, 1),
        step(r#"not json at all"#, 1),
        Step(b"\xff\xfe not utf8\n".to_vec(), 1),
        // The session the typed errors hit keeps serving.
        step(r#"{"op":"edit","session":"s1","kind":"replace","at":0,"tok":3}"#, 1),
        step(r#"{"op":"close","session":"s2"}"#, 1),
        step(r#"{"op":"close","session":"s2"}"#, 1),
    ]
}

/// Drive a server in lockstep (write one step, read its owed replies) and
/// return every reply line verbatim.
fn run_script(addr: std::net::SocketAddr, steps: &[Step]) -> Vec<String> {
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut out = Vec::new();
    for Step(bytes, replies) in steps {
        conn.write_all(bytes).unwrap();
        for _ in 0..*replies {
            let mut line = String::new();
            assert!(reader.read_line(&mut line).unwrap() > 0, "server hung up early");
            out.push(line);
        }
    }
    // Trailing unterminated request, then half-close: both servers process
    // it as a final request and reply before closing.
    conn.write_all(br#"{"op":"dense","tokens":[2,2,2]}"#).unwrap();
    conn.shutdown(std::net::Shutdown::Write).unwrap();
    let mut line = String::new();
    assert!(reader.read_line(&mut line).unwrap() > 0, "no reply to EOF-partial line");
    out.push(line);
    assert_eq!(reader.read_line(&mut String::new()).unwrap(), 0, "clean close after EOF");
    out
}

#[test]
fn async_server_is_bit_identical_to_blocking_server() {
    let steps = script();

    // Blocking reference endpoint.
    let c_blocking = coordinator("blk", |_| {});
    let client = c_blocking.client();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let blocking_addr = listener.local_addr().unwrap();
    let acceptor = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let _ = vqt::server::handle_conn(stream, client);
    });
    let blocking_replies = run_script(blocking_addr, &steps);
    acceptor.join().unwrap();

    // Async endpoint, identically-seeded coordinator.
    let c_async = coordinator("async", |_| {});
    let server = AsyncServer::start(
        "127.0.0.1:0",
        c_async.client(),
        FrontendOptions {
            io_threads: 2,
            max_connections: 0,
            max_inflight_per_conn: 32,
            trace_buffer: 0,
        },
    )
    .unwrap();
    let async_replies = run_script(server.local_addr(), &steps);
    server.shutdown();

    assert_eq!(blocking_replies.len(), async_replies.len());
    for (i, (b, a)) in blocking_replies.iter().zip(&async_replies).enumerate() {
        assert_eq!(b, a, "reply {i} diverged");
    }
    // Paranoia: the script exercised real replies, not just errors.
    assert!(blocking_replies.iter().any(|l| l.contains("\"logits\"")));
    assert!(blocking_replies.iter().any(|l| l.contains("\"suggestions\"")));
}

/// Pipelined requests on one connection come back in request order even
/// though shards complete them concurrently, and a full shard queue sheds
/// with `busy:true` instead of queueing unboundedly.
#[test]
fn pipelined_requests_stay_ordered_and_overload_sheds_typed_busy() {
    // A one-worker, one-slot queue: while the worker chews on the opening
    // request, pipelined followers overflow the queue and must be shed.
    let c = coordinator("shed", |sc| {
        sc.workers = 1;
        sc.queue_capacity = 1;
    });
    let server = AsyncServer::start(
        "127.0.0.1:0",
        c.client(),
        FrontendOptions {
            io_threads: 1,
            max_connections: 0,
            max_inflight_per_conn: 64,
            trace_buffer: 0,
        },
    )
    .unwrap();
    let mut conn = TcpStream::connect(server.local_addr()).unwrap();
    let mut batch = Vec::new();
    // An expensive head (fresh engine build) followed by a cheap tail,
    // written as ONE burst so the tail parses while the head executes.
    batch.extend_from_slice(
        br#"{"op":"open","session":"big","tokens":[1,2,3,4,5,6,7,8,9,10,11,12,13,14,15,16,17,18,19,20,21,22,23,24,25,26,27,28,29,30,31,32,33,34,35,36,37,38,39,40,41,42,43,44,45,46,47,48]}"#,
    );
    batch.push(b'\n');
    const TAIL: usize = 24;
    for _ in 0..TAIL {
        batch.extend_from_slice(br#"{"op":"dense","tokens":[1,2,3,4]}"#);
        batch.push(b'\n');
    }
    conn.write_all(&batch).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut ok = 0usize;
    let mut busy = 0usize;
    let mut first: Option<Json> = None;
    for _ in 0..TAIL + 1 {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "missing reply");
        let j = Json::parse(&line).unwrap();
        if first.is_none() {
            first = Some(j.clone());
        }
        match (j.get("ok").as_bool(), j.get("busy").as_bool()) {
            (Some(true), _) => ok += 1,
            (Some(false), Some(true)) => busy += 1,
            other => panic!("reply neither ok nor typed-busy: {other:?} in {line}"),
        }
    }
    // Ordering: the first reply on the wire is the head request's.
    assert!(
        first.unwrap().get("logits").as_arr().is_some(),
        "head reply must come first"
    );
    assert_eq!(ok + busy, TAIL + 1);
    assert!(busy >= 1, "tiny queue under a pipelined burst must shed");
    assert_eq!(
        server.stats().requests_shed.load(Ordering::Relaxed) as usize,
        busy,
        "shed counter must match busy replies"
    );
    server.shutdown();
}

/// `max_connections` admission control: past the cap a fresh connection
/// gets one typed busy line and is dropped; closing a connection frees a
/// slot.
#[test]
fn connection_cap_rejects_with_typed_busy_then_recovers() {
    let c = coordinator("cap", |_| {});
    let server = AsyncServer::start(
        "127.0.0.1:0",
        c.client(),
        FrontendOptions {
            io_threads: 2,
            max_connections: 8,
            max_inflight_per_conn: 4,
            trace_buffer: 0,
        },
    )
    .unwrap();
    let stats = server.stats();
    let gauge = |stats: &vqt::server::FrontendStats| stats.connections.load(Ordering::Relaxed);
    let mut held = Vec::new();
    for _ in 0..8 {
        held.push(TcpStream::connect(server.local_addr()).unwrap());
    }
    // The gauge is bumped at accept hand-off; wait for the acceptor to
    // catch up with the burst before poking the cap.
    for _ in 0..500 {
        if gauge(&stats) == 8 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert_eq!(gauge(&stats), 8);
    // Ninth connection: one typed busy line, then EOF.
    let over = TcpStream::connect(server.local_addr()).unwrap();
    let mut reader = BufReader::new(over);
    let mut line = String::new();
    assert!(reader.read_line(&mut line).unwrap() > 0);
    let j = Json::parse(&line).unwrap();
    assert_eq!(j.get("busy").as_bool(), Some(true), "{line}");
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "rejected conn must close");
    assert_eq!(stats.connections_rejected.load(Ordering::Relaxed), 1);
    // Free a slot and the next admission succeeds end to end.
    drop(held.pop());
    for _ in 0..500 {
        if gauge(&stats) < 8 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    let mut again = TcpStream::connect(server.local_addr()).unwrap();
    again
        .write_all(b"{\"op\":\"dense\",\"tokens\":[1,2,3]}\n")
        .unwrap();
    let mut reader = BufReader::new(again);
    let mut line = String::new();
    assert!(reader.read_line(&mut line).unwrap() > 0);
    assert_eq!(Json::parse(&line).unwrap().get("ok").as_bool(), Some(true), "{line}");
    drop(held);
    server.shutdown();
}

/// The async server's `stats` reply carries the front end's own counters
/// under `"frontend"` — the one deliberate difference from the blocking
/// server's stats reply.
#[test]
fn stats_reply_carries_frontend_counters() {
    let c = coordinator("fstats", |_| {});
    let server = AsyncServer::start(
        "127.0.0.1:0",
        c.client(),
        FrontendOptions {
            io_threads: 1,
            max_connections: 0,
            max_inflight_per_conn: 4,
            trace_buffer: 0,
        },
    )
    .unwrap();
    let mut conn = TcpStream::connect(server.local_addr()).unwrap();
    conn.write_all(b"{\"op\":\"stats\"}\n").unwrap();
    let mut reader = BufReader::new(conn);
    let mut line = String::new();
    assert!(reader.read_line(&mut line).unwrap() > 0);
    let j = Json::parse(&line).unwrap();
    assert_eq!(j.get("ok").as_bool(), Some(true));
    let fe = j.get("stats").get("frontend");
    assert_eq!(fe.get("connections").as_usize(), Some(1), "{line}");
    assert_eq!(fe.get("connections_accepted").as_usize(), Some(1));
    assert_eq!(fe.get("requests_shed").as_usize(), Some(0));
    // The per-IO-thread breakdown must cover every IO thread and sum back
    // to the merged gauge (the invariant `conn_gone` maintains).
    let per_thread = fe.get("per_io_thread").as_arr().expect("per_io_thread array");
    assert_eq!(per_thread.len(), 1, "{line}");
    let sum: usize = per_thread.iter().map(|v| v.as_usize().unwrap()).sum();
    assert_eq!(sum, 1, "per-thread gauges must sum to the merged gauge");
    server.shutdown();
}

/// Turning tracing ON (ring buffers + slow-request sampling armed) must
/// not change a single reply byte for requests that don't ask for a trace
/// — the span machinery rides alongside the reply, never inside it.
#[test]
fn tracing_enabled_servers_stay_bit_identical() {
    let steps = script();
    let traced = |sc: &mut ServeConfig| {
        sc.trace_buffer = 64;
        sc.slow_request_us = 1_000_000;
    };

    // Blocking reference endpoint with tracing armed.
    let c_blocking = coordinator("blk_tr", traced);
    let client = c_blocking.client();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let blocking_addr = listener.local_addr().unwrap();
    let acceptor = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let _ = vqt::server::handle_conn(stream, client);
    });
    let blocking_replies = run_script(blocking_addr, &steps);
    acceptor.join().unwrap();

    // Async endpoint, identically-seeded coordinator, tracing armed on
    // both the shard rings and the front-end ring.
    let c_async = coordinator("async_tr", traced);
    let server = AsyncServer::start(
        "127.0.0.1:0",
        c_async.client(),
        FrontendOptions {
            io_threads: 2,
            max_connections: 0,
            max_inflight_per_conn: 32,
            trace_buffer: 64,
        },
    )
    .unwrap();
    let async_replies = run_script(server.local_addr(), &steps);
    server.shutdown();

    assert_eq!(blocking_replies.len(), async_replies.len());
    for (i, (b, a)) in blocking_replies.iter().zip(&async_replies).enumerate() {
        assert_eq!(b, a, "reply {i} diverged with tracing enabled");
    }
    // No reply grew a trace field: the flag is per-request opt-in.
    assert!(blocking_replies.iter().all(|l| !l.contains("\"trace\"")));
    assert!(async_replies.iter().all(|l| !l.contains("\"trace\"")));
}
