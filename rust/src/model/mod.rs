//! VQT model definition: weights, dense oracle forward, and the classifier
//! head. The paper-specific pieces are the GELU-elementwise attention and
//! the multi-head VQ bottleneck on attention outputs (eq. 1).

pub mod dense;
pub mod weights;

pub use dense::{attn_out_scale, dense_forward, predict, ForwardOutput};
pub use weights::{LayerWeights, ModelWeights};
