//! Model weights: container, deterministic random init (tests), and loading
//! from the `VQTB` tensor files exported by `python/compile/aot.py`
//! (`make artifacts`) and `python/compile/train.py` (`make train`).
//!
//! Naming convention in the tensor file (all f32):
//! ```text
//! embed_tokens            (vocab, d)
//! embed_pos               (pos_pool, d)
//! layers.{i}.ln1.g / .b   (d,)
//! layers.{i}.wq / wk / wv (d, d)     [row-major: y = x · W]
//! layers.{i}.bq / bk / bv (d,)
//! layers.{i}.vq.book      (vq_heads, codes, d/vq_heads)   [optional]
//! layers.{i}.w_mix / b_mix
//! layers.{i}.ln2.g / .b
//! layers.{i}.w_ff1 / b_ff1 / w_ff2 / b_ff2
//! ln_f.g / ln_f.b
//! w_cls (d, n_classes) / b_cls
//! ```

use crate::config::ModelConfig;
use crate::tensor::Matrix;
use crate::util::{Rng, Tensor, TensorFile};
use crate::vq::VqCodebooks;
use anyhow::{Context, Result};
use std::path::Path;

/// Weights of one transformer block.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub wq: Matrix,
    pub wk: Matrix,
    pub wv: Matrix,
    pub bq: Vec<f32>,
    pub bk: Vec<f32>,
    pub bv: Vec<f32>,
    /// VQ codebooks on the attention output (None ⇒ baseline block).
    pub vq: Option<VqCodebooks>,
    pub w_mix: Matrix,
    pub b_mix: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub w_ff1: Matrix,
    pub b_ff1: Vec<f32>,
    pub w_ff2: Matrix,
    pub b_ff2: Vec<f32>,
}

/// Full model weights.
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub cfg: ModelConfig,
    pub embed_tokens: Matrix,
    pub embed_pos: Matrix,
    pub layers: Vec<LayerWeights>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
    pub w_cls: Matrix,
    pub b_cls: Vec<f32>,
}

impl ModelWeights {
    /// Deterministic random init (He-style scales). Used by tests and by
    /// the workload benches when no trained checkpoint is supplied — the
    /// incremental-vs-dense *exactness* and the FLOP accounting are
    /// weight-agnostic.
    pub fn random(cfg: &ModelConfig, seed: u64) -> ModelWeights {
        cfg.validate().expect("invalid config");
        let mut r = Rng::new(seed);
        let d = cfg.d_model;
        let emb_scale = 0.02;
        let proj_scale = 1.0 / (d as f32).sqrt();
        let ff_scale = 1.0 / (cfg.d_ff as f32).sqrt();
        let mat =
            |rows: usize, cols: usize, s: f32, r: &mut Rng| Matrix::from_fn(rows, cols, |_, _| r.normal() * s);
        let embed_tokens = mat(cfg.vocab_size, d, emb_scale, &mut r);
        let embed_pos = mat(cfg.pos_pool, d, emb_scale, &mut r);
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                ln1_g: vec![1.0; d],
                ln1_b: vec![0.0; d],
                wq: mat(d, d, proj_scale, &mut r),
                wk: mat(d, d, proj_scale, &mut r),
                wv: mat(d, d, proj_scale, &mut r),
                bq: vec![0.0; d],
                bk: vec![0.0; d],
                bv: vec![0.0; d],
                vq: if cfg.vq_heads > 0 {
                    Some(VqCodebooks::random(cfg.vq_heads, cfg.vq_codes, d, &mut r))
                } else {
                    None
                },
                w_mix: mat(d, d, proj_scale, &mut r),
                b_mix: vec![0.0; d],
                ln2_g: vec![1.0; d],
                ln2_b: vec![0.0; d],
                w_ff1: mat(d, cfg.d_ff, proj_scale, &mut r),
                b_ff1: vec![0.0; cfg.d_ff],
                w_ff2: mat(cfg.d_ff, d, ff_scale, &mut r),
                b_ff2: vec![0.0; d],
            })
            .collect();
        ModelWeights {
            cfg: cfg.clone(),
            embed_tokens,
            embed_pos,
            layers,
            lnf_g: vec![1.0; d],
            lnf_b: vec![0.0; d],
            w_cls: mat(d, cfg.n_classes, proj_scale, &mut r),
            b_cls: vec![0.0; cfg.n_classes],
        }
    }

    /// Load from a `VQTB` tensor file (see module docs for naming).
    pub fn load(path: impl AsRef<Path>, cfg: &ModelConfig) -> Result<ModelWeights> {
        let tf = TensorFile::load(path)?;
        Self::from_tensor_file(&tf, cfg)
    }

    pub fn from_tensor_file(tf: &TensorFile, cfg: &ModelConfig) -> Result<ModelWeights> {
        cfg.validate()?;
        let d = cfg.d_model;
        let getm = |name: &str, rows: usize, cols: usize| -> Result<Matrix> {
            let data = tf.f32_shaped(name, &[rows, cols])?;
            Ok(Matrix::from_vec(rows, cols, data.to_vec()))
        };
        let getv = |name: &str, len: usize| -> Result<Vec<f32>> {
            Ok(tf.f32_shaped(name, &[len])?.to_vec())
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = |s: &str| format!("layers.{i}.{s}");
            let vq = if cfg.vq_heads > 0 {
                let chunk = d / cfg.vq_heads;
                let (dims, data) = tf.get(&p("vq.book"))?.as_f32()?;
                anyhow::ensure!(
                    dims == [cfg.vq_heads, cfg.vq_codes, chunk],
                    "vq.book dims {dims:?} != {:?}",
                    [cfg.vq_heads, cfg.vq_codes, chunk]
                );
                let per = cfg.vq_codes * chunk;
                let books = (0..cfg.vq_heads)
                    .map(|h| {
                        Matrix::from_vec(
                            cfg.vq_codes,
                            chunk,
                            data[h * per..(h + 1) * per].to_vec(),
                        )
                    })
                    .collect();
                Some(VqCodebooks::new(books, d))
            } else {
                None
            };
            layers.push(LayerWeights {
                ln1_g: getv(&p("ln1.g"), d)?,
                ln1_b: getv(&p("ln1.b"), d)?,
                wq: getm(&p("wq"), d, d)?,
                wk: getm(&p("wk"), d, d)?,
                wv: getm(&p("wv"), d, d)?,
                bq: getv(&p("bq"), d)?,
                bk: getv(&p("bk"), d)?,
                bv: getv(&p("bv"), d)?,
                vq,
                w_mix: getm(&p("w_mix"), d, d)?,
                b_mix: getv(&p("b_mix"), d)?,
                ln2_g: getv(&p("ln2.g"), d)?,
                ln2_b: getv(&p("ln2.b"), d)?,
                w_ff1: getm(&p("w_ff1"), d, cfg.d_ff)?,
                b_ff1: getv(&p("b_ff1"), cfg.d_ff)?,
                w_ff2: getm(&p("w_ff2"), cfg.d_ff, d)?,
                b_ff2: getv(&p("b_ff2"), d)?,
            });
        }
        Ok(ModelWeights {
            cfg: cfg.clone(),
            embed_tokens: getm("embed_tokens", cfg.vocab_size, d)
                .context("embed_tokens")?,
            embed_pos: getm("embed_pos", cfg.pos_pool, d).context("embed_pos")?,
            layers,
            lnf_g: getv("ln_f.g", d)?,
            lnf_b: getv("ln_f.b", d)?,
            w_cls: getm("w_cls", d, cfg.n_classes)?,
            b_cls: getv("b_cls", cfg.n_classes)?,
        })
    }

    /// Layer `li`'s VQ codebooks, or a typed error when the layer lacks
    /// them. This is the boundary the serving path must use instead of
    /// `vq.as_ref().unwrap()`: a weights file whose config promises VQ
    /// (`vq_heads > 0`) but whose layer carries no codebooks must surface
    /// as a request error, never a worker panic.
    pub fn layer_vq(&self, li: usize) -> Result<&VqCodebooks> {
        self.layers
            .get(li)
            .with_context(|| format!("layer {li} out of range ({} layers)", self.layers.len()))?
            .vq
            .as_ref()
            .with_context(|| format!("layer {li} has no VQ config"))
    }

    /// Validate that a VQ model (`cfg.vq_heads > 0`) carries codebooks of
    /// the configured geometry on **every** layer. Engine constructors run
    /// this up front so malformed weights fail once, with a clear message,
    /// instead of panicking mid-request deep in the hot path.
    pub fn validate_vq(&self) -> Result<()> {
        if self.cfg.vq_heads == 0 {
            return Ok(());
        }
        for li in 0..self.layers.len() {
            let vq = self.layer_vq(li)?;
            anyhow::ensure!(
                vq.heads == self.cfg.vq_heads && vq.codes == self.cfg.vq_codes,
                "layer {li} VQ geometry ({}h/{}c) does not match config ({}h/{}c)",
                vq.heads,
                vq.codes,
                self.cfg.vq_heads,
                self.cfg.vq_codes
            );
        }
        Ok(())
    }

    /// Serialize to a tensor file (inverse of `from_tensor_file`).
    pub fn to_tensor_file(&self) -> TensorFile {
        let mut tf = TensorFile::new();
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let put_m = |tf: &mut TensorFile, name: String, m: &Matrix| {
            tf.insert(name, Tensor::f32(vec![m.rows, m.cols], m.data.clone()));
        };
        let put_v = |tf: &mut TensorFile, name: String, v: &[f32]| {
            tf.insert(name, Tensor::f32(vec![v.len()], v.to_vec()));
        };
        put_m(&mut tf, "embed_tokens".into(), &self.embed_tokens);
        put_m(&mut tf, "embed_pos".into(), &self.embed_pos);
        for (i, l) in self.layers.iter().enumerate() {
            let p = |s: &str| format!("layers.{i}.{s}");
            put_v(&mut tf, p("ln1.g"), &l.ln1_g);
            put_v(&mut tf, p("ln1.b"), &l.ln1_b);
            put_m(&mut tf, p("wq"), &l.wq);
            put_m(&mut tf, p("wk"), &l.wk);
            put_m(&mut tf, p("wv"), &l.wv);
            put_v(&mut tf, p("bq"), &l.bq);
            put_v(&mut tf, p("bk"), &l.bk);
            put_v(&mut tf, p("bv"), &l.bv);
            if let Some(vq) = &l.vq {
                let chunk = d / vq.heads;
                let mut data = Vec::with_capacity(vq.heads * vq.codes * chunk);
                for b in &vq.books {
                    data.extend_from_slice(&b.data);
                }
                tf.insert(
                    p("vq.book"),
                    Tensor::f32(vec![vq.heads, vq.codes, chunk], data),
                );
            }
            put_m(&mut tf, p("w_mix"), &l.w_mix);
            put_v(&mut tf, p("b_mix"), &l.b_mix);
            put_v(&mut tf, p("ln2.g"), &l.ln2_g);
            put_v(&mut tf, p("ln2.b"), &l.ln2_b);
            put_m(&mut tf, p("w_ff1"), &l.w_ff1);
            put_v(&mut tf, p("b_ff1"), &l.b_ff1);
            put_m(&mut tf, p("w_ff2"), &l.w_ff2);
            put_v(&mut tf, p("b_ff2"), &l.b_ff2);
        }
        put_v(&mut tf, "ln_f.g".into(), &self.lnf_g);
        put_v(&mut tf, "ln_f.b".into(), &self.lnf_b);
        put_m(&mut tf, "w_cls".into(), &self.w_cls);
        put_v(&mut tf, "b_cls".into(), &self.b_cls);
        tf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_is_deterministic() {
        let cfg = ModelConfig::vqt_tiny();
        let a = ModelWeights::random(&cfg, 42);
        let b = ModelWeights::random(&cfg, 42);
        assert_eq!(a.embed_tokens, b.embed_tokens);
        assert_eq!(a.layers[1].w_ff2, b.layers[1].w_ff2);
        let c = ModelWeights::random(&cfg, 43);
        assert_ne!(a.embed_tokens, c.embed_tokens);
    }

    #[test]
    fn tensor_file_roundtrip() {
        let cfg = ModelConfig::vqt_tiny();
        let w = ModelWeights::random(&cfg, 7);
        let tf = w.to_tensor_file();
        let back = ModelWeights::from_tensor_file(&tf, &cfg).unwrap();
        assert_eq!(back.embed_tokens, w.embed_tokens);
        assert_eq!(back.w_cls, w.w_cls);
        for (a, b) in back.layers.iter().zip(&w.layers) {
            assert_eq!(a.wq, b.wq);
            assert_eq!(
                a.vq.as_ref().unwrap().books[0],
                b.vq.as_ref().unwrap().books[0]
            );
        }
    }

    #[test]
    fn load_rejects_wrong_shape() {
        let cfg = ModelConfig::vqt_tiny();
        let w = ModelWeights::random(&cfg, 7);
        let mut tf = w.to_tensor_file();
        tf.insert("w_cls", Tensor::f32(vec![3, 3], vec![0.0; 9]));
        assert!(ModelWeights::from_tensor_file(&tf, &cfg).is_err());
    }

    #[test]
    fn validate_vq_names_the_broken_layer() {
        let cfg = ModelConfig::vqt_tiny();
        let mut w = ModelWeights::random(&cfg, 1);
        assert!(w.validate_vq().is_ok(), "well-formed weights validate");
        w.layers[1].vq = None;
        let err = w.validate_vq().unwrap_err().to_string();
        assert!(err.contains("layer 1 has no VQ config"), "{err}");
        let err = w.layer_vq(1).unwrap_err().to_string();
        assert!(err.contains("layer 1 has no VQ config"), "{err}");
        // Untouched layers still resolve.
        assert!(w.layer_vq(0).is_ok());
        // Out-of-range is a typed error too, not a slice panic.
        let err = w.layer_vq(99).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn validate_vq_is_vacuous_for_baseline_models() {
        let mut cfg = ModelConfig::vqt_tiny();
        cfg.vq_heads = 0;
        let w = ModelWeights::random(&cfg, 1);
        assert!(w.validate_vq().is_ok());
    }

    #[test]
    fn baseline_has_no_vq() {
        let mut cfg = ModelConfig::vqt_tiny();
        cfg.vq_heads = 0;
        let w = ModelWeights::random(&cfg, 1);
        assert!(w.layers.iter().all(|l| l.vq.is_none()));
        // And it round-trips without vq entries.
        let back = ModelWeights::from_tensor_file(&w.to_tensor_file(), &cfg).unwrap();
        assert!(back.layers[0].vq.is_none());
    }
}
