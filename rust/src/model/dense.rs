//! Dense (from-scratch) forward pass — the in-process numerical oracle.
//!
//! Supports both model variants:
//! - `AttentionKind::Softmax` + `vq_heads = 0`: the OPT-style baseline;
//! - `AttentionKind::GeluElementwise` + VQ: the paper's VQT (eq. 1).
//!
//! The incremental engine (`incremental::`) must produce outputs matching
//! this function for any edit sequence — that equivalence is the paper's
//! exactness claim and the core invariant of this repo's test suite.

use crate::config::AttentionKind;
use crate::flops::{self, Cat, FlopLedger, MULADD};
use crate::tensor::{self, Matrix};
use crate::vq::CodeTuple;

use super::weights::ModelWeights;

/// Everything the dense pass produces (enough to cross-check the
/// incremental engine's internal state, not just final logits).
#[derive(Clone, Debug)]
pub struct ForwardOutput {
    /// Final hidden states after `ln_f`, shape (n, d).
    pub hidden: Matrix,
    /// Classifier logits.
    pub logits: Vec<f32>,
    /// Per layer: the VQ code of every row (empty per-layer vecs when the
    /// model has no VQ).
    pub codes: Vec<Vec<CodeTuple>>,
    /// Per layer: the residual-stream input to the block, shape (n, d) —
    /// used by state-parity tests.
    pub layer_inputs: Vec<Matrix>,
}

/// Constant attention-output scale: keeps unnormalized GELU-attention sums
/// in a trainable range (σ(QKᵀ)V grows with context length; a *constant*
/// rescale is incremental-safe, unlike per-row 1/ctx normalization, which
/// would dirty every row on insertion). Shared with the L2 JAX model.
pub fn attn_out_scale(max_seq: usize) -> f32 {
    1.0 / (max_seq as f32).sqrt()
}

/// Run the dense forward pass over `tokens` with positional ids `pos_ids`
/// (strictly increasing, drawn from the position pool — see `positions::`).
pub fn dense_forward(
    w: &ModelWeights,
    tokens: &[u32],
    pos_ids: &[u32],
    ledger: &mut FlopLedger,
) -> ForwardOutput {
    let cfg = &w.cfg;
    let n = tokens.len();
    assert_eq!(n, pos_ids.len(), "tokens/positions length mismatch");
    assert!(n <= cfg.max_seq, "sequence length {n} exceeds max_seq");
    assert!(
        pos_ids.windows(2).all(|p| p[0] < p[1]),
        "pos_ids must be strictly increasing"
    );
    let d = cfg.d_model;

    // --- Embedding ------------------------------------------------------
    let mut x = Matrix::zeros(n, d);
    for i in 0..n {
        let t = tokens[i] as usize;
        let p = pos_ids[i] as usize;
        assert!(t < cfg.vocab_size, "token {t} out of vocab");
        assert!(p < cfg.pos_pool, "position {p} out of pool");
        let row = x.row_mut(i);
        for (o, (&a, &b)) in row
            .iter_mut()
            .zip(w.embed_tokens.row(t).iter().zip(w.embed_pos.row(p)))
        {
            *o = a + b;
        }
    }
    ledger.add(Cat::Embed, (n * d * 2) as u64);

    let mut codes_per_layer = Vec::with_capacity(cfg.n_layers);
    let mut layer_inputs = Vec::with_capacity(cfg.n_layers);

    for layer in &w.layers {
        layer_inputs.push(x.clone());
        let (attn_raw, codes) = block_attention(w, layer, &x, ledger);
        // VQ decode (or identity) → mix → residual, then LN2 → FFN → residual.
        let mut h2 = vec![0.0; d];
        let mut mixed = vec![0.0; d];
        let mut ff_mid = vec![0.0; cfg.d_ff];
        let mut ff_out = vec![0.0; d];
        for i in 0..n {
            // head-mix linear on the (possibly quantized) attention output
            tensor::vec_matmul_into(attn_raw.row(i), &layer.w_mix, &mut mixed);
            for (m, &b) in mixed.iter_mut().zip(&layer.b_mix) {
                *m += b;
            }
            // residual 1
            for (xv, &m) in x.row_mut(i).iter_mut().zip(&mixed) {
                *xv += m;
            }
            // LN2 → FFN → residual 2
            tensor::layernorm_into(x.row(i), &layer.ln2_g, &layer.ln2_b, cfg.ln_eps, &mut h2);
            tensor::vec_matmul_into(&h2, &layer.w_ff1, &mut ff_mid);
            tensor::bias_gelu(&mut ff_mid, &layer.b_ff1);
            tensor::vec_matmul_into(&ff_mid, &layer.w_ff2, &mut ff_out);
            for (v, &b) in ff_out.iter_mut().zip(&layer.b_ff2) {
                *v += b;
            }
            for (xv, &f) in x.row_mut(i).iter_mut().zip(&ff_out) {
                *xv += f;
            }
        }
        codes_per_layer.push(codes);
    }

    // --- Final LN, mean pool, classifier ---------------------------------
    let mut hidden = Matrix::zeros(n, d);
    for i in 0..n {
        tensor::layernorm_into(x.row(i), &w.lnf_g, &w.lnf_b, cfg.ln_eps, hidden.row_mut(i));
    }
    ledger.add(Cat::Elementwise, n as u64 * flops::layernorm_cost(d));
    let mut pooled = vec![0.0; d];
    for i in 0..n {
        tensor::axpy(1.0, hidden.row(i), &mut pooled);
    }
    let inv = 1.0 / n as f32;
    for p in pooled.iter_mut() {
        *p *= inv;
    }
    ledger.add(Cat::Elementwise, (n * d) as u64);
    let mut logits = vec![0.0; cfg.n_classes];
    tensor::vec_matmul_into(&pooled, &w.w_cls, &mut logits);
    for (l, &b) in logits.iter_mut().zip(&w.b_cls) {
        *l += b;
    }
    ledger.add(Cat::Linear, MULADD * (d * cfg.n_classes) as u64);

    ForwardOutput {
        hidden,
        logits,
        codes: codes_per_layer,
        layer_inputs,
    }
}

/// The attention sub-block: LN1 → QKV → multi-head σ(QKᵀ·s)V (causal) →
/// constant rescale → VQ (when configured). Returns the (possibly
/// quantized) attention output rows and per-row codes.
///
/// Ticks the ledger with exactly the analytic per-location + attention-row
/// + VQ costs, so the dense ledger matches `flops::dense_forward_flops`.
fn block_attention(
    w: &ModelWeights,
    layer: &super::weights::LayerWeights,
    x: &Matrix,
    ledger: &mut FlopLedger,
) -> (Matrix, Vec<CodeTuple>) {
    let cfg = &w.cfg;
    let n = x.rows;
    let d = cfg.d_model;
    let nh = cfg.n_heads;
    let dh = cfg.d_head();
    let scale = 1.0 / (dh as f32).sqrt();
    let out_scale = attn_out_scale(cfg.max_seq);

    // Per-location: LN1 + QKV projections (ticked as part of the
    // per-location bundle below, together with mix/LN2/FFN).
    let mut q = Matrix::zeros(n, d);
    let mut k = Matrix::zeros(n, d);
    let mut v = Matrix::zeros(n, d);
    let mut h1 = vec![0.0; d];
    for i in 0..n {
        tensor::layernorm_into(x.row(i), &layer.ln1_g, &layer.ln1_b, cfg.ln_eps, &mut h1);
        tensor::vec_matmul_into(&h1, &layer.wq, q.row_mut(i));
        tensor::vec_matmul_into(&h1, &layer.wk, k.row_mut(i));
        tensor::vec_matmul_into(&h1, &layer.wv, v.row_mut(i));
        for ((qv, &b), ((kv, &bk), (vv, &bv))) in q
            .row_mut(i)
            .iter_mut()
            .zip(&layer.bq)
            .zip(k.row_mut(i).iter_mut().zip(&layer.bk).zip(v.row_mut(i).iter_mut().zip(&layer.bv)))
        {
            *qv += b;
            *kv += bk;
            *vv += bv;
        }
    }
    // Tick the whole per-location bundle for this block at once.
    ledger.add(Cat::Elementwise, n as u64 * 2 * flops::layernorm_cost(d));
    ledger.add(
        Cat::Linear,
        n as u64 * MULADD as u64 * (4 * d * d + 2 * d * cfg.d_ff) as u64,
    );
    ledger.add(
        Cat::Elementwise,
        n as u64 * (cfg.d_ff as u64 * flops::TRANSCENDENTAL + 2 * d as u64),
    );

    // Attention accumulation, causal, per head.
    let mut attn = Matrix::zeros(n, d);
    for i in 0..n {
        for h in 0..nh {
            let qh = &q.row(i)[h * dh..(h + 1) * dh];
            let out = &mut attn.row_mut(i)[h * dh..(h + 1) * dh];
            match cfg.attention {
                AttentionKind::GeluElementwise => {
                    for j in 0..=i {
                        let kh = &k.row(j)[h * dh..(h + 1) * dh];
                        let s = tensor::gelu_scalar(tensor::dot(qh, kh) * scale);
                        if s != 0.0 {
                            tensor::axpy(s, &v.row(j)[h * dh..(h + 1) * dh], out);
                        }
                    }
                }
                AttentionKind::Softmax => {
                    let mut srow: Vec<f32> = (0..=i)
                        .map(|j| tensor::dot(qh, &k.row(j)[h * dh..(h + 1) * dh]) * scale)
                        .collect();
                    tensor::softmax_row(&mut srow);
                    for (j, &s) in srow.iter().enumerate() {
                        tensor::axpy(s, &v.row(j)[h * dh..(h + 1) * dh], out);
                    }
                }
            }
        }
        ledger.add(Cat::Attention, flops::attention_row_cost(cfg, i + 1));
        // Constant output rescale (counted inside attention_row_cost's
        // elementwise slack; one mul per dim).
        for o in attn.row_mut(i) {
            *o *= out_scale;
        }
    }

    // VQ on the attention output.
    match &layer.vq {
        Some(vq) => {
            let mut codes = Vec::with_capacity(n);
            let mut qout = vec![0.0; d];
            for i in 0..n {
                let code = vq.quantize_into(attn.row(i), &mut qout, ledger);
                attn.row_mut(i).copy_from_slice(&qout);
                codes.push(code);
            }
            (attn, codes)
        }
        None => (attn, Vec::new()),
    }
}

/// Predicted class = argmax of logits.
pub fn predict(out: &ForwardOutput) -> usize {
    tensor::argmax(&out.logits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::util::Rng;

    fn seq(n: usize, cfg: &ModelConfig, seed: u64) -> (Vec<u32>, Vec<u32>) {
        let mut r = Rng::new(seed);
        let tokens: Vec<u32> = (0..n).map(|_| r.below(cfg.vocab_size) as u32).collect();
        let pos: Vec<u32> = r
            .sorted_subset(cfg.pos_pool, n)
            .into_iter()
            .map(|p| p as u32)
            .collect();
        (tokens, pos)
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let cfg = ModelConfig::vqt_tiny();
        let w = ModelWeights::random(&cfg, 1);
        let (t, p) = seq(12, &cfg, 2);
        let mut l1 = FlopLedger::new();
        let mut l2 = FlopLedger::new();
        let a = dense_forward(&w, &t, &p, &mut l1);
        let b = dense_forward(&w, &t, &p, &mut l2);
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.hidden.rows, 12);
        assert_eq!(a.codes.len(), cfg.n_layers);
        assert_eq!(a.codes[0].len(), 12);
        assert_eq!(l1, l2);
    }

    #[test]
    fn ledger_matches_analytic_formula() {
        let cfg = ModelConfig::vqt_tiny();
        let w = ModelWeights::random(&cfg, 3);
        for n in [1usize, 5, 32] {
            let (t, p) = seq(n, &cfg, n as u64);
            let mut led = FlopLedger::new();
            dense_forward(&w, &t, &p, &mut led);
            assert_eq!(
                led.total(),
                flops::dense_forward_flops(&cfg, n),
                "n = {n}"
            );
        }
    }

    #[test]
    fn softmax_baseline_runs() {
        let mut cfg = ModelConfig::vqt_tiny();
        cfg.attention = AttentionKind::Softmax;
        cfg.vq_heads = 0;
        let w = ModelWeights::random(&cfg, 4);
        let (t, p) = seq(10, &cfg, 5);
        let mut led = FlopLedger::new();
        let out = dense_forward(&w, &t, &p, &mut led);
        assert!(out.logits.iter().all(|x| x.is_finite()));
        assert!(out.codes.iter().all(|c| c.is_empty()));
        assert_eq!(led.vq, 0);
    }

    #[test]
    fn causality_suffix_edit_preserves_prefix() {
        // Editing token at position p must not change hidden states of rows
        // before p (causal attention).
        let cfg = ModelConfig::vqt_tiny();
        let w = ModelWeights::random(&cfg, 6);
        let (mut t, p) = seq(16, &cfg, 7);
        let mut led = FlopLedger::new();
        let a = dense_forward(&w, &t, &p, &mut led);
        t[10] = (t[10] + 1) % cfg.vocab_size as u32;
        let b = dense_forward(&w, &t, &p, &mut led);
        for i in 0..10 {
            for j in 0..cfg.d_model {
                assert_eq!(a.hidden.get(i, j), b.hidden.get(i, j), "row {i}");
            }
        }
        // And the edited row must differ.
        assert!(a.hidden.row(10) != b.hidden.row(10));
    }

    #[test]
    fn quantized_outputs_are_codewords() {
        let cfg = ModelConfig::vqt_tiny();
        let w = ModelWeights::random(&cfg, 8);
        let (t, p) = seq(8, &cfg, 9);
        let mut led = FlopLedger::new();
        let out = dense_forward(&w, &t, &p, &mut led);
        // Re-derive: codes recorded for every layer/row must decode to a
        // vector the VQ would assign to itself (idempotence).
        for (li, layer) in w.layers.iter().enumerate() {
            let vq = layer.vq.as_ref().unwrap();
            for &code in &out.codes[li] {
                let dec = vq.decode(code);
                let mut led2 = FlopLedger::new();
                assert_eq!(vq.assign(&dec, &mut led2), code);
            }
        }
    }

    #[test]
    #[should_panic]
    fn rejects_unsorted_positions() {
        let cfg = ModelConfig::vqt_tiny();
        let w = ModelWeights::random(&cfg, 1);
        let mut led = FlopLedger::new();
        dense_forward(&w, &[1, 2], &[5, 5], &mut led);
    }
}
