//! Sampled absolute positional embeddings (paper §3.3, App. B).
//!
//! Conventional contiguous positions make token insertion shift every
//! subsequent position — nearly all representations change and nothing can
//! be reused. The paper instead trains positional embeddings on *random
//! ordered subsets* of a large pool (gap_factor × max_seq), so the network
//! only relies on position *order*. At inference we can then assign initial
//! positions with gaps, insert new tokens into gaps, and only *reindex*
//! ("defragment") when a gap is exhausted — an event this module counts so
//! the coordinator can report its amortized cost.

use crate::util::Rng;

/// Outcome of an insertion attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InsertOutcome {
    /// Position allocated in an existing gap; only the new row is fresh.
    InGap(u32),
    /// No gap available — the whole document was reindexed; every row's
    /// position changed (downstream caches are invalid).
    Defragged(u32),
}

/// Allocator of strictly-increasing position ids over a fixed pool.
#[derive(Clone, Debug)]
pub struct PositionAllocator {
    pool: usize,
    /// Current position ids, strictly increasing, one per token row.
    ids: Vec<u32>,
    /// Number of defragmentation events since creation.
    pub defrag_count: u64,
}

impl PositionAllocator {
    /// Evenly-spread initial assignment for `n` rows (deterministic):
    /// ids ≈ (i + 0.5) · pool / n, guaranteeing maximal initial gaps.
    pub fn spread(pool: usize, n: usize) -> PositionAllocator {
        assert!(n <= pool, "{n} rows exceed position pool {pool}");
        let ids = Self::spread_ids(pool, n);
        PositionAllocator {
            pool,
            ids,
            defrag_count: 0,
        }
    }

    /// Random sorted-subset assignment — the *training-time* distribution
    /// (App. B); used by tests to mirror the Python data pipeline.
    pub fn sampled(pool: usize, n: usize, rng: &mut Rng) -> PositionAllocator {
        let ids = rng
            .sorted_subset(pool, n)
            .into_iter()
            .map(|x| x as u32)
            .collect();
        PositionAllocator {
            pool,
            ids,
            defrag_count: 0,
        }
    }

    fn spread_ids(pool: usize, n: usize) -> Vec<u32> {
        (0..n)
            .map(|i| (((2 * i + 1) * pool) / (2 * n.max(1))) as u32)
            .collect()
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn pool(&self) -> usize {
        self.pool
    }

    /// Current ids (strictly increasing).
    pub fn ids(&self) -> &[u32] {
        &self.ids
    }

    /// Insert a token row before index `at` (`at == len` appends). Picks the
    /// midpoint of the surrounding gap; defragments when the gap is empty.
    pub fn insert(&mut self, at: usize) -> InsertOutcome {
        assert!(at <= self.ids.len(), "insert index out of bounds");
        assert!(
            self.ids.len() < self.pool,
            "position pool exhausted ({} rows)",
            self.ids.len()
        );
        let lo: i64 = if at == 0 { -1 } else { self.ids[at - 1] as i64 };
        let hi: i64 = if at == self.ids.len() {
            self.pool as i64
        } else {
            self.ids[at] as i64
        };
        if hi - lo >= 2 {
            let mid = ((lo + hi) / 2) as u32;
            debug_assert!((lo as i64) < mid as i64 && (mid as i64) < hi);
            self.ids.insert(at, mid);
            InsertOutcome::InGap(mid)
        } else {
            // Gap exhausted: reindex everything evenly, then insert.
            self.defrag_count += 1;
            let n = self.ids.len() + 1;
            let fresh = Self::spread_ids(self.pool, n);
            self.ids = fresh.clone();
            // Row `at` now owns fresh[at]; the rest shift by construction.
            InsertOutcome::Defragged(fresh[at])
        }
    }

    /// Remove the row at `at` (its position id returns to the gap pool
    /// implicitly).
    pub fn remove(&mut self, at: usize) -> u32 {
        self.ids.remove(at)
    }

    /// Invariant check: strictly increasing and within pool.
    pub fn check(&self) -> bool {
        self.ids.windows(2).all(|w| w[0] < w[1])
            && self.ids.iter().all(|&p| (p as usize) < self.pool)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spread_is_strictly_increasing_with_gaps() {
        let a = PositionAllocator::spread(4096, 512);
        assert!(a.check());
        assert_eq!(a.len(), 512);
        // Every adjacent pair should leave at least a gap of ~pool/n − 1.
        assert!(a.ids().windows(2).all(|w| w[1] - w[0] >= 7));
    }

    #[test]
    fn insert_in_gap_keeps_order_and_neighbors() {
        let mut a = PositionAllocator::spread(1024, 10);
        let before = a.ids().to_vec();
        match a.insert(5) {
            InsertOutcome::InGap(p) => {
                assert!(before[4] < p && p < before[5]);
            }
            InsertOutcome::Defragged(_) => panic!("huge gaps: no defrag expected"),
        }
        assert!(a.check());
        assert_eq!(a.len(), 11);
        assert_eq!(a.defrag_count, 0);
    }

    #[test]
    fn insert_at_ends() {
        let mut a = PositionAllocator::spread(1024, 4);
        let first = a.ids()[0];
        if let InsertOutcome::InGap(p) = a.insert(0) {
            assert!(p < first);
        } else {
            panic!();
        }
        let last = *a.ids().last().unwrap();
        if let InsertOutcome::InGap(p) = a.insert(a.len()) {
            assert!(p > last);
        } else {
            panic!();
        }
        assert!(a.check());
    }

    #[test]
    fn exhausted_gap_triggers_defrag() {
        // Tiny pool: repeatedly insert at index 1 until the local gap dies.
        let mut a = PositionAllocator::spread(16, 2);
        let mut defragged = false;
        for _ in 0..10 {
            if let InsertOutcome::Defragged(_) = a.insert(1) {
                defragged = true;
                break;
            }
        }
        assert!(defragged, "expected a defrag in a tiny pool");
        assert!(a.defrag_count >= 1);
        assert!(a.check());
    }

    #[test]
    fn defrag_rate_low_with_paper_gap_factor() {
        // With the paper's recommendation (pool ≫ max length), random
        // insertion workloads should defrag rarely.
        let mut rng = Rng::new(17);
        let mut a = PositionAllocator::spread(8 * 512, 256);
        let mut inserts = 0u64;
        while a.len() < 512 {
            let at = rng.below(a.len() + 1);
            a.insert(at);
            inserts += 1;
        }
        assert!(inserts >= 256);
        assert!(
            a.defrag_count * 20 <= inserts,
            "defrag rate too high: {}/{}",
            a.defrag_count,
            inserts
        );
    }

    #[test]
    fn remove_then_insert_reuses_space() {
        let mut a = PositionAllocator::spread(64, 8);
        let removed = a.remove(3);
        assert_eq!(a.len(), 7);
        if let InsertOutcome::InGap(p) = a.insert(3) {
            // The reopened gap contains the old id's neighborhood.
            assert!((p as i64 - removed as i64).abs() <= 8);
        }
        assert!(a.check());
    }

    #[test]
    fn sampled_matches_training_distribution_shape() {
        let mut rng = Rng::new(3);
        let a = PositionAllocator::sampled(1000, 100, &mut rng);
        assert!(a.check());
        assert_eq!(a.len(), 100);
    }

    #[test]
    #[should_panic]
    fn pool_exhaustion_panics() {
        let mut a = PositionAllocator::spread(4, 4);
        a.insert(0);
    }
}

impl PositionAllocator {
    /// Restore from checkpointed ids (must be strictly increasing and
    /// within the pool).
    pub fn restore(pool: usize, ids: Vec<u32>, defrag_count: u64) -> anyhow::Result<Self> {
        let a = PositionAllocator {
            pool,
            ids,
            defrag_count,
        };
        anyhow::ensure!(a.check(), "invalid checkpointed position ids");
        Ok(a)
    }
}
