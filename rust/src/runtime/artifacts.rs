//! Artifact registry: manifest parsing, lazy PJRT compilation, execution.

use super::xla;
use crate::config::ModelConfig;
use crate::util::{Json, TensorFile};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub preset: String,
    /// Model-weight tensor names in artifact argument order.
    pub param_order: Vec<String>,
    /// Sequence-length buckets with a compiled forward.
    pub buckets: Vec<usize>,
    /// Logical name → file name.
    pub artifacts: HashMap<String, String>,
    /// Serving model configuration mirrored from Python.
    pub config: ModelConfig,
}

/// File name of the serving weights inside an artifact directory (the
/// bundle layout is fixed by `python/compile/aot.py`).
pub const WEIGHTS_FILE: &str = "weights_serve.bin";

impl ArtifactManifest {
    /// Path of the serving weights inside an artifact directory. Needs no
    /// PJRT — callers that only want weights + config use this instead of
    /// opening an [`ArtifactRuntime`].
    pub fn weights_path(dir: impl AsRef<Path>) -> PathBuf {
        dir.as_ref().join(WEIGHTS_FILE)
    }

    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactManifest> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let param_order = j
            .get("param_order")
            .as_arr()
            .context("manifest: param_order")?
            .iter()
            .map(|v| v.as_str().unwrap_or_default().to_string())
            .collect();
        let buckets = j
            .get("buckets")
            .as_arr()
            .context("manifest: buckets")?
            .iter()
            .filter_map(|v| v.as_usize())
            .collect();
        let artifacts = j
            .get("artifacts")
            .as_obj()
            .context("manifest: artifacts")?
            .iter()
            .map(|(k, v)| (k.clone(), v.as_str().unwrap_or_default().to_string()))
            .collect();
        let config = ModelConfig::from_json(j.get("config")).context("manifest: config")?;
        Ok(ArtifactManifest {
            preset: j.get("preset").as_str().unwrap_or("?").to_string(),
            param_order,
            buckets,
            artifacts,
            config,
        })
    }

    /// Smallest bucket that fits a document of `n` tokens.
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.buckets
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .with_context(|| format!("no artifact bucket fits sequence length {n}"))
    }
}

/// Lazily-compiled PJRT executables over the artifact directory.
pub struct ArtifactRuntime {
    dir: PathBuf,
    pub manifest: ArtifactManifest,
    client: xla::PjRtClient,
    /// Weight literals in `param_order`, prepared once at load.
    param_literals: Vec<xla::Literal>,
    /// Layer-0 VQ (books, bias) literals for the standalone L1 artifact.
    vq_literals: Option<(xla::Literal, xla::Literal)>,
    /// Logical artifact name → compiled executable (lazy).
    ///
    /// NOTE: the `xla` crate's PJRT handles are `Rc`-based (not `Send`),
    /// so an `ArtifactRuntime` lives on one thread; the coordinator owns
    /// it on its worker thread and fronts it with channels.
    compiled: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl ArtifactRuntime {
    /// Open the artifact directory: parse the manifest, load weights,
    /// create the PJRT CPU client.
    pub fn open(dir: impl AsRef<Path>) -> Result<ArtifactRuntime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = ArtifactManifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let weights = TensorFile::load(ArtifactManifest::weights_path(&dir))?;
        let mut param_literals = Vec::with_capacity(manifest.param_order.len());
        for name in &manifest.param_order {
            let t = weights.get(name)?;
            let lit = match t {
                crate::util::Tensor::F32 { dims, data } => {
                    let l = xla::Literal::vec1(data);
                    if dims.is_empty() {
                        l
                    } else {
                        let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                        l.reshape(&dims)?
                    }
                }
                crate::util::Tensor::I32 { dims, data } => {
                    let l = xla::Literal::vec1(data);
                    let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                    l.reshape(&dims)?
                }
            };
            param_literals.push(lit);
        }
        log::info!(
            "artifact runtime: preset={} buckets={:?} params={} ({} artifacts)",
            manifest.preset,
            manifest.buckets,
            param_literals.len(),
            manifest.artifacts.len()
        );
        // Layer-0 codebooks + biases (b = −‖c‖²/2) for the standalone
        // vq_assign artifact.
        let vq_literals = if manifest.config.vq_heads > 0 {
            let (dims, data) = weights.get("layers.0.vq.book")?.as_f32()?;
            let (h, q, chunk) = (dims[0], dims[1], dims[2]);
            let books = xla::Literal::vec1(data).reshape(&[h as i64, q as i64, chunk as i64])?;
            let mut bias = vec![0f32; h * q];
            for hh in 0..h {
                for qq in 0..q {
                    let row = &data[(hh * q + qq) * chunk..(hh * q + qq + 1) * chunk];
                    bias[hh * q + qq] = -0.5 * row.iter().map(|x| x * x).sum::<f32>();
                }
            }
            let bias = xla::Literal::vec1(&bias).reshape(&[h as i64, q as i64])?;
            Some((books, bias))
        } else {
            None
        };
        Ok(ArtifactRuntime {
            dir,
            manifest,
            client,
            param_literals,
            vq_literals,
            compiled: RefCell::new(HashMap::new()),
        })
    }

    /// The serving model's weights file (for building the in-process
    /// engine against the same parameters the artifacts use).
    pub fn weights_path(&self) -> PathBuf {
        ArtifactManifest::weights_path(&self.dir)
    }

    /// Compile (or fetch cached) a logical artifact.
    fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.compiled.borrow().get(name) {
            return Ok(e.clone());
        }
        let file = self
            .manifest
            .artifacts
            .get(name)
            .with_context(|| format!("unknown artifact '{name}'"))?;
        let path = self.dir.join(file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile of {name}"))?;
        log::info!("compiled artifact {name} in {:.2}s", t0.elapsed().as_secs_f64());
        let exe = Rc::new(exe);
        self.compiled
            .borrow_mut()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Warm the compile cache for every bucket (server startup).
    pub fn warmup(&self) -> Result<()> {
        for &b in &self.manifest.buckets.clone() {
            self.executable(&format!("model_fwd_n{b}"))?;
        }
        Ok(())
    }

    /// Dense forward through the AOT model: pad to a bucket, execute,
    /// return logits. This is the L2 path the incremental engine is
    /// validated against (and the "dense baseline" serving mode).
    pub fn dense_logits(&self, tokens: &[u32], pos_ids: &[u32]) -> Result<Vec<f32>> {
        let n = tokens.len();
        anyhow::ensure!(n == pos_ids.len(), "tokens/pos length mismatch");
        let bucket = self.manifest.bucket_for(n)?;
        let exe = self.executable(&format!("model_fwd_n{bucket}"))?;
        let cfg = &self.manifest.config;
        let pad_tok = (cfg.vocab_size - 1) as i32;
        let mut toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let mut pos: Vec<i32> = pos_ids.iter().map(|&p| p as i32).collect();
        // Pad rows: PAD token. Pad positions are masked out of attention
        // columns and pooling, so any in-pool id works (wrap past the last
        // real position; collisions with real ids are harmless).
        let last = pos.last().copied().unwrap_or(-1);
        for i in 0..(bucket - n) {
            toks.push(pad_tok);
            pos.push(((last as i64 + 1 + i as i64) % cfg.pos_pool as i64) as i32);
        }
        let tail = [
            xla::Literal::vec1(&toks),
            xla::Literal::vec1(&pos),
            xla::Literal::scalar(n as i32),
        ];
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(self.param_literals.len() + 3);
        args.extend(self.param_literals.iter());
        args.extend(tail.iter());
        let result = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple1()?;
        Ok(tuple.to_vec::<f32>()?)
    }

    /// Execute the standalone L1 VQ-assignment artifact (microbench/tests).
    pub fn vq_assign(&self, x: &crate::tensor::Matrix) -> Result<Vec<i32>> {
        let n = x.rows;
        let name = self
            .manifest
            .artifacts
            .keys()
            .find(|k| k.starts_with("vq_assign_n"))
            .cloned()
            .context("no vq_assign artifact")?;
        let want_n: usize = name.trim_start_matches("vq_assign_n").parse()?;
        anyhow::ensure!(n == want_n, "vq_assign artifact expects n={want_n}, got {n}");
        let exe = self.executable(&name)?;
        let lit = super::matrix_to_literal(x)?;
        let (books, bias) = self.vq_literals.as_ref().context("no VQ literals")?;
        let args = [&lit, books, bias];
        let result = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple1()?;
        Ok(tuple.to_vec::<i32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let m = ArtifactManifest {
            preset: "t".into(),
            param_order: vec![],
            buckets: vec![32, 64, 128],
            artifacts: HashMap::new(),
            config: ModelConfig::vqt_tiny(),
        };
        assert_eq!(m.bucket_for(1).unwrap(), 32);
        assert_eq!(m.bucket_for(32).unwrap(), 32);
        assert_eq!(m.bucket_for(33).unwrap(), 64);
        assert_eq!(m.bucket_for(128).unwrap(), 128);
        assert!(m.bucket_for(129).is_err());
    }
}
