//! PJRT runtime: load AOT-compiled HLO-text artifacts (built once by
//! `make artifacts` from the L2 JAX model) and execute them from the Rust
//! request path. Python never runs here.
//!
//! Pattern: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`. Artifacts
//! are compiled lazily on first use and cached for the lifetime of the
//! runtime.
//!
//! The `xla` binding crate is not in the offline crate set, so [`xla`]
//! here is an in-crate stub: [`Literal`](xla::Literal) is fully
//! functional host data, while device entry points report "PJRT backend
//! unavailable" and every caller falls back to the in-process oracle.
//! See the [`xla`] module docs for the swap-in path to a real binding.

pub mod artifacts;
pub mod xla;

pub use artifacts::{ArtifactManifest, ArtifactRuntime};

use crate::tensor::Matrix;
use anyhow::Result;

/// Convert a Matrix to a 2-D f32 literal.
pub fn matrix_to_literal(m: &Matrix) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&m.data).reshape(&[m.rows as i64, m.cols as i64])?)
}

/// Convert a flat i32 slice to a 1-D literal.
pub fn i32_literal(xs: &[i32]) -> xla::Literal {
    xla::Literal::vec1(xs)
}

/// Extract an f32 vector from a literal.
pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let m = Matrix::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        let lit = matrix_to_literal(&m).unwrap();
        let back = literal_to_f32(&lit).unwrap();
        assert_eq!(back, m.data);
    }
}
