//! In-crate stand-in for the `xla` PJRT binding crate.
//!
//! The offline crate set ships only `anyhow` and `log` (see
//! `util/mod.rs`), so the real `xla` crate — Rust FFI over
//! `xla_extension` / PJRT — cannot be a dependency yet. This module
//! mirrors exactly the API surface [`super::artifacts`] is written
//! against:
//!
//! - [`Literal`] is **fully functional**: it is plain host data
//!   (dims + typed buffer) and is exercised by the literal round-trip
//!   tests in `runtime/mod.rs`.
//! - The device entry points ([`PjRtClient::cpu`],
//!   [`HloModuleProto::from_text_file`], compile/execute) return a clean
//!   "PJRT backend unavailable" error. `ArtifactRuntime::open` therefore
//!   fails fast, the coordinator logs a warning, and dense requests fall
//!   back to the in-process oracle (`model::dense_forward`) — every
//!   caller degrades gracefully and no test depends on a live PJRT.
//!
//! Swapping in the real binding later is local to `runtime/mod.rs`
//! (re-export the external crate instead of this module); the call sites
//! in `artifacts.rs` already use the real crate's method names and
//! signatures.

use std::fmt;

/// Error type for all fallible stub operations.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

/// Result alias matching the real crate's.
pub type Result<T> = std::result::Result<T, XlaError>;

const UNAVAILABLE: &str = "PJRT backend unavailable: the `xla` binding crate is not in the \
     offline crate set; dense requests use the in-process oracle";

fn unavailable<T>() -> Result<T> {
    Err(XlaError(UNAVAILABLE.to_string()))
}

/// Typed storage of a [`Literal`]. Public only because it appears in the
/// [`NativeType`] trait signature; construct literals via
/// [`Literal::vec1`] / [`Literal::scalar`].
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Element types a [`Literal`] can hold (`f32`, `i32`). Sealed.
pub trait NativeType: sealed::Sealed + Copy {
    #[doc(hidden)]
    fn wrap(data: Vec<Self>) -> Data
    where
        Self: Sized;
    #[doc(hidden)]
    fn unwrap(data: &Data) -> Result<Vec<Self>>
    where
        Self: Sized;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Data {
        Data::F32(data)
    }
    fn unwrap(data: &Data) -> Result<Vec<f32>> {
        match data {
            Data::F32(v) => Ok(v.clone()),
            Data::I32(_) => Err(XlaError("literal is i32, asked for f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Data {
        Data::I32(data)
    }
    fn unwrap(data: &Data) -> Result<Vec<i32>> {
        match data {
            Data::I32(v) => Ok(v.clone()),
            Data::F32(_) => Err(XlaError("literal is f32, asked for i32".into())),
        }
    }
}

/// Host tensor literal: dims + typed data (mirrors `xla::Literal`).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: T::wrap(data.to_vec()),
        }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal {
            dims: vec![],
            data: T::wrap(vec![v]),
        }
    }

    fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.len() {
            return Err(XlaError(format!(
                "reshape to {dims:?} ({want} elems) from {} elems",
                self.len()
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    /// Copy out the host data.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
    }

    /// Unwrap a 1-element tuple result (the artifacts are lowered with
    /// `return_tuple=True`). The stub has no device results to unwrap;
    /// kept for API parity.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Ok(self.clone())
    }
}

/// Parsed HLO module (device-only in the real crate; opaque here).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// Computation wrapper (opaque).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] always errors in the stub.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Compiled executable handle (unreachable in the stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Device buffer handle (unreachable in the stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_holds_real_data() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
        let s = Literal::scalar(7i32);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn device_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nope.hlo.txt").is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT backend unavailable"));
    }
}
