//! Arithmetic-operation accounting — the paper's evaluation metric.
//!
//! Table 2 and Figures 3/4 of the paper report *theoretical arithmetic
//! operations* ratios between the plain dense forward pass and the
//! incremental VQT forward pass. This module provides
//! (a) a `FlopLedger` the engines tick as they perform work, and
//! (b) closed-form dense-forward formulas so baselines (OPT-125M-scale
//!     included) can be reported without executing the dense pass.
//!
//! Convention: one multiply-accumulate = 2 ops; element-wise transcendental
//! (gelu/exp/tanh) = 8 ops; compare/select = 1 op. Constants cancel in the
//! dense/incremental *ratio* as long as both sides use the same convention,
//! which they do.

use crate::config::{AttentionKind, ModelConfig};

/// Cost classes, mirroring where time goes in a transformer block.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Cat {
    /// Linear projections (QKV, head mix, FFN) and classifier matmuls.
    Linear,
    /// Attention score/value aggregation (the n² part).
    Attention,
    /// VQ codebook scoring / assignment.
    Vq,
    /// Per-location element-wise work: layernorm, activations, residuals.
    Elementwise,
    /// Embedding gathers and positional adds.
    Embed,
    /// Compressed-format bookkeeping (index ops, memo lookups) — counted so
    /// we can show overhead is negligible, as the paper assumes.
    Bookkeeping,
}

pub const ALL_CATS: [Cat; 6] = [
    Cat::Linear,
    Cat::Attention,
    Cat::Vq,
    Cat::Elementwise,
    Cat::Embed,
    Cat::Bookkeeping,
];

/// Per-op-cost constants (see module docs).
pub const MULADD: u64 = 2;
pub const TRANSCENDENTAL: u64 = 8;

/// Accumulates operation counts by category.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FlopLedger {
    pub linear: u64,
    pub attention: u64,
    pub vq: u64,
    pub elementwise: u64,
    pub embed: u64,
    pub bookkeeping: u64,
}

impl FlopLedger {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, cat: Cat, ops: u64) {
        match cat {
            Cat::Linear => self.linear += ops,
            Cat::Attention => self.attention += ops,
            Cat::Vq => self.vq += ops,
            Cat::Elementwise => self.elementwise += ops,
            Cat::Embed => self.embed += ops,
            Cat::Bookkeeping => self.bookkeeping += ops,
        }
    }

    pub fn get(&self, cat: Cat) -> u64 {
        match cat {
            Cat::Linear => self.linear,
            Cat::Attention => self.attention,
            Cat::Vq => self.vq,
            Cat::Elementwise => self.elementwise,
            Cat::Embed => self.embed,
            Cat::Bookkeeping => self.bookkeeping,
        }
    }

    /// Total ops across all categories.
    pub fn total(&self) -> u64 {
        self.linear + self.attention + self.vq + self.elementwise + self.embed + self.bookkeeping
    }

    /// Merge another ledger in.
    pub fn merge(&mut self, other: &FlopLedger) {
        self.linear += other.linear;
        self.attention += other.attention;
        self.vq += other.vq;
        self.elementwise += other.elementwise;
        self.embed += other.embed;
        self.bookkeeping += other.bookkeeping;
    }

    /// Difference since a snapshot (self must be the later state).
    pub fn since(&self, snapshot: &FlopLedger) -> FlopLedger {
        FlopLedger {
            linear: self.linear - snapshot.linear,
            attention: self.attention - snapshot.attention,
            vq: self.vq - snapshot.vq,
            elementwise: self.elementwise - snapshot.elementwise,
            embed: self.embed - snapshot.embed,
            bookkeeping: self.bookkeeping - snapshot.bookkeeping,
        }
    }
}

/// Cost of layer-norming one d-vector.
pub fn layernorm_cost(d: usize) -> u64 {
    // mean + var (2 passes of d muladds) + normalize (d mul + d muladd) + sqrt
    (4 * d) as u64 * MULADD / 2 + (2 * d) as u64 + TRANSCENDENTAL
}

/// Cost of the per-location (non-attention) path for ONE sequence position:
/// LN1 + QKV proj + head-mix + LN2 + FFN + activations + residuals.
pub fn per_location_cost(cfg: &ModelConfig) -> u64 {
    let d = cfg.d_model as u64;
    let dff = cfg.d_ff as u64;
    let mut ops = 0u64;
    ops += layernorm_cost(cfg.d_model) * 2; // LN1, LN2
    ops += MULADD * 3 * d * d; // Q,K,V projections
    ops += MULADD * d * d; // head-mix linear
    ops += MULADD * 2 * d * dff; // FFN up + down
    ops += dff * TRANSCENDENTAL; // FFN activation
    ops += 2 * d; // two residual adds
    ops
}

/// Cost of one attention row with `ctx` visible key/value positions
/// (causal ⇒ ctx = position index + 1), for all heads combined:
/// scores (d muladds/position) + per-head scale & non-linearity + A·V
/// (d muladds/position) + the constant output rescale.
pub fn attention_row_cost(cfg: &ModelConfig, ctx: usize) -> u64 {
    let d = cfg.d_model as u64;
    let c = ctx as u64;
    let nh = cfg.n_heads as u64;
    let act = match cfg.attention {
        AttentionKind::GeluElementwise => TRANSCENDENTAL,
        AttentionKind::Softmax => TRANSCENDENTAL + 3, // exp + max/sum/normalize
    };
    MULADD * c * d          // scores
        + c * nh            // score scale muls
        + act * c * nh      // non-linearity per head per position
        + MULADD * c * d    // A·V
        + d                 // constant output rescale
}

// ---------------------------------------------------------------------------
// Streaming-softmax (semi-naive) attention attribution — the engine-side
// charges for softmax sessions (docs/ARCHITECTURE.md §12). These are the
// exact amounts `IncrementalEngine` ticks, so the per-row delta-vs-full
// decision can be made by comparing the two closed forms, and the ledger
// identity `flops_full − flops_delta == Σ per-row savings` holds exactly.
// ---------------------------------------------------------------------------

/// Cost of renormalizing one row's aggregates into its accumulator:
/// one reciprocal per attention head + one multiply per output element.
pub fn attn_sm_renorm_cost(cfg: &ModelConfig) -> u64 {
    cfg.n_heads as u64 + cfg.d_model as u64
}

/// Cost of ONE side term (subtract-old or add-new) of a streaming-softmax
/// delta update, all heads combined: the q·k score dots (d muladds), the
/// per-head scale multiply and exp, the per-head denominator update, and
/// the numerator axpy (d muladds). Deliberately identical to the
/// per-column cost inside [`attn_sm_full_cost`] — the same arithmetic is
/// performed either way, so `delta < full ⟺ sides < ctx`.
pub fn attn_sm_side_cost(cfg: &ModelConfig) -> u64 {
    let d = cfg.d_model as u64;
    let nh = cfg.n_heads as u64;
    2 * MULADD * d + nh * (2 + TRANSCENDENTAL)
}

/// Cost of a streaming-softmax delta update applying `sides` side terms
/// (a modified column contributes two — subtract old, add new; an
/// inserted or removed column contributes one) plus the final renorm.
pub fn attn_sm_delta_cost(cfg: &ModelConfig, sides: usize) -> u64 {
    sides as u64 * attn_sm_side_cost(cfg) + attn_sm_renorm_cost(cfg)
}

/// Cost of a full streaming-softmax recompute of one row over `ctx`
/// visible columns: per column the same side-term arithmetic (the
/// per-head max scan costs what the per-head denominator update costs —
/// one op per head per column), plus the final renorm.
pub fn attn_sm_full_cost(cfg: &ModelConfig, ctx: usize) -> u64 {
    ctx as u64 * attn_sm_side_cost(cfg) + attn_sm_renorm_cost(cfg)
}

/// Cost of multi-head VQ assignment of one d-vector against the per-head
/// codebooks (scores matmul + bias + argmax), per App. A.2's formulation.
pub fn vq_assign_cost(cfg: &ModelConfig) -> u64 {
    if cfg.vq_heads == 0 {
        return 0;
    }
    let d = cfg.d_model as u64;
    let q = cfg.vq_codes as u64;
    // per head: (d/h)·q muladds; summed over heads = d·q. + q bias adds + q compares per head.
    MULADD * d * q + (cfg.vq_heads as u64) * 2 * q
}

/// Closed-form dense forward cost for a causal transformer of `cfg` over a
/// sequence of `n` tokens. This is what a from-scratch revision costs, and
/// the numerator of every speedup the paper reports.
pub fn dense_forward_flops(cfg: &ModelConfig, n: usize) -> u64 {
    let d = cfg.d_model as u64;
    let nn = n as u64;
    let mut ops = 0u64;
    // Embedding gather + positional add.
    ops += nn * d * 2;
    for _ in 0..cfg.n_layers {
        ops += nn * per_location_cost(cfg);
        for i in 0..n {
            ops += attention_row_cost(cfg, i + 1);
        }
        ops += nn * vq_assign_cost(cfg);
    }
    // Final LN + mean-pool + classifier.
    ops += nn * layernorm_cost(cfg.d_model);
    ops += nn * d; // pooling
    ops += MULADD * d * cfg.n_classes as u64;
    ops
}

/// The fraction of dense-forward work that is per-location (the paper cites
/// >70 % for common configs, >97 % for GPT-3 scale) — used as a sanity check
/// in tests and reported by the benches.
pub fn per_location_fraction(cfg: &ModelConfig, n: usize) -> f64 {
    let per_loc: u64 = (0..cfg.n_layers)
        .map(|_| n as u64 * per_location_cost(cfg))
        .sum();
    per_loc as f64 / dense_forward_flops(cfg, n) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn ledger_totals_and_merge() {
        let mut a = FlopLedger::new();
        a.add(Cat::Linear, 10);
        a.add(Cat::Vq, 5);
        let mut b = FlopLedger::new();
        b.add(Cat::Linear, 3);
        a.merge(&b);
        assert_eq!(a.linear, 13);
        assert_eq!(a.total(), 18);
        let snap = a.clone();
        a.add(Cat::Attention, 7);
        assert_eq!(a.since(&snap).attention, 7);
        assert_eq!(a.since(&snap).linear, 0);
    }

    #[test]
    fn dense_flops_scale_superlinearly_in_n() {
        let cfg = ModelConfig::vqt_mini();
        let f1 = dense_forward_flops(&cfg, 128);
        let f2 = dense_forward_flops(&cfg, 256);
        assert!(f2 > 2 * f1, "attention term must make cost superlinear");
        assert!(f2 < 5 * f1);
    }

    #[test]
    fn opt125m_per_location_fraction_matches_paper_claim() {
        // Paper §3.2: per-location ops are >70 % of the forward pass for
        // common configurations. Check at OPT-125M scale, n = 2048.
        let cfg = ModelConfig::opt_125m_scale();
        let frac = per_location_fraction(&cfg, 2048);
        assert!(frac > 0.55, "per-location fraction {frac}");
        // And at shorter sequences it should dominate even more.
        let frac_short = per_location_fraction(&cfg, 512);
        assert!(frac_short > frac);
        assert!(frac_short > 0.8, "short-seq fraction {frac_short}");
    }

    #[test]
    fn vq_cost_zero_without_heads() {
        let mut cfg = ModelConfig::vqt_mini();
        cfg.vq_heads = 0;
        assert_eq!(vq_assign_cost(&cfg), 0);
    }

    #[test]
    fn attn_sm_delta_wins_exactly_when_sides_below_ctx() {
        // The decision rule of docs/ARCHITECTURE.md §12: side-term and
        // per-column costs are identical by construction, so the ledger
        // comparison reduces to `sides < ctx` — locked here so a later
        // formula change can't silently skew the decision boundary.
        let cfg = ModelConfig::vqt_mini();
        for ctx in [1usize, 2, 7, 64, 512] {
            for sides in [1usize, 2, 7, 64, 512] {
                let delta = attn_sm_delta_cost(&cfg, sides);
                let full = attn_sm_full_cost(&cfg, ctx);
                assert_eq!(delta < full, sides < ctx, "sides {sides} ctx {ctx}");
            }
        }
    }

    #[test]
    fn attn_sm_costs_compose_from_sides_and_renorm() {
        let cfg = ModelConfig::vqt_tiny();
        let side = attn_sm_side_cost(&cfg);
        let renorm = attn_sm_renorm_cost(&cfg);
        assert_eq!(attn_sm_delta_cost(&cfg, 0), renorm);
        assert_eq!(attn_sm_delta_cost(&cfg, 3), 3 * side + renorm);
        assert_eq!(attn_sm_full_cost(&cfg, 5), 5 * side + renorm);
        // The savings of a delta row is full − delta — always positive on
        // the delta side of the decision boundary.
        assert!(attn_sm_full_cost(&cfg, 10) > attn_sm_delta_cost(&cfg, 2));
    }
}

#[cfg(test)]
mod scaling_tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::edits::Edit;
    use crate::incremental::{EngineOptions, IncrementalEngine};
    use crate::model::ModelWeights;
    use std::sync::Arc;

    /// The paper's core complexity claim at the op-count level: the
    /// speedup of one atomic edit over a dense pass grows with document
    /// length (dense is Θ(n·d²+n²·d); a fixed-relative-position edit costs
    /// Θ(n) corrections).
    #[test]
    fn edit_cost_scales_sublinearly_in_document_length() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 3));
        let mut costs = Vec::new();
        let mut denses = Vec::new();
        for n in [16usize, 32, 64] {
            let tokens: Vec<u32> = (0..n).map(|i| (i * 13 % 60) as u32).collect();
            let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
            let rep = eng.apply_edit(Edit::Replace { at: n / 4, tok: 1 });
            costs.push(rep.flops as f64);
            denses.push(dense_forward_flops(&cfg, n) as f64);
        }
        let s0 = denses[0] / costs[0];
        let s2 = denses[2] / costs[2];
        assert!(s2 > s0, "speedup should grow with n: {s0} → {s2}");
    }

    /// Distil's Table-2 row: the FLOP ratio of half-depth models is ≈2×.
    #[test]
    fn distil_ratio_is_two() {
        let full = ModelConfig::table1("opt").unwrap();
        let half = ModelConfig::table1("distil").unwrap();
        let r = dense_forward_flops(&full, 128) as f64 / dense_forward_flops(&half, 128) as f64;
        assert!((1.7..=2.2).contains(&r), "depth ratio {r}");
    }
}
