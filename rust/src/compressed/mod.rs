//! The compressed vector-quantized activation format (paper §3.1) and the
//! efficient operations defined over it (§3.2, App. A.3).
//!
//! A batch of activations `X ∈ R^{b×n×d}` whose vectors are quantized can be
//! stored as a *codebook* `C ∈ R^{q×d}` of the unique vectors plus an index
//! matrix `P ∈ {1..q}^{b×n}`. When the batch holds near-identical revisions
//! of one document, `P`'s columns agree almost everywhere, so `P` itself is
//! stored as a per-location *base* index plus sparse per-member overrides —
//! `O((n+b))` indices and `O((n+b)·d)` floats instead of `O(b·n·d)`.
//!
//! Operations:
//! - per-location maps `Y = F(X)` touch only the codebook: `(P, F(C))`;
//! - binary element-wise ops resolve the *unique pairs* of operand indices
//!   (App. A.3), growing the codebook additively for aligned operands;
//! - materialization is only for tests/debugging.

use crate::flops::{Cat, FlopLedger};
use std::collections::HashMap;

/// Dense-id interner for arbitrary u64 keys (hash-consing). The engine uses
/// it to give every distinct quantized vector / residual-stream state a
/// compact identity.
#[derive(Clone, Debug, Default)]
pub struct Interner {
    map: HashMap<(u64, u64), u32>,
}

impl Interner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern a (namespace, key) pair into a dense id.
    pub fn intern(&mut self, ns: u64, key: u64) -> u32 {
        let next = self.map.len() as u32;
        *self.map.entry((ns, key)).or_insert(next)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// The compressed batch representation of one layer's activations.
#[derive(Clone, Debug)]
pub struct CompressedBatch {
    /// Sequence length (aligned across the batch; see §3.3 offline padding).
    pub n: usize,
    /// Batch size.
    pub b: usize,
    /// Vector width.
    pub d: usize,
    /// Codebook of unique vectors, row per index.
    pub codebook: Vec<Vec<f32>>,
    /// Base index per sequence location (the majority value of P[:, j]).
    pub base: Vec<u32>,
    /// Per member: sparse overrides (location, codebook index), sorted by
    /// location.
    pub overrides: Vec<Vec<(u32, u32)>>,
}

impl CompressedBatch {
    /// Build from a dense batch (`rows[member][loc]` of d-vectors) by
    /// hashing exact vector bit-patterns. Used by tests and by the batch
    /// ingestion path after quantization guarantees exact repeats.
    pub fn from_dense(batch: &[Vec<Vec<f32>>]) -> CompressedBatch {
        assert!(!batch.is_empty());
        let b = batch.len();
        let n = batch[0].len();
        let d = if n > 0 { batch[0][0].len() } else { 0 };
        let mut codebook: Vec<Vec<f32>> = Vec::new();
        let mut lut: HashMap<Vec<u32>, u32> = HashMap::new();
        let mut p = vec![vec![0u32; n]; b];
        for (bi, member) in batch.iter().enumerate() {
            assert_eq!(member.len(), n, "ragged batch");
            for (j, v) in member.iter().enumerate() {
                assert_eq!(v.len(), d);
                let bits: Vec<u32> = v.iter().map(|x| x.to_bits()).collect();
                let idx = *lut.entry(bits).or_insert_with(|| {
                    codebook.push(v.clone());
                    (codebook.len() - 1) as u32
                });
                p[bi][j] = idx;
            }
        }
        Self::from_index_matrix(n, b, d, codebook, &p)
    }

    /// Build from an explicit index matrix, choosing the per-location
    /// majority as base.
    pub fn from_index_matrix(
        n: usize,
        b: usize,
        d: usize,
        codebook: Vec<Vec<f32>>,
        p: &[Vec<u32>],
    ) -> CompressedBatch {
        let mut base = vec![0u32; n];
        for j in 0..n {
            let mut counts: HashMap<u32, usize> = HashMap::new();
            for member in p {
                *counts.entry(member[j]).or_insert(0) += 1;
            }
            base[j] = counts
                .into_iter()
                .max_by_key(|&(idx, c)| (c, std::cmp::Reverse(idx)))
                .map(|(idx, _)| idx)
                .unwrap_or(0);
        }
        let overrides = p
            .iter()
            .map(|member| {
                member
                    .iter()
                    .enumerate()
                    .filter(|&(j, &idx)| idx != base[j])
                    .map(|(j, &idx)| (j as u32, idx))
                    .collect()
            })
            .collect();
        CompressedBatch {
            n,
            b,
            d,
            codebook,
            base,
            overrides,
        }
    }

    /// Index of member `bi` at location `j`.
    #[inline]
    pub fn index_at(&self, bi: usize, j: usize) -> u32 {
        match self.overrides[bi].binary_search_by_key(&(j as u32), |&(l, _)| l) {
            Ok(k) => self.overrides[bi][k].1,
            Err(_) => self.base[j],
        }
    }

    /// Materialize one member densely (test/debug only).
    pub fn materialize(&self, bi: usize) -> Vec<Vec<f32>> {
        (0..self.n)
            .map(|j| self.codebook[self.index_at(bi, j) as usize].clone())
            .collect()
    }

    /// Total override count (the sparse part of P).
    pub fn override_count(&self) -> usize {
        self.overrides.iter().map(|o| o.len()).sum()
    }

    /// Floats stored by this representation (codebook + indices at one
    /// float-equivalent each, conservatively).
    pub fn storage_floats(&self) -> usize {
        self.codebook.len() * self.d + self.n + 2 * self.override_count()
    }

    /// Floats a dense representation would store.
    pub fn dense_floats(&self) -> usize {
        self.b * self.n * self.d
    }

    /// Apply a per-location vector map `f` (§3.2): only the codebook is
    /// touched — `O(q·cost(f))` instead of `O(b·n·cost(f))`. The ledger is
    /// ticked `per_vector_ops × q`.
    pub fn map_per_location(
        &self,
        mut f: impl FnMut(&[f32]) -> Vec<f32>,
        per_vector_ops: u64,
        ledger: &mut FlopLedger,
    ) -> CompressedBatch {
        let codebook: Vec<Vec<f32>> = self.codebook.iter().map(|v| f(v)).collect();
        ledger.add(Cat::Elementwise, per_vector_ops * self.codebook.len() as u64);
        let d = codebook.first().map(|v| v.len()).unwrap_or(0);
        CompressedBatch {
            n: self.n,
            b: self.b,
            d,
            codebook,
            base: self.base.clone(),
            overrides: self.overrides.clone(),
        }
    }

    /// Binary element-wise op with another compressed batch over the same
    /// (b, n) geometry (App. A.3): resolves unique index *pairs*, applies
    /// `f` once per unique pair, and re-bases. Codebook growth is additive
    /// when the operands are aligned revisions of the same input.
    pub fn zip_binary(
        &self,
        other: &CompressedBatch,
        mut f: impl FnMut(&[f32], &[f32]) -> Vec<f32>,
        per_vector_ops: u64,
        ledger: &mut FlopLedger,
    ) -> CompressedBatch {
        assert_eq!((self.n, self.b), (other.n, other.b), "geometry mismatch");
        let mut pair_lut: HashMap<(u32, u32), u32> = HashMap::new();
        let mut codebook: Vec<Vec<f32>> = Vec::new();
        let mut p = vec![vec![0u32; self.n]; self.b];
        for bi in 0..self.b {
            for j in 0..self.n {
                let pair = (self.index_at(bi, j), other.index_at(bi, j));
                let idx = *pair_lut.entry(pair).or_insert_with(|| {
                    codebook.push(f(
                        &self.codebook[pair.0 as usize],
                        &other.codebook[pair.1 as usize],
                    ));
                    (codebook.len() - 1) as u32
                });
                p[bi][j] = idx;
                // Index-pair resolution bookkeeping (cheap, but counted —
                // the paper's O(B log B) term).
                ledger.add(Cat::Bookkeeping, 1);
            }
        }
        ledger.add(Cat::Elementwise, per_vector_ops * codebook.len() as u64);
        Self::from_index_matrix(self.n, self.b, self.d, codebook, &p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Build a batch of `b` members that share a base sequence with `k`
    /// per-member divergent locations — the revision-batch shape.
    fn revision_like(b: usize, n: usize, d: usize, k: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
        let mut r = Rng::new(seed);
        // Quantized-like vocabulary of 8 distinct vectors.
        let vocab: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..d).map(|_| (r.below(5) as f32) - 2.0).collect())
            .collect();
        let base: Vec<usize> = (0..n).map(|_| r.below(vocab.len())).collect();
        (0..b)
            .map(|_| {
                let mut rows: Vec<Vec<f32>> =
                    base.iter().map(|&i| vocab[i].clone()).collect();
                for _ in 0..k {
                    let j = r.below(n);
                    rows[j] = vocab[r.below(vocab.len())].clone();
                }
                rows
            })
            .collect()
    }

    #[test]
    fn roundtrip_materialization() {
        let batch = revision_like(4, 20, 6, 3, 1);
        let c = CompressedBatch::from_dense(&batch);
        for (bi, member) in batch.iter().enumerate() {
            assert_eq!(&c.materialize(bi), member);
        }
    }

    #[test]
    fn storage_is_near_linear_not_b_n_d() {
        // §3.1: storage O((n+b)·d) ≪ O(b·n·d) for revision-like batches.
        let (b, n, d, k) = (16, 64, 32, 2);
        let batch = revision_like(b, n, d, k, 2);
        let c = CompressedBatch::from_dense(&batch);
        assert!(c.codebook.len() <= 8, "codebook {}", c.codebook.len());
        assert!(c.override_count() <= b * k);
        assert!(
            c.storage_floats() * 4 < c.dense_floats(),
            "compressed {} vs dense {}",
            c.storage_floats(),
            c.dense_floats()
        );
    }

    #[test]
    fn per_location_map_equals_dense_map() {
        let batch = revision_like(3, 15, 4, 2, 3);
        let c = CompressedBatch::from_dense(&batch);
        let mut led = FlopLedger::new();
        let mapped = c.map_per_location(|v| v.iter().map(|x| x * 2.0 + 1.0).collect(), 8, &mut led);
        for (bi, member) in batch.iter().enumerate() {
            let expect: Vec<Vec<f32>> = member
                .iter()
                .map(|row| row.iter().map(|x| x * 2.0 + 1.0).collect())
                .collect();
            assert_eq!(mapped.materialize(bi), expect);
        }
        // Cost ∝ codebook size, not b·n.
        assert_eq!(led.elementwise, 8 * c.codebook.len() as u64);
        assert!((c.codebook.len() as usize) < 3 * 15);
    }

    #[test]
    fn zip_binary_equals_dense_zip() {
        let x = revision_like(3, 12, 4, 2, 4);
        let y = revision_like(3, 12, 4, 2, 5);
        let cx = CompressedBatch::from_dense(&x);
        let cy = CompressedBatch::from_dense(&y);
        let mut led = FlopLedger::new();
        let z = cx.zip_binary(&cy, |a, b| a.iter().zip(b).map(|(p, q)| p + q).collect(), 4, &mut led);
        for bi in 0..3 {
            let expect: Vec<Vec<f32>> = x[bi]
                .iter()
                .zip(&y[bi])
                .map(|(a, b)| a.iter().zip(b).map(|(p, q)| p + q).collect())
                .collect();
            assert_eq!(z.materialize(bi), expect);
        }
    }

    #[test]
    fn zip_binary_additive_codebook_growth_when_aligned() {
        // App. A.3: aligned operands (same divergence pattern) grow the
        // codebook additively, not multiplicatively.
        let x = revision_like(8, 40, 4, 1, 6);
        // y = x scaled → same index structure.
        let y: Vec<Vec<Vec<f32>>> = x
            .iter()
            .map(|m| m.iter().map(|r| r.iter().map(|v| v * 3.0).collect()).collect())
            .collect();
        let cx = CompressedBatch::from_dense(&x);
        let cy = CompressedBatch::from_dense(&y);
        let mut led = FlopLedger::new();
        let z = cx.zip_binary(&cy, |a, b| a.iter().zip(b).map(|(p, q)| p + q).collect(), 4, &mut led);
        assert_eq!(z.codebook.len(), cx.codebook.len(), "aligned ⇒ no growth");
    }

    #[test]
    fn interner_dense_and_stable() {
        let mut i = Interner::new();
        let a = i.intern(1, 100);
        let b = i.intern(1, 200);
        let a2 = i.intern(1, 100);
        assert_eq!(a, a2);
        assert_ne!(a, b);
        assert_eq!(i.len(), 2);
        // Different namespaces don't collide.
        let c = i.intern(2, 100);
        assert_ne!(a, c);
    }

    #[test]
    fn index_at_binary_search_paths() {
        let batch = revision_like(2, 10, 3, 4, 7);
        let c = CompressedBatch::from_dense(&batch);
        for bi in 0..2 {
            for j in 0..10 {
                let direct = &c.codebook[c.index_at(bi, j) as usize];
                assert_eq!(direct, &batch[bi][j]);
            }
        }
    }
}
