//! Mini property-testing framework (proptest is not in the offline crate
//! set). Seeded generators + a fixed case budget + failure reporting with
//! the offending seed, so failures reproduce deterministically.

use crate::util::Rng;

/// Run `f` over `cases` generated inputs; panics with the failing seed.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut f: impl FnMut(&T),
) {
    for case in 0..cases {
        let seed = 0x5EED_0000 + case as u64;
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&input)));
        if let Err(e) = result {
            eprintln!("property '{name}' failed on case {case} (seed {seed:#x});\ninput: {input:?}");
            std::panic::resume_unwind(e);
        }
    }
}

/// Generate a random token document.
pub fn gen_doc(rng: &mut Rng, min_len: usize, max_len: usize, vocab: usize) -> Vec<u32> {
    let n = rng.range(min_len, max_len);
    (0..n).map(|_| rng.below(vocab) as u32).collect()
}

/// Generate a random valid edit for a document of length `len`.
pub fn gen_edit(rng: &mut Rng, len: usize, vocab: usize, max_seq: usize) -> crate::edits::Edit {
    use crate::edits::Edit;
    loop {
        match rng.below(3) {
            0 if len > 0 => {
                return Edit::Replace {
                    at: rng.below(len),
                    tok: rng.below(vocab) as u32,
                }
            }
            1 if len < max_seq => {
                return Edit::Insert {
                    at: rng.below(len + 1),
                    tok: rng.below(vocab) as u32,
                }
            }
            2 if len > 1 => return Edit::Delete { at: rng.below(len) },
            _ => continue,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0;
        check("count", 10, |r| r.below(100), |_| {});
        check("side", 3, |r| r.below(5), |_| count += 1);
        assert_eq!(count, 3);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failure() {
        check("fail", 5, |r| r.below(10), |&x| assert!(x > 100));
    }

    #[test]
    fn gen_edit_always_valid() {
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let len = rng.range(1, 20);
            let e = gen_edit(&mut rng, len, 50, 64);
            match e {
                crate::edits::Edit::Replace { at, tok } => {
                    assert!(at < len && tok < 50);
                }
                crate::edits::Edit::Insert { at, .. } => assert!(at <= len),
                crate::edits::Edit::Delete { at } => {
                    assert!(len > 1 && at < len);
                }
            }
        }
    }
}
