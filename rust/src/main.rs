//! `vqt` — the leader binary: serve, bench-style smoke commands, and state
//! validation. (clap is not in the offline crate set; the CLI is a small
//! hand-rolled dispatcher.)

use anyhow::{bail, Context, Result};
use std::sync::Arc;
use vqt::config::{load_config_file, ModelConfig, ServeConfig};
use vqt::coordinator::{Backend, Coordinator, Request, Response};
use vqt::incremental::EngineOptions;
use vqt::model::ModelWeights;
use vqt::runtime::ArtifactRuntime;

const USAGE: &str = "vqt — incrementally-computable VQ transformers

USAGE:
  vqt serve [--config FILE] [--artifacts DIR] [--bind ADDR]
  vqt validate [--artifacts DIR]      cross-check L1/L2/L3 numerics
  vqt demo                            quick in-process session demo
  vqt help

Environment: VQT_LOG=off|none|error|warn|info|debug|trace";

fn main() {
    vqt::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let result = match cmd {
        "serve" => serve(&args[1..]),
        "validate" => validate(&args[1..]),
        "demo" => demo(),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

/// Default artifact directory: `rust/artifacts/` (where `make artifacts`
/// writes), resolved via the crate manifest so it works from any cwd.
fn default_artifacts_dir() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
}

fn serve(args: &[String]) -> Result<()> {
    let (model_cfg, mut serve_cfg) = match flag(args, "--config") {
        Some(path) => load_config_file(&path)?,
        None => (ModelConfig::vqt_mini(), ServeConfig::default()),
    };
    if let Some(bind) = flag(args, "--bind") {
        serve_cfg.bind = bind;
    }
    let artifacts = flag(args, "--artifacts").unwrap_or_else(default_artifacts_dir);
    let dir = std::path::PathBuf::from(&artifacts);

    // Prefer the artifact bundle's weights + config so the engine and the
    // AOT dense path agree; fall back to random weights for bring-up. The
    // manifest + weights load without PJRT — the coordinator probes the
    // execution backend itself and falls back to the oracle if needed.
    let (cfg, weights) = if dir.join("manifest.json").exists() {
        let manifest = vqt::runtime::ArtifactManifest::load(&dir)?;
        let cfg = manifest.config.clone();
        let w = ModelWeights::load(vqt::runtime::ArtifactManifest::weights_path(&dir), &cfg)?;
        (cfg, w)
    } else {
        log::warn!(
            "no artifacts at {artifacts}; serving random-init weights (run `make artifacts`)"
        );
        let w = ModelWeights::random(&model_cfg, 7);
        (model_cfg, w)
    };
    log::info!(
        "serving {} params, d={} L={} vq_heads={}",
        cfg.param_count(),
        cfg.d_model,
        cfg.n_layers,
        cfg.vq_heads
    );
    let coordinator = Coordinator::start(
        Backend {
            weights: Arc::new(weights),
            artifacts_dir: dir.join("manifest.json").exists().then_some(dir),
            engine_opts: EngineOptions::default(),
        },
        serve_cfg.clone(),
    );
    // Readiness-driven event loop on Linux; thread-per-connection
    // elsewhere (same wire protocol, bit-identical replies).
    vqt::server::serve_async(&serve_cfg, coordinator.client())
}

fn validate(args: &[String]) -> Result<()> {
    let dir = std::path::PathBuf::from(
        flag(args, "--artifacts").unwrap_or_else(default_artifacts_dir),
    );
    if !dir.join("manifest.json").exists() {
        bail!("no artifacts at {} — run `make artifacts`", dir.display());
    }
    let rt = ArtifactRuntime::open(&dir)?;
    let cfg = rt.manifest.config.clone();
    let w = Arc::new(ModelWeights::load(rt.weights_path(), &cfg)?);
    let mut rng = vqt::util::Rng::new(1234);
    let mut worst: f32 = 0.0;
    for trial in 0..5 {
        let n = rng.range(8, cfg.max_seq.min(100));
        let tokens: Vec<u32> = (0..n).map(|_| rng.below(cfg.vocab_size - 1) as u32).collect();
        let mut eng = vqt::incremental::IncrementalEngine::new(
            w.clone(),
            &tokens,
            EngineOptions::default(),
        );
        for _ in 0..3 {
            let at = rng.below(eng.len());
            let tok = rng.below(cfg.vocab_size - 1) as u32;
            eng.apply_edit(vqt::edits::Edit::Replace { at, tok });
        }
        let l2 = rt.dense_logits(eng.tokens(), eng.position_ids())?;
        let rep = eng.verify();
        let l2diff = l2
            .iter()
            .zip(eng.logits())
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        worst = worst.max(l2diff).max(rep.max_logit_diff);
        println!(
            "trial {trial}: n={n} L2-vs-engine max diff {l2diff:.2e}, dense-vs-engine {:.2e}, code mismatches {}/{}",
            rep.max_logit_diff, rep.code_mismatches, rep.total_codes
        );
        if rep.code_mismatches != 0 || l2diff > 2e-3 {
            bail!("validation FAILED");
        }
    }
    println!("validate OK (worst logit diff {worst:.2e})");
    Ok(())
}

fn demo() -> Result<()> {
    let cfg = ModelConfig::vqt_tiny();
    let w = Arc::new(ModelWeights::random(&cfg, 7));
    let coordinator = Coordinator::start(
        Backend {
            weights: w,
            artifacts_dir: None,
            engine_opts: EngineOptions::default(),
        },
        ServeConfig::default(),
    );
    let client = coordinator.client();
    let tokens: Vec<u32> = (0..24).map(|i| (i * 7 % 60) as u32).collect();
    let r = client
        .request(Request::Open {
            session: "demo".into(),
            tokens,
        })
        .context("open")?;
    println!("open → {:?}", r.logits()?);
    let r = client.request(Request::Edit {
        session: "demo".into(),
        edit: vqt::edits::Edit::Replace { at: 3, tok: 42 },
    })?;
    if let Response::Logits {
        flops,
        dense_equiv_flops,
        ..
    } = &r
    {
        println!(
            "edit → {:.1}× fewer ops than dense ({flops} vs {dense_equiv_flops})",
            *dense_equiv_flops as f64 / *flops as f64
        );
    }
    if let Response::Stats(s) = client.request(Request::Stats)? {
        println!("stats: {}", s.to_string());
    }
    Ok(())
}
