//! TCP JSON server: newline-delimited JSON requests over TCP, one
//! connection per client thread, all inference routed through the
//! coordinator's channel client.
//!
//! Wire protocol (one JSON object per line):
//! ```text
//! → {"op":"open","session":"s1","tokens":[10,20,30]}
//! ← {"ok":true,"logits":[...],"predicted":1,"flops":123,"speedup":9.7}
//! → {"op":"edit","session":"s1","kind":"replace","at":1,"tok":99}
//! → {"op":"edit","session":"s1","kind":"insert","at":0,"tok":5}
//! → {"op":"edit","session":"s1","kind":"delete","at":2}
//! → {"op":"revision","session":"s1","tokens":[...]}
//! → {"op":"dense","tokens":[...]}
//! → {"op":"stats"}   |   {"op":"close","session":"s1"}
//! ```

pub mod protocol;

use crate::coordinator::Client;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

pub use protocol::{parse_request, response_to_json};

/// Serve forever on `bind`, handling each connection on its own thread.
pub fn serve(bind: &str, client: Client) -> Result<()> {
    let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
    log::info!("vqt server listening on {bind}");
    for conn in listener.incoming() {
        match conn {
            Ok(stream) => {
                let c = client.clone();
                std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, c) {
                        log::debug!("connection ended: {e:#}");
                    }
                });
            }
            Err(e) => log::warn!("accept failed: {e}"),
        }
    }
    Ok(())
}

/// Handle one connection: line in → request → coordinator → line out.
pub fn handle_conn(stream: TcpStream, client: Client) -> Result<()> {
    let peer = stream.peer_addr()?;
    log::debug!("connection from {peer}");
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let out = match parse_request(&line) {
            Ok(req) => match client.request(req) {
                Ok(resp) => response_to_json(&resp),
                Err(e) => protocol::error_json(&format!("{e:#}")),
            },
            Err(e) => protocol::error_json(&format!("{e:#}")),
        };
        writer.write_all(out.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}
