//! TCP JSON server: newline-delimited JSON requests over TCP, all
//! inference routed through the coordinator's channel client. Two front
//! ends share the wire protocol and produce bit-identical replies:
//!
//! - [`serve`]: the blocking thread-per-connection reference server;
//! - [`serve_async`] (Linux): the readiness-driven epoll event loop in
//!   [`event_loop`] — a fixed pool of IO threads, incremental framing
//!   ([`framer`]), admission control, and typed `Busy` load shedding.
//!   See `docs/ARCHITECTURE.md` §10.
//!
//! Wire protocol (one JSON object per line):
//! ```text
//! → {"op":"open","session":"s1","tokens":[10,20,30]}
//! ← {"ok":true,"logits":[...],"predicted":1,"flops":123,"speedup":9.7}
//! → {"op":"edit","session":"s1","kind":"replace","at":1,"tok":99}
//! → {"op":"edit","session":"s1","kind":"insert","at":0,"tok":5}
//! → {"op":"edit","session":"s1","kind":"delete","at":2}
//! → {"op":"revision","session":"s1","tokens":[...]}
//! → {"op":"dense","tokens":[...]}
//! → {"op":"stats"}   |   {"op":"close","session":"s1"}
//! → {"op":"suspend","session":"s1"}      spill the session to disk
//! → {"op":"resume","session":"s1"}       eager resume (requests also
//!                                        resume suspended sessions lazily)
//! → {"op":"session_info","session":"s1"}
//! ← {"ok":true,"state":"resident","resident_bytes":123,...}
//! → {"op":"checkpoint","session":"s1","path":"s1.vqss"}
//! → {"op":"restore","session":"s1","path":"s1.vqss"}
//! ```

pub mod framer;
pub mod protocol;

#[cfg(target_os = "linux")]
pub mod event_loop;
#[cfg(target_os = "linux")]
pub mod poll;

use crate::coordinator::{Client, Request, Response};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};

pub use protocol::{parse_request, parse_request_traced, response_to_json, MAX_REQUEST_BYTES};

#[cfg(target_os = "linux")]
pub use event_loop::{serve_async, AsyncServer, FrontendOptions, FrontendStats};

/// Non-Linux fallback: the readiness-driven front end is epoll-based, so
/// other platforms keep the thread-per-connection blocking server (same
/// wire protocol, same replies — only the concurrency model differs).
#[cfg(not(target_os = "linux"))]
pub fn serve_async(cfg: &crate::config::ServeConfig, client: Client) -> Result<()> {
    log::warn!(
        "readiness-driven front end requires Linux; serving with the blocking \
         thread-per-connection server"
    );
    serve(&cfg.bind, client)
}

/// Socket read cap for one request line: the single shared
/// [`MAX_REQUEST_BYTES`] plus newline slack (CR+LF). Derived — never
/// redefined — so the read cap and the parser's cap cannot drift apart;
/// a line the reader admits is never rejected by the parser as oversized
/// and vice versa.
const READ_LIMIT_BYTES: u64 = MAX_REQUEST_BYTES as u64 + 2;

/// Serve forever on `bind`, handling each connection on its own thread.
pub fn serve(bind: &str, client: Client) -> Result<()> {
    let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
    log::info!("vqt server listening on {bind}");
    for conn in listener.incoming() {
        match conn {
            Ok(stream) => {
                let c = client.clone();
                std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, c) {
                        log::debug!("connection ended: {e:#}");
                    }
                });
            }
            Err(e) => log::warn!("accept failed: {e}"),
        }
    }
    Ok(())
}

/// Handle one connection: line in → request → coordinator → line out.
///
/// The read itself is capped at `READ_LIMIT_BYTES` (the shared
/// [`MAX_REQUEST_BYTES`] plus newline slack): a client streaming an
/// endless line never makes the server buffer more than the cap — the
/// connection is answered with the oversized-request error and dropped
/// (the rest of the line cannot be resynced to a message boundary).
pub fn handle_conn(stream: TcpStream, client: Client) -> Result<()> {
    let peer = stream.peer_addr()?;
    log::debug!("connection from {peer}");
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::new();
    loop {
        buf.clear();
        let n = Read::by_ref(&mut reader)
            .take(READ_LIMIT_BYTES)
            .read_until(b'\n', &mut buf)?;
        if n == 0 {
            return Ok(()); // clean EOF
        }
        if buf.last() != Some(&b'\n') && n as u64 == READ_LIMIT_BYTES {
            let out = protocol::error_json(&format!(
                "oversized request: line exceeds {MAX_REQUEST_BYTES} bytes"
            ));
            writer.write_all(out.to_string().as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
            anyhow::bail!("oversized request line from {peer}");
        }
        let out = match std::str::from_utf8(&buf) {
            Ok(line) if line.trim().is_empty() => continue,
            // Plain-HTTP scrape endpoint: a Prometheus scraper speaks
            // `GET /metrics HTTP/1.x`, not the line protocol. Serve the
            // text exposition as one HTTP/1.0 response and close — the
            // scraper opens a fresh connection per scrape anyway.
            Ok(line) if line.trim_end().starts_with("GET /metrics") => {
                let body = metrics_exposition(&client);
                writer.write_all(http_metrics_response(&body).as_bytes())?;
                writer.flush()?;
                return Ok(());
            }
            Ok(line) => match parse_request_traced(line.trim()) {
                Ok((req, trace)) => match client.request_traced(req, trace) {
                    Ok(resp) => response_to_json(&resp),
                    Err(e) => protocol::error_json(&format!("{e:#}")),
                },
                Err(e) => protocol::error_json(&format!("{e:#}")),
            },
            Err(_) => protocol::error_json("request line is not valid UTF-8"),
        };
        writer.write_all(out.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
}

/// Render the pool's Prometheus exposition (errors become a commented-out
/// exposition so a scrape never sees a half-broken body).
pub(crate) fn metrics_exposition(client: &Client) -> String {
    match client.request(Request::Metrics) {
        Ok(Response::MetricsText(text)) => text,
        Ok(other) => format!("# metrics unavailable: unexpected response {other:?}\n"),
        Err(e) => format!("# metrics unavailable: {e:#}\n"),
    }
}

/// Wrap the exposition text in a minimal HTTP/1.0 response for scrapers.
pub(crate) fn http_metrics_response(body: &str) -> String {
    format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The HTTP wrapper is well-formed: status line, both headers, an
    /// exact byte-length, and the body after the blank line.
    #[test]
    fn http_metrics_response_shape() {
        let body = "# TYPE vqt_edits_total counter\nvqt_edits_total 3\n";
        let resp = http_metrics_response(body);
        assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"));
        assert!(resp.contains("Content-Type: text/plain; version=0.0.4\r\n"));
        assert!(resp.contains(&format!("Content-Length: {}\r\n", body.len())));
        let split = resp.split_once("\r\n\r\n").expect("header/body split");
        assert_eq!(split.1, body);
    }

    /// The read cap is DERIVED from the parse cap (one shared constant):
    /// any line the reader admits whole (≤ cap bytes + newline) is within
    /// the parser's limit, and the parser's boundary sits exactly at the
    /// re-exported `MAX_REQUEST_BYTES`.
    #[test]
    fn read_cap_and_parse_cap_share_one_constant() {
        assert_eq!(READ_LIMIT_BYTES, MAX_REQUEST_BYTES as u64 + 2);
        // At the cap: not "oversized" (it fails later, as invalid JSON).
        let at_cap = "x".repeat(MAX_REQUEST_BYTES);
        let err = parse_request(&at_cap).unwrap_err().to_string();
        assert!(!err.contains("oversized"), "{err}");
        // One past the cap: rejected up front by the shared constant.
        let over = "x".repeat(MAX_REQUEST_BYTES + 1);
        let err = parse_request(&over).unwrap_err().to_string();
        assert!(err.contains("oversized"), "{err}");
    }
}
