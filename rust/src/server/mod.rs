//! TCP JSON server: newline-delimited JSON requests over TCP, one
//! connection per client thread, all inference routed through the
//! coordinator's channel client.
//!
//! Wire protocol (one JSON object per line):
//! ```text
//! → {"op":"open","session":"s1","tokens":[10,20,30]}
//! ← {"ok":true,"logits":[...],"predicted":1,"flops":123,"speedup":9.7}
//! → {"op":"edit","session":"s1","kind":"replace","at":1,"tok":99}
//! → {"op":"edit","session":"s1","kind":"insert","at":0,"tok":5}
//! → {"op":"edit","session":"s1","kind":"delete","at":2}
//! → {"op":"revision","session":"s1","tokens":[...]}
//! → {"op":"dense","tokens":[...]}
//! → {"op":"stats"}   |   {"op":"close","session":"s1"}
//! → {"op":"suspend","session":"s1"}      spill the session to disk
//! → {"op":"resume","session":"s1"}       eager resume (requests also
//!                                        resume suspended sessions lazily)
//! → {"op":"session_info","session":"s1"}
//! ← {"ok":true,"state":"resident","resident_bytes":123,...}
//! → {"op":"checkpoint","session":"s1","path":"s1.vqss"}
//! → {"op":"restore","session":"s1","path":"s1.vqss"}
//! ```

pub mod protocol;

use crate::coordinator::Client;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};

pub use protocol::{parse_request, response_to_json};

/// Serve forever on `bind`, handling each connection on its own thread.
pub fn serve(bind: &str, client: Client) -> Result<()> {
    let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
    log::info!("vqt server listening on {bind}");
    for conn in listener.incoming() {
        match conn {
            Ok(stream) => {
                let c = client.clone();
                std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, c) {
                        log::debug!("connection ended: {e:#}");
                    }
                });
            }
            Err(e) => log::warn!("accept failed: {e}"),
        }
    }
    Ok(())
}

/// Handle one connection: line in → request → coordinator → line out.
///
/// The read itself is capped at [`protocol::MAX_REQUEST_BYTES`] (plus
/// newline slack): a client streaming an endless line never makes the
/// server buffer more than the cap — the connection is answered with the
/// oversized-request error and dropped (the rest of the line cannot be
/// resynced to a message boundary).
pub fn handle_conn(stream: TcpStream, client: Client) -> Result<()> {
    let peer = stream.peer_addr()?;
    log::debug!("connection from {peer}");
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::new();
    let limit = protocol::MAX_REQUEST_BYTES as u64 + 2;
    loop {
        buf.clear();
        let n = Read::by_ref(&mut reader)
            .take(limit)
            .read_until(b'\n', &mut buf)?;
        if n == 0 {
            return Ok(()); // clean EOF
        }
        if buf.last() != Some(&b'\n') && n as u64 == limit {
            let out = protocol::error_json(&format!(
                "oversized request: line exceeds {} bytes",
                protocol::MAX_REQUEST_BYTES
            ));
            writer.write_all(out.to_string().as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
            anyhow::bail!("oversized request line from {peer}");
        }
        let out = match std::str::from_utf8(&buf) {
            Ok(line) if line.trim().is_empty() => continue,
            Ok(line) => match parse_request(line.trim()) {
                Ok(req) => match client.request(req) {
                    Ok(resp) => response_to_json(&resp),
                    Err(e) => protocol::error_json(&format!("{e:#}")),
                },
                Err(e) => protocol::error_json(&format!("{e:#}")),
            },
            Err(_) => protocol::error_json("request line is not valid UTF-8"),
        };
        writer.write_all(out.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
}
