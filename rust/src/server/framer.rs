//! Streaming newline framer for the readiness-driven front end.
//!
//! The blocking server frames with `BufRead::read_until(b'\n')` behind a
//! `take(READ_LIMIT_BYTES)` guard: a connection is declared oversized
//! exactly when the first `READ_LIMIT_BYTES` bytes of a line contain no
//! newline. The event loop receives the same byte stream in arbitrary
//! readiness-sized chunks, so this framer re-implements that rule
//! incrementally — the differential tests in `tests/prop_framer.rs` hold
//! the two framings bit-identical at every split boundary.

/// One framing step's output.
#[derive(Debug, PartialEq, Eq)]
pub enum Frame {
    /// A complete line, trailing newline stripped. Byte content is
    /// unvalidated — UTF-8 and JSON checks happen downstream, in the same
    /// order the blocking server applies them.
    Line(Vec<u8>),
    /// The line cap was exceeded before a newline arrived. The connection
    /// cannot be resynced to a message boundary: the caller must emit the
    /// oversized error and close. The framer yields this once and then
    /// only `None`.
    Oversized,
}

/// Incremental line framer with the blocking server's oversized rule.
#[derive(Debug)]
pub struct LineFramer {
    buf: Vec<u8>,
    /// Prefix of `buf` already scanned and known newline-free, so repeated
    /// `next()` calls across partial reads stay O(bytes), not O(bytes²).
    scanned: usize,
    limit: usize,
    dead: bool,
}

impl LineFramer {
    /// `limit` is the per-line byte cap INCLUDING the newline window —
    /// the server passes `READ_LIMIT_BYTES`, keeping the async cap derived
    /// from the same shared constant as the blocking read cap.
    pub fn new(limit: usize) -> Self {
        LineFramer {
            buf: Vec::new(),
            scanned: 0,
            limit,
            dead: false,
        }
    }

    /// Append bytes read from the socket.
    pub fn push(&mut self, bytes: &[u8]) {
        if !self.dead {
            self.buf.extend_from_slice(bytes);
        }
    }

    /// Bytes currently buffered and not yet framed.
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Pop the next complete frame, if any. `None` means "need more
    /// bytes" (or the framer is dead after `Oversized`).
    pub fn next(&mut self) -> Option<Frame> {
        if self.dead {
            return None;
        }
        let window = self.buf.len().min(self.limit);
        if let Some(off) = self.buf[self.scanned..window].iter().position(|&b| b == b'\n') {
            let nl = self.scanned + off;
            let mut line: Vec<u8> = self.buf.drain(..=nl).collect();
            line.pop(); // strip '\n'
            self.scanned = 0;
            return Some(Frame::Line(line));
        }
        self.scanned = window;
        if self.scanned >= self.limit {
            // Same boundary as the blocking server: `limit` bytes read,
            // none of them a newline ⇒ oversized, unrecoverable.
            self.dead = true;
            return Some(Frame::Oversized);
        }
        None
    }

    /// Take the trailing unterminated line at EOF, if any. The blocking
    /// server's `read_until` returns a final partial line when the peer
    /// half-closes without a newline and processes it as a request; call
    /// this once `next()` returns `None` on an EOF'd stream to match.
    /// Always under `limit` bytes — a full window is `Oversized`, not a
    /// remainder.
    pub fn take_remainder(&mut self) -> Option<Vec<u8>> {
        if self.dead || self.buf.is_empty() {
            return None;
        }
        self.scanned = 0;
        Some(std::mem::take(&mut self.buf))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(f: &mut LineFramer) -> Vec<Frame> {
        std::iter::from_fn(|| f.next()).collect()
    }

    #[test]
    fn frames_whole_and_split_lines() {
        let mut f = LineFramer::new(64);
        f.push(b"abc\nde");
        assert_eq!(drain(&mut f), vec![Frame::Line(b"abc".to_vec())]);
        f.push(b"f\n\n");
        assert_eq!(
            drain(&mut f),
            vec![Frame::Line(b"def".to_vec()), Frame::Line(b"".to_vec())]
        );
    }

    #[test]
    fn byte_at_a_time_equals_one_push() {
        let input = b"hello\nworld\n";
        let mut a = LineFramer::new(64);
        a.push(input);
        let whole = drain(&mut a);
        let mut b = LineFramer::new(64);
        let mut split = Vec::new();
        for &byte in input {
            b.push(&[byte]);
            split.extend(drain(&mut b));
        }
        assert_eq!(whole, split);
    }

    #[test]
    fn oversized_at_exactly_the_blocking_boundary() {
        // A newline AT the limit boundary (line of limit-1 content bytes)
        // is still a line; one more content byte is oversized.
        let mut ok = LineFramer::new(8);
        ok.push(b"1234567\n");
        assert_eq!(drain(&mut ok), vec![Frame::Line(b"1234567".to_vec())]);
        let mut over = LineFramer::new(8);
        over.push(b"12345678");
        assert_eq!(drain(&mut over), vec![Frame::Oversized]);
        // Dead after oversized: later bytes never resync.
        over.push(b"\nok\n");
        assert_eq!(drain(&mut over), vec![]);
    }

    #[test]
    fn remainder_is_the_trailing_partial_line_only() {
        let mut f = LineFramer::new(64);
        f.push(b"done\npartial");
        assert_eq!(drain(&mut f), vec![Frame::Line(b"done".to_vec())]);
        assert_eq!(f.take_remainder(), Some(b"partial".to_vec()));
        assert_eq!(f.take_remainder(), None);
        // A dead framer never yields a remainder.
        let mut over = LineFramer::new(4);
        over.push(b"12345");
        assert_eq!(drain(&mut over), vec![Frame::Oversized]);
        assert_eq!(over.take_remainder(), None);
    }

    #[test]
    fn limit_window_resets_per_line() {
        let mut f = LineFramer::new(8);
        f.push(b"1234567\n1234567\n");
        assert_eq!(
            drain(&mut f),
            vec![
                Frame::Line(b"1234567".to_vec()),
                Frame::Line(b"1234567".to_vec())
            ]
        );
    }
}
