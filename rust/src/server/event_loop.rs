//! Readiness-driven TCP front end (Linux): a fixed pool of IO threads
//! owns every socket in nonblocking mode behind per-thread epoll sets, so
//! concurrent connections cost bytes, not threads.
//!
//! Thread 0 owns the listener and distributes accepted connections
//! round-robin across the pool via per-thread injection queues (an
//! `eventfd` wakes the adoptive thread). Each connection's byte stream is
//! framed incrementally ([`super::framer::LineFramer`], carrying the same
//! `READ_LIMIT_BYTES` cap as the blocking reader), parsed with the shared
//! protocol, and submitted to the coordinator WITHOUT blocking
//! ([`Client::submit`]). Shards deliver [`Completion`]s to the owning IO
//! thread's channel and ring its waker; replies are released strictly in
//! per-connection request order (a `BTreeMap` keyed by sequence number),
//! so the wire is bit-identical to the blocking server's
//! one-request-at-a-time loop.
//!
//! Admission control and backpressure:
//! - `max_connections`: past the cap, an accepted socket is answered with
//!   one typed busy line and dropped.
//! - `max_inflight_per_conn`: a connection at its in-flight cap (or with a
//!   backed-up write buffer) simply stops being polled for reads — the
//!   bytes wait in the kernel, and TCP flow control pushes back on the
//!   client. No thread blocks.
//! - A full shard queue sheds the request with a typed busy reply
//!   (`protocol::busy_json`) instead of queueing unboundedly.
//!
//! Graceful drain: `AsyncServer::shutdown` stops the acceptor, stops
//! polling reads, flushes every in-flight reply, closes the sockets, joins
//! the IO threads, and only then should the caller tear down the
//! coordinator — the shards are still alive for every reply the drain
//! waits on.

use super::framer::{Frame, LineFramer};
use super::poll::{Epoll, EventFd, EPOLLERR, EPOLLHUP, EPOLLIN, EPOLLOUT, EPOLLRDHUP};
use super::{protocol, READ_LIMIT_BYTES};
use crate::config::ServeConfig;
use crate::coordinator::{Client, Completion, ReplyTo, Request, Response, SubmitError};
use crate::util::trace::TraceRing;
use crate::util::Json;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Epoll user-data sentinels for the two non-connection fds; connection
/// ids come from a counter and can never collide with them.
const DATA_WAKE: u64 = u64::MAX;
const DATA_LISTENER: u64 = u64::MAX - 1;

/// Stop polling reads while a connection's pending write bytes exceed
/// this; a client that doesn't read its replies doesn't get to keep
/// submitting work.
const WBUF_HIGH_WATER: usize = 1 << 20;

/// Front-end counters (server-side, not per-shard): surfaced under a
/// `"frontend"` object inside the `stats` reply by the async server.
#[derive(Default)]
pub struct FrontendStats {
    /// Currently open connections (gauge, process-wide).
    pub connections: AtomicU64,
    /// Per-IO-thread breakdown of `connections` (same gauge protocol:
    /// bumped for thread `t` at accept hand-off, decremented by `t` when
    /// it drops the socket), so a load skew across the round-robin spread
    /// is observable the same way the coordinator's `per_shard` is. The
    /// entries always sum to `connections`.
    pub per_thread_connections: Vec<AtomicU64>,
    /// Connections admitted over the lifetime of the server.
    pub connections_accepted: AtomicU64,
    /// Connections refused at accept by `max_connections` (each got one
    /// typed busy line).
    pub connections_rejected: AtomicU64,
    /// Requests shed with a typed busy reply because the target shard's
    /// queue was full.
    pub requests_shed: AtomicU64,
}

impl FrontendStats {
    /// Counters for a front end with `io_threads` event-loop threads.
    pub fn new(io_threads: usize) -> FrontendStats {
        FrontendStats {
            per_thread_connections: (0..io_threads.max(1)).map(|_| AtomicU64::new(0)).collect(),
            ..FrontendStats::default()
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "connections",
                Json::num(self.connections.load(Ordering::Relaxed) as f64),
            ),
            (
                "per_io_thread",
                Json::Arr(
                    self.per_thread_connections
                        .iter()
                        .map(|g| Json::num(g.load(Ordering::Relaxed) as f64))
                        .collect(),
                ),
            ),
            (
                "connections_accepted",
                Json::num(self.connections_accepted.load(Ordering::Relaxed) as f64),
            ),
            (
                "connections_rejected",
                Json::num(self.connections_rejected.load(Ordering::Relaxed) as f64),
            ),
            (
                "requests_shed",
                Json::num(self.requests_shed.load(Ordering::Relaxed) as f64),
            ),
        ])
    }

    /// Append the front end's own series to a Prometheus exposition (the
    /// coordinator rendered everything else; the async server calls this
    /// before the text leaves the process).
    pub fn append_prometheus(&self, out: &mut String) {
        use std::fmt::Write as _;
        let _ = writeln!(
            out,
            "# HELP vqt_frontend_connections Currently open connections.\n\
             # TYPE vqt_frontend_connections gauge\n\
             vqt_frontend_connections {}",
            self.connections.load(Ordering::Relaxed)
        );
        let _ = writeln!(
            out,
            "# HELP vqt_frontend_thread_connections Open connections per IO thread.\n\
             # TYPE vqt_frontend_thread_connections gauge"
        );
        for (t, g) in self.per_thread_connections.iter().enumerate() {
            let _ = writeln!(
                out,
                "vqt_frontend_thread_connections{{io_thread=\"{t}\"}} {}",
                g.load(Ordering::Relaxed)
            );
        }
        for (name, help, v) in [
            (
                "vqt_frontend_connections_accepted_total",
                "Connections admitted over the server lifetime.",
                self.connections_accepted.load(Ordering::Relaxed),
            ),
            (
                "vqt_frontend_connections_rejected_total",
                "Connections refused at accept by max_connections.",
                self.connections_rejected.load(Ordering::Relaxed),
            ),
            (
                "vqt_frontend_requests_shed_total",
                "Requests shed with a typed busy reply (shard queue full).",
                self.requests_shed.load(Ordering::Relaxed),
            ),
        ] {
            let _ = writeln!(out, "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}");
        }
    }
}

/// Admission/backpressure knobs, lifted from [`ServeConfig`].
#[derive(Clone, Copy, Debug)]
pub struct FrontendOptions {
    pub io_threads: usize,
    pub max_connections: usize,
    pub max_inflight_per_conn: usize,
    /// Capacity of the front end's completed-trace ring (0 ⇒ traces from
    /// async replies are dropped after any per-request delivery).
    pub trace_buffer: usize,
}

impl FrontendOptions {
    pub fn from_cfg(cfg: &ServeConfig) -> FrontendOptions {
        FrontendOptions {
            io_threads: cfg.io_threads.max(1),
            max_connections: cfg.max_connections,
            max_inflight_per_conn: cfg.max_inflight_per_conn.max(1),
            trace_buffer: cfg.trace_buffer,
        }
    }
}

/// State shared by every IO thread.
struct Shared {
    client: Client,
    stats: Arc<FrontendStats>,
    shutdown: AtomicBool,
    /// Accepted-but-unadopted sockets, one queue per IO thread.
    inject: Vec<Mutex<Vec<TcpStream>>>,
    /// One waker per IO thread (shard completions and injections ring it).
    wakers: Vec<Arc<EventFd>>,
    max_connections: usize,
    max_inflight: usize,
    rr: AtomicUsize,
    conn_ids: AtomicU64,
    /// Completed traces from async replies, `reply_write` span included.
    /// The mutex is touched only when a completion actually carries a
    /// record (tracing on) and by the rare `trace` dump — never on the
    /// untraced fast path.
    traces: Mutex<TraceRing>,
}

fn locked<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// One nonblocking connection owned by an IO thread.
struct Conn {
    stream: TcpStream,
    framer: LineFramer,
    /// Serialized reply bytes not yet written to the socket.
    wbuf: Vec<u8>,
    /// Next sequence number to assign to an incoming request.
    next_seq: u64,
    /// Next sequence number to release onto the wire.
    next_flush: u64,
    /// Completed reply lines waiting for their turn (out-of-order shard
    /// completions park here; size is bounded by `max_inflight`).
    done: BTreeMap<u64, Vec<u8>>,
    /// Requests submitted to the coordinator and not yet completed.
    inflight: usize,
    /// Epoll interest mask currently registered for this socket.
    interest: u32,
    /// Peer half-closed its write side (clean EOF).
    eof: bool,
    /// Close once every pending reply is flushed (oversized line,
    /// coordinator gone, or server drain).
    closing: bool,
    /// Unrecoverable socket error: drop immediately.
    dead: bool,
    /// The peer spoke HTTP (`GET /metrics`): later frames are its header
    /// lines (dropped, never replies), and the one completion is written
    /// back as an HTTP response before closing.
    http: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            framer: LineFramer::new(READ_LIMIT_BYTES as usize),
            wbuf: Vec::new(),
            next_seq: 0,
            next_flush: 0,
            done: BTreeMap::new(),
            inflight: 0,
            interest: EPOLLIN | EPOLLRDHUP,
            eof: false,
            closing: false,
            dead: false,
            http: false,
        }
    }

    /// Everything owed to the peer is on the wire and nothing more can
    /// arrive: safe to close.
    fn finished(&self) -> bool {
        self.dead
            || ((self.eof || self.closing)
                && self.inflight == 0
                && self.done.is_empty()
                && self.wbuf.is_empty())
    }
}

fn reply_line(j: Json) -> Vec<u8> {
    let mut line = j.to_string().into_bytes();
    line.push(b'\n');
    line
}

/// One IO thread's world: its epoll set, its connections, its completion
/// channel, and (for thread 0) the listener.
struct IoThread {
    idx: usize,
    shared: Arc<Shared>,
    epoll: Epoll,
    wake: Arc<EventFd>,
    ctx: mpsc::Sender<Completion>,
    crx: mpsc::Receiver<Completion>,
    /// Waker closure cloned into every `ReplyTo::Async` this thread mints
    /// (type-erased so the coordinator stays free of server types).
    wake_fn: Arc<dyn Fn() + Send + Sync>,
    listener: Option<TcpListener>,
    conns: HashMap<u64, Conn>,
    draining: bool,
}

impl IoThread {
    fn run(mut self) {
        let mut events =
            vec![super::poll::EpollEvent { events: 0, data: 0 }; 256];
        loop {
            let n = match self.epoll.wait(&mut events, 100) {
                Ok(n) => n,
                Err(e) => {
                    log::error!("io thread {}: epoll_wait failed: {e}", self.idx);
                    break;
                }
            };
            let ready: Vec<(u32, u64)> = events
                .iter()
                .take(n)
                .map(|ev| (ev.events, ev.data)) // copy out of the packed struct
                .collect();
            for (mask, data) in ready {
                match data {
                    DATA_WAKE => self.wake.drain(),
                    DATA_LISTENER => self.accept_ready(),
                    id => self.conn_ready(id, mask),
                }
            }
            // Completions and injections are drained every tick — the
            // waker guarantees promptness, draining unconditionally
            // guarantees none are stranded behind a lost wakeup.
            self.drain_completions();
            self.adopt_injected();
            if self.shared.shutdown.load(Ordering::Relaxed) && !self.draining {
                self.begin_drain();
            }
            if self.draining && self.conns.is_empty() {
                break;
            }
        }
        log::debug!("io thread {} exiting", self.idx);
    }

    /// Enter graceful drain: stop accepting, stop reading, keep flushing.
    fn begin_drain(&mut self) {
        self.draining = true;
        if let Some(listener) = self.listener.take() {
            let _ = self.epoll.del(listener.as_raw_fd());
        }
        let ids: Vec<u64> = self.conns.keys().copied().collect();
        for id in ids {
            let mut conn = self.conns.remove(&id).expect("listed id");
            conn.closing = true;
            self.flush(&mut conn);
            self.settle(id, conn);
        }
    }

    fn accept_ready(&mut self) {
        loop {
            let Some(listener) = &self.listener else { return };
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let live = self.shared.stats.connections.load(Ordering::Relaxed) as usize;
                    if self.shared.max_connections > 0 && live >= self.shared.max_connections {
                        // Admission reject: one typed busy line, best
                        // effort (a fresh socket's buffer always has room
                        // for it in practice), then drop.
                        self.shared
                            .stats
                            .connections_rejected
                            .fetch_add(1, Ordering::Relaxed);
                        let _ = stream.set_nonblocking(true);
                        let mut s = stream;
                        let _ = s.write(&reply_line(protocol::busy_json(
                            "server busy: connection limit reached",
                        )));
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    self.shared
                        .stats
                        .connections_accepted
                        .fetch_add(1, Ordering::Relaxed);
                    // The gauge is bumped at hand-off (not adoption) so
                    // the admission check never undercounts a burst that
                    // is still sitting in injection queues.
                    self.shared.stats.connections.fetch_add(1, Ordering::Relaxed);
                    let nthreads = self.shared.inject.len();
                    let t = self.shared.rr.fetch_add(1, Ordering::Relaxed) % nthreads;
                    // Attributed to the adoptive thread from hand-off, so
                    // the per-thread gauges always sum to `connections`.
                    self.shared.stats.per_thread_connections[t].fetch_add(1, Ordering::Relaxed);
                    locked(&self.shared.inject[t]).push(stream);
                    self.shared.wakers[t].ring();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    log::warn!("accept failed: {e}");
                    return;
                }
            }
        }
    }

    /// Register connections handed over by the acceptor.
    fn adopt_injected(&mut self) {
        let streams: Vec<TcpStream> = std::mem::take(&mut *locked(&self.shared.inject[self.idx]));
        for stream in streams {
            if self.draining {
                self.conn_gone();
                continue; // drained before adoption: just drop
            }
            let id = self.shared.conn_ids.fetch_add(1, Ordering::Relaxed);
            let fd = stream.as_raw_fd();
            let conn = Conn::new(stream);
            if self.epoll.add(fd, conn.interest, id).is_err() {
                self.conn_gone();
                continue;
            }
            self.conns.insert(id, conn);
        }
    }

    /// A connection owned (or owed) to this thread is gone: decrement the
    /// process gauge and this thread's slice of it together so the
    /// per-thread breakdown keeps summing to the total.
    fn conn_gone(&self) {
        self.shared.stats.connections.fetch_sub(1, Ordering::Relaxed);
        self.shared.stats.per_thread_connections[self.idx].fetch_sub(1, Ordering::Relaxed);
    }

    fn conn_ready(&mut self, id: u64, mask: u32) {
        let Some(mut conn) = self.conns.remove(&id) else {
            return;
        };
        if mask & (EPOLLIN | EPOLLRDHUP | EPOLLERR | EPOLLHUP) != 0 {
            self.read_ready(id, &mut conn);
        }
        if mask & EPOLLOUT != 0 {
            self.write_socket(&mut conn);
            self.flush(&mut conn);
        }
        self.settle(id, conn);
    }

    /// Drain the socket into the framer, then run as many complete frames
    /// as the in-flight cap allows.
    fn read_ready(&mut self, id: u64, conn: &mut Conn) {
        let mut buf = [0u8; 16384];
        loop {
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    conn.eof = true;
                    break;
                }
                Ok(n) => conn.framer.push(&buf[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    return;
                }
            }
        }
        self.process_frames(id, conn);
    }

    /// Frame → parse → submit, mirroring the blocking server's per-line
    /// pipeline (UTF-8 check, blank-line skip, shared parser) exactly.
    /// Every frame that produces a reply claims a sequence number, so
    /// immediate replies (parse errors, sheds) stay ordered with shard
    /// completions.
    fn process_frames(&mut self, id: u64, conn: &mut Conn) {
        while !conn.closing && conn.inflight < self.shared.max_inflight {
            let frame = match conn.framer.next() {
                Some(f) => f,
                // At EOF the blocking server processes a trailing
                // unterminated line as a request; do the same.
                None => match conn.eof.then(|| conn.framer.take_remainder()).flatten() {
                    Some(bytes) => Frame::Line(bytes),
                    None => break,
                },
            };
            match frame {
                Frame::Oversized => {
                    let seq = conn.next_seq;
                    conn.next_seq += 1;
                    conn.done.insert(
                        seq,
                        reply_line(protocol::error_json(&format!(
                            "oversized request: line exceeds {} bytes",
                            protocol::MAX_REQUEST_BYTES
                        ))),
                    );
                    conn.closing = true;
                }
                Frame::Line(bytes) => {
                    if conn.http {
                        continue; // HTTP header lines: no replies, no seqs
                    }
                    let parsed = match std::str::from_utf8(&bytes) {
                        Ok(line) if line.trim().is_empty() => continue, // no reply, no seq
                        // Plain-HTTP scrape endpoint, mirroring the
                        // blocking server: the one reply is the metrics
                        // exposition wrapped as an HTTP response (formatted
                        // at completion time, in drain_completions), then
                        // the connection closes.
                        Ok(line) if line.trim_end().starts_with("GET /metrics") => {
                            conn.http = true;
                            let seq = conn.next_seq;
                            conn.next_seq += 1;
                            let reply = ReplyTo::Async {
                                tx: self.ctx.clone(),
                                conn: id,
                                seq,
                                wake: self.wake_fn.clone(),
                            };
                            match self.shared.client.submit(Request::Metrics, reply) {
                                Ok(()) => conn.inflight += 1,
                                Err(_) => {
                                    conn.done.insert(
                                        seq,
                                        super::http_metrics_response(
                                            "# metrics unavailable: server busy\n",
                                        )
                                        .into_bytes(),
                                    );
                                    conn.closing = true;
                                }
                            }
                            continue;
                        }
                        Ok(line) => protocol::parse_request_traced(line.trim())
                            .map_err(|e| format!("{e:#}")),
                        Err(_) => Err("request line is not valid UTF-8".to_string()),
                    };
                    let seq = conn.next_seq;
                    conn.next_seq += 1;
                    match parsed {
                        Ok((req, trace)) => {
                            let reply = ReplyTo::Async {
                                tx: self.ctx.clone(),
                                conn: id,
                                seq,
                                wake: self.wake_fn.clone(),
                            };
                            match self.shared.client.submit_traced(req, reply, trace) {
                                Ok(()) => conn.inflight += 1,
                                Err(SubmitError::Busy) => {
                                    self.shared
                                        .stats
                                        .requests_shed
                                        .fetch_add(1, Ordering::Relaxed);
                                    conn.done.insert(
                                        seq,
                                        reply_line(protocol::busy_json(
                                            "server busy: shard queue full",
                                        )),
                                    );
                                }
                                Err(SubmitError::Closed) => {
                                    conn.done.insert(
                                        seq,
                                        reply_line(protocol::error_json(
                                            "server shutting down",
                                        )),
                                    );
                                    conn.closing = true;
                                }
                            }
                        }
                        Err(e) => {
                            conn.done.insert(seq, reply_line(protocol::error_json(&e)));
                        }
                    }
                }
            }
        }
        self.flush(conn);
    }

    /// Serialize a shard response; the pool-wide monitoring verbs get the
    /// front end's own state grafted in (stats counters, the reply-write
    /// trace ring, the frontend Prometheus series).
    fn serialize(&self, resp: &Response) -> Vec<u8> {
        let j = match resp {
            Response::Stats(inner) => {
                let mut stats = inner.clone();
                if let Json::Obj(map) = &mut stats {
                    map.insert("frontend".into(), self.shared.stats.to_json());
                }
                Json::obj(vec![("ok", Json::Bool(true)), ("stats", stats)])
            }
            // Shard rings first (sync-reply traces), then the front end's
            // ring (async traces, reply_write included).
            Response::Traces(inner) => {
                let mut all = inner.as_arr().map(<[Json]>::to_vec).unwrap_or_default();
                if let Json::Arr(mut fe) = locked(&self.shared.traces).to_json() {
                    all.append(&mut fe);
                }
                Json::obj(vec![("ok", Json::Bool(true)), ("traces", Json::Arr(all))])
            }
            Response::MetricsText(text) => {
                let mut t = text.clone();
                self.shared.stats.append_prometheus(&mut t);
                Json::obj(vec![("ok", Json::Bool(true)), ("metrics", Json::str(t))])
            }
            other => protocol::response_to_json(other),
        };
        reply_line(j)
    }

    fn drain_completions(&mut self) {
        while let Ok(c) = self.crx.try_recv() {
            let Some(mut conn) = self.conns.remove(&c.conn) else {
                continue; // connection died with requests in flight
            };
            let t_reply = Instant::now();
            let line = if conn.http {
                // The scrape reply leaves as HTTP and the connection ends.
                let body = match &c.resp {
                    Response::MetricsText(text) => {
                        let mut t = text.clone();
                        self.shared.stats.append_prometheus(&mut t);
                        t
                    }
                    Response::Err(e) => format!("# metrics unavailable: {e}\n"),
                    other => format!("# metrics unavailable: unexpected response {other:?}\n"),
                };
                conn.closing = true;
                super::http_metrics_response(&body).into_bytes()
            } else {
                self.serialize(&c.resp)
            };
            conn.inflight -= 1;
            conn.done.insert(c.seq, line);
            // Capacity freed: frames parked in the framer can resume.
            self.process_frames(c.conn, &mut conn);
            self.settle(c.conn, conn);
            // Retire the request's trace with the reply-write stage:
            // serialization through this flush attempt (the bytes may
            // still ride the socket buffer, but this is the moment the
            // event loop is done with the reply).
            if let Some(mut rec) = c.trace {
                rec.push_span("reply_write", t_reply, Instant::now());
                locked(&self.shared.traces).push(rec);
            }
        }
    }

    /// Release in-order completed replies into the write buffer and push
    /// bytes at the socket.
    fn flush(&mut self, conn: &mut Conn) {
        while let Some(line) = conn.done.remove(&conn.next_flush) {
            conn.wbuf.extend_from_slice(&line);
            conn.next_flush += 1;
        }
        self.write_socket(conn);
    }

    fn write_socket(&mut self, conn: &mut Conn) {
        let mut written = 0;
        while written < conn.wbuf.len() {
            match conn.stream.write(&conn.wbuf[written..]) {
                Ok(0) => {
                    conn.dead = true;
                    break;
                }
                Ok(n) => written += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    break;
                }
            }
        }
        conn.wbuf.drain(..written);
    }

    /// Re-register interest and put the connection back in the map — or
    /// close it if it has finished.
    fn settle(&mut self, id: u64, mut conn: Conn) {
        if conn.finished() {
            let _ = self.epoll.del(conn.stream.as_raw_fd());
            self.conn_gone();
            return; // dropping the Conn closes the socket
        }
        let mut want = EPOLLRDHUP;
        let reads_on = !conn.eof
            && !conn.closing
            && !self.draining
            && conn.inflight < self.shared.max_inflight
            && conn.wbuf.len() < WBUF_HIGH_WATER;
        if reads_on {
            want |= EPOLLIN;
        }
        if !conn.wbuf.is_empty() {
            want |= EPOLLOUT;
        }
        if want != conn.interest
            && self
                .epoll
                .modify(conn.stream.as_raw_fd(), want, id)
                .is_ok()
        {
            conn.interest = want;
        }
        self.conns.insert(id, conn);
    }
}

/// A running readiness-driven front end.
pub struct AsyncServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl AsyncServer {
    /// Bind and spawn `opts.io_threads` event-loop threads. Thread 0 owns
    /// the listener; all threads serve connections.
    pub fn start(bind: &str, client: Client, opts: FrontendOptions) -> Result<AsyncServer> {
        let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
        listener
            .set_nonblocking(true)
            .context("nonblocking listener")?;
        let addr = listener.local_addr().context("listener addr")?;
        let nthreads = opts.io_threads.max(1);
        let wakers: Vec<Arc<EventFd>> = (0..nthreads)
            .map(|_| EventFd::new().map(Arc::new))
            .collect::<std::io::Result<_>>()
            .context("creating wakers")?;
        let shared = Arc::new(Shared {
            client,
            stats: Arc::new(FrontendStats::new(nthreads)),
            shutdown: AtomicBool::new(false),
            inject: (0..nthreads).map(|_| Mutex::new(Vec::new())).collect(),
            wakers,
            max_connections: opts.max_connections,
            max_inflight: opts.max_inflight_per_conn.max(1),
            rr: AtomicUsize::new(0),
            conn_ids: AtomicU64::new(0),
            traces: Mutex::new(TraceRing::new(opts.trace_buffer)),
        });
        let mut threads = Vec::with_capacity(nthreads);
        let mut listener = Some(listener);
        for idx in 0..nthreads {
            let epoll = Epoll::new().context("epoll_create1")?;
            let wake = shared.wakers[idx].clone();
            epoll
                .add(wake.raw(), EPOLLIN, DATA_WAKE)
                .context("registering waker")?;
            let own_listener = if idx == 0 { listener.take() } else { None };
            if let Some(l) = &own_listener {
                epoll
                    .add(l.as_raw_fd(), EPOLLIN, DATA_LISTENER)
                    .context("registering listener")?;
            }
            let (ctx, crx) = mpsc::channel();
            let wake_for_fn = wake.clone();
            let thread = IoThread {
                idx,
                shared: shared.clone(),
                epoll,
                wake,
                ctx,
                crx,
                wake_fn: Arc::new(move || wake_for_fn.ring()),
                listener: own_listener,
                conns: HashMap::new(),
                draining: false,
            };
            let handle = std::thread::Builder::new()
                .name(format!("vqt-io-{idx}"))
                .spawn(move || thread.run())
                .context("spawning io thread")?;
            threads.push(handle);
        }
        log::info!(
            "vqt async server listening on {addr} ({nthreads} io threads, \
             max_connections={}, max_inflight_per_conn={})",
            opts.max_connections,
            opts.max_inflight_per_conn
        );
        Ok(AsyncServer {
            addr,
            shared,
            threads,
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> Arc<FrontendStats> {
        self.shared.stats.clone()
    }

    /// Graceful drain: stop accepting, flush in-flight replies, close
    /// connections, join the IO threads. Call BEFORE tearing down the
    /// coordinator — the drain waits on shard replies.
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        for w in &self.shared.wakers {
            w.ring();
        }
        for h in self.threads.drain(..) {
            if h.join().is_err() {
                log::error!("io thread panicked during shutdown");
            }
        }
    }

    /// Park until the IO threads exit (they don't, short of `shutdown` or
    /// a fatal epoll error) — the serve-forever entry point.
    pub fn join(mut self) -> Result<()> {
        for h in self.threads.drain(..) {
            h.join()
                .map_err(|_| anyhow::anyhow!("io thread panicked"))?;
        }
        Ok(())
    }
}

/// Serve forever on `cfg.bind` with the readiness-driven front end.
pub fn serve_async(cfg: &ServeConfig, client: Client) -> Result<()> {
    AsyncServer::start(&cfg.bind, client, FrontendOptions::from_cfg(cfg))?.join()
}
