//! Wire protocol: JSON ↔ coordinator request/response mapping.

use crate::coordinator::{Request, Response};
use crate::edits::Edit;
use crate::util::Json;
use anyhow::{bail, Context, Result};

/// Maximum accepted request-line length. A line past this is rejected up
/// front — before JSON parsing allocates anything proportional to it — so
/// an oversized payload costs the server one length check, not a parse.
pub const MAX_REQUEST_BYTES: usize = 1 << 20;

/// A token id: a non-negative integer that fits u32 (silent `as u32`
/// truncation of a huge number would corrupt the document instead of
/// erroring).
fn token_value(v: &Json, key: &str) -> Result<u32> {
    let u = v
        .as_usize()
        .with_context(|| format!("'{key}' must hold non-negative integers"))?;
    anyhow::ensure!(u <= u32::MAX as usize, "'{key}' token {u} exceeds u32 range");
    Ok(u as u32)
}

fn tokens_field(j: &Json, key: &str) -> Result<Vec<u32>> {
    j.get(key)
        .as_arr()
        .with_context(|| format!("missing '{key}' array"))?
        .iter()
        .map(|v| token_value(v, key))
        .collect()
}

fn session_field(j: &Json) -> Result<String> {
    Ok(j.get("session")
        .as_str()
        .context("missing 'session'")?
        .to_string())
}

/// Parse one request line (trace flag discarded — test/tooling shorthand).
pub fn parse_request(line: &str) -> Result<Request> {
    parse_request_traced(line).map(|(req, _)| req)
}

/// Parse one request line plus its opt-in `"trace": true` flag. The flag
/// rides on any request; a flagged request's reply carries a `"trace"`
/// object with the span breakdown. Replies without the flag are
/// byte-identical to a build that never heard of tracing.
pub fn parse_request_traced(line: &str) -> Result<(Request, bool)> {
    if line.len() > MAX_REQUEST_BYTES {
        bail!(
            "oversized request: {} bytes (limit {MAX_REQUEST_BYTES})",
            line.len()
        );
    }
    let j = Json::parse(line).context("invalid JSON")?;
    let op = j.get("op").as_str().context("missing 'op'")?;
    let trace = j.get("trace").as_bool().unwrap_or(false);
    let req = match op {
        "open" => Request::Open {
            session: session_field(&j)?,
            tokens: tokens_field(&j, "tokens")?,
        },
        "edit" => {
            let at = j.get("at").as_usize().context("missing 'at'")?;
            let edit = match j.get("kind").as_str().context("missing 'kind'")? {
                "replace" => Edit::Replace {
                    at,
                    tok: token_value(j.get("tok"), "tok")?,
                },
                "insert" => Edit::Insert {
                    at,
                    tok: token_value(j.get("tok"), "tok")?,
                },
                "delete" => Edit::Delete { at },
                k => bail!("unknown edit kind '{k}'"),
            };
            Request::Edit {
                session: session_field(&j)?,
                edit,
            }
        }
        "revision" => Request::Revision {
            session: session_field(&j)?,
            tokens: tokens_field(&j, "tokens")?,
        },
        "batch_revisions" => {
            let base = tokens_field(&j, "base")?;
            let revisions = j
                .get("revisions")
                .as_arr()
                .context("missing 'revisions'")?
                .iter()
                .map(|r| {
                    r.as_arr()
                        .context("revision must be an array")?
                        .iter()
                        .map(|v| token_value(v, "revisions"))
                        .collect::<Result<Vec<u32>>>()
                })
                .collect::<Result<Vec<_>>>()?;
            Request::BatchRevisions { base, revisions }
        }
        "dense" => Request::Dense {
            tokens: tokens_field(&j, "tokens")?,
        },
        "suggest" => Request::Suggest {
            session: session_field(&j)?,
            k: j.get("k").as_usize().unwrap_or(5),
        },
        "checkpoint" => Request::Checkpoint {
            session: session_field(&j)?,
            path: j.get("path").as_str().context("missing 'path'")?.to_string(),
        },
        "restore" => Request::Restore {
            session: session_field(&j)?,
            path: j.get("path").as_str().context("missing 'path'")?.to_string(),
        },
        "suspend" => Request::Suspend {
            session: session_field(&j)?,
        },
        "resume" => Request::Resume {
            session: session_field(&j)?,
        },
        "session_info" => Request::SessionInfo {
            session: session_field(&j)?,
        },
        "close" => Request::Close {
            session: session_field(&j)?,
        },
        "stats" => Request::Stats,
        "trace" => Request::TraceDump,
        "metrics" => Request::Metrics,
        op => bail!("unknown op '{op}'"),
    };
    Ok((req, trace))
}

/// Serialize a response line.
pub fn response_to_json(resp: &Response) -> Json {
    match resp {
        Response::Logits {
            logits,
            predicted,
            flops,
            dense_equiv_flops,
            defragged,
        } => Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "logits",
                Json::Arr(logits.iter().map(|&x| Json::num(x as f64)).collect()),
            ),
            ("predicted", Json::num(*predicted as f64)),
            ("flops", Json::num(*flops as f64)),
            ("dense_equiv_flops", Json::num(*dense_equiv_flops as f64)),
            (
                "speedup",
                Json::num(if *flops > 0 {
                    *dense_equiv_flops as f64 / *flops as f64
                } else {
                    0.0
                }),
            ),
            ("defragged", Json::Bool(*defragged)),
        ]),
        Response::BatchLogits {
            each,
            flops,
            dense_equiv_flops,
            storage,
        } => Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "each",
                Json::Arr(
                    each.iter()
                        .map(|l| Json::Arr(l.iter().map(|&x| Json::num(x as f64)).collect()))
                        .collect(),
                ),
            ),
            ("flops", Json::num(*flops as f64)),
            ("dense_equiv_flops", Json::num(*dense_equiv_flops as f64)),
            ("storage_compressed", Json::num(storage.0 as f64)),
            ("storage_dense", Json::num(storage.1 as f64)),
        ]),
        Response::Stats(j) => Json::obj(vec![("ok", Json::Bool(true)), ("stats", j.clone())]),
        // Normally merged into `Stats` by the client before reaching the
        // wire; serialized directly if a raw shard snapshot ever escapes.
        Response::ShardStats {
            metrics,
            live_sessions,
            spilled_sessions,
            resident_bytes,
        } => {
            let mut stats = metrics.to_json();
            if let Json::Obj(map) = &mut stats {
                map.insert("live_sessions".into(), Json::num(*live_sessions as f64));
                map.insert(
                    "spilled_sessions".into(),
                    Json::num(*spilled_sessions as f64),
                );
                map.insert("resident_bytes".into(), Json::num(*resident_bytes as f64));
            }
            Json::obj(vec![("ok", Json::Bool(true)), ("stats", stats)])
        }
        Response::SessionInfo {
            state,
            resident_bytes,
            spill_bytes,
            edits,
            doc_len,
        } => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("state", Json::str(*state)),
            ("resident_bytes", Json::num(*resident_bytes as f64)),
            ("spill_bytes", Json::num(*spill_bytes as f64)),
            ("edits", Json::num(*edits as f64)),
            ("len", Json::num(*doc_len as f64)),
        ]),
        Response::Suggestions(top) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "suggestions",
                Json::Arr(
                    top.iter()
                        .map(|(t, s)| {
                            Json::obj(vec![
                                ("tok", Json::num(*t as f64)),
                                ("score", Json::num(*s as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
        Response::Traces(traces) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("traces", traces.clone()),
        ]),
        // The exposition text is shipped inside JSON on the line protocol;
        // `GET /metrics` peels it back out as text/plain for scrapers.
        Response::MetricsText(text) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("metrics", Json::str(text.clone())),
        ]),
        // The span breakdown rides inside the inner reply's object — the
        // reply keeps its normal shape plus one extra "trace" key.
        Response::Traced { inner, trace } => {
            let mut j = response_to_json(inner);
            if let Json::Obj(map) = &mut j {
                map.insert("trace".into(), trace.clone());
            }
            j
        }
        Response::Done => Json::obj(vec![("ok", Json::Bool(true))]),
        Response::Closed { existed } => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("existed", Json::Bool(*existed)),
        ]),
        Response::Err(e) => error_json(e),
    }
}

pub fn error_json(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(msg))])
}

/// Typed load-shed reply: `busy:true` distinguishes "server saturated,
/// retry later" from a request the client got wrong — a client can back
/// off on `busy` without parsing error strings.
pub fn busy_json(msg: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("busy", Json::Bool(true)),
        ("error", Json::str(msg)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_open_and_edits() {
        let r = parse_request(r#"{"op":"open","session":"s","tokens":[1,2,3]}"#).unwrap();
        assert!(matches!(r, Request::Open { ref session, ref tokens } if session == "s" && tokens == &[1,2,3]));
        let r = parse_request(r#"{"op":"edit","session":"s","kind":"replace","at":1,"tok":9}"#)
            .unwrap();
        assert!(matches!(
            r,
            Request::Edit {
                edit: Edit::Replace { at: 1, tok: 9 },
                ..
            }
        ));
        let r = parse_request(r#"{"op":"edit","session":"s","kind":"delete","at":0}"#).unwrap();
        assert!(matches!(
            r,
            Request::Edit {
                edit: Edit::Delete { at: 0 },
                ..
            }
        ));
    }

    #[test]
    fn parse_batch() {
        let r = parse_request(
            r#"{"op":"batch_revisions","base":[1,2],"revisions":[[1,3],[2,2]]}"#,
        )
        .unwrap();
        match r {
            Request::BatchRevisions { base, revisions } => {
                assert_eq!(base, vec![1, 2]);
                assert_eq!(revisions.len(), 2);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parse_errors() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"op":"zap"}"#).is_err());
        assert!(parse_request(r#"{"op":"open","tokens":[1]}"#).is_err());
        assert!(parse_request(r#"{"op":"edit","session":"s","kind":"warp","at":0}"#).is_err());
        assert!(parse_request(r#"{"op":"open","session":"s","tokens":[-1]}"#).is_err());
        // Token values past u32 must be rejected, not silently truncated.
        assert!(parse_request(r#"{"op":"open","session":"s","tokens":[4294967296]}"#).is_err());
        assert!(
            parse_request(r#"{"op":"edit","session":"s","kind":"insert","at":0,"tok":1e18}"#)
                .is_err()
        );
    }

    #[test]
    fn parse_lifecycle_verbs() {
        let r = parse_request(r#"{"op":"suspend","session":"s"}"#).unwrap();
        assert!(matches!(r, Request::Suspend { ref session } if session == "s"));
        let r = parse_request(r#"{"op":"resume","session":"s"}"#).unwrap();
        assert!(matches!(r, Request::Resume { ref session } if session == "s"));
        let r = parse_request(r#"{"op":"session_info","session":"s"}"#).unwrap();
        assert!(matches!(r, Request::SessionInfo { ref session } if session == "s"));
        assert!(parse_request(r#"{"op":"suspend"}"#).is_err(), "missing session");
    }

    #[test]
    fn session_info_response_shape() {
        let j = response_to_json(&Response::SessionInfo {
            state: "suspended",
            resident_bytes: 0,
            spill_bytes: 1234,
            edits: 7,
            doc_len: 42,
        });
        assert_eq!(j.get("ok").as_bool(), Some(true));
        assert_eq!(j.get("state").as_str(), Some("suspended"));
        assert_eq!(j.get("spill_bytes").as_usize(), Some(1234));
        assert_eq!(j.get("len").as_usize(), Some(42));
    }

    #[test]
    fn parse_trace_flag_and_observability_verbs() {
        let (r, t) = parse_request_traced(r#"{"op":"trace"}"#).unwrap();
        assert!(matches!(r, Request::TraceDump));
        assert!(!t);
        let (r, _) = parse_request_traced(r#"{"op":"metrics"}"#).unwrap();
        assert!(matches!(r, Request::Metrics));
        // The flag rides on ordinary requests and defaults to off.
        let (r, t) = parse_request_traced(
            r#"{"op":"edit","session":"s","kind":"delete","at":0,"trace":true}"#,
        )
        .unwrap();
        assert!(matches!(r, Request::Edit { .. }));
        assert!(t);
        let (_, t) =
            parse_request_traced(r#"{"op":"edit","session":"s","kind":"delete","at":0}"#).unwrap();
        assert!(!t);
        // Non-boolean values of the flag read as off, not as an error.
        let (_, t) = parse_request_traced(r#"{"op":"stats","trace":"yes"}"#).unwrap();
        assert!(!t);
    }

    #[test]
    fn traced_and_observability_response_shapes() {
        // Traced: the inner reply keeps its shape, plus one "trace" key.
        let inner = Response::Closed { existed: true };
        let plain = response_to_json(&inner).to_string();
        let j = response_to_json(&Response::Traced {
            inner: Box::new(inner),
            trace: Json::obj(vec![("total_us", Json::num(42.0))]),
        });
        assert_eq!(j.get("ok").as_bool(), Some(true));
        assert_eq!(j.get("existed").as_bool(), Some(true));
        assert_eq!(j.get("trace").get("total_us").as_usize(), Some(42));
        assert!(!plain.contains("trace"), "untraced replies carry no key");
        // Traces: array passthrough under "traces".
        let j = response_to_json(&Response::Traces(Json::Arr(vec![])));
        assert_eq!(j.get("ok").as_bool(), Some(true));
        assert_eq!(j.get("traces").as_arr().map(<[Json]>::len), Some(0));
        // MetricsText: exposition text embedded as a JSON string (newlines
        // escaped by the serializer, so it stays one protocol line).
        let j = response_to_json(&Response::MetricsText("# TYPE a counter\na 1\n".into()));
        assert_eq!(j.get("metrics").as_str(), Some("# TYPE a counter\na 1\n"));
        assert!(!j.to_string().contains('\n'), "one line on the wire");
    }

    #[test]
    fn oversized_line_rejected_cheaply() {
        let huge = format!(
            r#"{{"op":"open","session":"s","tokens":[{}1]}}"#,
            "1,".repeat(MAX_REQUEST_BYTES / 2)
        );
        let err = parse_request(&huge).unwrap_err().to_string();
        assert!(err.contains("oversized"), "{err}");
    }

    #[test]
    fn response_roundtrip_shape() {
        let resp = Response::Logits {
            logits: vec![0.5, -0.5],
            predicted: 0,
            flops: 100,
            dense_equiv_flops: 1000,
            defragged: false,
        };
        let j = response_to_json(&resp);
        assert_eq!(j.get("ok").as_bool(), Some(true));
        assert_eq!(j.get("speedup").as_f64(), Some(10.0));
        let err = error_json("boom");
        assert_eq!(err.get("ok").as_bool(), Some(false));
        assert_eq!(err.get("error").as_str(), Some("boom"));
        let busy = busy_json("shard queue full");
        assert_eq!(busy.get("ok").as_bool(), Some(false));
        assert_eq!(busy.get("busy").as_bool(), Some(true));
        assert_eq!(busy.get("error").as_str(), Some("shard queue full"));
    }
}
