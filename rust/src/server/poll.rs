//! Minimal epoll + eventfd bindings (Linux), declared directly against the
//! libc that `std` already links — the crate keeps its zero-heavy-deps
//! posture, so there is no `libc`/`mio` crate to lean on. Only what the
//! event loop needs is wrapped: an epoll instance with add/modify/delete/
//! wait, and an eventfd used as a cross-thread waker. Sockets themselves
//! stay `std::net` types in nonblocking mode; raw `read`/`write` are used
//! for the eventfd alone.

use std::io;
use std::os::unix::io::RawFd;

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;
const EPOLL_CLOEXEC: i32 = 0x80000;
const EFD_CLOEXEC: i32 = 0x80000;
const EFD_NONBLOCK: i32 = 0x800;

/// `struct epoll_event`. x86-64 packs it (the kernel ABI there has no
/// padding between `events` and `data`); other architectures use natural
/// layout — the same split glibc's header makes.
#[cfg_attr(target_arch = "x86_64", repr(C, packed))]
#[cfg_attr(not(target_arch = "x86_64"), repr(C))]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout_ms: i32) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn close(fd: i32) -> i32;
}

fn cvt(ret: i32) -> io::Result<i32> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

/// An epoll instance (closed on drop). Level-triggered throughout: the
/// event loop re-arms nothing and simply reacts to whatever is still
/// ready, which keeps the readiness bookkeeping trivially correct.
pub struct Epoll {
    fd: RawFd,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        let fd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: i32, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, data };
        cvt(unsafe { epoll_ctl(self.fd, op, fd, &mut ev) }).map(|_| ())
    }

    pub fn add(&self, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, events, data)
    }

    pub fn modify(&self, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, events, data)
    }

    pub fn del(&self, fd: RawFd) -> io::Result<()> {
        // A non-null event pointer keeps pre-2.6.9 kernels happy; the
        // contents are ignored for DEL.
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Wait for readiness; retries transparent EINTR wakeups.
    pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        loop {
            let n = unsafe {
                epoll_wait(
                    self.fd,
                    events.as_mut_ptr(),
                    events.len() as i32,
                    timeout_ms,
                )
            };
            if n >= 0 {
                return Ok(n as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe { close(self.fd) };
    }
}

/// Nonblocking eventfd used as a cross-thread waker: shard threads `ring`
/// it when a completion is queued; the owning IO thread has it registered
/// in its epoll set and `drain`s it on wakeup. Counter semantics (writes
/// add, one read zeroes) coalesce any number of pending rings into a
/// single wakeup.
pub struct EventFd {
    fd: RawFd,
}

impl EventFd {
    pub fn new() -> io::Result<EventFd> {
        let fd = cvt(unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) })?;
        Ok(EventFd { fd })
    }

    pub fn raw(&self) -> RawFd {
        self.fd
    }

    /// Wake the owning thread. Best-effort: the only failure mode of an
    /// eventfd write is a full counter, which still leaves it readable —
    /// i.e. the wakeup is already pending.
    pub fn ring(&self) {
        let one: u64 = 1;
        unsafe { write(self.fd, one.to_ne_bytes().as_ptr(), 8) };
    }

    /// Zero the counter so the (level-triggered) fd stops polling ready.
    pub fn drain(&self) {
        let mut buf = [0u8; 8];
        unsafe { read(self.fd, buf.as_mut_ptr(), 8) };
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        unsafe { close(self.fd) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eventfd_wakes_epoll_and_drains_quiet() {
        let ep = Epoll::new().unwrap();
        let ev = EventFd::new().unwrap();
        ep.add(ev.raw(), EPOLLIN, 7).unwrap();
        let mut events = [EpollEvent { events: 0, data: 0 }; 4];
        // Quiet: zero-timeout wait sees nothing.
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        // Ring twice — coalesces into one readiness event with our data.
        ev.ring();
        ev.ring();
        let n = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 1);
        let data = events[0].data; // copy out of the (packed) struct
        assert_eq!(data, 7);
        // Drain zeroes the counter: level-triggered readiness clears.
        ev.drain();
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
    }

    #[test]
    fn epoll_modify_and_del_rewire_interest() {
        let ep = Epoll::new().unwrap();
        let ev = EventFd::new().unwrap();
        ep.add(ev.raw(), EPOLLIN, 1).unwrap();
        ev.ring();
        // Interest without EPOLLIN: readable, but not reported.
        ep.modify(ev.raw(), 0, 1).unwrap();
        let mut events = [EpollEvent { events: 0, data: 0 }; 4];
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        // Re-enable: the pending readiness resurfaces (level-triggered).
        ep.modify(ev.raw(), EPOLLIN, 2).unwrap();
        assert_eq!(ep.wait(&mut events, 1000).unwrap(), 1);
        let data = events[0].data;
        assert_eq!(data, 2);
        ep.del(ev.raw()).unwrap();
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
    }
}
