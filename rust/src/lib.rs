//! # vqt — Incrementally-Computable Neural Networks
//!
//! A production-shaped reproduction of *"Incrementally-Computable Neural
//! Networks: Efficient Inference for Dynamic Inputs"* (Sharir & Anandkumar,
//! 2023): Vector-Quantized Transformers (VQT) whose inference cost under
//! document edits is proportional to the edit distance, not the document
//! length.
//!
//! Architecture (three layers, Python never on the request path):
//! - **L3 (this crate)** — serving coordinator + the incremental inference
//!   engine ([`incremental`], [`coordinator`], [`server`]).
//! - **L2 (JAX, build time)** — dense VQT forward lowered to HLO text
//!   artifacts, executed through PJRT by [`runtime`].
//! - **L1 (Pallas, build time)** — VQ-assignment and GELU-attention kernels
//!   validated against pure-jnp references.
//!
//! The incremental dataflow (edit → diff → VQ code comparison → row
//! reuse), the monotonic-reuse argument, and the FLOP-accounting model are
//! documented in `docs/ARCHITECTURE.md` at the repository root; the build
//! and artifact pipeline is in `README.md`.
//!
//! ## Quickstart
//!
//! Open a session on a document, apply an edit, and verify that the
//! incrementally-maintained state matches a from-scratch dense recompute
//! (the paper's exactness claim):
//!
//! ```
//! use std::sync::Arc;
//! use vqt::config::ModelConfig;
//! use vqt::edits::Edit;
//! use vqt::incremental::{EngineOptions, IncrementalEngine};
//! use vqt::model::ModelWeights;
//!
//! let cfg = ModelConfig::vqt_tiny();
//! let weights = Arc::new(ModelWeights::random(&cfg, 7));
//! let tokens: Vec<u32> = (0..12).map(|i| i % 60).collect();
//!
//! let mut engine = IncrementalEngine::new(weights, &tokens, EngineOptions::default());
//! let report = engine.apply_edit(Edit::Replace { at: 3, tok: 9 });
//! assert_eq!(report.logits.len(), cfg.n_classes);
//! assert!(report.flops > 0);
//!
//! let verify = engine.verify();
//! assert!(verify.is_exact(1e-3), "incremental state must match dense");
//! ```
//!
//! Start with [`config::ModelConfig`], [`model::ModelWeights`], and
//! [`incremental::IncrementalEngine`]; `examples/quickstart.rs` is the
//! runnable version of the snippet above.

pub mod bench;
pub mod compressed;
pub mod config;
pub mod coordinator;
pub mod edits;
pub mod flops;
pub mod incremental;
pub mod model;
pub mod positions;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod testutil;
pub mod util;
pub mod vq;
