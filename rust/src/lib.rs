//! # vqt — Incrementally-Computable Neural Networks
//!
//! A production-shaped reproduction of *"Incrementally-Computable Neural
//! Networks: Efficient Inference for Dynamic Inputs"* (Sharir & Anandkumar,
//! 2023): Vector-Quantized Transformers (VQT) whose inference cost under
//! document edits is proportional to the edit distance, not the document
//! length.
//!
//! Architecture (three layers, Python never on the request path):
//! - **L3 (this crate)** — serving coordinator + the incremental inference
//!   engine ([`incremental`], [`coordinator`], [`server`]).
//! - **L2 (JAX, build time)** — dense VQT forward lowered to HLO text
//!   artifacts, executed through PJRT by [`runtime`].
//! - **L1 (Pallas, build time)** — VQ-assignment and GELU-attention kernels
//!   validated against pure-jnp references.
//!
//! Start with [`config::ModelConfig`], [`model::ModelWeights`], and
//! `incremental::IncrementalEngine`; see `examples/quickstart.rs`.

pub mod bench;
pub mod compressed;
pub mod config;
pub mod coordinator;
pub mod edits;
pub mod flops;
pub mod incremental;
pub mod model;
pub mod positions;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod testutil;
pub mod util;
pub mod vq;
