//! Versioned, checksummed session snapshots — the serialization layer of
//! the session lifecycle subsystem.
//!
//! A snapshot captures the FULL per-document reuse state of an
//! [`IncrementalEngine`] (row stores, VQ code assignments, position
//! bookkeeping, classifier caches) **plus** its FLOP ledger and lifetime
//! statistics, so that a restored engine is *indistinguishable* from one
//! that never left memory: subsequent edits produce bit-identical logits,
//! identical `EditReport::flops`, and identical reuse counters. That
//! invariant is what makes LRU spill-to-disk transparent (and what the
//! `differential_lifecycle` suite locks).
//!
//! On-disk layout (little-endian):
//! ```text
//! magic   "VQSS"          4 bytes
//! version u8              (currently 1)
//! len     u64             payload byte count
//! payload [len]           a util::binfmt TensorFile (state + counters)
//! check   u64             FNV-1a 64 over payload
//! ```
//! The envelope makes corruption failure modes *clean*: a bad magic,
//! unknown version, short read, or checksum mismatch each produce a
//! descriptive `Err` from [`IncrementalEngine::restore`] — never a panic
//! and never a partially-restored session (the engine is only constructed
//! after every field validates).
//!
//! The payload embeds a fingerprint of the model configuration; restoring
//! against different weights geometry is rejected up front rather than
//! producing silently-wrong state.
//!
//! The shared codebook-product cache ([`crate::incremental::codecache`])
//! is deliberately NOT part of a snapshot — neither its entries nor the
//! engine's `cache_*` counters. The cache is process-global derived
//! state: a restored engine re-attaches whatever cache its host serves
//! and rewarms lazily (first touches miss and repopulate), which stays
//! bit-exact because cached and uncached tails are bit-identical. The
//! stats tensor therefore stays at the 8 pre-cache counters and the
//! snapshot format needs no version bump. The semi-naive attention
//! counters (`attn_*`, docs/ARCHITECTURE.md §12) follow the same
//! exclusion: they describe work already paid for, not reusable state.
//!
//! Softmax-attention engines additionally serialize their per-layer
//! streaming-softmax aggregates (`sm_num`/`sm_den`/`sm_m` plus the
//! `sm_drift` refresh counters) so a restored engine keeps taking delta
//! updates with the exact same weights it would have used in memory.
//! These are ordinary named tensors in the payload — gelu-series
//! snapshots don't carry them and stay byte-identical to before, so this
//! too needs no version bump.

use crate::flops::FlopLedger;
use crate::incremental::{EngineOptions, IncrementalEngine};
use crate::model::ModelWeights;
use crate::util::{fnv1a64, Tensor, TensorFile};
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// Snapshot container magic ("VQ Session Snapshot").
pub const SNAPSHOT_MAGIC: &[u8; 4] = b"VQSS";
/// Current snapshot format version. Bump on any payload schema change.
pub const SNAPSHOT_VERSION: u8 = 1;
/// Envelope overhead: magic + version + payload length + checksum.
const HEADER_LEN: usize = 4 + 1 + 8;
const FOOTER_LEN: usize = 8;

/// Pack a u64 counter into two i32 lanes (binfmt carries f32/i32 only).
fn u64_lanes(x: u64) -> [i32; 2] {
    [(x & 0xffff_ffff) as u32 as i32, (x >> 32) as u32 as i32]
}

fn lanes_u64(lanes: &[i32]) -> u64 {
    (lanes[0] as u32 as u64) | ((lanes[1] as u32 as u64) << 32)
}

/// Stable fingerprint of the model geometry a snapshot was taken under.
/// Hashes the deterministic JSON form of the config, so any dimension or
/// attention-kind change invalidates old snapshots.
pub fn config_fingerprint(cfg: &crate::config::ModelConfig) -> u64 {
    fnv1a64(cfg.to_json().to_string().as_bytes())
}

impl IncrementalEngine {
    /// Serialize the full session — reuse state AND counters — into the
    /// versioned, checksummed snapshot format.
    pub fn snapshot(&self) -> Vec<u8> {
        let mut tf = self.to_tensor_file();
        tf.insert(
            "model_fp",
            Tensor::i32(vec![2], u64_lanes(config_fingerprint(&self.weights().cfg)).to_vec()),
        );
        let led = &self.ledger;
        let counters: Vec<u64> = vec![
            led.linear,
            led.attention,
            led.vq,
            led.elementwise,
            led.embed,
            led.bookkeeping,
        ];
        tf.insert(
            "ledger",
            Tensor::i32(
                vec![counters.len(), 2],
                counters.iter().flat_map(|&x| u64_lanes(x)).collect(),
            ),
        );
        let s = &self.stats;
        let stats: Vec<u64> = vec![
            s.edits_applied,
            s.defrags,
            s.full_rebuilds,
            s.rows_recomputed,
            s.corrections,
            s.code_flips,
            s.outputs_recomputed,
            s.verifications,
        ];
        tf.insert(
            "stats",
            Tensor::i32(
                vec![stats.len(), 2],
                stats.iter().flat_map(|&x| u64_lanes(x)).collect(),
            ),
        );
        let mut payload = Vec::new();
        tf.write_to(&mut payload)
            .expect("in-memory tensor write cannot fail");
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + FOOTER_LEN);
        out.extend_from_slice(SNAPSHOT_MAGIC);
        out.push(SNAPSHOT_VERSION);
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&payload);
        out.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
        out
    }

    /// Restore a session from [`Self::snapshot`] bytes. Validates the
    /// envelope (magic, version, length, checksum) and the model
    /// fingerprint before touching any engine state; every failure mode is
    /// a clean `Err` with no partial session constructed.
    pub fn restore(
        w: Arc<ModelWeights>,
        bytes: &[u8],
        opts: EngineOptions,
    ) -> Result<IncrementalEngine> {
        ensure!(
            bytes.len() >= HEADER_LEN + FOOTER_LEN,
            "truncated snapshot: {} bytes is shorter than the envelope",
            bytes.len()
        );
        ensure!(
            &bytes[..4] == SNAPSHOT_MAGIC,
            "bad magic {:?}: not a VQSS session snapshot",
            &bytes[..4]
        );
        let version = bytes[4];
        ensure!(
            version == SNAPSHOT_VERSION,
            "unsupported snapshot version {version} (this build reads version {SNAPSHOT_VERSION})"
        );
        let len = u64::from_le_bytes(bytes[5..13].try_into().unwrap()) as usize;
        let have = bytes.len() - HEADER_LEN - FOOTER_LEN;
        if have < len {
            bail!("truncated snapshot: payload has {have} of {len} bytes");
        }
        if have > len {
            bail!("oversized snapshot: {} trailing bytes", have - len);
        }
        let payload = &bytes[HEADER_LEN..HEADER_LEN + len];
        let want = u64::from_le_bytes(bytes[HEADER_LEN + len..].try_into().unwrap());
        let got = fnv1a64(payload);
        ensure!(
            got == want,
            "snapshot checksum mismatch (stored {want:#018x}, computed {got:#018x}) — file corrupted"
        );
        let tf = TensorFile::read_from(&mut &payload[..]).context("parsing snapshot payload")?;
        let (_, fp) = tf.get("model_fp")?.as_i32()?;
        let snap_fp = lanes_u64(fp);
        let our_fp = config_fingerprint(&w.cfg);
        ensure!(
            snap_fp == our_fp,
            "snapshot was taken under a different model configuration \
             (fingerprint {snap_fp:#018x}, serving {our_fp:#018x})"
        );
        let mut eng = IncrementalEngine::from_tensor_file(w, &tf, opts)?;
        let (dims, led) = tf.get("ledger")?.as_i32()?;
        ensure!(dims == [6, 2], "ledger dims {dims:?}");
        eng.ledger = FlopLedger {
            linear: lanes_u64(&led[0..2]),
            attention: lanes_u64(&led[2..4]),
            vq: lanes_u64(&led[4..6]),
            elementwise: lanes_u64(&led[6..8]),
            embed: lanes_u64(&led[8..10]),
            bookkeeping: lanes_u64(&led[10..12]),
        };
        let (dims, st) = tf.get("stats")?.as_i32()?;
        ensure!(dims == [8, 2], "stats dims {dims:?}");
        eng.stats.edits_applied = lanes_u64(&st[0..2]);
        eng.stats.defrags = lanes_u64(&st[2..4]);
        eng.stats.full_rebuilds = lanes_u64(&st[4..6]);
        eng.stats.rows_recomputed = lanes_u64(&st[6..8]);
        eng.stats.corrections = lanes_u64(&st[8..10]);
        eng.stats.code_flips = lanes_u64(&st[10..12]);
        eng.stats.outputs_recomputed = lanes_u64(&st[12..14]);
        eng.stats.verifications = lanes_u64(&st[14..16]);
        Ok(eng)
    }

    /// Write a snapshot to `path` atomically (temp file + rename), so a
    /// crash mid-spill never leaves a half-written snapshot where the
    /// resume path will find it.
    pub fn snapshot_to_file(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.snapshot())
            .with_context(|| format!("writing snapshot {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("publishing snapshot {}", path.display()))
    }

    /// Load a snapshot written by [`Self::snapshot_to_file`].
    pub fn restore_from_file(
        w: Arc<ModelWeights>,
        path: impl AsRef<Path>,
        opts: EngineOptions,
    ) -> Result<IncrementalEngine> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("reading snapshot {}", path.as_ref().display()))?;
        Self::restore(w, &bytes, opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::edits::Edit;
    use crate::util::Rng;

    fn built_engine(seed: u64) -> (Arc<ModelWeights>, IncrementalEngine) {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, seed));
        let mut r = Rng::new(seed ^ 0x5A5A);
        let tokens: Vec<u32> = (0..14).map(|_| r.below(cfg.vocab_size) as u32).collect();
        let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        eng.apply_edit(Edit::Replace { at: 2, tok: 7 });
        eng.apply_edit(Edit::Insert { at: 5, tok: 11 });
        eng.apply_edit(Edit::Delete { at: 0 });
        (w, eng)
    }

    #[test]
    fn roundtrip_is_indistinguishable() {
        let (w, eng) = built_engine(1);
        let bytes = eng.snapshot();
        let back = IncrementalEngine::restore(w, &bytes, EngineOptions::default()).unwrap();
        assert_eq!(back.tokens(), eng.tokens());
        assert_eq!(back.position_ids(), eng.position_ids());
        // Bit-exact logits, carried-over counters.
        for (a, b) in eng.logits().iter().zip(back.logits()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.ledger, eng.ledger, "ledger must survive the cycle");
        assert_eq!(back.stats, eng.stats, "stats must survive the cycle");
    }

    #[test]
    fn roundtrip_through_file() {
        let (w, eng) = built_engine(2);
        let path = std::env::temp_dir().join(format!("vqss_rt_{}.vqss", std::process::id()));
        eng.snapshot_to_file(&path).unwrap();
        let back =
            IncrementalEngine::restore_from_file(w, &path, EngineOptions::default()).unwrap();
        assert_eq!(back.tokens(), eng.tokens());
        assert_eq!(back.ledger, eng.ledger);
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn corrupted_checksum_rejected() {
        let (w, eng) = built_engine(3);
        let mut bytes = eng.snapshot();
        // Flip one payload byte: the checksum no longer matches.
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = IncrementalEngine::restore(w, &bytes, EngineOptions::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("checksum"), "{err}");
    }

    #[test]
    fn truncated_snapshot_rejected() {
        let (w, eng) = built_engine(4);
        let bytes = eng.snapshot();
        // Every truncation point must fail cleanly (spot-check a spread).
        for cut in [0, 3, 4, 5, 12, 13, bytes.len() / 2, bytes.len() - 1] {
            let err = IncrementalEngine::restore(w.clone(), &bytes[..cut], EngineOptions::default());
            assert!(err.is_err(), "cut at {cut} must be rejected");
        }
    }

    #[test]
    fn bumped_version_rejected() {
        let (w, eng) = built_engine(5);
        let mut bytes = eng.snapshot();
        bytes[4] = SNAPSHOT_VERSION + 1;
        let err = IncrementalEngine::restore(w, &bytes, EngineOptions::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn bad_magic_and_trailing_garbage_rejected() {
        let (w, eng) = built_engine(6);
        let mut bad = eng.snapshot();
        bad[0] = b'X';
        assert!(IncrementalEngine::restore(w.clone(), &bad, EngineOptions::default()).is_err());
        let mut long = eng.snapshot();
        long.extend_from_slice(&[0u8; 16]);
        assert!(IncrementalEngine::restore(w, &long, EngineOptions::default()).is_err());
    }

    #[test]
    fn wrong_model_fingerprint_rejected() {
        let (_, eng) = built_engine(7);
        let bytes = eng.snapshot();
        let mut cfg2 = ModelConfig::vqt_tiny();
        cfg2.d_ff += 16; // same layer count, different geometry
        let w2 = Arc::new(ModelWeights::random(&cfg2, 7));
        let err = IncrementalEngine::restore(w2, &bytes, EngineOptions::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("configuration"), "{err}");
    }

    #[test]
    fn counter_lane_packing_roundtrips() {
        for x in [0u64, 1, 0xffff_ffff, 0x1_0000_0000, u64::MAX, 0xdead_beef_cafe_f00d] {
            assert_eq!(lanes_u64(&u64_lanes(x)), x);
        }
    }
}
