//! Incremental inference (the paper's core algorithm). See
//! [`engine::IncrementalEngine`].

pub mod attn_delta;
pub mod batch;
pub mod codecache;
pub mod engine;
pub mod rowstore;
pub mod snapshot;

pub use batch::{apply_scripts_batched, BatchOutcome};
pub use codecache::{weights_fingerprint, CacheHandle, CodeCache, CodeCacheStats};
pub use engine::{EditReport, EngineOptions, EngineStats, IncrementalEngine, VerifyReport};
pub use snapshot::{config_fingerprint, SNAPSHOT_MAGIC, SNAPSHOT_VERSION};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::edits::Edit;
    use crate::flops::{self, FlopLedger};
    use crate::model::{dense_forward, ModelWeights};
    use crate::util::Rng;
    use std::sync::Arc;

    fn setup(seed: u64, n: usize) -> (Arc<ModelWeights>, Vec<u32>) {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, seed));
        let mut r = Rng::new(seed ^ 0xABCD);
        let tokens: Vec<u32> = (0..n).map(|_| r.below(cfg.vocab_size) as u32).collect();
        (w, tokens)
    }

    /// Random valid edit for the current document length.
    fn random_edit(r: &mut Rng, len: usize, vocab: usize, max_seq: usize) -> Edit {
        loop {
            match r.below(3) {
                0 => {
                    return Edit::Replace {
                        at: r.below(len),
                        tok: r.below(vocab) as u32,
                    }
                }
                1 if len < max_seq => {
                    return Edit::Insert {
                        at: r.below(len + 1),
                        tok: r.below(vocab) as u32,
                    }
                }
                2 if len > 1 => return Edit::Delete { at: r.below(len) },
                _ => continue,
            }
        }
    }

    #[test]
    fn try_new_rejects_vq_less_layer_with_typed_error() {
        // A weights file whose config promises VQ but whose layer lacks
        // codebooks must be a typed error at construction, never a panic
        // deep in the hot path (regression: `vq.as_ref().unwrap()`).
        let (w, tokens) = setup(3, 8);
        let mut broken = (*w).clone();
        broken.layers[1].vq = None;
        let opts = EngineOptions::default();
        let msg = match IncrementalEngine::try_new(Arc::new(broken), &tokens, opts) {
            Ok(_) => panic!("vq-less layer must be rejected"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("layer 1 has no VQ config"), "{msg}");
        // Well-formed weights still construct through the same path.
        assert!(IncrementalEngine::try_new(w, &tokens, EngineOptions::default()).is_ok());
    }

    #[test]
    fn initial_state_matches_dense() {
        let (w, tokens) = setup(1, 20);
        let eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let rep = eng.verify();
        assert_eq!(rep.code_mismatches, 0, "codes after rebuild must match dense");
        assert!(rep.max_logit_diff < 1e-4, "logit diff {}", rep.max_logit_diff);
        assert!(rep.max_hidden_diff < 1e-3, "hidden diff {}", rep.max_hidden_diff);
    }

    #[test]
    fn replace_edit_exactness() {
        let (w, tokens) = setup(2, 24);
        let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let mut r = Rng::new(99);
        for _ in 0..10 {
            let at = r.below(eng.len());
            let tok = r.below(w.cfg.vocab_size) as u32;
            eng.apply_edit(Edit::Replace { at, tok });
            let rep = eng.verify();
            assert_eq!(rep.code_mismatches, 0, "VQ codes must match dense recompute");
            assert!(rep.max_logit_diff < 1e-3, "logit diff {}", rep.max_logit_diff);
        }
    }

    #[test]
    fn insert_and_delete_exactness() {
        let (w, tokens) = setup(3, 16);
        let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let mut r = Rng::new(7);
        for step in 0..12 {
            let e = if step % 2 == 0 {
                Edit::Insert {
                    at: r.below(eng.len() + 1),
                    tok: r.below(w.cfg.vocab_size) as u32,
                }
            } else {
                Edit::Delete { at: r.below(eng.len()) }
            };
            eng.apply_edit(e);
            let rep = eng.verify();
            assert_eq!(rep.code_mismatches, 0, "step {step} {e:?}");
            assert!(rep.max_logit_diff < 1e-3, "step {step} diff {}", rep.max_logit_diff);
        }
    }

    #[test]
    fn mixed_edit_scripts_property() {
        // Property: for arbitrary edit scripts, incremental == dense.
        for seed in 0..8u64 {
            let (w, tokens) = setup(100 + seed, 14);
            let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
            let mut r = Rng::new(seed * 31 + 5);
            let mut doc = tokens.clone();
            for _ in 0..15 {
                let e = random_edit(&mut r, doc.len(), w.cfg.vocab_size, w.cfg.max_seq);
                doc = crate::edits::apply_edits(&doc, &[e]);
                eng.apply_edit(e);
            }
            assert_eq!(eng.tokens(), &doc[..], "token state diverged");
            let rep = eng.verify();
            assert_eq!(rep.code_mismatches, 0, "seed {seed}");
            assert!(rep.max_logit_diff < 1e-3, "seed {seed}: {}", rep.max_logit_diff);
        }
    }

    #[test]
    fn naive_variant_matches_trick_variant() {
        let (w, tokens) = setup(5, 18);
        let mut a = IncrementalEngine::new(
            w.clone(),
            &tokens,
            EngineOptions {
                score_trick: true,
                ..EngineOptions::default()
            },
        );
        let mut b = IncrementalEngine::new(
            w.clone(),
            &tokens,
            EngineOptions {
                score_trick: false,
                ..EngineOptions::default()
            },
        );
        let mut r = Rng::new(55);
        for _ in 0..8 {
            let e = random_edit(&mut r, a.len(), w.cfg.vocab_size, w.cfg.max_seq);
            a.apply_edit(e);
            b.apply_edit(e);
            for (x, y) in a.logits().iter().zip(b.logits()) {
                assert!((x - y).abs() < 1e-3, "trick vs naive logits {x} {y}");
            }
        }
        assert_eq!(b.verify().code_mismatches, 0);
    }

    #[test]
    fn rebuild_cost_tracks_dense_cost() {
        // The ledger of a fresh build should be within ~35 % of the dense
        // analytic formula (the score-space representation does slightly
        // different—but same-order—arithmetic).
        let (w, tokens) = setup(6, 32);
        let eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let built = eng.ledger.total() as f64;
        let dense = flops::dense_forward_flops(&w.cfg, tokens.len()) as f64;
        let ratio = built / dense;
        assert!(
            (0.65..=1.35).contains(&ratio),
            "rebuild/dense flops ratio {ratio}"
        );
    }

    #[test]
    fn edit_cost_far_below_dense_cost() {
        // The headline claim at unit scale: one edit costs a small fraction
        // of a dense forward pass.
        let (w, tokens) = setup(7, 48);
        let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let dense = flops::dense_forward_flops(&w.cfg, tokens.len());
        let mut r = Rng::new(11);
        let mut total = 0u64;
        let k = 10;
        for _ in 0..k {
            let at = r.below(eng.len());
            let tok = r.below(w.cfg.vocab_size) as u32;
            total += eng.apply_edit(Edit::Replace { at, tok }).flops;
        }
        let avg = total / k;
        assert!(
            avg * 2 < dense,
            "avg edit cost {avg} not well below dense {dense}"
        );
    }

    #[test]
    fn late_edits_cheaper_than_early_edits() {
        // Causality: editing near the end touches fewer attention rows.
        let (w, tokens) = setup(8, 48);
        let opts = EngineOptions::default();
        let mut early_eng = IncrementalEngine::new(w.clone(), &tokens, opts);
        let mut late_eng = IncrementalEngine::new(w.clone(), &tokens, opts);
        let early = early_eng
            .apply_edit(Edit::Replace { at: 1, tok: 3 })
            .flops;
        let late = late_eng
            .apply_edit(Edit::Replace {
                at: tokens.len() - 2,
                tok: 3,
            })
            .flops;
        assert!(
            late < early,
            "late edit ({late}) should be cheaper than early edit ({early})"
        );
    }

    #[test]
    fn fork_is_independent() {
        let (w, tokens) = setup(9, 12);
        let base = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let mut f1 = base.fork();
        let mut f2 = base.fork();
        f1.apply_edit(Edit::Replace { at: 0, tok: 1 });
        f2.apply_edit(Edit::Replace { at: 5, tok: 2 });
        assert_ne!(f1.tokens(), f2.tokens());
        assert_eq!(base.tokens(), &tokens[..]);
        assert_eq!(f1.verify().code_mismatches, 0);
        assert_eq!(f2.verify().code_mismatches, 0);
    }

    #[test]
    fn defrag_recovers_exactness() {
        // Force defragmentation with a tiny position pool and check the
        // engine stays exact through it.
        let mut cfg = ModelConfig::vqt_tiny();
        cfg.pos_pool = cfg.max_seq; // zero slack ⇒ frequent defrag
        let w = Arc::new(ModelWeights::random(&cfg, 10));
        let mut r = Rng::new(13);
        let tokens: Vec<u32> = (0..10).map(|_| r.below(cfg.vocab_size) as u32).collect();
        let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let mut defrags = 0;
        for _ in 0..20 {
            let at = r.below(eng.len() + 1);
            let tok = r.below(cfg.vocab_size) as u32;
            let rep = eng.apply_edit(Edit::Insert { at, tok });
            if rep.defragged {
                defrags += 1;
            }
            if eng.len() > 30 {
                eng.apply_edit(Edit::Delete { at: r.below(eng.len()) });
            }
        }
        assert!(defrags > 0, "expected at least one defrag with zero slack");
        assert_eq!(eng.stats.defrags as usize, defrags);
        let rep = eng.verify();
        assert_eq!(rep.code_mismatches, 0);
        assert!(rep.max_logit_diff < 1e-3);
    }

    #[test]
    fn logits_track_dense_after_each_edit() {
        let (w, tokens) = setup(11, 20);
        let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let mut r = Rng::new(17);
        let mut doc = tokens.clone();
        for _ in 0..6 {
            let e = random_edit(&mut r, doc.len(), w.cfg.vocab_size, w.cfg.max_seq);
            doc = crate::edits::apply_edits(&doc, &[e]);
            let rep = eng.apply_edit(e);
            let mut led = FlopLedger::new();
            let dense = dense_forward(&w, &doc, eng.position_ids(), &mut led);
            for (a, b) in rep.logits.iter().zip(&dense.logits) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn verify_every_auto_rebuild_path() {
        let (w, tokens) = setup(12, 10);
        let mut eng = IncrementalEngine::new(
            w.clone(),
            &tokens,
            EngineOptions {
                score_trick: true,
                verify_every: 2,
                ..EngineOptions::default()
            },
        );
        for i in 0..6 {
            eng.apply_edit(Edit::Replace {
                at: i % tokens.len(),
                tok: (i % w.cfg.vocab_size) as u32,
            });
        }
        assert_eq!(eng.stats.verifications, 3);
    }

    /// Smoke for the semi-naive softmax path: the engine accepts a
    /// softmax config, stays within the §12 tolerance of the dense oracle
    /// under mixed edits, and actually exercises the delta arm.
    #[test]
    fn softmax_engine_tracks_dense_with_delta_updates() {
        let cfg = ModelConfig {
            attention: crate::config::AttentionKind::Softmax,
            ..ModelConfig::vqt_tiny()
        };
        let w = Arc::new(ModelWeights::random(&cfg, 21));
        let mut r = Rng::new(210);
        let tokens: Vec<u32> = (0..32).map(|_| r.below(cfg.vocab_size) as u32).collect();
        let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let rep = eng.verify();
        assert_eq!(rep.code_mismatches, 0, "softmax rebuild must match dense");
        assert!(rep.max_logit_diff < 1e-3, "{}", rep.max_logit_diff);
        for _ in 0..10 {
            let e = random_edit(&mut r, eng.len(), cfg.vocab_size, cfg.max_seq);
            eng.apply_edit(e);
            let rep = eng.verify();
            assert_eq!(rep.code_mismatches, 0, "{e:?}");
            assert!(rep.max_logit_diff < 1e-3, "{e:?}: {}", rep.max_logit_diff);
        }
        assert!(
            eng.stats.attn_delta_rows > 0,
            "edits on a 32-token doc must take the delta arm somewhere"
        );
        assert!(eng.stats.attn_delta_saved_flops > 0);
    }

    /// Softmax checkpoints carry the aggregates: restore resumes
    /// delta-updating without recompute and stays within tolerance.
    #[test]
    fn softmax_checkpoint_roundtrips_aggregates() {
        let cfg = ModelConfig {
            attention: crate::config::AttentionKind::Softmax,
            ..ModelConfig::vqt_tiny()
        };
        let w = Arc::new(ModelWeights::random(&cfg, 22));
        let tokens: Vec<u32> = (0..16).map(|i| (i * 5 % 60) as u32).collect();
        let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        eng.apply_edit(Edit::Replace { at: 3, tok: 7 });
        let tf = eng.to_tensor_file();
        let mut back =
            IncrementalEngine::from_tensor_file(w.clone(), &tf, EngineOptions::default()).unwrap();
        assert_eq!(back.logits(), eng.logits());
        assert_eq!(back.ledger.total(), 0, "restore must not recompute");
        back.apply_edit(Edit::Replace { at: 9, tok: 11 });
        eng.apply_edit(Edit::Replace { at: 9, tok: 11 });
        // Same aggregates ⇒ bit-identical continuation.
        assert_eq!(back.logits(), eng.logits());
        assert!(back.stats.attn_delta_rows > 0, "restored engine keeps delta-updating");
        let rep = back.verify();
        assert_eq!(rep.code_mismatches, 0);
        assert!(rep.max_logit_diff < 1e-3);
    }
}

#[cfg(test)]
mod batch_tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::edits::{apply_edits, diff_tokens};
    use crate::model::ModelWeights;
    use crate::util::Rng;
    use std::sync::Arc;

    fn setup(seed: u64, n: usize) -> (Arc<ModelWeights>, Vec<u32>) {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, seed));
        let mut r = Rng::new(seed ^ 0xBEEF);
        let tokens: Vec<u32> = (0..n).map(|_| r.below(cfg.vocab_size) as u32).collect();
        (w, tokens)
    }

    /// Core property: the batched revision pass is EXACT — identical state
    /// to a dense recompute, for arbitrary revision pairs.
    #[test]
    fn batched_revision_matches_dense() {
        for seed in 0..8u64 {
            let (w, a) = setup(200 + seed, 20);
            let mut r = Rng::new(seed * 7 + 1);
            // Random revision: several replaces, inserts, deletes.
            let mut b = a.clone();
            for _ in 0..r.range(2, 10) {
                let e = crate::testutil::gen_edit(&mut r, b.len(), w.cfg.vocab_size, w.cfg.max_seq);
                b = apply_edits(&b, &[e]);
            }
            let mut eng = IncrementalEngine::new(w.clone(), &a, EngineOptions::default());
            let script = diff_tokens(&a, &b);
            eng.apply_revision(&script);
            assert_eq!(eng.tokens(), &b[..], "seed {seed}: tokens diverged");
            let rep = eng.verify();
            assert_eq!(rep.code_mismatches, 0, "seed {seed}");
            assert!(rep.max_logit_diff < 1e-3, "seed {seed}: {}", rep.max_logit_diff);
        }
    }

    /// Batched pass == sequential pass (same logits).
    #[test]
    fn batched_equals_sequential() {
        for seed in 0..5u64 {
            let (w, a) = setup(300 + seed, 16);
            let mut r = Rng::new(seed * 13 + 3);
            let mut b = a.clone();
            for _ in 0..6 {
                let e = crate::testutil::gen_edit(&mut r, b.len(), w.cfg.vocab_size, w.cfg.max_seq);
                b = apply_edits(&b, &[e]);
            }
            let script = diff_tokens(&a, &b);
            let mut batched = IncrementalEngine::new(w.clone(), &a, EngineOptions::default());
            batched.apply_revision(&script);
            let mut seq = IncrementalEngine::new(w.clone(), &a, EngineOptions::default());
            seq.apply_edits(&script);
            assert_eq!(seq.tokens(), batched.tokens());
            for (x, y) in batched.logits().iter().zip(seq.logits()) {
                assert!((x - y).abs() < 1e-3, "batched {x} vs sequential {y}");
            }
        }
    }

    /// Batched pass must be cheaper than sequential for multi-edit scripts.
    #[test]
    fn batched_is_cheaper_than_sequential() {
        let (w, a) = setup(400, 48);
        let mut r = Rng::new(77);
        let mut b = a.clone();
        for _ in 0..12 {
            let e = crate::testutil::gen_edit(&mut r, b.len(), w.cfg.vocab_size, w.cfg.max_seq);
            b = apply_edits(&b, &[e]);
        }
        let script = diff_tokens(&a, &b);
        let mut batched = IncrementalEngine::new(w.clone(), &a, EngineOptions::default());
        let f_b = batched.apply_revision(&script).flops;
        let mut seq = IncrementalEngine::new(w.clone(), &a, EngineOptions::default());
        let f_s = seq.apply_edits(&script).flops;
        assert!(
            f_b * 2 < f_s,
            "batched {f_b} should be ≪ sequential {f_s} for {} edits",
            script.len()
        );
    }

    /// Defrag inside a batched revision still converges exactly.
    #[test]
    fn batched_defrag_recovers() {
        let mut cfg = ModelConfig::vqt_tiny();
        cfg.pos_pool = cfg.max_seq; // zero slack
        let w = Arc::new(ModelWeights::random(&cfg, 5));
        let mut r = Rng::new(1);
        let a: Vec<u32> = (0..12).map(|_| r.below(cfg.vocab_size) as u32).collect();
        let mut eng = IncrementalEngine::new(w.clone(), &a, EngineOptions::default());
        // Insert many tokens at one position to force a defrag mid-script.
        let script: Vec<crate::edits::Edit> = (0..8)
            .map(|i| crate::edits::Edit::Insert {
                at: 5,
                tok: (i % 50) as u32,
            })
            .collect();
        let rep = eng.apply_revision(&script);
        assert!(rep.defragged, "zero-slack pool must defrag");
        let rep = eng.verify();
        assert_eq!(rep.code_mismatches, 0);
        assert!(rep.max_logit_diff < 1e-3);
    }

    /// Empty and single-edit scripts take the cheap paths.
    #[test]
    fn batched_trivial_scripts() {
        let (w, a) = setup(500, 10);
        let mut eng = IncrementalEngine::new(w.clone(), &a, EngineOptions::default());
        let rep = eng.apply_revision(&[]);
        assert_eq!(rep.flops, 0);
        let rep = eng.apply_revision(&[crate::edits::Edit::Replace { at: 3, tok: 9 }]);
        assert!(rep.flops > 0);
        assert_eq!(eng.verify().code_mismatches, 0);
    }
}

#[cfg(test)]
mod revision_overflow_tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::edits::{diff_tokens, Edit};
    use crate::model::ModelWeights;
    use std::sync::Arc;

    /// Revision scripts may exceed max_seq transiently (inserts before the
    /// matching deletes in LCS position order); only the final length is
    /// bounded. Regression test for the fig3 500-pair crash.
    #[test]
    fn transient_overflow_during_revision_is_ok() {
        let cfg = ModelConfig::vqt_tiny(); // max_seq 64
        let w = Arc::new(ModelWeights::random(&cfg, 2));
        let n = cfg.max_seq; // document exactly at capacity
        let a: Vec<u32> = (0..n).map(|i| (i % 50) as u32).collect();
        // Replace a middle block with different tokens at a shifted offset
        // so the LCS diff interleaves inserts before deletes.
        let mut b = a.clone();
        for i in 10..20 {
            b[i] = 55;
        }
        b.insert(5, 51);
        b.remove(40);
        assert_eq!(b.len(), n);
        let script = diff_tokens(&a, &b);
        let mut eng = IncrementalEngine::new(w.clone(), &a, EngineOptions::default());
        eng.apply_revision(&script);
        assert_eq!(eng.tokens(), &b[..]);
        let rep = eng.verify();
        assert_eq!(rep.code_mismatches, 0);
        assert!(rep.max_logit_diff < 1e-3);
    }

    /// Checkpoint → restore round-trips full state with zero recompute.
    #[test]
    fn checkpoint_restore_roundtrip() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 3));
        let tokens: Vec<u32> = (0..20).map(|i| (i * 3 % 60) as u32).collect();
        let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        eng.apply_edit(Edit::Replace { at: 4, tok: 9 });
        eng.apply_edit(Edit::Insert { at: 0, tok: 5 });
        let tf = eng.to_tensor_file();
        let mut back =
            IncrementalEngine::from_tensor_file(w.clone(), &tf, EngineOptions::default()).unwrap();
        assert_eq!(back.tokens(), eng.tokens());
        assert_eq!(back.position_ids(), eng.position_ids());
        assert_eq!(back.logits(), eng.logits());
        assert_eq!(back.ledger.total(), 0, "restore must not recompute");
        // The restored engine keeps working incrementally and exactly.
        back.apply_edit(Edit::Delete { at: 3 });
        let rep = back.verify();
        assert_eq!(rep.code_mismatches, 0);
        assert!(rep.max_logit_diff < 1e-3);
    }

    /// Restore rejects mismatched configurations.
    #[test]
    fn checkpoint_restore_rejects_mismatch() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 3));
        let tokens: Vec<u32> = (0..8).map(|i| i as u32).collect();
        let eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let tf = eng.to_tensor_file();
        // Wrong score-trick mode.
        assert!(IncrementalEngine::from_tensor_file(
            w.clone(),
            &tf,
            EngineOptions {
                score_trick: false,
                ..EngineOptions::default()
            }
        )
        .is_err());
        // Wrong layer count.
        let mut cfg2 = cfg.clone();
        cfg2.n_layers = 1;
        let w2 = Arc::new(ModelWeights::random(&cfg2, 3));
        assert!(IncrementalEngine::from_tensor_file(w2, &tf, EngineOptions::default()).is_err());
    }

    /// Suggestions equal a brute-force computation from the dense oracle.
    #[test]
    fn suggestions_match_dense_lm_head() {
        use crate::flops::FlopLedger;
        use crate::model::dense_forward;
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 5));
        let tokens: Vec<u32> = (0..12).map(|i| (i * 7 % 60) as u32).collect();
        let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let top = eng.suggest_topk(5);
        assert_eq!(top.len(), 5);
        assert!(top.windows(2).all(|p| p[0].1 >= p[1].1), "sorted by score");
        let mut led = FlopLedger::new();
        let dense = dense_forward(&w, &tokens, eng.position_ids(), &mut led);
        let h = dense.hidden.row(tokens.len() - 1);
        let best_dense = (0..cfg.vocab_size)
            .max_by(|&a, &b| {
                crate::tensor::dot(h, w.embed_tokens.row(a))
                    .partial_cmp(&crate::tensor::dot(h, w.embed_tokens.row(b)))
                    .unwrap()
            })
            .unwrap();
        assert_eq!(top[0].0 as usize, best_dense);
    }

    /// An empty document yields an empty suggestion list — no panic, no
    /// phantom scores (the serving layer turns this into a typed reply).
    #[test]
    fn suggestions_on_empty_document_are_empty() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 5));
        let mut eng = IncrementalEngine::new(w, &[], EngineOptions::default());
        assert!(eng.is_empty());
        assert!(eng.suggest_topk(5).is_empty());
    }
}
