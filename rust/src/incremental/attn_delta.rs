//! Semi-naive softmax-attention recompute: per-(layer, query-row)
//! streaming-softmax aggregates and the delta-update primitives over them.
//!
//! With true softmax attention an edited key/value column changes every
//! later row's attention output through the *normalizer* — the reason the
//! paper restricts its exact delta rules to element-wise σ (App. A.1).
//! The semi-naive recipe recovers most of the saving anyway: keep, per
//! query row i and head h, the streaming-softmax state
//!
//! ```text
//!   m_h  — the shift (frozen at the last full refresh of row i)
//!   D_h  = Σ_j exp(s_ij − m_h)                 (denominator)
//!   N_h  = Σ_j exp(s_ij − m_h) · v_j|_h        (numerator, d_head wide)
//! ```
//!
//! so the attention output is `N_h / D_h`. When an edit changes a *set*
//! of key/value columns, an unchanged query row re-evaluates only the
//! variants where one term is restricted to the delta: subtract the old
//! columns' terms (recomputed bit-identically from the retained old K/V),
//! add the new ones, renormalize. Cost is `O(|changed columns|)` instead
//! of `O(context)`; the engine picks per row between the delta and a full
//! recompute via the FLOP-ledger arms in [`crate::flops`]
//! (`attn_sm_delta_cost` vs `attn_sm_full_cost`).
//!
//! The trade is explicit and bounded (docs/ARCHITECTURE.md §12): each
//! delta application can cancel at most one f32 rounding step per element
//! against the original addition, a per-row drift counter caps how many
//! applications accumulate before a full refresh re-freezes the shift,
//! and two guards force an early refresh when the frozen shift goes stale
//! ([`MAX_EXP_ARG`]) or the denominator loses too much mass ([`MIN_DEN`]).

use super::rowstore::RowStore;
use crate::tensor;

/// Largest tolerated `score − shift` before `exp` under a stale frozen
/// shift risks blow-up: beyond this the row falls back to a full refresh,
/// which re-freezes the shift at the true row maximum. `exp(30) ≈ 1e13`
/// still sits comfortably inside f32 range (~3.4e38) even summed over a
/// max_seq context, so the guard fires well before overflow.
pub const MAX_EXP_ARG: f32 = 30.0;

/// Smallest tolerated per-head denominator after subtractions. Below this
/// the running sum has cancelled almost entirely and the renormalized
/// ratio amplifies rounding error unboundedly — full refresh instead.
pub const MIN_DEN: f32 = 1e-6;

/// Per-layer streaming-softmax state: one row per sequence position,
/// structurally maintained in lock-step with the engine's other per-layer
/// row stores (same insert/remove/reindex at the same call sites).
#[derive(Clone, Debug, Default)]
pub struct AttnAggregates {
    /// Numerators — (n, d_model): head h's d_head-wide `N_h` in its slice.
    pub num: RowStore,
    /// Denominators — (n, n_heads).
    pub den: RowStore,
    /// Frozen shifts — (n, n_heads).
    pub m: RowStore,
    /// Delta applications since each row's last full refresh.
    pub drift: Vec<u32>,
}

impl AttnAggregates {
    pub fn new(d_model: usize, n_heads: usize) -> AttnAggregates {
        AttnAggregates {
            num: RowStore::new(d_model),
            den: RowStore::new(n_heads),
            m: RowStore::new(n_heads),
            drift: Vec::new(),
        }
    }

    pub fn rows(&self) -> usize {
        self.drift.len()
    }

    pub fn clear(&mut self) {
        self.num.clear();
        self.den.clear();
        self.m.clear();
        self.drift.clear();
    }

    /// Append a zeroed row (filled by the next full refresh of that row).
    pub fn push_zero_row(&mut self) {
        self.num.insert_zero_row(self.num.rows());
        self.den.insert_zero_row(self.den.rows());
        self.m.insert_zero_row(self.m.rows());
        self.drift.push(0);
    }

    pub fn insert_zero_row(&mut self, at: usize) {
        self.num.insert_zero_row(at);
        self.den.insert_zero_row(at);
        self.m.insert_zero_row(at);
        self.drift.insert(at, 0);
    }

    pub fn remove_row(&mut self, at: usize) {
        self.num.remove_row(at);
        self.den.remove_row(at);
        self.m.remove_row(at);
        self.drift.remove(at);
    }

    /// Batched-revision restructure — same mapping contract as
    /// [`RowStore::reindex`]; rows without an origin start zeroed with a
    /// fresh drift counter (they are dirty and refresh in the same pass).
    pub fn reindex(&mut self, mapping: &[Option<usize>]) {
        self.num.reindex(mapping);
        self.den.reindex(mapping);
        self.m.reindex(mapping);
        let old = std::mem::take(&mut self.drift);
        self.drift = mapping
            .iter()
            .map(|o| o.map(|o| old[o]).unwrap_or(0))
            .collect();
    }

    /// Resident payload bytes (counted by the session memory accountant).
    pub fn bytes(&self) -> usize {
        self.num.bytes()
            + self.den.bytes()
            + self.m.bytes()
            + self.drift.len() * std::mem::size_of::<u32>()
    }
}

/// One key/value-column change, normalized for aggregate application:
/// rows at index ≥ `start` (in the *current* layout, after structural
/// restructuring) are affected. `old` carries the retained pre-edit
/// (key, value) rows to subtract — recomputing their weights from the
/// retained key reproduces the originally-added term bit-identically, so
/// subtraction cancels exactly up to one rounding step per element. A
/// `new_j` names a current column whose fresh (key, value) is added.
pub struct SmChange {
    pub start: usize,
    pub old: Option<(Vec<f32>, Vec<f32>)>,
    pub new_j: Option<usize>,
}

impl SmChange {
    /// Terms this change contributes to one affected row.
    pub fn sides(&self) -> usize {
        self.old.is_some() as usize + self.new_j.is_some() as usize
    }
}

/// Per-head `exp(q·k·scale − m)` weights for one (query, key) pair under
/// frozen shifts `m` — into a fixed buffer, no ledger (callers account in
/// bulk). Returns `false` — **without partial output** the caller may
/// rely on — when any head trips the [`MAX_EXP_ARG`] stale-shift guard;
/// the caller must then fall back to a full refresh.
#[inline]
pub fn side_weights(
    q: &[f32],
    k: &[f32],
    m: &[f32],
    nh: usize,
    dh: usize,
    scale: f32,
    out: &mut [f32; 16],
) -> bool {
    debug_assert!(nh <= 16);
    for h in 0..nh {
        let s = tensor::dot(&q[h * dh..(h + 1) * dh], &k[h * dh..(h + 1) * dh]) * scale;
        let z = s - m[h];
        if z > MAX_EXP_ARG {
            return false;
        }
        out[h] = z.exp();
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_track_structure() {
        let mut a = AttnAggregates::new(8, 2);
        a.push_zero_row();
        a.push_zero_row();
        a.num.row_mut(1)[0] = 5.0;
        a.drift[1] = 3;
        a.insert_zero_row(1);
        assert_eq!(a.rows(), 3);
        assert_eq!(a.num.row(2)[0], 5.0);
        assert_eq!(a.drift, vec![0, 0, 3]);
        a.remove_row(0);
        assert_eq!(a.rows(), 2);
        assert_eq!(a.num.row(1)[0], 5.0);
        // reindex: keep old row 1 at new 0, fresh zero row at new 1.
        a.reindex(&[Some(1), None]);
        assert_eq!(a.num.row(0)[0], 5.0);
        assert_eq!(a.drift, vec![3, 0]);
        assert_eq!(a.num.row(1)[0], 0.0);
        assert!(a.bytes() > 0);
    }

    #[test]
    fn side_weights_guard_trips_on_stale_shift() {
        let q = vec![8.0f32; 4];
        let k = vec![8.0f32; 4];
        let mut out = [0f32; 16];
        // score = 8·8·2·scale per head (dh = 2, scale = 1/√2) ≈ 90 ≫ m + 30.
        let ok = side_weights(&q, &k, &[0.0, 0.0], 2, 2, 1.0 / (2f32).sqrt(), &mut out);
        assert!(!ok);
        // A generous shift keeps it in range.
        let ok = side_weights(&q, &k, &[85.0, 85.0], 2, 2, 1.0 / (2f32).sqrt(), &mut out);
        assert!(ok);
        assert!(out[0].is_finite() && out[0] > 0.0);
    }
}
