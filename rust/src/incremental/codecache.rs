//! Process-global codebook-product cache — VQ discreteness turned into
//! cross-session amortization.
//!
//! The block tail's first two stages, `decode(code)` followed by the mix
//! GEMV `decode(code) · w_mix`, are a pure function of `(layer, code)`:
//! they do not depend on the row's hidden state `x`, the session, or the
//! user. VQ collapses hidden rows onto a finite codebook, so across many
//! sessions the same `(layer, code)` pairs recur constantly — every
//! session typing the same token through the same layer recomputes an
//! identical d-vector. This module caches those mix vectors once,
//! process-wide, so the dense mix GEMV is charged only when a code is
//! genuinely new (the Sigma-Delta insight taken to serving scale).
//!
//! **Bit-exactness contract.** A cached entry is the byte-exact output of
//! the same tiled kernel (`tensor::vec_matmul_into`, fixed accumulation
//! order) that the uncached path runs, captured on the miss that first
//! computed it. A hit copies those bytes back; every later tail stage
//! (residual, LN2, FFN) consumes them identically. Cached and uncached
//! execution therefore produce bit-identical logits — locked by
//! `tests/differential_codecache.rs`.
//!
//! **Keying and invalidation.** Entries are keyed `(layer, CodeTuple::pack())`
//! and guarded by a weights fingerprint ([`weights_fingerprint`]:
//! `util::fnv1a64` over the model config JSON, every layer's `w_mix`
//! bytes, and every codebook's bytes — exactly the inputs the cached
//! product depends on). Every `lookup`/`insert` carries the caller's
//! fingerprint; a mismatch flushes the whole cache before proceeding, so
//! a weight reload can never serve stale products. The cache assumes one
//! active weight set at a time (the coordinator guarantees this); two
//! fingerprints ping-ponging concurrently degrade to flush-thrash, never
//! to wrong bytes served under a *stable* fingerprint.
//!
//! **Concurrency and memory.** The key space is split across
//! [`N_SHARDS`] `RwLock`ed shards so hot-path lookups from many worker
//! threads take only a shared read lock (LRU ticks are atomics bumped
//! under that read lock). Each shard owns `capacity / N_SHARDS` bytes;
//! inserts evict least-recently-used entries until the new entry fits,
//! and an entry that alone exceeds the shard budget is simply not cached
//! — resident bytes are strictly bounded by the configured budget
//! (`code_cache_mb`). Global hit/miss/evict/byte counters feed the
//! coordinator's Stats JSON; per-engine deltas are attributed by the
//! callers (engine stats), and the two views stay consistent: global
//! counters equal the sum of per-engine deltas.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::model::ModelWeights;
use crate::util::fnv1a64;

/// Shard count for the key space. A fixed small power of two: enough to
/// keep write-lock contention (inserts, evictions) off unrelated keys,
/// few enough that the per-shard byte budget stays meaningful for tiny
/// test budgets.
const N_SHARDS: usize = 16;

/// Accounting overhead charged per entry on top of the payload floats —
/// covers the key, the LRU tick, and hash-map slot bookkeeping. An
/// estimate (exact allocator numbers are unknowable), but a *consistent*
/// one: the bound it enforces is deterministic.
const ENTRY_OVERHEAD: usize = 64;

/// How one block-tail row interacted with the cache. The batched path
/// returns one per pooled row so the caller can attribute stats to the
/// row's owning engine (the engine is not threaded through the kernel).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TailOutcome {
    /// No cache attached — the row ran the classic full tail.
    Uncached,
    /// Mix vector served from the cache (or deduped within a pooled
    /// wave): the mix GEMV was skipped.
    Hit,
    /// Full product computed and offered to the cache; `bytes` is the
    /// payload accepted (0 if it lost an insert race or exceeded the
    /// shard budget), `evictions` the entries displaced to make room.
    Miss { bytes: u64, evictions: u64 },
}

struct Entry {
    mix: Vec<f32>,
    /// Global LRU tick at last touch; bumped under the shard's *read*
    /// lock so hits never serialize against each other.
    last_used: AtomicU64,
}

#[derive(Default)]
struct CacheShard {
    map: HashMap<(u32, u64), Entry>,
    bytes: usize,
}

/// Counter snapshot for Stats JSON / assertions.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CodeCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub bytes_inserted: u64,
    pub resident_bytes: u64,
    pub flushes: u64,
    /// Times a poisoned shard lock was recovered (see
    /// [`CodeCache`]'s poison-recovery contract). Non-zero means a worker
    /// panicked while holding a shard guard and the cache carried on.
    pub poison_recoveries: u64,
}

/// The shared cache. Cheap to clone via `Arc`; see the module docs for
/// the full contract.
pub struct CodeCache {
    shards: Vec<RwLock<CacheShard>>,
    capacity_bytes: usize,
    tick: AtomicU64,
    /// Fingerprint of the weight set the resident entries were computed
    /// from; 0 = unset (no entries yet). Checked on every access.
    fingerprint: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    bytes_inserted: AtomicU64,
    resident: AtomicU64,
    flushes: AtomicU64,
    poison_recoveries: AtomicU64,
}

impl CodeCache {
    /// A cache bounded to `capacity_bytes` of resident payload+overhead.
    pub fn new(capacity_bytes: usize) -> Self {
        CodeCache {
            shards: (0..N_SHARDS).map(|_| RwLock::new(CacheShard::default())).collect(),
            capacity_bytes,
            tick: AtomicU64::new(1),
            fingerprint: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            bytes_inserted: AtomicU64::new(0),
            resident: AtomicU64::new(0),
            flushes: AtomicU64::new(0),
            poison_recoveries: AtomicU64::new(0),
        }
    }

    /// Constructor matching the `code_cache_mb` config knob.
    pub fn from_mb(mb: usize) -> Self {
        CodeCache::new(mb * 1024 * 1024)
    }

    fn shard_of(layer: u32, key: u64) -> usize {
        let mut bytes = [0u8; 12];
        bytes[..4].copy_from_slice(&layer.to_le_bytes());
        bytes[4..].copy_from_slice(&key.to_le_bytes());
        (fnv1a64(&bytes) as usize) % N_SHARDS
    }

    fn per_shard_budget(&self) -> usize {
        self.capacity_bytes / N_SHARDS
    }

    /// Shard read guard with poison recovery. The cache is process-global:
    /// a worker panicking while it holds a shard guard (the coordinator
    /// catches request panics) must not cascade `PoisonError` panics into
    /// every session on every shard forever after. Recovery is sound here
    /// because shard state is crash-consistent under this module's
    /// discipline: entries are immutable once inserted, `bytes` is only
    /// adjusted together with `map` under the same guard, and the worst
    /// torn state — an entry removed but its byte count not yet settled —
    /// only skews the LRU budget, never the served bits.
    fn read_shard(&self, idx: usize) -> std::sync::RwLockReadGuard<'_, CacheShard> {
        match self.shards[idx].read() {
            Ok(g) => g,
            Err(poisoned) => {
                self.note_poison(idx);
                poisoned.into_inner()
            }
        }
    }

    /// Shard write guard with poison recovery (see [`Self::read_shard`]).
    fn write_shard(&self, idx: usize) -> std::sync::RwLockWriteGuard<'_, CacheShard> {
        match self.shards[idx].write() {
            Ok(g) => g,
            Err(poisoned) => {
                self.note_poison(idx);
                poisoned.into_inner()
            }
        }
    }

    /// Count one recovery and clear the flag so the counter tracks
    /// panic *events*, not every access that follows one.
    fn note_poison(&self, idx: usize) {
        self.poison_recoveries.fetch_add(1, Ordering::Relaxed);
        self.shards[idx].clear_poison();
        log::warn!("code cache shard {idx}: recovered a poisoned lock (worker panic upstream)");
    }

    /// Flush-on-mismatch guard: if the cache currently holds entries for
    /// a different weight set, clear everything before serving `fp`.
    /// Fast path is one relaxed load.
    fn ensure_fp(&self, fp: u64) {
        debug_assert_ne!(fp, 0, "0 is the unset sentinel");
        if self.fingerprint.load(Ordering::Acquire) == fp {
            return;
        }
        // Slow path: take every shard's write lock so no concurrent
        // reader can observe a half-flushed cache, then re-check.
        let mut guards: Vec<_> = (0..self.shards.len()).map(|i| self.write_shard(i)).collect();
        let prev = self.fingerprint.load(Ordering::Acquire);
        if prev == fp {
            return; // another thread flushed for us while we queued
        }
        for g in guards.iter_mut() {
            g.map.clear();
            g.bytes = 0;
        }
        self.resident.store(0, Ordering::Relaxed);
        if prev != 0 {
            self.flushes.fetch_add(1, Ordering::Relaxed);
        }
        self.fingerprint.store(fp, Ordering::Release);
    }

    /// Look up `(layer, key)` under fingerprint `fp`. On hit the cached
    /// mix vector is copied into `out` and `true` is returned; counters
    /// record one hit or one miss either way.
    pub fn lookup(&self, fp: u64, layer: u32, key: u64, out: &mut [f32]) -> bool {
        let _span = crate::util::trace::stage("cache_lookup");
        self.ensure_fp(fp);
        let shard = self.read_shard(Self::shard_of(layer, key));
        if let Some(e) = shard.map.get(&(layer, key)) {
            assert_eq!(e.mix.len(), out.len(), "cached width vs caller width");
            out.copy_from_slice(&e.mix);
            e.last_used
                .store(self.tick.fetch_add(1, Ordering::Relaxed), Ordering::Relaxed);
            self.hits.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            false
        }
    }

    /// Offer a freshly computed mix vector. Returns `(bytes_accepted,
    /// evictions)` so the calling engine can attribute them to its own
    /// stats; `(0, n)` means the entry was not kept (insert race, or it
    /// alone exceeds the shard budget — n is then 0 or the evictions
    /// performed before giving up, which for an oversized entry is 0
    /// because we check the entry size first).
    pub fn insert(&self, fp: u64, layer: u32, key: u64, mix: &[f32]) -> (u64, u64) {
        self.ensure_fp(fp);
        let entry_bytes = mix.len() * std::mem::size_of::<f32>() + ENTRY_OVERHEAD;
        if entry_bytes > self.per_shard_budget() {
            return (0, 0); // can never fit; bound is strict
        }
        let mut shard = self.write_shard(Self::shard_of(layer, key));
        if shard.map.contains_key(&(layer, key)) {
            return (0, 0); // lost a concurrent insert race — entry already present
        }
        let mut evicted = 0u64;
        while shard.bytes + entry_bytes > self.per_shard_budget() {
            // Evict the least-recently-used entry of this shard. O(n)
            // scan, but n is small (per-shard) and eviction is off the
            // hit path entirely.
            let victim = shard
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used.load(Ordering::Relaxed))
                .map(|(&k, _)| k)
                .expect("budget exceeded with empty shard");
            let gone = shard.map.remove(&victim).unwrap();
            let gone_bytes = gone.mix.len() * std::mem::size_of::<f32>() + ENTRY_OVERHEAD;
            shard.bytes -= gone_bytes;
            self.resident.fetch_sub(gone_bytes as u64, Ordering::Relaxed);
            evicted += 1;
        }
        shard.map.insert(
            (layer, key),
            Entry {
                mix: mix.to_vec(),
                last_used: AtomicU64::new(self.tick.fetch_add(1, Ordering::Relaxed)),
            },
        );
        shard.bytes += entry_bytes;
        self.resident.fetch_add(entry_bytes as u64, Ordering::Relaxed);
        self.bytes_inserted.fetch_add(entry_bytes as u64, Ordering::Relaxed);
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
        (entry_bytes as u64, evicted)
    }

    /// Count a hit that never touched a shard: a pooled wave deduped
    /// this row against another row's in-flight product (the code missed
    /// the cache once, for its first occurrence; later occurrences in
    /// the same wave are hits by construction). Keeps the global
    /// counters equal to the sum of per-engine deltas.
    pub fn note_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Counter snapshot (relaxed loads — exact once quiescent).
    pub fn stats(&self) -> CodeCacheStats {
        CodeCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            bytes_inserted: self.bytes_inserted.load(Ordering::Relaxed),
            resident_bytes: self.resident.load(Ordering::Relaxed),
            flushes: self.flushes.load(Ordering::Relaxed),
            poison_recoveries: self.poison_recoveries.load(Ordering::Relaxed),
        }
    }

    /// Total resident entries across shards.
    pub fn len(&self) -> usize {
        (0..self.shards.len()).map(|i| self.read_shard(i).map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident payload+overhead bytes (the quantity bounded by the
    /// configured budget).
    pub fn resident_bytes(&self) -> u64 {
        self.resident.load(Ordering::Relaxed)
    }
}

/// Fingerprint of everything a cached product depends on: the model
/// config (shapes, head/code counts) plus the raw bytes of every layer's
/// `w_mix` and every VQ codebook. Biases, LN parameters, FFN weights
/// etc. are deliberately excluded — they act downstream of the cached
/// value. 0 is remapped to 1 so it can never collide with the cache's
/// "unset" sentinel.
pub fn weights_fingerprint(w: &ModelWeights) -> u64 {
    let mut bytes: Vec<u8> = w.cfg.to_json().to_string().into_bytes();
    for layer in &w.layers {
        for &v in &layer.w_mix.data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        if let Some(vq) = &layer.vq {
            for book in &vq.books {
                for &v in &book.data {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
    }
    match fnv1a64(&bytes) {
        0 => 1,
        h => h,
    }
}

/// An engine's view of the shared cache: the `Arc` plus the fingerprint
/// of the weight set the engine runs — computed once at attach time, not
/// per lookup. Cloning shares the cache (forked engines inherit it).
#[derive(Clone)]
pub struct CacheHandle {
    pub cache: Arc<CodeCache>,
    pub fp: u64,
}

impl CacheHandle {
    pub fn new(cache: Arc<CodeCache>, w: &ModelWeights) -> Self {
        let fp = weights_fingerprint(w);
        CacheHandle { cache, fp }
    }
}

impl std::fmt::Debug for CacheHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CacheHandle")
            .field("fp", &self.fp)
            .field("resident_bytes", &self.cache.resident_bytes())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    const FP: u64 = 0xFEED;

    #[test]
    fn miss_then_hit_roundtrips_exact_bits() {
        let c = CodeCache::new(1 << 20);
        let mix: Vec<f32> = (0..32).map(|i| (i as f32) * 0.37 - 1.0).collect();
        let mut out = vec![0.0f32; 32];
        assert!(!c.lookup(FP, 3, 42, &mut out), "cold cache must miss");
        let (bytes, ev) = c.insert(FP, 3, 42, &mix);
        assert_eq!(bytes as usize, 32 * 4 + 64);
        assert_eq!(ev, 0);
        assert!(c.lookup(FP, 3, 42, &mut out));
        let a: Vec<u32> = mix.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "hit must return the exact inserted bits");
        // Same code under a different layer is a distinct key.
        assert!(!c.lookup(FP, 4, 42, &mut out));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 2, 0));
        assert_eq!(s.resident_bytes, bytes);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn eviction_keeps_resident_bytes_under_budget() {
        // Budget sized so each shard holds ~2 entries of d=16.
        let entry = 16 * 4 + ENTRY_OVERHEAD;
        let c = CodeCache::new(entry * 2 * N_SHARDS);
        let mix = vec![1.0f32; 16];
        for k in 0..200u64 {
            c.insert(FP, 0, k, &mix);
            assert!(
                c.resident_bytes() as usize <= entry * 2 * N_SHARDS,
                "budget violated at k={k}"
            );
        }
        let s = c.stats();
        assert!(s.evictions > 0, "200 inserts into ~32 slots must evict");
        // Evicted keys miss; the most recently inserted key still hits.
        let mut out = vec![0.0f32; 16];
        assert!(c.lookup(FP, 0, 199, &mut out));
    }

    #[test]
    fn lru_evicts_the_stale_entry_not_the_touched_one() {
        // One shard's worth of budget for exactly 2 entries; find two
        // keys landing in the same shard so the third insert must evict.
        let entry = 8 * 4 + ENTRY_OVERHEAD;
        let c = CodeCache::new(entry * 2 * N_SHARDS);
        let shard0 = CodeCache::shard_of(0, 0);
        let mut same: Vec<u64> = Vec::new();
        let mut k = 0u64;
        while same.len() < 3 {
            if CodeCache::shard_of(0, k) == shard0 {
                same.push(k);
            }
            k += 1;
        }
        let mix = vec![2.5f32; 8];
        let mut out = vec![0.0f32; 8];
        c.insert(FP, 0, same[0], &mix);
        c.insert(FP, 0, same[1], &mix);
        // Touch the older entry so the *other* one becomes LRU.
        assert!(c.lookup(FP, 0, same[0], &mut out));
        let (_, ev) = c.insert(FP, 0, same[2], &mix);
        assert_eq!(ev, 1, "third entry in a 2-entry shard evicts one");
        assert!(c.lookup(FP, 0, same[0], &mut out), "recently touched survives");
        assert!(!c.lookup(FP, 0, same[1], &mut out), "LRU entry evicted");
    }

    #[test]
    fn oversized_entry_is_refused_not_partially_cached() {
        let c = CodeCache::new(128); // per-shard budget: 8 bytes
        let mix = vec![0.5f32; 64];
        let (bytes, ev) = c.insert(FP, 0, 7, &mix);
        assert_eq!((bytes, ev), (0, 0));
        assert_eq!(c.resident_bytes(), 0);
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn fingerprint_mismatch_flushes_instead_of_serving_stale() {
        let c = CodeCache::new(1 << 20);
        let mix = vec![1.0f32; 16];
        let mut out = vec![0.0f32; 16];
        c.insert(0xAAAA, 0, 1, &mix);
        assert!(c.lookup(0xAAAA, 0, 1, &mut out));
        // New weight set: the old product must NOT be served.
        assert!(!c.lookup(0xBBBB, 0, 1, &mut out), "stale product served");
        assert_eq!(c.len(), 0, "flush clears every shard");
        assert_eq!(c.resident_bytes(), 0);
        assert_eq!(c.stats().flushes, 1);
        // And the cache now serves the new fingerprint normally.
        c.insert(0xBBBB, 0, 1, &mix);
        assert!(c.lookup(0xBBBB, 0, 1, &mut out));
    }

    #[test]
    fn note_hit_counts_without_touching_shards() {
        let c = CodeCache::new(1 << 20);
        c.note_hit();
        c.note_hit();
        let s = c.stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 0);
        assert_eq!(c.len(), 0);
    }

    /// Poison-injection regression: a worker panic while a shard guard is
    /// held (the coordinator catches request panics) used to poison the
    /// process-global cache and cascade `PoisonError` panics into every
    /// session on every shard. The cache must recover, keep serving the
    /// exact cached bits, and count the recovery once.
    #[test]
    fn poisoned_shard_recovers_and_stays_serveable() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let c = CodeCache::new(1 << 20);
        let mix = vec![1.5f32, -2.25, 0.5, 8.0];
        c.insert(FP, 0, 42, &mix);
        let idx = CodeCache::shard_of(0, 42);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            let _g = c.shards[idx].write().unwrap();
            panic!("injected worker panic while holding the shard guard");
        }));
        assert!(caught.is_err());
        assert!(c.shards[idx].is_poisoned(), "injection must poison the lock");
        // Hits still serve byte-identical payloads through the recovery.
        let mut out = vec![0.0f32; 4];
        assert!(c.lookup(FP, 0, 42, &mut out), "entry survives the panic");
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&out), bits(&mix), "recovered hit must be bit-exact");
        // Inserts keep working after recovery.
        let (bytes, _) = c.insert(FP, 0, 43, &mix);
        assert!(bytes > 0, "insert after recovery must be accepted");
        assert!(c.lookup(FP, 0, 43, &mut out));
        assert_eq!(c.len(), 2);
        // `clear_poison` means the counter tracks panic events, not every
        // access after one.
        assert_eq!(c.stats().poison_recoveries, 1);
        assert!(!c.shards[idx].is_poisoned(), "flag cleared after recovery");
    }

    #[test]
    fn weights_fingerprint_tracks_the_cached_inputs() {
        let cfg = ModelConfig::vqt_tiny();
        let w1 = ModelWeights::random(&cfg, 1);
        let w1b = ModelWeights::random(&cfg, 1);
        let w2 = ModelWeights::random(&cfg, 2);
        assert_eq!(
            weights_fingerprint(&w1),
            weights_fingerprint(&w1b),
            "same seed, same fingerprint"
        );
        assert_ne!(
            weights_fingerprint(&w1),
            weights_fingerprint(&w2),
            "different weights, different fingerprint"
        );
        // Perturbing one w_mix element changes the fingerprint — the
        // guard actually covers the cached product's inputs.
        let mut w3 = ModelWeights::random(&cfg, 1);
        w3.layers[0].w_mix.data[0] += 1.0;
        assert_ne!(weights_fingerprint(&w1), weights_fingerprint(&w3));
        assert_ne!(weights_fingerprint(&w1), 0, "0 is reserved for unset");
    }
}
