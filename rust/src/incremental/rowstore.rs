//! Flat row-major storage with row insertion/removal — the per-layer state
//! arrays of the incremental engine. Contiguous storage keeps the
//! correction inner loops cache-friendly; structural edits are O(n·cols)
//! memmoves, which is bookkeeping (not arithmetic) and is counted as such.

/// A growable matrix of f32 rows with stable width.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RowStore {
    pub cols: usize,
    data: Vec<f32>,
}

impl RowStore {
    pub fn new(cols: usize) -> RowStore {
        RowStore { cols, data: Vec::new() }
    }

    pub fn with_rows(cols: usize, rows: usize) -> RowStore {
        RowStore {
            cols,
            data: vec![0.0; cols * rows],
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        if self.cols == 0 {
            0
        } else {
            self.data.len() / self.cols
        }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Two disjoint mutable rows (i != j).
    pub fn rows_mut2(&mut self, i: usize, j: usize) -> (&mut [f32], &mut [f32]) {
        assert_ne!(i, j);
        let c = self.cols;
        if i < j {
            let (a, b) = self.data.split_at_mut(j * c);
            (&mut a[i * c..(i + 1) * c], &mut b[..c])
        } else {
            let (a, b) = self.data.split_at_mut(i * c);
            (&mut b[..c], &mut a[j * c..(j + 1) * c])
        }
    }

    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.cols);
        self.data.extend_from_slice(row);
    }

    pub fn insert_row(&mut self, at: usize, row: &[f32]) {
        assert_eq!(row.len(), self.cols);
        assert!(at <= self.rows());
        let idx = at * self.cols;
        // splice is an O(n) memmove — structural bookkeeping.
        self.data.splice(idx..idx, row.iter().copied());
    }

    /// Insert an all-zero row without a caller-side temporary — the common
    /// case for stores whose new rows are filled by a later full pass
    /// (attention accumulators and streaming-softmax aggregates).
    pub fn insert_zero_row(&mut self, at: usize) {
        assert!(at <= self.rows());
        let idx = at * self.cols;
        self.data.splice(idx..idx, std::iter::repeat(0.0).take(self.cols));
    }

    pub fn remove_row(&mut self, at: usize) -> Vec<f32> {
        assert!(at < self.rows());
        let idx = at * self.cols;
        self.data.drain(idx..idx + self.cols).collect()
    }

    pub fn copy_row(&self, i: usize) -> Vec<f32> {
        self.row(i).to_vec()
    }

    pub fn clear(&mut self) {
        self.data.clear();
    }

    /// Resident payload size in bytes (the data slab only — the session
    /// memory accountant sums these across all per-layer stores).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_roundtrip() {
        let mut s = RowStore::new(3);
        s.push_row(&[1.0, 2.0, 3.0]);
        s.push_row(&[7.0, 8.0, 9.0]);
        s.insert_row(1, &[4.0, 5.0, 6.0]);
        assert_eq!(s.rows(), 3);
        assert_eq!(s.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(s.row(2), &[7.0, 8.0, 9.0]);
        let removed = s.remove_row(0);
        assert_eq!(removed, vec![1.0, 2.0, 3.0]);
        assert_eq!(s.row(0), &[4.0, 5.0, 6.0]);
        assert_eq!(s.rows(), 2);
    }

    #[test]
    fn rows_mut2_disjoint() {
        let mut s = RowStore::with_rows(2, 3);
        {
            let (a, b) = s.rows_mut2(0, 2);
            a[0] = 1.0;
            b[1] = 2.0;
        }
        assert_eq!(s.row(0), &[1.0, 0.0]);
        assert_eq!(s.row(2), &[0.0, 2.0]);
        let (x, y) = s.rows_mut2(2, 0);
        x[0] = 5.0;
        y[1] = 6.0;
        assert_eq!(s.row(2), &[5.0, 2.0]);
        assert_eq!(s.row(0), &[1.0, 6.0]);
    }

    #[test]
    fn insert_zero_row_matches_explicit_zeros() {
        let mut s = RowStore::new(3);
        s.push_row(&[1.0, 2.0, 3.0]);
        s.insert_zero_row(0);
        s.insert_zero_row(2);
        assert_eq!(s.rows(), 3);
        assert_eq!(s.row(0), &[0.0, 0.0, 0.0]);
        assert_eq!(s.row(1), &[1.0, 2.0, 3.0]);
        assert_eq!(s.row(2), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn insert_at_ends() {
        let mut s = RowStore::new(2);
        s.insert_row(0, &[1.0, 1.0]);
        s.insert_row(1, &[3.0, 3.0]);
        s.insert_row(1, &[2.0, 2.0]);
        assert_eq!(s.row(0), &[1.0, 1.0]);
        assert_eq!(s.row(1), &[2.0, 2.0]);
        assert_eq!(s.row(2), &[3.0, 3.0]);
    }
}

impl RowStore {
    /// Rebuild the store in a new layout: `mapping[f]` gives the old row
    /// to copy into new row f (None ⇒ zero row). Used by the batched
    /// revision pass to apply all structural changes at once.
    pub fn reindex(&mut self, mapping: &[Option<usize>]) {
        let cols = self.cols;
        let mut data = vec![0.0; mapping.len() * cols];
        for (f, o) in mapping.iter().enumerate() {
            if let Some(o) = o {
                data[f * cols..(f + 1) * cols].copy_from_slice(self.row(*o));
            }
        }
        self.data = data;
    }
}
