//! The incremental VQT inference engine — the paper's core contribution.
//!
//! Holds the per-layer state of one document and updates it under edits
//! with cost proportional to the edit's effect, not the document length:
//!
//! - **Per-location reuse** (§3.2): a row's block output is a pure function
//!   of its residual-stream input and its VQ code; unchanged ⇒ reused.
//! - **Attention deltas** (App. A.1): with element-wise σ instead of
//!   softmax, a changed key/value at column j contributes an exact
//!   correction term `±σ(q_i·k_j·s)·v_j` to every later row i — no
//!   renormalization, unlike softmax.
//! - **Semi-naive softmax recompute** (delta-restricted propagation):
//!   with true softmax the exact rule above breaks — the normalizer
//!   couples every column. Softmax engines instead keep per-row
//!   streaming-softmax aggregates ([`super::attn_delta`]) and update
//!   unchanged query rows by subtracting the changed columns' old terms
//!   and adding the new ones, renormalizing once — choosing per row
//!   between delta and full recompute via the FLOP ledger, with a
//!   bounded, drift-refreshed tolerance (docs/ARCHITECTURE.md §12).
//! - **VQ cost hiding** (App. A.2): attention outputs are maintained
//!   directly in *VQ score space*. Per row we keep
//!   `acc[i] = ⟨Σ_j σ_h(q_i,k_j)·v_j, C⟩`, exploiting linearity of the
//!   codebook projection: corrections update `acc` with the precomputed
//!   per-attention-head projections `⟨v_j|_h, C⟩` in O(n_heads·q) and
//!   re-assignment is a scale+bias+argmax — the d-dimensional attention
//!   accumulator never materializes.
//! - **Insert/delete** (§3.3): sampled positional embeddings with gaps; a
//!   gap-exhausted insert triggers defragmentation (full rebuild), counted
//!   in the stats and in the FLOP ledger (the amortized-cost story is
//!   reported honestly by the benches).
//!
//! Head-alignment requirement: each attention head's value slice must lie
//! inside a single VQ chunk, i.e. `n_heads % vq_heads == 0` — checked at
//! construction. (`vq_heads=2, n_heads=4`: heads {0,1} ↦ chunk 0, {2,3} ↦
//! chunk 1.)

use crate::config::AttentionKind;
use crate::edits::Edit;
use crate::flops::{self, Cat, FlopLedger, MULADD, TRANSCENDENTAL};
use crate::model::{attn_out_scale, dense_forward, ModelWeights};
use crate::positions::{InsertOutcome, PositionAllocator};
use crate::tensor;
use crate::vq::CodeTuple;
use anyhow::Result;
use std::sync::Arc;

use super::attn_delta::{self, AttnAggregates, SmChange};
use super::codecache::CacheHandle;
use super::rowstore::RowStore;

/// Engine tuning knobs (ablation surface).
#[derive(Clone, Copy, Debug)]
pub struct EngineOptions {
    /// Use the App. A.2 score-space trick. When false, the engine maintains
    /// the d-dimensional attention accumulator and re-quantizes touched
    /// rows from scratch (the naive exact variant, for the ablation bench).
    pub score_trick: bool,
    /// After this many edits, self-verify against a dense recompute and
    /// rebuild on drift (0 = never).
    pub verify_every: usize,
    /// Softmax engines only: allow per-row delta updates of the
    /// streaming-softmax aggregates (semi-naive recompute). When false,
    /// every affected consumer row recomputes its attention in full — the
    /// forced-full ablation arm the differential suite compares against.
    pub attn_delta: bool,
    /// Softmax engines only: full-refresh a row's aggregates after this
    /// many delta applications, bounding accumulated rounding drift
    /// (0 = never refresh on the counter; the stale-shift and denominator
    /// guards in [`super::attn_delta`] still force refreshes).
    pub attn_refresh_every: usize,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            score_trick: true,
            verify_every: 0,
            attn_delta: true,
            attn_refresh_every: 64,
        }
    }
}

/// Lifetime statistics.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EngineStats {
    pub edits_applied: u64,
    pub defrags: u64,
    pub full_rebuilds: u64,
    /// Rows whose attention was recomputed in full.
    pub rows_recomputed: u64,
    /// Column-correction terms applied to clean rows.
    pub corrections: u64,
    /// VQ code changes observed (dirty propagation across layers).
    pub code_flips: u64,
    /// Rows whose block output was recomputed.
    pub outputs_recomputed: u64,
    pub verifications: u64,
    /// Block-tail mix vectors served from the shared code cache (this
    /// engine's share; zero when no cache is attached).
    pub cache_hits: u64,
    /// Cache lookups that fell through to the full decode→mix product.
    pub cache_misses: u64,
    /// Entries this engine's inserts displaced from the shared cache.
    pub cache_evictions: u64,
    /// Payload+overhead bytes this engine's inserts added to the cache.
    pub cache_bytes_inserted: u64,
    /// Softmax engines: clean consumer rows updated via aggregate delta
    /// (semi-naive recompute) instead of full re-attention.
    pub attn_delta_rows: u64,
    /// Softmax engines: clean consumer rows that fell back to a full
    /// attention recompute — cost rule, guard trip, or drift refresh.
    pub attn_full_rows: u64,
    /// Drift-counter-triggered full refreshes (a subset of
    /// `attn_full_rows`; see `EngineOptions::attn_refresh_every`).
    pub attn_refreshes: u64,
    /// FLOPs the delta rows saved vs the full recompute the cost rule
    /// priced for them (Σ full − delta) — the operand of the ledger
    /// identity checked by `tests/differential_attn_delta.rs`.
    pub attn_delta_saved_flops: u64,
}

/// Result of one edit (or edit-script) application.
#[derive(Clone, Debug)]
pub struct EditReport {
    /// Arithmetic operations spent.
    pub flops: u64,
    /// Classifier logits afterwards.
    pub logits: Vec<f32>,
    /// Whether a defrag (full rebuild) happened.
    pub defragged: bool,
}

/// Dense-recompute comparison (the exactness check).
#[derive(Clone, Debug)]
pub struct VerifyReport {
    pub max_logit_diff: f32,
    pub max_hidden_diff: f32,
    pub code_mismatches: usize,
    pub total_codes: usize,
}

impl VerifyReport {
    pub fn is_exact(&self, tol: f32) -> bool {
        self.code_mismatches == 0 && self.max_logit_diff <= tol
    }
}

/// Per-layer cached state (one entry per sequence row throughout).
#[derive(Clone, Debug)]
struct LayerState {
    /// Residual-stream input to the block — (n, d).
    x: RowStore,
    /// Post-LN1 projections — (n, d) each.
    q: RowStore,
    k: RowStore,
    v: RowStore,
    /// Per-attention-head codebook projections ⟨v|_h, C⟩ — (n, n_heads·q)
    /// (score trick only; zero-width otherwise).
    vc: RowStore,
    /// Attention accumulator: score space (n, vq_heads·q) with the trick,
    /// value space (n, d) without.
    acc: RowStore,
    /// Current VQ code per row.
    codes: Vec<CodeTuple>,
    /// Streaming-softmax aggregates (softmax attention only; `None` for
    /// element-wise engines, whose deltas are exact without them).
    agg: Option<AttnAggregates>,
}

/// A pending change to attention column `j` within a layer.
enum ColChange {
    /// k/v at j changed: carries the previous key and value-projection.
    Modified {
        j: usize,
        k_old: Vec<f32>,
        val_old: Vec<f32>,
    },
    /// New column inserted at j (the new row recomputes itself fully).
    Added { j: usize },
    /// Column removed: carries the removed key and value-projection.
    Removed {
        j: usize,
        k_old: Vec<f32>,
        val_old: Vec<f32>,
    },
}

/// The incremental inference engine for one document session.
#[derive(Clone)]
pub struct IncrementalEngine {
    w: Arc<ModelWeights>,
    opts: EngineOptions,
    tokens: Vec<u32>,
    positions: PositionAllocator,
    layers: Vec<LayerState>,
    /// Final hidden states (post ln_f) per row — (n, d).
    final_hidden: RowStore,
    /// Running sum of final hidden rows (mean-pool numerator).
    pooled_sum: Vec<f32>,
    logits: Vec<f32>,
    /// Reusable hot-path scratch (row_output / qkv_row temporaries).
    scratch: Scratch,
    /// Shared codebook-product cache, if the host attached one (strictly
    /// opt-in: `None` preserves the classic uncached numerics AND the
    /// classic stat/ledger series exactly). Travels through `clone`/
    /// `fork`; deliberately excluded from snapshots — a restored engine
    /// re-attaches and rewarms lazily.
    cache: Option<CacheHandle>,
    /// Whether the most recent `block_tail` was served from the cache —
    /// read by `row_output` (and collected by `apply_edit`) to charge the
    /// ledger honestly for that row.
    tail_cached: bool,
    pub ledger: FlopLedger,
    pub stats: EngineStats,
}

/// Per-engine scratch buffers — avoids per-row allocations on hot paths.
#[derive(Clone, Default)]
struct Scratch {
    a: Vec<f32>,
    b: Vec<f32>,
    c: Vec<f32>,
    mid: Vec<f32>,
}

/// Layer `li`'s codebooks on a validated engine. Presence is checked by
/// [`IncrementalEngine::try_new`] (and the snapshot-restore path) before
/// any hot-path code runs, so this can only fire when a caller bypassed
/// construction-time validation — it stays a panic (with the same message
/// the typed boundary uses) rather than threading `Result` through every
/// per-edit hot-path frame.
fn expect_vq(w: &ModelWeights, li: usize) -> &crate::vq::VqCodebooks {
    w.layers[li].vq.as_ref().unwrap_or_else(|| {
        panic!("layer {li} has no VQ config (engine construction should have rejected it)")
    })
}

impl IncrementalEngine {
    /// Create an engine and build the full state for `tokens`.
    ///
    /// Panics on a config/weights combination that cannot drive
    /// incremental inference; serving paths use [`Self::try_new`], which
    /// surfaces the same conditions as typed errors instead.
    pub fn new(w: Arc<ModelWeights>, tokens: &[u32], opts: EngineOptions) -> Self {
        Self::try_new(w, tokens, opts).expect("invalid engine configuration")
    }

    /// Fallible [`Self::new`]: validates up front — a supported attention
    /// kind, `vq_heads > 0`, head divisibility, and (crucially for
    /// serving) that **every** layer of the weight set actually carries
    /// VQ codebooks. A weights file with a VQ-less layer thus fails here
    /// with "layer N has no VQ config" instead of panicking a worker
    /// mid-request deep in the hot path.
    ///
    /// Element-wise engines update exactly (paper §3 / App. A.1); softmax
    /// engines run the semi-naive aggregate path with its documented
    /// tolerance (docs/ARCHITECTURE.md §12). The App. A.2 score-space
    /// trick relies on update linearity, which softmax's renormalization
    /// breaks, so softmax engines always run in value space —
    /// `opts.score_trick` is normalized to `false` here (and checkpoints
    /// record the normalized mode).
    pub fn try_new(w: Arc<ModelWeights>, tokens: &[u32], mut opts: EngineOptions) -> Result<Self> {
        let cfg = &w.cfg;
        anyhow::ensure!(
            matches!(
                cfg.attention,
                AttentionKind::GeluElementwise | AttentionKind::Softmax
            ),
            "incremental inference requires element-wise or softmax attention"
        );
        if cfg.attention == AttentionKind::Softmax {
            opts.score_trick = false;
        }
        anyhow::ensure!(cfg.vq_heads > 0, "incremental inference requires VQ layers");
        anyhow::ensure!(
            cfg.n_heads % cfg.vq_heads == 0,
            "n_heads must be a multiple of vq_heads for score-space updates"
        );
        w.validate_vq()?;
        let d = cfg.d_model;
        let hq = cfg.vq_heads * cfg.vq_codes;
        let (vc_w, acc_w) = if opts.score_trick {
            (cfg.n_heads * cfg.vq_codes, hq)
        } else {
            (0, d)
        };
        let layers = (0..cfg.n_layers)
            .map(|_| LayerState {
                x: RowStore::new(d),
                q: RowStore::new(d),
                k: RowStore::new(d),
                v: RowStore::new(d),
                vc: RowStore::new(vc_w),
                acc: RowStore::new(acc_w),
                codes: Vec::new(),
                agg: (cfg.attention == AttentionKind::Softmax)
                    .then(|| AttnAggregates::new(d, cfg.n_heads)),
            })
            .collect();
        let mut eng = IncrementalEngine {
            positions: PositionAllocator::spread(w.cfg.pos_pool, tokens.len()),
            w,
            opts,
            tokens: tokens.to_vec(),
            layers,
            final_hidden: RowStore::new(d),
            pooled_sum: vec![0.0; d],
            logits: vec![],
            scratch: Scratch::default(),
            cache: None,
            tail_cached: false,
            ledger: FlopLedger::new(),
            stats: EngineStats::default(),
        };
        eng.rebuild();
        Ok(eng)
    }

    /// Attach (or detach, with `None`) a shared codebook-product cache.
    /// The handle carries the fingerprint of the weight set it was built
    /// for; attaching a handle fingerprinted for different weights would
    /// flush the shared cache on first use, so hosts build one handle per
    /// weight set ([`CacheHandle::new`]) and clone it per engine.
    pub fn set_code_cache(&mut self, cache: Option<CacheHandle>) {
        self.cache = cache;
        self.tail_cached = false;
    }

    /// The attached cache handle, if any (the pooled batch executor uses
    /// this to decide whether a wave shares one cache).
    pub fn code_cache(&self) -> Option<&CacheHandle> {
        self.cache.as_ref()
    }

    pub fn tokens(&self) -> &[u32] {
        &self.tokens
    }

    pub fn position_ids(&self) -> &[u32] {
        self.positions.ids()
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn logits(&self) -> &[f32] {
        &self.logits
    }

    pub fn predict(&self) -> usize {
        tensor::argmax(&self.logits)
    }

    pub fn weights(&self) -> &Arc<ModelWeights> {
        &self.w
    }

    /// Fork an independent copy with fresh counters (offline batch: one
    /// fork per revision — the shared base state is the compressed-batch
    /// reuse of §3.1).
    pub fn fork(&self) -> IncrementalEngine {
        let mut c = self.clone();
        c.ledger = FlopLedger::new();
        c.stats = EngineStats::default();
        c
    }

    // ------------------------------------------------------------------
    // Full build
    // ------------------------------------------------------------------

    /// Rebuild all state from `self.tokens` / `self.positions` (session
    /// start and defragmentation). Costs a full forward pass, on-ledger.
    pub fn rebuild(&mut self) {
        self.stats.full_rebuilds += 1;
        let cfg = self.w.cfg.clone();
        let n = self.tokens.len();
        assert!(n <= cfg.max_seq, "document exceeds max_seq");
        assert_eq!(self.positions.len(), n);
        let d = cfg.d_model;

        for l in &mut self.layers {
            l.x.clear();
            l.q.clear();
            l.k.clear();
            l.v.clear();
            l.vc.clear();
            l.acc.clear();
            l.codes.clear();
            if let Some(a) = &mut l.agg {
                a.clear();
            }
        }
        self.final_hidden.clear();
        self.pooled_sum = vec![0.0; d];

        let pos = self.positions.ids().to_vec();
        let mut x_rows: Vec<Vec<f32>> = (0..n)
            .map(|i| self.embed_row(self.tokens[i], pos[i]))
            .collect();

        for li in 0..cfg.n_layers {
            for x in x_rows.iter().take(n) {
                let (q, k, v) = self.qkv_row(li, x);
                let vc = self.project_value(li, &v);
                let layer = &mut self.layers[li];
                layer.x.push_row(x);
                layer.q.push_row(&q);
                layer.k.push_row(&k);
                layer.v.push_row(&v);
                layer.vc.push_row(&vc);
            }
            // Aggregate rows must exist before the per-row full pass below
            // writes them (softmax only).
            if let Some(a) = &mut self.layers[li].agg {
                for _ in 0..n {
                    a.push_zero_row();
                }
            }
            for (i, x) in x_rows.iter_mut().enumerate() {
                let acc = self.attn_full_row(li, i);
                self.layers[li].acc.push_row(&acc);
                let code = self.assign_code(li, &acc);
                self.layers[li].codes.push(code);
                *x = self.row_output(li, x, code);
            }
        }

        for x in &x_rows {
            let h = self.final_row(x);
            tensor::axpy(1.0, &h, &mut self.pooled_sum);
            self.final_hidden.push_row(&h);
        }
        self.ledger.add(Cat::Elementwise, (n * d) as u64);
        self.recompute_logits();
    }

    // ------------------------------------------------------------------
    // Primitive computations (each ticks the ledger with its actual cost)
    // ------------------------------------------------------------------

    fn embed_row(&mut self, tok: u32, pos: u32) -> Vec<f32> {
        let d = self.w.cfg.d_model;
        let te = self.w.embed_tokens.row(tok as usize);
        let pe = self.w.embed_pos.row(pos as usize);
        let out = te.iter().zip(pe).map(|(a, b)| a + b).collect();
        self.ledger.add(Cat::Embed, 2 * d as u64);
        out
    }

    /// LN1 + QKV projections for one row (scratch-buffered).
    fn qkv_row(&mut self, li: usize, x: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let w = Arc::clone(&self.w);
        let layer = &w.layers[li];
        let cfg = &w.cfg;
        let d = cfg.d_model;
        let h = &mut self.scratch.a;
        h.resize(d, 0.0);
        tensor::layernorm_into(x, &layer.ln1_g, &layer.ln1_b, cfg.ln_eps, h);
        let (mut q, mut k, mut v) = (vec![0.0; d], vec![0.0; d], vec![0.0; d]);
        tensor::vec_matmul_into(h, &layer.wq, &mut q);
        tensor::vec_matmul_into(h, &layer.wk, &mut k);
        tensor::vec_matmul_into(h, &layer.wv, &mut v);
        for i in 0..d {
            q[i] += layer.bq[i];
            k[i] += layer.bk[i];
            v[i] += layer.bv[i];
        }
        self.ledger.add(Cat::Elementwise, flops::layernorm_cost(d));
        self.ledger.add(Cat::Linear, MULADD * (3 * d * d) as u64);
        (q, k, v)
    }

    /// Per-attention-head codebook projections of a value row:
    /// `out[h·q + c] = ⟨v|_h , C_{g(h)}[c]|_h⟩` where g(h) is the VQ chunk
    /// containing head h and the codeword is restricted to head h's slice.
    /// Empty when the trick is off.
    fn project_value(&mut self, li: usize, v: &[f32]) -> Vec<f32> {
        if !self.opts.score_trick {
            return Vec::new();
        }
        let w = Arc::clone(&self.w);
        let vq = expect_vq(&w, li);
        let cfg = &w.cfg;
        let nh = cfg.n_heads;
        let dh = cfg.d_head();
        let chunk = vq.chunk();
        let mut out = vec![0.0; nh * vq.codes];
        for h in 0..nh {
            let g = h * vq.heads / nh; // VQ chunk containing head h
            let off_in_chunk = h * dh - g * chunk;
            let vh = &v[h * dh..(h + 1) * dh];
            let book = &vq.books[g];
            for c in 0..vq.codes {
                let cw = &book.row(c)[off_in_chunk..off_in_chunk + dh];
                out[h * vq.codes + c] = tensor::dot(vh, cw);
            }
        }
        // nh · q dots of width d_head ⇒ d·q muladds total.
        self.ledger
            .add(Cat::Vq, MULADD * (cfg.d_model * vq.codes) as u64);
        out
    }

    /// Unified correction sweep: apply one column change (optional old
    /// term to subtract, optional new column to add) to every clean row in
    /// `range`. Allocation-free inner loop; ledger ticked in bulk.
    /// Returns the number of corrected rows.
    fn correct_rows(
        &mut self,
        li: usize,
        range: std::ops::Range<usize>,
        row_dirty: &[bool],
        old: Option<(&[f32], &[f32])>,
        new_j: Option<usize>,
        mut acc_touched: Option<&mut Vec<bool>>,
    ) -> u64 {
        let cfg = &self.w.cfg;
        let (nh, dh, d) = (cfg.n_heads, cfg.d_head(), cfg.d_model);
        let scale = 1.0 / (dh as f32).sqrt();
        let trick = self.opts.score_trick;
        let (vqh, codes) = if trick {
            let vq = expect_vq(&self.w, li);
            (vq.heads, vq.codes)
        } else {
            (0, 0)
        };
        let mut coeffs = [0f32; 16];
        debug_assert!(nh <= 16);
        let mut count = 0u64;
        {
            let layer = &mut self.layers[li];
            let newkv = new_j.map(|j| {
                (
                    layer.k.copy_row(j),
                    if trick {
                        layer.vc.copy_row(j)
                    } else {
                        layer.v.copy_row(j)
                    },
                )
            });
            for i in range {
                if row_dirty[i] {
                    continue;
                }
                let q = layer.q.row(i);
                let acc = layer.acc.row_mut(i);
                if let Some((k_old, val_old)) = old {
                    head_coeffs_raw(q, k_old, nh, dh, scale, &mut coeffs);
                    apply_term_raw(acc, &coeffs[..nh], val_old, -1.0, trick, vqh, codes, dh);
                }
                if let Some((k_new, val_new)) = &newkv {
                    head_coeffs_raw(q, k_new, nh, dh, scale, &mut coeffs);
                    apply_term_raw(acc, &coeffs[..nh], val_new, 1.0, trick, vqh, codes, dh);
                }
                if let Some(t) = acc_touched.as_deref_mut() {
                    t[i] = true;
                }
                count += 1;
            }
        }
        // Bulk accounting: per corrected row, per term: q·k (d muladds) +
        // per-head scale/σ, plus the score-space (h·q) or value-space (d)
        // accumulate.
        let terms = (old.is_some() as u64) + (new_j.is_some() as u64);
        let per_coeff = MULADD * d as u64 + (nh as u64) * (1 + TRANSCENDENTAL);
        let per_acc = if trick {
            MULADD * (nh * codes) as u64
        } else {
            MULADD * d as u64
        };
        self.ledger
            .add(Cat::Attention, count * terms * per_coeff);
        self.ledger.add(
            if trick { Cat::Vq } else { Cat::Attention },
            count * terms * per_acc,
        );
        self.stats.corrections += count;
        count
    }

    /// Full attention accumulator for row i (over all visible columns).
    /// Allocation-free per column; ledger ticked in bulk. Softmax engines
    /// divert to the streaming-softmax variant, which also refreshes the
    /// row's aggregates.
    fn attn_full_row(&mut self, li: usize, i: usize) -> Vec<f32> {
        if self.is_softmax() {
            return self.attn_sm_full_row(li, i);
        }
        self.stats.rows_recomputed += 1;
        let cfg = &self.w.cfg;
        let (nh, dh, d) = (cfg.n_heads, cfg.d_head(), cfg.d_model);
        let scale = 1.0 / (dh as f32).sqrt();
        let trick = self.opts.score_trick;
        let (vqh, codes) = if trick {
            let vq = expect_vq(&self.w, li);
            (vq.heads, vq.codes)
        } else {
            (0, 0)
        };
        let layer = &self.layers[li];
        let width = layer.acc.cols;
        let mut acc = vec![0.0; width];
        let q = layer.q.row(i);
        let mut coeffs = [0f32; 16];
        debug_assert!(nh <= 16);
        for j in 0..=i {
            let k = layer.k.row(j);
            head_coeffs_raw(q, k, nh, dh, scale, &mut coeffs);
            let val = if trick { layer.vc.row(j) } else { layer.v.row(j) };
            apply_term_raw(&mut acc, &coeffs[..nh], val, 1.0, trick, vqh, codes, dh);
        }
        let per_coeff = MULADD * d as u64 + (nh as u64) * (1 + TRANSCENDENTAL);
        let per_acc = if trick {
            MULADD * (nh * codes) as u64
        } else {
            MULADD * d as u64
        };
        let c = (i + 1) as u64;
        self.ledger.add(Cat::Attention, c * per_coeff);
        self.ledger
            .add(if trick { Cat::Vq } else { Cat::Attention }, c * per_acc);
        acc
    }

    #[inline]
    fn is_softmax(&self) -> bool {
        self.w.cfg.attention == AttentionKind::Softmax
    }

    /// Full streaming-softmax recompute of row i: fresh per-head shifts
    /// (the true row maxima), aggregates written back, drift counter
    /// reset. Returns the renormalized value-space accumulator. Ledger:
    /// [`flops::attn_sm_full_cost`] — the figure the decision rule in
    /// [`Self::attn_sm_apply_changes`] prices delta updates against.
    fn attn_sm_full_row(&mut self, li: usize, i: usize) -> Vec<f32> {
        self.stats.rows_recomputed += 1;
        let (nh, dh, d) = (
            self.w.cfg.n_heads,
            self.w.cfg.d_head(),
            self.w.cfg.d_model,
        );
        let scale = 1.0 / (dh as f32).sqrt();
        let full_cost = flops::attn_sm_full_cost(&self.w.cfg, i + 1);
        debug_assert!(nh <= 16);
        let scores = &mut self.scratch.mid;
        let layer = &mut self.layers[li];
        let agg = layer.agg.as_mut().expect("softmax engine carries aggregates");
        let q = layer.q.row(i);
        // Pass 1: scores and per-head maxima (the fresh frozen shifts).
        scores.resize((i + 1) * nh, 0.0);
        let mut m = [f32::NEG_INFINITY; 16];
        for j in 0..=i {
            let k = layer.k.row(j);
            for h in 0..nh {
                let s = tensor::dot(&q[h * dh..(h + 1) * dh], &k[h * dh..(h + 1) * dh]) * scale;
                scores[j * nh + h] = s;
                m[h] = m[h].max(s);
            }
        }
        // Pass 2: accumulate num/den under the fresh shifts; renormalize.
        let num = agg.num.row_mut(i);
        num.fill(0.0);
        let mut den = [0f32; 16];
        for j in 0..=i {
            let v = layer.v.row(j);
            for h in 0..nh {
                let wj = (scores[j * nh + h] - m[h]).exp();
                tensor::sm_add_term(
                    &mut num[h * dh..(h + 1) * dh],
                    &mut den[h],
                    wj,
                    &v[h * dh..(h + 1) * dh],
                );
            }
        }
        let mut acc = vec![0.0; d];
        for h in 0..nh {
            tensor::sm_renorm_into(
                &num[h * dh..(h + 1) * dh],
                den[h],
                &mut acc[h * dh..(h + 1) * dh],
            );
        }
        agg.den.row_mut(i).copy_from_slice(&den[..nh]);
        agg.m.row_mut(i).copy_from_slice(&m[..nh]);
        agg.drift[i] = 0;
        self.ledger.add(Cat::Attention, full_cost);
        acc
    }

    /// Semi-naive sweep over clean consumer rows for a set of key/value
    /// column changes — the softmax counterpart of the exact
    /// [`Self::correct_rows`] sweeps. Per affected row the engine picks
    /// delta-update vs full recompute by comparing the two FLOP-ledger
    /// arms ([`flops::attn_sm_delta_cost`] vs [`flops::attn_sm_full_cost`]);
    /// the drift counter and the guards in [`super::attn_delta`] can force
    /// the full path regardless (docs/ARCHITECTURE.md §12).
    fn attn_sm_apply_changes(
        &mut self,
        li: usize,
        changes: &[SmChange],
        row_dirty: &[bool],
        mut acc_touched: Option<&mut Vec<bool>>,
    ) {
        if changes.is_empty() {
            return;
        }
        let n = self.layers[li].x.rows();
        let start_min = changes.iter().map(|c| c.start).min().unwrap_or(n);
        let (delta_on, refresh) = (self.opts.attn_delta, self.opts.attn_refresh_every);
        let dh = self.w.cfg.d_head();
        let scale = 1.0 / (dh as f32).sqrt();
        for i in start_min..n {
            if row_dirty[i] {
                continue;
            }
            let sides: usize = changes
                .iter()
                .filter(|c| c.start <= i)
                .map(|c| c.sides())
                .sum();
            if sides == 0 {
                continue;
            }
            let full_cost = flops::attn_sm_full_cost(&self.w.cfg, i + 1);
            let delta_cost = flops::attn_sm_delta_cost(&self.w.cfg, sides);
            let want_delta = delta_on && delta_cost < full_cost;
            let drift_ok = refresh == 0
                || (self.layers[li]
                    .agg
                    .as_ref()
                    .expect("softmax engine carries aggregates")
                    .drift[i] as usize)
                    < refresh;
            if want_delta && !drift_ok {
                self.stats.attn_refreshes += 1;
            }
            let mut applied = false;
            if want_delta && drift_ok {
                applied = self.attn_sm_delta_row(li, i, changes, scale);
                if applied {
                    self.ledger.add(Cat::Attention, delta_cost);
                    self.stats.attn_delta_rows += 1;
                    self.stats.attn_delta_saved_flops += full_cost - delta_cost;
                }
            }
            if !applied {
                let acc = self.attn_sm_full_row(li, i);
                self.layers[li].acc.row_mut(i).copy_from_slice(&acc);
                self.stats.attn_full_rows += 1;
            }
            if let Some(t) = acc_touched.as_deref_mut() {
                t[i] = true;
            }
        }
    }

    /// Attempt the delta update of one clean row's aggregates: subtract
    /// each change's retained old term (bit-identical weight — recomputed
    /// from the retained old key under the same frozen shift), add its new
    /// term, renormalize once. All sides are staged against scratch copies
    /// so a guard trip midway leaves the row untouched; returns whether
    /// the delta committed.
    fn attn_sm_delta_row(&mut self, li: usize, i: usize, changes: &[SmChange], scale: f32) -> bool {
        let (nh, dh) = (self.w.cfg.n_heads, self.w.cfg.d_head());
        let num = &mut self.scratch.a;
        let layer = &mut self.layers[li];
        let agg = layer.agg.as_mut().expect("softmax engine carries aggregates");
        let q = layer.q.row(i);
        let mut m = [0f32; 16];
        m[..nh].copy_from_slice(agg.m.row(i));
        let mut den = [0f32; 16];
        den[..nh].copy_from_slice(agg.den.row(i));
        num.clear();
        num.extend_from_slice(agg.num.row(i));
        let mut w = [0f32; 16];
        for ch in changes {
            if ch.start > i {
                continue;
            }
            if let Some((k_old, v_old)) = &ch.old {
                if !attn_delta::side_weights(q, k_old, &m[..nh], nh, dh, scale, &mut w) {
                    return false;
                }
                for h in 0..nh {
                    tensor::sm_sub_term(
                        &mut num[h * dh..(h + 1) * dh],
                        &mut den[h],
                        w[h],
                        &v_old[h * dh..(h + 1) * dh],
                    );
                }
            }
            if let Some(j) = ch.new_j {
                let (kn, vn) = (layer.k.row(j), layer.v.row(j));
                if !attn_delta::side_weights(q, kn, &m[..nh], nh, dh, scale, &mut w) {
                    return false;
                }
                for h in 0..nh {
                    tensor::sm_add_term(
                        &mut num[h * dh..(h + 1) * dh],
                        &mut den[h],
                        w[h],
                        &vn[h * dh..(h + 1) * dh],
                    );
                }
            }
        }
        if den[..nh].iter().any(|&dv| dv < attn_delta::MIN_DEN) {
            return false;
        }
        agg.num.row_mut(i).copy_from_slice(num);
        agg.den.row_mut(i).copy_from_slice(&den[..nh]);
        agg.drift[i] += 1;
        let acc = layer.acc.row_mut(i);
        for h in 0..nh {
            tensor::sm_renorm_into(
                &num[h * dh..(h + 1) * dh],
                den[h],
                &mut acc[h * dh..(h + 1) * dh],
            );
        }
        true
    }

    /// VQ assignment from an accumulator.
    fn assign_code(&mut self, li: usize, acc: &[f32]) -> CodeTuple {
        let w = Arc::clone(&self.w);
        let vq = expect_vq(&w, li);
        let out_scale = attn_out_scale(w.cfg.max_seq);
        if self.opts.score_trick {
            // biased[k] = acc[k]·scale + b[k]; argmax per VQ head.
            let mut biased = vec![0.0; acc.len()];
            for h in 0..vq.heads {
                for c in 0..vq.codes {
                    let k = h * vq.codes + c;
                    biased[k] = acc[k] * out_scale + vq.bias[h][c];
                }
            }
            self.ledger.add(Cat::Vq, MULADD * acc.len() as u64);
            vq.codes_from_scores(&biased, &mut self.ledger)
        } else {
            let scaled: Vec<f32> = acc.iter().map(|x| x * out_scale).collect();
            self.ledger.add(Cat::Vq, acc.len() as u64);
            vq.assign(&scaled, &mut self.ledger)
        }
    }

    /// Block tail for one row: VQ-decode(code) → mix → residual → LN2 →
    /// FFN → residual. Pure function of (x, code) — the paper's reuse unit.
    /// Ledger/stats charged via [`Self::charge_row_output`] with the
    /// hit/miss flag `block_tail` leaves in `self.tail_cached`.
    fn row_output(&mut self, li: usize, x: &[f32], code: CodeTuple) -> Vec<f32> {
        let out = self.block_tail(li, x, code);
        self.charge_row_output(self.tail_cached);
        out
    }

    /// The block-tail arithmetic alone — NO ledger side effects (cache
    /// hit/miss/eviction stats are updated here, because only this seam
    /// knows the outcome; `self.tail_cached` records it for the caller's
    /// ledger charge). The staged (batchable) edit path computes tails
    /// externally and charges per row on scatter; this is the single-row
    /// reference the pooled executor ([`super::batch`]) must match
    /// bit-for-bit. Scratch-buffered: zero allocations beyond the
    /// returned vector.
    ///
    /// With a cache attached, the decode→mix prefix — a pure function of
    /// `(layer, code)` — is served from the shared cache when present. A
    /// cached entry is the byte-exact product the miss path computed with
    /// the same tiled kernel, so the cached and uncached tails are
    /// bit-identical (locked by `tests/differential_codecache.rs`).
    pub(crate) fn block_tail(&mut self, li: usize, x: &[f32], code: CodeTuple) -> Vec<f32> {
        let w = Arc::clone(&self.w);
        let layer = &w.layers[li];
        let cfg = &w.cfg;
        let d = cfg.d_model;
        let vq = expect_vq(&w, li);
        let sc = &mut self.scratch;
        sc.a.resize(d, 0.0);
        sc.b.resize(d, 0.0);
        sc.c.resize(d, 0.0);
        sc.mid.resize(cfg.d_ff, 0.0);
        let mut hit = false;
        if let Some(h) = &self.cache {
            let key = code.pack();
            if h.cache.lookup(h.fp, li as u32, key, &mut sc.b) {
                self.stats.cache_hits += 1;
                hit = true;
            } else {
                self.stats.cache_misses += 1;
            }
        }
        if !hit {
            vq.decode_into(code, &mut sc.a);
            tensor::vec_matmul_into(&sc.a, &layer.w_mix, &mut sc.b);
            if let Some(h) = &self.cache {
                let (bytes, ev) = h.cache.insert(h.fp, li as u32, code.pack(), &sc.b);
                self.stats.cache_bytes_inserted += bytes;
                self.stats.cache_evictions += ev;
            }
        }
        self.tail_cached = hit;
        // y (residual 1) in sc.c
        for i in 0..d {
            sc.c[i] = x[i] + sc.b[i] + layer.b_mix[i];
        }
        tensor::layernorm_into(&sc.c, &layer.ln2_g, &layer.ln2_b, cfg.ln_eps, &mut sc.a);
        tensor::vec_matmul_into(&sc.a, &layer.w_ff1, &mut sc.mid);
        tensor::bias_gelu(&mut sc.mid, &layer.b_ff1);
        let mut out = vec![0.0; d];
        tensor::vec_matmul_into(&sc.mid, &layer.w_ff2, &mut out);
        for i in 0..d {
            out[i] += layer.b_ff2[i] + sc.c[i];
        }
        out
    }

    /// The exact ledger/stat cost of one block-tail row — shared by
    /// [`Self::row_output`] and the staged scatter path so the two charge
    /// identically by construction. `cached` keeps the FLOP ledger
    /// honest: a cache hit skips the `d·d` mix GEMV (and the decode
    /// bookkeeping) but pays a lookup+copy (`2d` bookkeeping); every
    /// stage after residual 1 is charged identically. Per hit the ledger
    /// saves exactly `MULADD·d² − d` — asserted by the differential
    /// suite's attribution test.
    fn charge_row_output(&mut self, cached: bool) {
        self.stats.outputs_recomputed += 1;
        let cfg = &self.w.cfg;
        let d = cfg.d_model;
        if cached {
            self.ledger.add(Cat::Bookkeeping, 2 * d as u64);
            self.ledger
                .add(Cat::Linear, MULADD * (2 * d * cfg.d_ff) as u64);
        } else {
            self.ledger.add(Cat::Bookkeeping, d as u64);
            self.ledger
                .add(Cat::Linear, MULADD * (d * d + 2 * d * cfg.d_ff) as u64);
        }
        self.ledger.add(
            Cat::Elementwise,
            flops::layernorm_cost(d) + cfg.d_ff as u64 * TRANSCENDENTAL + 2 * d as u64,
        );
    }

    fn final_row(&mut self, x: &[f32]) -> Vec<f32> {
        let w = Arc::clone(&self.w);
        let d = w.cfg.d_model;
        let mut h = vec![0.0; d];
        tensor::layernorm_into(x, &w.lnf_g, &w.lnf_b, w.cfg.ln_eps, &mut h);
        self.ledger.add(Cat::Elementwise, flops::layernorm_cost(d));
        h
    }

    fn recompute_logits(&mut self) {
        let w = Arc::clone(&self.w);
        let cfg = &w.cfg;
        let d = cfg.d_model;
        let n = self.tokens.len().max(1);
        let inv = 1.0 / n as f32;
        let pooled: Vec<f32> = self.pooled_sum.iter().map(|s| s * inv).collect();
        let mut logits = vec![0.0; cfg.n_classes];
        tensor::vec_matmul_into(&pooled, &w.w_cls, &mut logits);
        for (l, &b) in logits.iter_mut().zip(&w.b_cls) {
            *l += b;
        }
        self.ledger
            .add(Cat::Linear, d as u64 + MULADD * (d * cfg.n_classes) as u64);
        self.logits = logits;
    }

    // ------------------------------------------------------------------
    // Incremental edit application
    // ------------------------------------------------------------------

    /// Apply one edit incrementally. Cost ∝ affected rows, not document
    /// length (modulo defragmentation). Runs the staged pipeline with the
    /// in-process single-row block-tail executor — the batched coordinator
    /// path drives the same staged hooks with a pooled executor, so the
    /// two paths share every line of orchestration code.
    pub fn apply_edit(&mut self, edit: Edit) -> EditReport {
        let _span = crate::util::trace::stage("engine");
        let mut st = match self.stage_edit(edit) {
            Staged::Done(rep) => return rep,
            Staged::Pending(st) => st,
        };
        while !self.staged_done(&st) {
            self.staged_pre(&mut st);
            let li = st.layer;
            let mut outs: Vec<Vec<f32>> = Vec::with_capacity(st.pending.len());
            let mut cached: Vec<bool> = Vec::with_capacity(st.pending.len());
            for rw in &st.pending {
                outs.push(self.block_tail(li, &rw.x, rw.code));
                cached.push(self.tail_cached);
            }
            self.staged_post_owned(&mut st, outs, &cached);
        }
        self.finish_staged(st)
    }

    /// Apply a whole edit script.
    pub fn apply_edits(&mut self, edits: &[Edit]) -> EditReport {
        let snapshot = self.ledger.clone();
        let mut defragged = false;
        for &e in edits {
            defragged |= self.apply_edit(e).defragged;
        }
        EditReport {
            flops: self.ledger.since(&snapshot).total(),
            logits: self.logits.clone(),
            defragged,
        }
    }

    // ------------------------------------------------------------------
    // Staged edit application: the per-layer dense block tails are
    // extracted as row-work units an external executor computes — the
    // cross-session batcher pools them into stacked GEMMs. The unbatched
    // path (`apply_edit`) drives the same hooks with the single-row
    // executor, so orchestration cannot diverge between the two.
    // ------------------------------------------------------------------

    /// Begin a staged edit: applies the token/position/embedding part.
    /// `Done` means the edit was fully absorbed internally (a defrag
    /// rebuilds everything — nothing is left to batch).
    pub(crate) fn stage_edit(&mut self, edit: Edit) -> Staged {
        let snapshot = self.ledger.clone();
        self.stats.edits_applied += 1;

        let change0: ChangeSet = match edit {
            Edit::Replace { at, tok } => {
                assert!(at < self.tokens.len(), "replace out of bounds");
                self.tokens[at] = tok;
                let pos = self.positions.ids()[at];
                let emb = self.embed_row(tok, pos);
                ChangeSet::modified(at, emb)
            }
            Edit::Insert { at, tok } => {
                assert!(at <= self.tokens.len(), "insert out of bounds");
                assert!(self.tokens.len() < self.w.cfg.max_seq, "document full");
                match self.positions.insert(at) {
                    InsertOutcome::InGap(p) => {
                        self.tokens.insert(at, tok);
                        let emb = self.embed_row(tok, p);
                        ChangeSet::inserted(at, emb)
                    }
                    InsertOutcome::Defragged(_) => {
                        self.tokens.insert(at, tok);
                        self.stats.defrags += 1;
                        self.rebuild();
                        return Staged::Done(EditReport {
                            flops: self.ledger.since(&snapshot).total(),
                            logits: self.logits.clone(),
                            defragged: true,
                        });
                    }
                }
            }
            Edit::Delete { at } => {
                assert!(at < self.tokens.len(), "delete out of bounds");
                assert!(self.tokens.len() > 1, "cannot delete the last token");
                self.tokens.remove(at);
                self.positions.remove(at);
                ChangeSet::deleted(at)
            }
        };
        Staged::Pending(StagedEdit {
            snapshot,
            layer: 0,
            change: Some(change0),
            pending: Vec::new(),
            next: None,
        })
    }

    /// Whether every layer of a staged edit has been processed (ready for
    /// [`Self::finish_staged`]).
    pub(crate) fn staged_done(&self, st: &StagedEdit) -> bool {
        st.layer == self.w.cfg.n_layers
    }

    /// Run the non-batchable phases of layer `st.layer()` — structural and
    /// input updates, attention corrections, VQ re-assignment — and emit
    /// the layer's block-tail row work into `st.pending()`. The executor
    /// computes `block_tail(x, code)` for each unit (its numerics must be
    /// bit-identical to the single-row tail; see [`super::batch`]) and
    /// hands results back via [`Self::staged_post`].
    pub(crate) fn staged_pre(&mut self, st: &mut StagedEdit) {
        assert!(st.layer < self.w.cfg.n_layers, "edit already fully staged");
        assert!(
            st.pending.is_empty() && st.next.is_none(),
            "staged_post for layer {} not called",
            st.layer
        );
        let li = st.layer;
        let change = st.change.take().expect("staged change set present");
        let score_trick = self.opts.score_trick;
        let mut col_changes: Vec<ColChange> = Vec::new();

        // --- 1. structural + input updates ---------------------------------
        match change.structural {
            Some(Structural::Inserted(at)) => {
                let new_x = change
                    .rows
                    .iter()
                    .find(|(r, _)| *r == at)
                    .map(|(_, v)| v.clone())
                    .expect("inserted row must carry its input");
                let (q, k, v) = self.qkv_row(li, &new_x);
                let vc = self.project_value(li, &v);
                let vq_heads = self.w.cfg.vq_heads;
                let layer = &mut self.layers[li];
                layer.x.insert_row(at, &new_x);
                layer.q.insert_row(at, &q);
                layer.k.insert_row(at, &k);
                layer.v.insert_row(at, &v);
                if score_trick {
                    layer.vc.insert_row(at, &vc);
                }
                layer.acc.insert_zero_row(at);
                if let Some(a) = &mut layer.agg {
                    a.insert_zero_row(at);
                }
                layer.codes.insert(at, CodeTuple::new(&vec![0; vq_heads]));
                col_changes.push(ColChange::Added { j: at });
            }
            Some(Structural::Deleted(at)) => {
                let layer = &mut self.layers[li];
                layer.x.remove_row(at);
                layer.q.remove_row(at);
                let k_old = layer.k.remove_row(at);
                let v_old = layer.v.remove_row(at);
                let vc_old = if score_trick {
                    layer.vc.remove_row(at)
                } else {
                    Vec::new()
                };
                layer.acc.remove_row(at);
                if let Some(a) = &mut layer.agg {
                    a.remove_row(at);
                }
                layer.codes.remove(at);
                let val_old = if score_trick { vc_old } else { v_old };
                col_changes.push(ColChange::Removed { j: at, k_old, val_old });
            }
            None => {}
        }
        for (r, new_x) in &change.rows {
            let r = *r;
            if change.structural == Some(Structural::Inserted(r)) {
                continue; // handled above
            }
            let k_old = self.layers[li].k.copy_row(r);
            let val_old = if score_trick {
                self.layers[li].vc.copy_row(r)
            } else {
                self.layers[li].v.copy_row(r)
            };
            let (q, k, v) = self.qkv_row(li, new_x);
            let vc = self.project_value(li, &v);
            let layer = &mut self.layers[li];
            layer.x.row_mut(r).copy_from_slice(new_x);
            layer.q.row_mut(r).copy_from_slice(&q);
            layer.k.row_mut(r).copy_from_slice(&k);
            layer.v.row_mut(r).copy_from_slice(&v);
            if score_trick {
                layer.vc.row_mut(r).copy_from_slice(&vc);
            }
            col_changes.push(ColChange::Modified { j: r, k_old, val_old });
        }

        // --- 2. attention updates -------------------------------------------
        let n = self.layers[li].x.rows();
        let mut row_dirty = vec![false; n];
        for cc in &col_changes {
            match cc {
                ColChange::Modified { j, .. } | ColChange::Added { j } => row_dirty[*j] = true,
                ColChange::Removed { .. } => {}
            }
        }
        let mut acc_touched = vec![false; n];
        if self.is_softmax() {
            // Semi-naive path: normalize the column changes and let the
            // aggregate sweep pick delta vs full per clean row. The old
            // (k, val) rows move into the change records — they are the
            // retained terms the delta subtracts bit-identically.
            let changes: Vec<SmChange> = col_changes
                .into_iter()
                .map(|cc| match cc {
                    ColChange::Modified { j, k_old, val_old } => SmChange {
                        start: j,
                        old: Some((k_old, val_old)),
                        new_j: Some(j),
                    },
                    // The inserted row itself is dirty (full recompute);
                    // later rows add the new column's term.
                    ColChange::Added { j } => SmChange {
                        start: j,
                        old: None,
                        new_j: Some(j),
                    },
                    // Rows now at index ≥ j were at ≥ j+1 and saw column j.
                    ColChange::Removed { j, k_old, val_old } => SmChange {
                        start: j,
                        old: Some((k_old, val_old)),
                        new_j: None,
                    },
                })
                .collect();
            self.attn_sm_apply_changes(li, &changes, &row_dirty, Some(&mut acc_touched));
        } else {
            for cc in &col_changes {
                match cc {
                    ColChange::Modified { j, k_old, val_old } => {
                        self.correct_rows(
                            li,
                            *j..n,
                            &row_dirty,
                            Some((k_old, val_old)),
                            Some(*j),
                            Some(&mut acc_touched),
                        );
                    }
                    ColChange::Added { j } => {
                        self.correct_rows(
                            li,
                            (*j + 1)..n,
                            &row_dirty,
                            None,
                            Some(*j),
                            Some(&mut acc_touched),
                        );
                    }
                    ColChange::Removed { j, k_old, val_old } => {
                        // Rows now at index ≥ j were at ≥ j+1 and saw column j.
                        self.correct_rows(
                            li,
                            *j..n,
                            &row_dirty,
                            Some((k_old, val_old)),
                            None,
                            Some(&mut acc_touched),
                        );
                    }
                }
            }
        }
        for i in 0..n {
            if row_dirty[i] {
                let acc = self.attn_full_row(li, i);
                self.layers[li].acc.row_mut(i).copy_from_slice(&acc);
                acc_touched[i] = true;
            }
        }

        // --- 3. re-assignment; block tails become pending row work ---------
        let next = ChangeSet::carry_structural(&change);
        let mut pending = Vec::new();
        for i in 0..n {
            let input_changed = change.row_changed(i);
            if !acc_touched[i] && !input_changed {
                continue;
            }
            let acc = self.layers[li].acc.copy_row(i);
            let new_code = self.assign_code(li, &acc);
            let code_changed = new_code != self.layers[li].codes[i];
            if code_changed {
                self.stats.code_flips += 1;
                self.layers[li].codes[i] = new_code;
            }
            if input_changed || code_changed {
                let x = self.layers[li].x.copy_row(i);
                pending.push(RowWork {
                    row: i,
                    x,
                    code: new_code,
                });
            }
        }
        st.pending = pending;
        st.next = Some(next);
    }

    /// Scatter externally computed block-tail outputs back (one slice per
    /// [`StagedEdit::pending`] entry, same order), charge the ledger and
    /// stats exactly as the single-row path would, and advance to the
    /// next layer. `cached` carries one hit/miss flag per row (all-false
    /// for an uncached executor) so the ledger attribution matches the
    /// single-row path per row. The batched executor's outputs live in a
    /// stacked matrix, so this entry point copies; an executor that owns
    /// its row vectors should use [`Self::staged_post_owned`] and move
    /// them.
    pub(crate) fn staged_post(&mut self, st: &mut StagedEdit, outs: &[&[f32]], cached: &[bool]) {
        self.staged_post_owned(st, outs.iter().map(|o| o.to_vec()).collect(), cached);
    }

    /// [`Self::staged_post`] over owned row outputs — the single-row
    /// executor in [`Self::apply_edit`] moves each tail result straight
    /// into the next layer's change set, no per-row copy.
    pub(crate) fn staged_post_owned(
        &mut self,
        st: &mut StagedEdit,
        outs: Vec<Vec<f32>>,
        cached: &[bool],
    ) {
        assert_eq!(outs.len(), st.pending.len(), "one output per pending row");
        assert_eq!(cached.len(), outs.len(), "one cached flag per row");
        let mut next = st.next.take().expect("staged_pre first");
        for ((rw, out), &hit) in st.pending.drain(..).zip(outs).zip(cached) {
            assert_eq!(out.len(), self.w.cfg.d_model, "row {} output width", rw.row);
            self.charge_row_output(hit);
            next.rows.push((rw.row, out));
        }
        st.change = Some(next);
        st.layer += 1;
    }

    /// Complete a staged edit after every layer's tails have scattered:
    /// classifier maintenance, periodic self-verification, final report.
    pub(crate) fn finish_staged(&mut self, st: StagedEdit) -> EditReport {
        assert!(self.staged_done(&st), "layers remaining in staged edit");
        assert!(st.pending.is_empty(), "pending rows never scattered");
        let change = st.change.expect("staged change set present");
        self.apply_classifier(change);

        if self.opts.verify_every > 0
            && self.stats.edits_applied % self.opts.verify_every as u64 == 0
        {
            self.stats.verifications += 1;
            let rep = self.verify();
            if !rep.is_exact(1e-3) {
                log::warn!(
                    "incremental drift (max logit diff {:.2e}, {} code mismatches) — rebuilding",
                    rep.max_logit_diff,
                    rep.code_mismatches
                );
                self.rebuild();
            }
        }

        EditReport {
            flops: self.ledger.since(&st.snapshot).total(),
            logits: self.logits.clone(),
            defragged: false,
        }
    }

    // ------------------------------------------------------------------
    // Classifier maintenance
    // ------------------------------------------------------------------

    fn apply_classifier(&mut self, change: ChangeSet) {
        let d = self.w.cfg.d_model;
        match change.structural {
            Some(Structural::Inserted(at)) => {
                self.final_hidden.insert_row(at, &vec![0.0; d]);
            }
            Some(Structural::Deleted(at)) => {
                let old = self.final_hidden.remove_row(at);
                tensor::axpy(-1.0, &old, &mut self.pooled_sum);
                self.ledger.add(Cat::Elementwise, d as u64);
            }
            None => {}
        }
        for (r, new_x) in &change.rows {
            let h = self.final_row(new_x);
            let old = self.final_hidden.copy_row(*r);
            for ((s, &o), &nv) in self.pooled_sum.iter_mut().zip(&old).zip(&h) {
                *s += nv - o;
            }
            self.ledger.add(Cat::Elementwise, 2 * d as u64);
            self.final_hidden.row_mut(*r).copy_from_slice(&h);
        }
        self.recompute_logits();
    }

    // ------------------------------------------------------------------
    // Verification
    // ------------------------------------------------------------------

    /// Compare against a from-scratch dense recompute (the exactness
    /// claim, modulo f32 accumulation order).
    pub fn verify(&self) -> VerifyReport {
        let mut led = FlopLedger::new();
        let dense = dense_forward(&self.w, &self.tokens, self.positions.ids(), &mut led);
        let mut max_logit = 0f32;
        for (a, b) in self.logits.iter().zip(&dense.logits) {
            max_logit = max_logit.max((a - b).abs());
        }
        let (mut mism, mut total) = (0, 0);
        for li in 0..self.w.cfg.n_layers {
            for (a, b) in self.layers[li].codes.iter().zip(&dense.codes[li]) {
                total += 1;
                if a != b {
                    mism += 1;
                }
            }
        }
        let mut max_hidden = 0f32;
        for i in 0..self.tokens.len() {
            for (a, b) in self.final_hidden.row(i).iter().zip(dense.hidden.row(i)) {
                max_hidden = max_hidden.max((a - b).abs());
            }
        }
        VerifyReport {
            max_logit_diff: max_logit,
            max_hidden_diff: max_hidden,
            code_mismatches: mism,
            total_codes: total,
        }
    }
}


/// Per-head σ(q·k·s) coefficients — hot-path variant with a fixed-size
/// output buffer and no ledger (callers account in bulk).
#[inline]
fn head_coeffs_raw(q: &[f32], k: &[f32], nh: usize, dh: usize, scale: f32, out: &mut [f32; 16]) {
    for h in 0..nh {
        let s = tensor::dot(&q[h * dh..(h + 1) * dh], &k[h * dh..(h + 1) * dh]) * scale;
        out[h] = tensor::gelu_scalar(s);
    }
}

/// `acc ±= Σ_h coeffs[h] · val_h` — score space (trick: per-head codebook
/// projections landing in their VQ chunk segment) or value space.
#[inline]
fn apply_term_raw(
    acc: &mut [f32],
    coeffs: &[f32],
    val: &[f32],
    sign: f32,
    trick: bool,
    vq_heads: usize,
    codes: usize,
    dh: usize,
) {
    let nh = coeffs.len();
    if trick {
        for (h, &c) in coeffs.iter().enumerate() {
            if c == 0.0 {
                continue;
            }
            let g = h * vq_heads / nh;
            let seg = &val[h * codes..(h + 1) * codes];
            let dst = &mut acc[g * codes..(g + 1) * codes];
            tensor::axpy(sign * c, seg, dst);
        }
    } else {
        for (h, &c) in coeffs.iter().enumerate() {
            if c == 0.0 {
                continue;
            }
            let seg = &val[h * dh..(h + 1) * dh];
            let dst = &mut acc[h * dh..(h + 1) * dh];
            tensor::axpy(sign * c, seg, dst);
        }
    }
}

/// One externally-executable unit of dense block-tail work emitted by
/// [`IncrementalEngine::staged_pre`]: row `row`'s block input and its
/// freshly re-assigned VQ code. The executor computes the block tail for
/// the unit — by any means bit-identical to the single-row tail — and
/// returns the result through [`IncrementalEngine::staged_post`].
pub(crate) struct RowWork {
    /// Row index within the engine's (current) layout.
    pub row: usize,
    /// Residual-stream input to the block for this row.
    pub x: Vec<f32>,
    /// VQ code to decode-and-mix.
    pub code: CodeTuple,
}

/// An in-flight staged edit: per-layer progress plus the pending
/// block-tail work between a `staged_pre` and its `staged_post`.
pub(crate) struct StagedEdit {
    snapshot: FlopLedger,
    /// Next layer to process (`== n_layers` ⇒ ready for finish).
    layer: usize,
    /// Change set feeding `layer`'s pre phase.
    change: Option<ChangeSet>,
    /// Block-tail work emitted by the last `staged_pre`, awaiting results.
    pending: Vec<RowWork>,
    /// Next layer's change set under construction (post fills the rows).
    next: Option<ChangeSet>,
}

impl StagedEdit {
    /// Layer the edit is currently staged at.
    pub fn layer(&self) -> usize {
        self.layer
    }

    /// Pending block-tail work for the current layer (valid between
    /// `staged_pre` and `staged_post`).
    pub(crate) fn pending(&self) -> &[RowWork] {
        &self.pending
    }
}

/// Outcome of [`IncrementalEngine::stage_edit`].
pub(crate) enum Staged {
    /// The edit was fully applied internally (defragmentation rebuilds
    /// everything; there is nothing left to batch).
    Done(EditReport),
    /// Per-layer block tails pending: drive with `staged_pre` /
    /// `staged_post`, then `finish_staged`.
    Pending(StagedEdit),
}

/// Rows whose input hidden vector changed this layer (with new values),
/// plus at most one structural op per edit.
struct ChangeSet {
    rows: Vec<(usize, Vec<f32>)>,
    structural: Option<Structural>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Structural {
    Inserted(usize),
    Deleted(usize),
}

impl ChangeSet {
    fn modified(at: usize, x: Vec<f32>) -> ChangeSet {
        ChangeSet {
            rows: vec![(at, x)],
            structural: None,
        }
    }

    fn inserted(at: usize, x: Vec<f32>) -> ChangeSet {
        ChangeSet {
            rows: vec![(at, x)],
            structural: Some(Structural::Inserted(at)),
        }
    }

    fn deleted(at: usize) -> ChangeSet {
        ChangeSet {
            rows: vec![],
            structural: Some(Structural::Deleted(at)),
        }
    }

    fn carry_structural(prev: &ChangeSet) -> ChangeSet {
        ChangeSet {
            rows: vec![],
            structural: prev.structural,
        }
    }

    fn row_changed(&self, i: usize) -> bool {
        self.rows.iter().any(|(r, _)| *r == i)
    }
}

impl IncrementalEngine {
    /// Current VQ codes of layer `li` (one per row) — used by the batch
    /// coordinator's §3.1 storage measurement and by state-parity tests.
    pub fn layer_codes(&self, li: usize) -> &[CodeTuple] {
        &self.layers[li].codes
    }

    /// Bytes of per-session reuse state held in RAM: every per-layer row
    /// store, the VQ code vectors, the classifier caches, and the
    /// token/position bookkeeping. This is what the coordinator's
    /// memory-budget accountant charges a resident session for (weights are
    /// shared across sessions and excluded; allocator overhead and scratch
    /// buffers are not, so the figure is a tight lower bound).
    pub fn resident_bytes(&self) -> usize {
        let mut b = 0usize;
        for l in &self.layers {
            b += l.x.bytes() + l.q.bytes() + l.k.bytes() + l.v.bytes();
            b += l.vc.bytes() + l.acc.bytes();
            if let Some(a) = &l.agg {
                b += a.bytes();
            }
            b += l.codes.len() * std::mem::size_of::<CodeTuple>();
        }
        b += self.final_hidden.bytes();
        b += (self.pooled_sum.len() + self.logits.len()) * std::mem::size_of::<f32>();
        b += self.tokens.len() * std::mem::size_of::<u32>();
        b += self.positions.ids().len() * std::mem::size_of::<u32>();
        b
    }
}

// ---------------------------------------------------------------------------
// Batched revision application (the paper's OFFLINE path, §3.1/§3.2):
// all of a revision's changes propagate in ONE pass per layer, so each
// clean row receives all column corrections together and re-assigns its
// VQ code once — instead of once per edit.
// ---------------------------------------------------------------------------

/// Structural description of a whole revision against the current state.
struct BatchPlan {
    /// final row index → original row index (None = inserted row).
    final_ids: Vec<Option<usize>>,
    /// original rows that were deleted (sorted).
    deleted: Vec<usize>,
    /// original rows whose token changed (sorted, survivors only).
    modified: Vec<usize>,
}

impl IncrementalEngine {
    /// Apply a whole-revision edit script in one batched propagation pass.
    /// Exact (same result as sequential `apply_edit`s) but with offline
    /// batch cost: corrections are aggregated per clean row and each row
    /// re-quantizes once.
    pub fn apply_revision(&mut self, edits: &[Edit]) -> EditReport {
        if edits.is_empty() {
            return EditReport {
                flops: 0,
                logits: self.logits.clone(),
                defragged: false,
            };
        }
        if edits.len() == 1 {
            return self.apply_edit(edits[0]);
        }
        // After the single-edit delegation: `apply_edit` opens its own
        // "engine" span, and nesting two same-name spans would double-count
        // busy time.
        let _span = crate::util::trace::stage("engine");
        let snapshot = self.ledger.clone();
        self.stats.edits_applied += edits.len() as u64;

        // ---- plan: simulate the script over (tokens, positions, ids) ----
        let n0 = self.tokens.len();
        let mut ids: Vec<Option<usize>> = (0..n0).map(Some).collect();
        let mut modified = std::collections::BTreeSet::new();
        let mut deleted = std::collections::BTreeSet::new();
        let mut defragged = false;
        for &e in edits {
            match e {
                Edit::Replace { at, tok } => {
                    assert!(at < self.tokens.len(), "replace out of bounds");
                    self.tokens[at] = tok;
                    if let Some(orig) = ids[at] {
                        modified.insert(orig);
                    }
                }
                Edit::Insert { at, tok } => {
                    assert!(at <= self.tokens.len(), "insert out of bounds");
                    // Scripts may exceed max_seq *transiently* (LCS order
                    // interleaves inserts/deletes by position); only the
                    // final length is bounded — checked after the loop.
                    assert!(
                        self.tokens.len() < self.w.cfg.pos_pool,
                        "position pool exhausted"
                    );
                    match self.positions.insert(at) {
                        InsertOutcome::InGap(_) => {
                            self.tokens.insert(at, tok);
                            ids.insert(at, None);
                        }
                        InsertOutcome::Defragged(_) => {
                            // Positions all moved: finish token edits, then
                            // rebuild from scratch.
                            self.tokens.insert(at, tok);
                            ids.insert(at, None);
                            self.stats.defrags += 1;
                            defragged = true;
                        }
                    }
                }
                Edit::Delete { at } => {
                    assert!(at < self.tokens.len(), "delete out of bounds");
                    assert!(self.tokens.len() > 1, "cannot delete the last token");
                    self.tokens.remove(at);
                    self.positions.remove(at);
                    if let Some(orig) = ids.remove(at) {
                        deleted.insert(orig);
                        modified.remove(&orig);
                    }
                }
            }
        }
        assert!(
            self.tokens.len() <= self.w.cfg.max_seq,
            "revision leaves document over max_seq"
        );
        if defragged {
            // Any remaining structural edits were already applied to
            // tokens/positions above (the loop continued); rebuild now.
            self.rebuild();
            return EditReport {
                flops: self.ledger.since(&snapshot).total(),
                logits: self.logits.clone(),
                defragged: true,
            };
        }
        let plan = BatchPlan {
            final_ids: ids,
            deleted: deleted.into_iter().collect(),
            modified: modified.into_iter().collect(),
        };

        // ---- layer-0 inputs for new/modified rows ----
        let pos = self.positions.ids().to_vec();
        let mut rows: Vec<(usize, Vec<f32>)> = Vec::new();
        for (f, orig) in plan.final_ids.iter().enumerate() {
            let recompute = match orig {
                None => true,
                Some(o) => plan.modified.binary_search(o).is_ok(),
            };
            if recompute {
                let emb = self.embed_row(self.tokens[f], pos[f]);
                rows.push((f, emb));
            }
        }

        // ---- propagate through layers ----
        for li in 0..self.w.cfg.n_layers {
            rows = self.apply_layer_batch(li, &plan, rows, li == 0);
        }
        self.apply_classifier_batch(&plan, rows);

        EditReport {
            flops: self.ledger.since(&snapshot).total(),
            logits: self.logits.clone(),
            defragged: false,
        }
    }

    /// One layer of the batched pass. `rows` carries the new block inputs
    /// (final-layout indices). `restructure` layers 0..L all need the same
    /// structural reindex exactly once — we do it per layer (each layer's
    /// stores are in original layout until its turn).
    fn apply_layer_batch(
        &mut self,
        li: usize,
        plan: &BatchPlan,
        rows: Vec<(usize, Vec<f32>)>,
        _first: bool,
    ) -> Vec<(usize, Vec<f32>)> {
        let score_trick = self.opts.score_trick;
        let nf = plan.final_ids.len();

        // 1. Capture old (k, val) of deleted and modified original rows.
        let mut removed_cols: Vec<(usize, Vec<f32>, Vec<f32>)> = Vec::new(); // (orig, k, val)
        for &o in &plan.deleted {
            let k_old = self.layers[li].k.copy_row(o);
            let val_old = if score_trick {
                self.layers[li].vc.copy_row(o)
            } else {
                self.layers[li].v.copy_row(o)
            };
            removed_cols.push((o, k_old, val_old));
        }
        let mut modified_cols: std::collections::HashMap<usize, (Vec<f32>, Vec<f32>)> =
            std::collections::HashMap::new(); // orig -> old (k, val)
        // Rows whose input changed include both plan.modified (token-level)
        // and code-flip propagation from the previous layer; capture the
        // old k/val for every SURVIVING row in `rows`.
        let orig_of: Vec<Option<usize>> = plan.final_ids.clone();
        for (f, _) in &rows {
            if let Some(o) = orig_of[*f] {
                let k_old = self.layers[li].k.copy_row(o);
                let val_old = if score_trick {
                    self.layers[li].vc.copy_row(o)
                } else {
                    self.layers[li].v.copy_row(o)
                };
                modified_cols.insert(o, (k_old, val_old));
            }
        }

        // 2. Restructure every store into the final layout.
        {
            let layer = &mut self.layers[li];
            layer.x.reindex(&plan.final_ids);
            layer.q.reindex(&plan.final_ids);
            layer.k.reindex(&plan.final_ids);
            layer.v.reindex(&plan.final_ids);
            if score_trick {
                layer.vc.reindex(&plan.final_ids);
            }
            layer.acc.reindex(&plan.final_ids);
            if let Some(a) = &mut layer.agg {
                a.reindex(&plan.final_ids);
            }
            let old_codes = std::mem::take(&mut layer.codes);
            let vq_heads = self.w.cfg.vq_heads;
            layer.codes = plan
                .final_ids
                .iter()
                .map(|o| match o {
                    Some(o) => old_codes[*o],
                    None => CodeTuple::new(&vec![0; vq_heads]),
                })
                .collect();
            // `agg_cols` is 0 for element-wise engines, keeping their
            // ledger series (and golden traces) byte-identical.
            let agg_cols = layer
                .agg
                .as_ref()
                .map_or(0, |a| a.num.cols + a.den.cols + a.m.cols);
            self.ledger.add(
                Cat::Bookkeeping,
                (nf * (4 * self.w.cfg.d_model + layer.acc.cols + agg_cols)) as u64,
            );
        }

        // 3. Update projections for changed rows (new x values).
        let mut row_dirty = vec![false; nf];
        for (f, new_x) in &rows {
            let (q, k, v) = self.qkv_row(li, new_x);
            let vc = self.project_value(li, &v);
            let layer = &mut self.layers[li];
            layer.x.row_mut(*f).copy_from_slice(new_x);
            layer.q.row_mut(*f).copy_from_slice(&q);
            layer.k.row_mut(*f).copy_from_slice(&k);
            layer.v.row_mut(*f).copy_from_slice(&v);
            if score_trick {
                layer.vc.row_mut(*f).copy_from_slice(&vc);
            }
            row_dirty[*f] = true;
        }

        // 4. Aggregate corrections per clean row.
        //    boundary(c) for a removed/modified ORIGINAL column c: first
        //    final row whose orig > c (survivor order is preserved).
        let orig_positions: Vec<(usize, usize)> = orig_of
            .iter()
            .enumerate()
            .filter_map(|(f, o)| o.map(|o| (o, f)))
            .collect(); // sorted by o (and by f)
        let boundary = |c: usize| -> usize {
            match orig_positions.binary_search_by_key(&(c + 1), |&(o, _)| o) {
                Ok(i) => orig_positions[i].1,
                Err(i) if i < orig_positions.len() => orig_positions[i].1,
                _ => nf,
            }
        };
        if self.is_softmax() {
            // Semi-naive path: pool the whole revision's column changes
            // into one aggregate sweep, so each clean row decides delta vs
            // full ONCE for the pooled wave (same decision rule as the
            // staged single-edit path).
            let mut changes: Vec<SmChange> = Vec::new();
            for (c, k_old, val_old) in &removed_cols {
                changes.push(SmChange {
                    start: boundary(*c),
                    old: Some((k_old.clone(), val_old.clone())),
                    new_j: None,
                });
            }
            for (f_col, _) in &rows {
                let old = orig_of[*f_col].and_then(|o| modified_cols.remove(&o));
                // `f_col` itself is dirty, so `start` at the column is safe
                // and later rows pick up both sides.
                changes.push(SmChange {
                    start: *f_col,
                    old,
                    new_j: Some(*f_col),
                });
            }
            self.attn_sm_apply_changes(li, &changes, &row_dirty, None);
        } else {
            // Removed columns.
            for (c, k_old, val_old) in &removed_cols {
                self.correct_rows(li, boundary(*c)..nf, &row_dirty, Some((k_old, val_old)), None, None);
            }
            // Modified columns (changed k/v at surviving rows) and Added
            // columns (inserted rows' k/v): every clean row after the column
            // is a survivor (inserted rows are all dirty), so one sweep each.
            for (f_col, _) in &rows {
                let old = orig_of[*f_col].map(|o| &modified_cols[&o]);
                match old {
                    Some((k_old, val_old)) => {
                        self.correct_rows(
                            li,
                            (*f_col + 1)..nf,
                            &row_dirty,
                            Some((k_old, val_old)),
                            Some(*f_col),
                            None,
                        );
                    }
                    None => {
                        self.correct_rows(li, (*f_col + 1)..nf, &row_dirty, None, Some(*f_col), None);
                    }
                }
            }
        }
        // Dirty rows: full recompute in the final layout.
        for f in 0..nf {
            if row_dirty[f] {
                let acc = self.attn_full_row(li, f);
                self.layers[li].acc.row_mut(f).copy_from_slice(&acc);
            }
        }

        // 5. Re-assign every touched row ONCE; emit next layer's changes.
        //    Touched = dirty rows + every clean row at/after the earliest
        //    column change (their accumulators may have moved).
        let first_change = rows
            .iter()
            .map(|(f, _)| *f)
            .chain(removed_cols.iter().map(|(c, _, _)| boundary(*c)))
            .min()
            .unwrap_or(nf);
        let mut next = Vec::new();
        for f in 0..nf {
            let input_changed = row_dirty[f];
            if f < first_change && !input_changed {
                continue;
            }
            let acc = self.layers[li].acc.copy_row(f);
            let new_code = self.assign_code(li, &acc);
            let code_changed = new_code != self.layers[li].codes[f];
            if code_changed {
                self.stats.code_flips += 1;
                self.layers[li].codes[f] = new_code;
            }
            if input_changed || code_changed {
                let x = self.layers[li].x.copy_row(f);
                let out = self.row_output(li, &x, new_code);
                next.push((f, out));
            }
        }
        next
    }

    /// Classifier maintenance for the batched pass.
    fn apply_classifier_batch(&mut self, plan: &BatchPlan, rows: Vec<(usize, Vec<f32>)>) {
        let d = self.w.cfg.d_model;
        // Subtract deleted rows' contributions, restructure, then update
        // changed rows.
        for &o in &plan.deleted {
            let old = self.final_hidden.copy_row(o);
            tensor::axpy(-1.0, &old, &mut self.pooled_sum);
        }
        self.ledger
            .add(Cat::Elementwise, (plan.deleted.len() * d) as u64);
        self.final_hidden.reindex(&plan.final_ids);
        for (f, new_x) in &rows {
            let h = self.final_row(new_x);
            let old = self.final_hidden.copy_row(*f);
            for ((s, &o), &nv) in self.pooled_sum.iter_mut().zip(&old).zip(&h) {
                *s += nv - o;
            }
            self.ledger.add(Cat::Elementwise, 2 * d as u64);
            self.final_hidden.row_mut(*f).copy_from_slice(&h);
        }
        self.recompute_logits();
    }
}

// ---------------------------------------------------------------------------
// Serving extensions: next-token suggestions (the writing-assistant payload)
// and session persistence (checkpoint/restore without recompute).
// ---------------------------------------------------------------------------

impl IncrementalEngine {
    /// Next-token suggestions from the last row's hidden state with tied
    /// embeddings (OPT-style LM head: `h_last · E_tokensᵀ`). Returns the
    /// top-k (token, score) pairs. Cost is `vocab·d` muladds — independent
    /// of document length, so suggestions stay cheap after every edit.
    ///
    /// An empty document has no last row to score from, so it yields an
    /// empty suggestion list rather than panicking the caller's thread.
    pub fn suggest_topk(&mut self, k: usize) -> Vec<(u32, f32)> {
        if self.is_empty() {
            return Vec::new();
        }
        let w = Arc::clone(&self.w);
        let cfg = &w.cfg;
        let h = self.final_hidden.copy_row(self.len() - 1);
        let mut scored: Vec<(u32, f32)> = (0..cfg.vocab_size)
            .map(|t| (t as u32, tensor::dot(&h, w.embed_tokens.row(t))))
            .collect();
        self.ledger.add(
            Cat::Linear,
            MULADD * (cfg.vocab_size * cfg.d_model) as u64,
        );
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        scored.truncate(k);
        scored
    }

    /// Serialize the FULL session state (document, positions, per-layer
    /// caches) so a restart restores the session without a forward pass.
    pub fn to_tensor_file(&self) -> crate::util::TensorFile {
        use crate::util::Tensor;
        let mut tf = crate::util::TensorFile::new();
        let n = self.len();
        tf.insert(
            "tokens",
            Tensor::i32(vec![n], self.tokens.iter().map(|&t| t as i32).collect()),
        );
        tf.insert(
            "pos_ids",
            Tensor::i32(
                vec![n],
                self.positions.ids().iter().map(|&p| p as i32).collect(),
            ),
        );
        tf.insert(
            "meta",
            Tensor::i32(
                vec![4],
                vec![
                    self.w.cfg.n_layers as i32,
                    self.opts.score_trick as i32,
                    self.positions.defrag_count as i32,
                    self.opts.verify_every as i32,
                ],
            ),
        );
        let put = |tf: &mut crate::util::TensorFile, name: String, rs: &RowStore| {
            let mut data = Vec::with_capacity(rs.rows() * rs.cols);
            for i in 0..rs.rows() {
                data.extend_from_slice(rs.row(i));
            }
            tf.insert(name, Tensor::f32(vec![rs.rows(), rs.cols], data));
        };
        for (li, l) in self.layers.iter().enumerate() {
            let p = |s: &str| format!("layer.{li}.{s}");
            put(&mut tf, p("x"), &l.x);
            put(&mut tf, p("q"), &l.q);
            put(&mut tf, p("k"), &l.k);
            put(&mut tf, p("v"), &l.v);
            if self.opts.score_trick {
                put(&mut tf, p("vc"), &l.vc);
            }
            put(&mut tf, p("acc"), &l.acc);
            // Softmax engines persist the streaming-softmax aggregates so
            // a restored session can keep delta-updating without a full
            // refresh. Element-wise checkpoints stay byte-identical to the
            // pre-aggregate format (no tensors added, no version bump).
            if let Some(a) = &l.agg {
                put(&mut tf, p("sm_num"), &a.num);
                put(&mut tf, p("sm_den"), &a.den);
                put(&mut tf, p("sm_m"), &a.m);
                tf.insert(
                    p("sm_drift"),
                    Tensor::i32(vec![n], a.drift.iter().map(|&x| x as i32).collect()),
                );
            }
            let mut codes = Vec::with_capacity(n * self.w.cfg.vq_heads);
            for c in &l.codes {
                codes.extend(c.as_slice().iter().map(|&x| x as i32));
            }
            tf.insert(
                p("codes"),
                Tensor::i32(vec![n, self.w.cfg.vq_heads], codes),
            );
        }
        put(&mut tf, "final_hidden".into(), &self.final_hidden);
        tf.insert(
            "pooled_sum",
            Tensor::f32(vec![self.pooled_sum.len()], self.pooled_sum.clone()),
        );
        tf.insert("logits", Tensor::f32(vec![self.logits.len()], self.logits.clone()));
        tf
    }

    /// Restore a session saved by [`Self::to_tensor_file`]. The weights
    /// must be the same model the checkpoint was taken from.
    pub fn from_tensor_file(
        w: Arc<ModelWeights>,
        tf: &crate::util::TensorFile,
        opts: EngineOptions,
    ) -> anyhow::Result<IncrementalEngine> {
        // Same normalization as `try_new`: softmax engines run in value
        // space, and checkpoints recorded the normalized mode.
        let mut opts = opts;
        if w.cfg.attention == AttentionKind::Softmax {
            opts.score_trick = false;
        }
        let (_, toks) = tf.get("tokens")?.as_i32()?;
        let (_, pos) = tf.get("pos_ids")?.as_i32()?;
        let (_, meta) = tf.get("meta")?.as_i32()?;
        anyhow::ensure!(
            meta[0] as usize == w.cfg.n_layers,
            "checkpoint has {} layers, model has {}",
            meta[0],
            w.cfg.n_layers
        );
        anyhow::ensure!(
            (meta[1] != 0) == opts.score_trick,
            "checkpoint score-trick mode mismatch"
        );
        // Same construction-time validation as `try_new`: restoring onto
        // malformed weights must be a typed error, not a later panic.
        w.validate_vq()?;
        let tokens: Vec<u32> = toks.iter().map(|&t| t as u32).collect();
        let n = tokens.len();
        // Rebuild through `new` would recompute; instead construct shell
        // state and fill from the file.
        let mut eng = IncrementalEngine::new_shell(w.clone(), &tokens, opts);
        eng.positions = PositionAllocator::restore(
            w.cfg.pos_pool,
            pos.iter().map(|&p| p as u32).collect(),
            meta[2] as u64,
        )?;
        let get = |name: String, want_cols: usize| -> anyhow::Result<RowStore> {
            let (dims, data) = tf.get(&name)?.as_f32()?;
            anyhow::ensure!(
                dims.len() == 2 && dims[0] == n && dims[1] == want_cols,
                "{name}: dims {dims:?} != [{n}, {want_cols}]"
            );
            let mut rs = RowStore::new(want_cols);
            for i in 0..n {
                rs.push_row(&data[i * want_cols..(i + 1) * want_cols]);
            }
            Ok(rs)
        };
        let d = w.cfg.d_model;
        let hq = w.cfg.vq_heads * w.cfg.vq_codes;
        let (vc_w, acc_w) = if opts.score_trick {
            (w.cfg.n_heads * w.cfg.vq_codes, hq)
        } else {
            (0, d)
        };
        for li in 0..w.cfg.n_layers {
            let p = |s: &str| format!("layer.{li}.{s}");
            eng.layers[li].x = get(p("x"), d)?;
            eng.layers[li].q = get(p("q"), d)?;
            eng.layers[li].k = get(p("k"), d)?;
            eng.layers[li].v = get(p("v"), d)?;
            if opts.score_trick {
                eng.layers[li].vc = get(p("vc"), vc_w)?;
            }
            eng.layers[li].acc = get(p("acc"), acc_w)?;
            if w.cfg.attention == AttentionKind::Softmax {
                let num = get(p("sm_num"), d)?;
                let den = get(p("sm_den"), w.cfg.n_heads)?;
                let m = get(p("sm_m"), w.cfg.n_heads)?;
                let (dims, drift) = tf.get(&p("sm_drift"))?.as_i32()?;
                anyhow::ensure!(dims == [n], "sm_drift dims");
                let agg = eng.layers[li]
                    .agg
                    .as_mut()
                    .expect("softmax shell carries aggregates");
                agg.num = num;
                agg.den = den;
                agg.m = m;
                agg.drift = drift.iter().map(|&x| x as u32).collect();
            }
            let (dims, codes) = tf.get(&p("codes"))?.as_i32()?;
            anyhow::ensure!(dims == [n, w.cfg.vq_heads], "codes dims");
            eng.layers[li].codes = (0..n)
                .map(|i| {
                    let cs: Vec<crate::vq::Code> = codes
                        [i * w.cfg.vq_heads..(i + 1) * w.cfg.vq_heads]
                        .iter()
                        .map(|&c| c as crate::vq::Code)
                        .collect();
                    CodeTuple::new(&cs)
                })
                .collect();
        }
        eng.final_hidden = get("final_hidden".into(), d)?;
        let (_, pooled) = tf.get("pooled_sum")?.as_f32()?;
        eng.pooled_sum = pooled.to_vec();
        let (_, logits) = tf.get("logits")?.as_f32()?;
        eng.logits = logits.to_vec();
        eng.ledger = FlopLedger::new();
        eng.stats = EngineStats::default();
        Ok(eng)
    }

    /// Construct an engine with empty layer state (no forward pass) —
    /// internal helper for checkpoint restore.
    fn new_shell(w: Arc<ModelWeights>, tokens: &[u32], mut opts: EngineOptions) -> IncrementalEngine {
        let cfg = &w.cfg;
        if cfg.attention == AttentionKind::Softmax {
            opts.score_trick = false;
        }
        let d = cfg.d_model;
        let hq = cfg.vq_heads * cfg.vq_codes;
        let (vc_w, acc_w) = if opts.score_trick {
            (cfg.n_heads * cfg.vq_codes, hq)
        } else {
            (0, d)
        };
        let layers = (0..cfg.n_layers)
            .map(|_| LayerState {
                x: RowStore::new(d),
                q: RowStore::new(d),
                k: RowStore::new(d),
                v: RowStore::new(d),
                vc: RowStore::new(vc_w),
                acc: RowStore::new(acc_w),
                codes: Vec::new(),
                agg: (cfg.attention == AttentionKind::Softmax)
                    .then(|| AttnAggregates::new(d, cfg.n_heads)),
            })
            .collect();
        IncrementalEngine {
            positions: PositionAllocator::spread(w.cfg.pos_pool, tokens.len()),
            w,
            opts,
            tokens: tokens.to_vec(),
            layers,
            final_hidden: RowStore::new(d),
            pooled_sum: vec![0.0; d],
            logits: vec![],
            scratch: Scratch::default(),
            cache: None,
            tail_cached: false,
            ledger: FlopLedger::new(),
            stats: EngineStats::default(),
        }
    }
}
