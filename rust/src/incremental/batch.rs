//! Cross-session batched dense execution — the serving-throughput lever.
//!
//! Under load, a coordinator shard holds queued edit requests from many
//! sessions. Each edit's per-layer dense block tails (decode → mix →
//! residual → LN2 → FFN → residual; see [`super::engine`]) are row ×
//! matrix products over the SAME shared weights, so executing them one
//! session at a time traverses every weight matrix once per session. This
//! module pools the pending block-tail rows of all queued sessions, layer
//! by layer, into stacked GEMMs: the weight traversal is amortized over
//! the pooled rows (the classic dynamic-batching lever), while each
//! session's orchestration — corrections, code re-assignment, FLOP-ledger
//! attribution — stays per-engine through the staged hooks.
//!
//! **Bit-exactness argument** (docs/ARCHITECTURE.md §7): the tiled GEMM
//! core (`tensor::ops::accum_row_tiled_scalar` and its bit-identical
//! SIMD mirrors) processes each output row
//! independently with a fixed accumulation order, so a stacked
//! `matmul_into` over gathered rows is bitwise identical to the per-row
//! `vec_matmul_into` calls it replaces; every element-wise stage
//! (residual adds, LN2, fused bias-GELU) is shared scalar code applied
//! row-wise. Locked by `pooled_block_tail_bitwise_matches_single_row`
//! below and by `tests/differential_batch.rs`.
//!
//! **Attention-kind agnosticism**: this module only pools the dense block
//! *tails*, which are identical across attention kinds. The attention
//! stage itself — including the softmax semi-naive delta-vs-full decision
//! (docs/ARCHITECTURE.md §12) — runs inside each engine's staged hooks
//! (`staged_pre`/`staged_post`), so pooled waves inherit exactly the
//! per-row recompute choices an unpooled `apply_edits` would have made,
//! and the `attn_*` counters attribute identically either way.

use crate::edits::Edit;
use crate::model::ModelWeights;
use crate::tensor::{self, Matrix};
use crate::vq::CodeTuple;
use std::collections::HashMap;
use std::sync::Arc;

use super::codecache::{CacheHandle, TailOutcome};
use super::engine::{EditReport, IncrementalEngine, Staged, StagedEdit};

/// Result of one batched multi-session application.
pub struct BatchOutcome {
    /// One aggregate report per engine, with `apply_edits` semantics:
    /// summed flops, last logits, defragged-anywhere.
    pub reports: Vec<EditReport>,
    /// Total rows executed through pooled block-tail GEMMs.
    pub batched_rows: u64,
    /// Rows per pooled GEMM issued — the batch-occupancy series the
    /// coordinator folds into its `batch_fill` histogram.
    pub gemm_fills: Vec<usize>,
}

/// Reusable intermediate buffers for [`block_tail_batch`]. The single-row
/// tail runs on the engine's persistent scratch; the pooled path must not
/// trade that for five heap allocations per chunk per layer. Reuse cannot
/// move numerics: every buffer is fully overwritten each call
/// (`matmul_into` zeroes its output, `decode_into` covers every element,
/// the residual/LN loops write every row).
struct TailScratch {
    a: Matrix,
    mix: Matrix,
    c: Matrix,
    mid: Matrix,
}

impl TailScratch {
    fn new() -> Self {
        TailScratch {
            a: Matrix::zeros(0, 0),
            mix: Matrix::zeros(0, 0),
            c: Matrix::zeros(0, 0),
            mid: Matrix::zeros(0, 0),
        }
    }

    /// (Re)allocate only when the chunk shape actually changes — under a
    /// steady `max_batch_rows` cap that is once per wave at most.
    fn shape(&mut self, b: usize, d: usize, d_ff: usize) {
        if self.a.rows != b || self.a.cols != d || self.mid.cols != d_ff {
            self.a = Matrix::zeros(b, d);
            self.mix = Matrix::zeros(b, d);
            self.c = Matrix::zeros(b, d);
            self.mid = Matrix::zeros(b, d_ff);
        }
    }
}

/// Stacked block tail over pooled rows of layer `li`: bitwise identical
/// to `IncrementalEngine::block_tail` applied to each row independently
/// (same kernels, same per-row accumulation order), but each weight
/// matrix is streamed once for the whole stack. Returns the fresh output
/// stack (it outlives the chunk loop for the scatter); intermediates live
/// in `scratch`.
fn block_tail_batch(
    w: &ModelWeights,
    li: usize,
    xs: &[f32],
    b: usize,
    codes: &[CodeTuple],
    scratch: &mut TailScratch,
) -> Matrix {
    let layer = &w.layers[li];
    let cfg = &w.cfg;
    let d = cfg.d_model;
    assert_eq!(xs.len(), b * d);
    assert_eq!(codes.len(), b);
    let vq = layer.vq.as_ref().expect("VQ layer");
    scratch.shape(b, d, cfg.d_ff);
    {
        let TailScratch { a, mix, .. } = scratch;
        // Decoded codewords, stacked.
        for (i, &code) in codes.iter().enumerate() {
            vq.decode_into(code, a.row_mut(i));
        }
        // Mix: one pass over w_mix for the whole stack.
        tensor::matmul_into(a, &layer.w_mix, mix);
    }
    finish_tail_from_mix(w, li, xs, b, scratch)
}

/// The tail stages downstream of the mix product — residual 1, LN2, FFN,
/// residual 2 — over a `scratch.mix` whose rows are already filled
/// (freshly computed, cache-served, or wave-deduped; the bytes are
/// identical either way). Shared by the cached and uncached pooled
/// kernels so they cannot diverge.
fn finish_tail_from_mix(
    w: &ModelWeights,
    li: usize,
    xs: &[f32],
    b: usize,
    scratch: &mut TailScratch,
) -> Matrix {
    let layer = &w.layers[li];
    let cfg = &w.cfg;
    let d = cfg.d_model;
    let TailScratch { a, mix, c, mid } = scratch;
    // Residual 1 — identical expression order to the single-row tail.
    for i in 0..b {
        let (xr, mr) = (&xs[i * d..(i + 1) * d], mix.row(i));
        let cr = c.row_mut(i);
        for j in 0..d {
            cr[j] = xr[j] + mr[j] + layer.b_mix[j];
        }
    }
    // LN2 rows into the (reused) decode buffer.
    tensor::layernorm_rows_into(c, &layer.ln2_g, &layer.ln2_b, cfg.ln_eps, a);
    // FFN: two stacked GEMMs around the fused bias-GELU.
    tensor::matmul_into(a, &layer.w_ff1, mid);
    tensor::bias_gelu_rows(mid, &layer.b_ff1);
    let mut out = Matrix::zeros(b, d);
    tensor::matmul_into(mid, &layer.w_ff2, &mut out);
    // Residual 2 — same `o += (b_ff2 + c)` association as the single row.
    for i in 0..b {
        let cr = c.row(i);
        let or = out.row_mut(i);
        for j in 0..d {
            or[j] += layer.b_ff2[j] + cr[j];
        }
    }
    out
}

/// [`block_tail_batch`] with the shared code cache in front of the mix
/// GEMM, plus intra-wave dedupe: each row's mix vector is (1) served
/// from the cache, (2) aliased to another row of this chunk with the
/// same code (cost one product, not N — the "N sessions typing the same
/// token" case), or (3) computed, once per distinct code, by one stacked
/// GEMM over the *unique* misses and then inserted into the cache.
///
/// Bit-exactness: the unique-miss GEMM is the same row-decomposable
/// tiled kernel, so a deduped or cache-served row receives byte-for-byte
/// the vector it would have computed itself; the downstream stages are
/// literally shared ([`finish_tail_from_mix`]).
///
/// Returns one [`TailOutcome`] per row, in row order, so the caller can
/// attribute hit/miss/eviction/bytes to each row's owning engine
/// (insert accounting lands on the code's first-occurrence row).
fn block_tail_batch_cached(
    w: &ModelWeights,
    li: usize,
    xs: &[f32],
    b: usize,
    codes: &[CodeTuple],
    scratch: &mut TailScratch,
    cache: &CacheHandle,
) -> (Matrix, Vec<TailOutcome>) {
    let layer = &w.layers[li];
    let cfg = &w.cfg;
    let d = cfg.d_model;
    assert_eq!(xs.len(), b * d);
    assert_eq!(codes.len(), b);
    let vq = layer.vq.as_ref().expect("VQ layer");
    scratch.shape(b, d, cfg.d_ff);

    // Phase 1: resolve every row's mix-vector source. `seen` tracks
    // codes that MISSED earlier in this chunk (a code whose first
    // occurrence hit the cache keeps hitting it on re-lookup).
    let mut outcomes = vec![TailOutcome::Uncached; b];
    let mut uniq_codes: Vec<CodeTuple> = Vec::new();
    let mut first_row: Vec<usize> = Vec::new();
    let mut from_uniq: Vec<Option<usize>> = vec![None; b];
    let mut seen: HashMap<u64, usize> = HashMap::new();
    for (i, &code) in codes.iter().enumerate() {
        let key = code.pack();
        if let Some(&u) = seen.get(&key) {
            // Wave dedupe: the product is already being computed this
            // chunk. Counts as a hit for the row's engine AND globally
            // (note_hit keeps the two views summing identically).
            cache.cache.note_hit();
            outcomes[i] = TailOutcome::Hit;
            from_uniq[i] = Some(u);
        } else if cache
            .cache
            .lookup(cache.fp, li as u32, key, scratch.mix.row_mut(i))
        {
            outcomes[i] = TailOutcome::Hit;
        } else {
            let u = uniq_codes.len();
            uniq_codes.push(code);
            first_row.push(i);
            seen.insert(key, u);
            from_uniq[i] = Some(u);
            // Outcome recorded as Miss below, with insert accounting.
        }
    }

    // Phase 2: one stacked GEMM over the unique misses only.
    let m = uniq_codes.len();
    if m > 0 {
        let mut ua = Matrix::zeros(m, d);
        for (u, &code) in uniq_codes.iter().enumerate() {
            vq.decode_into(code, ua.row_mut(u));
        }
        let mut umix = Matrix::zeros(m, d);
        tensor::matmul_into(&ua, &layer.w_mix, &mut umix);
        for u in 0..m {
            let (bytes, evictions) =
                cache
                    .cache
                    .insert(cache.fp, li as u32, uniq_codes[u].pack(), umix.row(u));
            outcomes[first_row[u]] = TailOutcome::Miss { bytes, evictions };
        }
        for i in 0..b {
            if let Some(u) = from_uniq[i] {
                scratch.mix.row_mut(i).copy_from_slice(umix.row(u));
            }
        }
    }

    (finish_tail_from_mix(w, li, xs, b, scratch), outcomes)
}

/// Apply one edit script per engine with the per-layer block tails of ALL
/// engines pooled into stacked GEMMs of at most `max_batch_rows` rows.
///
/// Engines must share one weight set (the coordinator guarantees this per
/// shard). Scripts advance in lockstep — edit k of every script runs
/// concurrently layer by layer; scripts shorter than the longest simply
/// finish early. Per-engine results (logits bits, per-edit FLOP ledger,
/// reuse statistics) are identical to `apply_edits` on each engine alone:
/// the orchestration is the same staged code path, and the pooled tails
/// are bitwise equal to the single-row tails.
pub fn apply_scripts_batched(
    engines: &mut [&mut IncrementalEngine],
    scripts: &[&[Edit]],
    max_batch_rows: usize,
) -> BatchOutcome {
    assert_eq!(engines.len(), scripts.len(), "one script per engine");
    let cap = max_batch_rows.max(1);
    let mut reports: Vec<EditReport> = engines
        .iter()
        .map(|e| EditReport {
            flops: 0,
            logits: e.logits().to_vec(),
            defragged: false,
        })
        .collect();
    let mut batched_rows = 0u64;
    let mut gemm_fills = Vec::new();
    let Some(first) = engines.first() else {
        return BatchOutcome {
            reports,
            batched_rows,
            gemm_fills,
        };
    };
    let w = first.weights().clone();
    for e in engines.iter() {
        assert!(
            Arc::ptr_eq(e.weights(), &w),
            "batched engines must share one weight set"
        );
    }
    // The pooled kernels use the cache only when EVERY engine of the
    // wave holds a handle to the SAME cache under the SAME fingerprint
    // (the coordinator sets exactly this up). Mixed attachment falls
    // back to the uncached kernel for the whole wave: correctness would
    // hold either way, but per-engine hit/miss attribution would depend
    // on wave interleaving, and the all-or-nothing rule keeps batched
    // stats reproducible.
    let wave_cache: Option<CacheHandle> = match first.code_cache() {
        Some(h0)
            if engines.iter().all(|e| {
                e.code_cache()
                    .is_some_and(|h| Arc::ptr_eq(&h.cache, &h0.cache) && h.fp == h0.fp)
            }) =>
        {
            Some(h0.clone())
        }
        _ => None,
    };
    let d = w.cfg.d_model;
    let n_layers = w.cfg.n_layers;
    let max_len = scripts.iter().map(|s| s.len()).max().unwrap_or(0);
    // Gather buffers and GEMM intermediates persist across layers and
    // edit cycles — the steady state allocates nothing but the per-chunk
    // output stacks (which must outlive the scatter).
    let mut scratch = TailScratch::new();
    let mut xs: Vec<f32> = Vec::new();
    let mut codes: Vec<CodeTuple> = Vec::new();

    for k in 0..max_len {
        // Stage edit k of every engine that still has one. A defrag is
        // absorbed inside stage_edit (full rebuild) — that engine just
        // sits this inner cycle's layer loop out.
        let mut staged: Vec<Option<StagedEdit>> = (0..engines.len()).map(|_| None).collect();
        for (i, script) in scripts.iter().enumerate() {
            if let Some(&edit) = script.get(k) {
                match engines[i].stage_edit(edit) {
                    Staged::Done(rep) => accumulate(&mut reports[i], rep),
                    Staged::Pending(st) => staged[i] = Some(st),
                }
            }
        }
        for li in 0..n_layers {
            for (i, slot) in staged.iter_mut().enumerate() {
                if let Some(st) = slot {
                    engines[i].staged_pre(st);
                }
            }
            // Gather the pending rows of every engine into one stack.
            let gather_span = crate::util::trace::stage("wave_gather");
            xs.clear();
            codes.clear();
            for slot in staged.iter().flatten() {
                for rw in slot.pending() {
                    xs.extend_from_slice(&rw.x);
                    codes.push(rw.code);
                }
            }
            drop(gather_span);
            let total = codes.len();
            // Chunked execution straight off the gather buffer: each
            // chunk's output matrix is kept and scattered from in place,
            // so no full-stack staging copy on either side of the GEMMs.
            let mut chunks: Vec<Matrix> = Vec::new();
            let mut outcomes: Vec<TailOutcome> = Vec::with_capacity(total);
            let gemm_span = crate::util::trace::stage("wave_gemm");
            let mut r0 = 0;
            while r0 < total {
                let rows = (total - r0).min(cap);
                let chunk_xs = &xs[r0 * d..(r0 + rows) * d];
                let chunk_codes = &codes[r0..r0 + rows];
                let chunk = match &wave_cache {
                    Some(h) => {
                        let (out, outs) = block_tail_batch_cached(
                            &w,
                            li,
                            chunk_xs,
                            rows,
                            chunk_codes,
                            &mut scratch,
                            h,
                        );
                        outcomes.extend(outs);
                        out
                    }
                    None => {
                        outcomes.extend(std::iter::repeat(TailOutcome::Uncached).take(rows));
                        block_tail_batch(&w, li, chunk_xs, rows, chunk_codes, &mut scratch)
                    }
                };
                chunks.push(chunk);
                batched_rows += rows as u64;
                gemm_fills.push(rows);
                r0 += rows;
            }
            drop(gemm_span);
            // Scatter back, engine by engine (gather order is preserved;
            // global row j lives in chunk j / cap at local row j % cap,
            // since every chunk except the last holds exactly `cap` rows).
            // Each row's cache outcome lands on its OWNING engine's stats,
            // and its hit/miss flag rides into staged_post so the ledger
            // attribution matches the single-row path.
            let _scatter_span = crate::util::trace::stage("wave_scatter");
            let mut r = 0;
            for (i, slot) in staged.iter_mut().enumerate() {
                if let Some(st) = slot {
                    let cnt = st.pending().len();
                    let refs: Vec<&[f32]> =
                        (r..r + cnt).map(|j| chunks[j / cap].row(j % cap)).collect();
                    let mut flags: Vec<bool> = Vec::with_capacity(cnt);
                    for j in r..r + cnt {
                        match outcomes[j] {
                            TailOutcome::Uncached => flags.push(false),
                            TailOutcome::Hit => {
                                engines[i].stats.cache_hits += 1;
                                flags.push(true);
                            }
                            TailOutcome::Miss { bytes, evictions } => {
                                engines[i].stats.cache_misses += 1;
                                engines[i].stats.cache_bytes_inserted += bytes;
                                engines[i].stats.cache_evictions += evictions;
                                flags.push(false);
                            }
                        }
                    }
                    engines[i].staged_post(st, &refs, &flags);
                    r += cnt;
                }
            }
            debug_assert_eq!(r, total, "every pooled row scattered");
        }
        for (i, slot) in staged.iter_mut().enumerate() {
            if let Some(st) = slot.take() {
                let rep = engines[i].finish_staged(st);
                accumulate(&mut reports[i], rep);
            }
        }
    }
    BatchOutcome {
        reports,
        batched_rows,
        gemm_fills,
    }
}

/// Fold one edit's report into a script-level aggregate (`apply_edits`
/// semantics: flops sum, last logits, defragged-anywhere).
fn accumulate(total: &mut EditReport, rep: EditReport) {
    total.flops += rep.flops;
    total.defragged |= rep.defragged;
    total.logits = rep.logits;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::incremental::EngineOptions;
    use crate::util::Rng;
    use crate::vq::Code;

    fn setup(seed: u64, n: usize) -> (Arc<ModelWeights>, Vec<u32>) {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, seed));
        let mut r = Rng::new(seed ^ 0x5A5A);
        let tokens: Vec<u32> = (0..n).map(|_| r.below(cfg.vocab_size) as u32).collect();
        (w, tokens)
    }

    /// The kernel-level lock: the pooled stacked tail equals the single-row
    /// tail at the BIT level, for every layer, at ragged batch sizes.
    #[test]
    fn pooled_block_tail_bitwise_matches_single_row() {
        let (w, tokens) = setup(3, 10);
        let mut eng = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let cfg = w.cfg.clone();
        let mut r = Rng::new(5);
        let mut scratch = TailScratch::new();
        for li in 0..cfg.n_layers {
            for &b in &[1usize, 3, 5] {
                let xs = Matrix::from_fn(b, cfg.d_model, |_, _| r.normal());
                let codes: Vec<CodeTuple> = (0..b)
                    .map(|_| {
                        let cs: Vec<Code> = (0..cfg.vq_heads)
                            .map(|_| r.below(cfg.vq_codes) as Code)
                            .collect();
                        CodeTuple::new(&cs)
                    })
                    .collect();
                let pooled = block_tail_batch(&w, li, &xs.data, b, &codes, &mut scratch);
                for i in 0..b {
                    let single = eng.block_tail(li, xs.row(i), codes[i]);
                    for (j, (p, s)) in pooled.row(i).iter().zip(&single).enumerate() {
                        assert_eq!(
                            p.to_bits(),
                            s.to_bits(),
                            "layer {li} batch {b} row {i} col {j}: pooled {p} vs single {s}"
                        );
                    }
                }
            }
        }
    }

    /// End-to-end: pooling across engines changes nothing observable —
    /// logits bits, per-script FLOPs, stats, tokens.
    #[test]
    fn batched_scripts_bit_exact_vs_unbatched() {
        let (w, _) = setup(7, 0);
        let cfg = w.cfg.clone();
        let mut r = Rng::new(11);
        let n_engines = 3;
        let docs: Vec<Vec<u32>> = (0..n_engines)
            .map(|i| {
                (0..(10 + 3 * i))
                    .map(|_| r.below(cfg.vocab_size) as u32)
                    .collect()
            })
            .collect();
        let mut batched: Vec<IncrementalEngine> = docs
            .iter()
            .map(|d| IncrementalEngine::new(w.clone(), d, EngineOptions::default()))
            .collect();
        let mut serial: Vec<IncrementalEngine> = docs
            .iter()
            .map(|d| IncrementalEngine::new(w.clone(), d, EngineOptions::default()))
            .collect();
        let scripts: Vec<Vec<Edit>> = docs
            .iter()
            .map(|doc| {
                let mut len = doc.len();
                (0..4)
                    .map(|_| {
                        let e = crate::testutil::gen_edit(&mut r, len, cfg.vocab_size, cfg.max_seq);
                        len = (len as isize + e.len_delta()) as usize;
                        e
                    })
                    .collect()
            })
            .collect();
        let script_refs: Vec<&[Edit]> = scripts.iter().map(|s| s.as_slice()).collect();
        let outcome = {
            let mut refs: Vec<&mut IncrementalEngine> = batched.iter_mut().collect();
            apply_scripts_batched(&mut refs, &script_refs, 4)
        };
        assert!(outcome.batched_rows > 0, "pooled path must actually run");
        assert!(outcome.gemm_fills.iter().all(|&f| (1..=4).contains(&f)));
        for (i, (b, s)) in batched.iter_mut().zip(serial.iter_mut()).enumerate() {
            let rep = s.apply_edits(&scripts[i]);
            assert_eq!(b.tokens(), s.tokens(), "engine {i} tokens");
            assert_eq!(outcome.reports[i].flops, rep.flops, "engine {i} flops");
            assert_eq!(outcome.reports[i].defragged, rep.defragged, "engine {i}");
            let bb: Vec<u32> = b.logits().iter().map(|x| x.to_bits()).collect();
            let sb: Vec<u32> = rep.logits.iter().map(|x| x.to_bits()).collect();
            assert_eq!(bb, sb, "engine {i} logits bits");
            assert_eq!(b.ledger.total(), s.ledger.total(), "engine {i} ledger");
            assert_eq!(b.stats, s.stats, "engine {i} stats");
            let v = b.verify();
            assert_eq!(v.code_mismatches, 0, "engine {i} dense parity");
            assert!(v.max_logit_diff < 1e-3, "engine {i}: {}", v.max_logit_diff);
        }
    }

    /// The chunk cap only splits GEMMs, never changes results.
    #[test]
    fn chunk_cap_is_numerically_invariant() {
        let (w, _) = setup(9, 0);
        let cfg = w.cfg.clone();
        let mut r = Rng::new(13);
        let docs: Vec<Vec<u32>> = (0..3)
            .map(|_| (0..12).map(|_| r.below(cfg.vocab_size) as u32).collect())
            .collect();
        let scripts: Vec<Vec<Edit>> = docs
            .iter()
            .map(|d| {
                vec![
                    Edit::Replace {
                        at: 2,
                        tok: r.below(cfg.vocab_size) as u32,
                    },
                    Edit::Insert {
                        at: d.len() / 2,
                        tok: r.below(cfg.vocab_size) as u32,
                    },
                ]
            })
            .collect();
        let script_refs: Vec<&[Edit]> = scripts.iter().map(|s| s.as_slice()).collect();
        let mut bits_per_cap: Vec<Vec<Vec<u32>>> = Vec::new();
        for cap in [1usize, 2, 7, 1024] {
            let mut engines: Vec<IncrementalEngine> = docs
                .iter()
                .map(|d| IncrementalEngine::new(w.clone(), d, EngineOptions::default()))
                .collect();
            let outcome = {
                let mut refs: Vec<&mut IncrementalEngine> = engines.iter_mut().collect();
                apply_scripts_batched(&mut refs, &script_refs, cap)
            };
            assert!(outcome.gemm_fills.iter().all(|&f| f <= cap), "cap {cap}");
            bits_per_cap.push(
                engines
                    .iter()
                    .map(|e| e.logits().iter().map(|x| x.to_bits()).collect())
                    .collect(),
            );
        }
        for other in &bits_per_cap[1..] {
            assert_eq!(&bits_per_cap[0], other, "chunk cap moved numerics");
        }
    }

    /// Cached pooled execution is bit-identical to the uncached pooled
    /// path; an identical rerun against the warmed cache is all-hits;
    /// the global cache counters equal the sum of per-engine deltas; and
    /// the per-engine FLOP saving is exactly `hits · (MULADD·d² − d)`.
    #[test]
    fn cached_waves_bit_exact_warm_rerun_all_hits() {
        use crate::flops::MULADD;
        use crate::incremental::codecache::{CacheHandle, CodeCache};
        let (w, _) = setup(21, 0);
        let cfg = w.cfg.clone();
        let mut r = Rng::new(17);
        let docs: Vec<Vec<u32>> = (0..3)
            .map(|i| {
                (0..(9 + 2 * i))
                    .map(|_| r.below(cfg.vocab_size) as u32)
                    .collect()
            })
            .collect();
        // Replace-only scripts: no structural edits, so no defrag can
        // route rows around the pooled path — every block tail of the
        // run flows through `batched_rows` and the outcome accounting
        // below is exact.
        let scripts: Vec<Vec<Edit>> = docs
            .iter()
            .map(|doc| {
                (0..4)
                    .map(|_| Edit::Replace {
                        at: r.below(doc.len()),
                        tok: r.below(cfg.vocab_size) as u32,
                    })
                    .collect()
            })
            .collect();
        let script_refs: Vec<&[Edit]> = scripts.iter().map(|s| s.as_slice()).collect();
        let cache = Arc::new(CodeCache::new(1 << 22));
        let handle = CacheHandle::new(cache.clone(), &w);

        let run = |attach: bool| -> (Vec<IncrementalEngine>, BatchOutcome) {
            let mut engines: Vec<IncrementalEngine> = docs
                .iter()
                .map(|doc| {
                    let mut e = IncrementalEngine::new(w.clone(), doc, EngineOptions::default());
                    if attach {
                        e.set_code_cache(Some(handle.clone()));
                    }
                    e
                })
                .collect();
            let outcome = {
                let mut refs: Vec<&mut IncrementalEngine> = engines.iter_mut().collect();
                apply_scripts_batched(&mut refs, &script_refs, 4)
            };
            (engines, outcome)
        };

        let (plain, _) = run(false);
        let (warming, o1) = run(true);
        let (warm, o2) = run(true);
        assert!(o1.batched_rows > 0, "pooled path must actually run");
        assert_eq!(o1.batched_rows, o2.batched_rows, "same wave both runs");
        for (name, cached_run) in [("cold", &warming), ("warm", &warm)] {
            for (i, (p, c)) in plain.iter().zip(cached_run.iter()).enumerate() {
                let pb: Vec<u32> = p.logits().iter().map(|x| x.to_bits()).collect();
                let cb: Vec<u32> = c.logits().iter().map(|x| x.to_bits()).collect();
                assert_eq!(pb, cb, "engine {i}: {name} cached run moved logits bits");
            }
        }
        // Every pooled row is attributed hit-or-miss to exactly one engine.
        let hits1: u64 = warming.iter().map(|e| e.stats.cache_hits).sum();
        let miss1: u64 = warming.iter().map(|e| e.stats.cache_misses).sum();
        assert_eq!(hits1 + miss1, o1.batched_rows, "every row attributed");
        let hits2: u64 = warm.iter().map(|e| e.stats.cache_hits).sum();
        let miss2: u64 = warm.iter().map(|e| e.stats.cache_misses).sum();
        assert_eq!(miss2, 0, "identical rerun against a warm cache must be all hits");
        assert_eq!(hits2, o2.batched_rows);
        // Global counters == sum of per-engine deltas across both runs.
        let s = cache.stats();
        assert_eq!(s.hits, hits1 + hits2, "global hits vs engine sum");
        assert_eq!(s.misses, miss1, "global misses vs engine sum");
        let bytes: u64 = warming
            .iter()
            .chain(&warm)
            .map(|e| e.stats.cache_bytes_inserted)
            .sum();
        assert_eq!(s.bytes_inserted, bytes, "global bytes vs engine sum");
        let evs: u64 = warming
            .iter()
            .chain(&warm)
            .map(|e| e.stats.cache_evictions)
            .sum();
        assert_eq!(s.evictions, evs, "global evictions vs engine sum");
        // FLOP attribution: per hit, exactly the mix GEMV (MULADD·d²)
        // minus the decode bookkeeping swap (d vs 2d) is saved.
        let d = cfg.d_model as u64;
        let per_hit = MULADD * d * d - d;
        for (i, (p, c)) in plain.iter().zip(&warm).enumerate() {
            assert_eq!(
                p.ledger.total() - c.ledger.total(),
                c.stats.cache_hits * per_hit,
                "engine {i}: warm-cache FLOP saving must be exactly per-hit"
            );
        }
    }

    /// Empty scripts are no-ops with current logits and zero flops.
    #[test]
    fn empty_scripts_are_noops() {
        let (w, tokens) = setup(15, 8);
        let mut e = IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default());
        let before: Vec<u32> = e.logits().iter().map(|x| x.to_bits()).collect();
        let outcome = {
            let mut refs: Vec<&mut IncrementalEngine> = vec![&mut e];
            apply_scripts_batched(&mut refs, &[&[]], 8)
        };
        assert_eq!(outcome.reports[0].flops, 0);
        assert_eq!(outcome.batched_rows, 0);
        let after: Vec<u32> = outcome.reports[0]
            .logits
            .iter()
            .map(|x| x.to_bits())
            .collect();
        assert_eq!(before, after);
    }
}
