//! Minimal dense f32 linear algebra for the L3 engine.
//!
//! The incremental engine operates on small row vectors and codebook-sized
//! matrices, so a contiguous row-major `Matrix` with a handful of fused
//! primitives is all we need. Heavier dense work (full-model baseline
//! forward) can also be delegated to AOT-compiled XLA artifacts through
//! `runtime::`; this module is the in-process oracle and the incremental
//! hot path's arithmetic layer.

pub mod ops;
pub mod simd;

pub use ops::*;
pub use simd::{
    active_backend, requested_backend, set_kernel_backend, KernelBackend, ResolvedBackend,
};

/// Row-major 2-D f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "matrix data length mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Max absolute element-wise difference (for parity tests).
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 10 + j) as f32);
        assert_eq!(m.get(2, 3), 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
    }

    #[test]
    fn transpose_involution() {
        let m = Matrix::from_fn(5, 3, |i, j| (i * 7 + j * 13) as f32);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0]);
    }
}
