//! Dense f32 primitives used by the oracle forward pass and the incremental
//! engine's hot path. All routines are allocation-conscious: the hot-path
//! variants write into caller-provided buffers.

use super::Matrix;

/// `C = A · B` — blocked row-major matmul. `A: (m,k)`, `B: (k,n)`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// Column-tile width for the blocked GEMM/GEMV core: a 64-float strip of
/// the output row (256 B) plus the four active `W` row segments fit
/// comfortably in L1, so every float of the strip is touched once per
/// 4-row k-step instead of once per k-step.
pub(crate) const N_TILE: usize = 64;

/// `y += x · W` — the scalar reference implementation of the tiled core
/// behind [`matmul_into`] and [`vec_matmul_into`]. Columns are processed
/// in `N_TILE`-wide strips; `x` is consumed four entries at a time so the
/// write stream over the strip (the bottleneck at 128–3072-wide rows) is
/// quartered. All inner loops are exact-length slice zips, which the
/// autovectorizer lowers to SIMD without bounds checks.
///
/// The explicit-SIMD backends in [`super::simd`] mirror this core
/// bit-for-bit (same per-element accumulation order, same zero-quad skip,
/// no FMA contraction); dispatch between them is process-global (see
/// `tensor::set_kernel_backend`). Any change to the arithmetic here must
/// be applied to the AVX2/NEON mirrors in lockstep — the
/// backend-equivalence suite in `tensor/simd.rs` fails otherwise.
pub(crate) fn accum_row_tiled_scalar(x: &[f32], w: &Matrix, y: &mut [f32]) {
    debug_assert_eq!(x.len(), w.rows);
    debug_assert_eq!(y.len(), w.cols);
    let n = w.cols;
    let k = x.len();
    let k4 = k - k % 4;
    let mut j0 = 0;
    while j0 < n {
        let jw = (n - j0).min(N_TILE);
        let ytile = &mut y[j0..j0 + jw];
        let mut p = 0;
        while p < k4 {
            let (x0, x1, x2, x3) = (x[p], x[p + 1], x[p + 2], x[p + 3]);
            if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                p += 4;
                continue; // sparse-row fast path (zero-padded inputs)
            }
            let w0 = &w.data[p * n + j0..p * n + j0 + jw];
            let w1 = &w.data[(p + 1) * n + j0..(p + 1) * n + j0 + jw];
            let w2 = &w.data[(p + 2) * n + j0..(p + 2) * n + j0 + jw];
            let w3 = &w.data[(p + 3) * n + j0..(p + 3) * n + j0 + jw];
            for ((((yv, &a0), &a1), &a2), &a3) in
                ytile.iter_mut().zip(w0).zip(w1).zip(w2).zip(w3)
            {
                *yv += x0 * a0 + x1 * a1 + x2 * a2 + x3 * a3;
            }
            p += 4;
        }
        for (pp, &xv) in x.iter().enumerate().skip(k4) {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w.data[pp * n + j0..pp * n + j0 + jw];
            for (yv, &wv) in ytile.iter_mut().zip(wrow) {
                *yv += xv * wv;
            }
        }
        j0 += jw;
    }
}

/// `C = A · B` into an existing buffer (zeroed here). Tiled: each output
/// row goes through the blocked [`accum_row_tiled_scalar`] core (or its
/// bit-identical SIMD mirror, per the process-global backend selection).
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    matmul_into_with(super::simd::active_backend(), a, b, c);
}

/// [`matmul_into`] with kernel dispatch pinned to `backend` — for the
/// backend-equivalence suite and the scalar-vs-SIMD benchmark table.
/// Semantics (and bits) are identical on every backend.
pub fn matmul_into_with(
    backend: super::simd::ResolvedBackend,
    a: &Matrix,
    b: &Matrix,
    c: &mut Matrix,
) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    let (m, k, n) = (a.rows, a.cols, b.cols);
    c.data.iter_mut().for_each(|x| *x = 0.0);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut c.data[i * n..(i + 1) * n];
        super::simd::accum_row_tiled_with(backend, arow, b, crow);
    }
}

/// `y = x · W` for a single row vector. `x: (k)`, `w: (k,n)`, `y: (n)`.
/// This is THE serving hot path (QKV, mix, FFN, classifier are all row ×
/// matrix); it runs on the tiled core.
///
/// Row-decomposability guarantee (the batched-execution bit-exactness
/// argument, docs/ARCHITECTURE.md §7): [`matmul_into`] runs this exact
/// per-row core over each stacked row, so `matmul_into(stack(x₀..xₙ), W)`
/// is bitwise identical to n independent `vec_matmul_into` calls. The
/// cross-session batcher leans on this; a kernel change that breaks it
/// fails `batched_gemm_rows_bitwise_equal_gemv` below.
///
/// The same fixed accumulation order is what makes the codebook-product
/// cache (docs/ARCHITECTURE.md §8) bit-exact: `decode(code)·w_mix` computed
/// once and replayed from the cache is byte-identical to recomputing it, so
/// a cache hit cannot perturb downstream logits.
#[inline]
pub fn vec_matmul_into(x: &[f32], w: &Matrix, y: &mut [f32]) {
    vec_matmul_into_with(super::simd::active_backend(), x, w, y);
}

/// [`vec_matmul_into`] with kernel dispatch pinned to `backend` — for the
/// backend-equivalence suite and the scalar-vs-SIMD benchmark table.
/// Semantics (and bits) are identical on every backend.
#[inline]
pub fn vec_matmul_into_with(
    backend: super::simd::ResolvedBackend,
    x: &[f32],
    w: &Matrix,
    y: &mut [f32],
) {
    assert_eq!(x.len(), w.rows);
    assert_eq!(y.len(), w.cols);
    y.iter_mut().for_each(|v| *v = 0.0);
    super::simd::accum_row_tiled_with(backend, x, w, y);
}

/// Row-wise layer normalization over stacked rows: `out.row(i) =
/// LN(x.row(i))`. Batched form of [`layernorm_into`] — same scalar code
/// per row, so the pooled block-tail path cannot drift from the per-row
/// path.
pub fn layernorm_rows_into(x: &Matrix, gamma: &[f32], beta: &[f32], eps: f32, out: &mut Matrix) {
    assert_eq!((x.rows, x.cols), (out.rows, out.cols));
    for i in 0..x.rows {
        layernorm_into(x.row(i), gamma, beta, eps, out.row_mut(i));
    }
}

/// Fused `row = GELU(row + b)` over every stacked row — batched form of
/// [`bias_gelu`], same per-row scalar sequence.
pub fn bias_gelu_rows(m: &mut Matrix, bias: &[f32]) {
    assert_eq!(m.cols, bias.len());
    for i in 0..m.rows {
        bias_gelu(m.row_mut(i), bias);
    }
}

/// Dot product — 8-wide chunks feeding 4 independent accumulators, so the
/// autovectorizer can keep two FMA pipes busy without a reduction
/// dependency chain.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for (x, y) in ca.zip(cb) {
        s0 += x[0] * y[0] + x[1] * y[1];
        s1 += x[2] * y[2] + x[3] * y[3];
        s2 += x[4] * y[4] + x[5] * y[5];
        s3 += x[6] * y[6] + x[7] * y[7];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for (x, y) in ra.iter().zip(rb) {
        s += x * y;
    }
    s
}

/// `y += alpha * x` (axpy).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv;
    }
}

/// In-place bias add over every row.
pub fn add_bias(m: &mut Matrix, bias: &[f32]) {
    assert_eq!(m.cols, bias.len());
    for i in 0..m.rows {
        for (v, &b) in m.row_mut(i).iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// GELU, tanh approximation — matches `jax.nn.gelu(x, approximate=True)`,
/// which is what the L2 model uses, so L2/L3 parity holds bit-for-bit at the
/// formula level.
#[inline]
pub fn gelu_scalar(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + ((C * (x + 0.044715 * x * x * x)).tanh()))
}

/// Element-wise GELU over a slice.
pub fn gelu_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = gelu_scalar(*x);
    }
}

/// Fused `x = GELU(x + b)` — one pass over the FFN mid-layer row instead
/// of a bias pass followed by an activation pass. Bit-identical to the
/// unfused sequence (same scalar ops in the same order), so swapping it
/// into the engine/oracle cannot move numerics.
#[inline]
pub fn bias_gelu(xs: &mut [f32], bias: &[f32]) {
    debug_assert_eq!(xs.len(), bias.len());
    for (x, &b) in xs.iter_mut().zip(bias) {
        *x = gelu_scalar(*x + b);
    }
}

/// Layer normalization of a single row into `out`.
#[inline]
pub fn layernorm_into(x: &[f32], gamma: &[f32], beta: &[f32], eps: f32, out: &mut [f32]) {
    let n = x.len() as f32;
    let mean = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + eps).sqrt();
    for i in 0..x.len() {
        out[i] = (x[i] - mean) * inv * gamma[i] + beta[i];
    }
}

/// Row-wise softmax in place (baseline attention only).
pub fn softmax_row(xs: &mut [f32]) {
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in xs.iter_mut() {
        *x *= inv;
    }
}

// ---------------------------------------------------------------------------
// Streaming-softmax aggregate kernels (docs/ARCHITECTURE.md §12): one
// attention head's running numerator/denominator state, updated by adding
// or subtracting a single key's contribution and renormalized on demand.
// The subtract kernel is the add kernel with the weight negated — the same
// multiply in the same order — so `add_term` followed by `sub_term` with
// the identical weight returns each element to within one f32 rounding
// step of its starting value (the §12 tolerance contract's per-term bound).
// ---------------------------------------------------------------------------

/// Add one key's contribution to a head's aggregates:
/// `num += w · val`, `den += w`, where `w = exp(score − shift)` was
/// computed by the caller (the shift is frozen between full refreshes).
#[inline]
pub fn sm_add_term(num: &mut [f32], den: &mut f32, w: f32, val: &[f32]) {
    axpy(w, val, num);
    *den += w;
}

/// Subtract one key's previous contribution from a head's aggregates:
/// `num −= w · val`, `den −= w`. `w` must be recomputed from the RETAINED
/// old key under the same frozen shift, so it equals the weight originally
/// added bit-for-bit and the subtraction cancels up to f32 rounding.
#[inline]
pub fn sm_sub_term(num: &mut [f32], den: &mut f32, w: f32, val: &[f32]) {
    axpy(-w, val, num);
    *den -= w;
}

/// Renormalize a head's aggregates into the attention output slice:
/// `out = num / den` via one reciprocal + one multiply per element (the
/// same shape `softmax_row`'s normalize step uses). The caller guards
/// `den` away from zero (the §12 cancellation guard) before calling.
#[inline]
pub fn sm_renorm_into(num: &[f32], den: f32, out: &mut [f32]) {
    debug_assert_eq!(num.len(), out.len());
    let inv = 1.0 / den;
    for (o, &nv) in out.iter_mut().zip(num) {
        *o = nv * inv;
    }
}

/// `out = a + b` element-wise.
pub fn add(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    Matrix {
        rows: a.rows,
        cols: a.cols,
        data: a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect(),
    }
}

/// Squared Euclidean norm.
#[inline]
pub fn norm_sq(x: &[f32]) -> f32 {
    dot(x, x)
}

/// Argmax index of a slice (first maximal element).
#[inline]
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = xs[0];
    for (i, &v) in xs.iter().enumerate().skip(1) {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for p in 0..a.cols {
                    s += a.get(i, p) * b.get(p, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    fn naive_vec_matmul(x: &[f32], w: &Matrix) -> Vec<f32> {
        let mut y = vec![0.0; w.cols];
        for (p, &xv) in x.iter().enumerate() {
            for (j, yv) in y.iter_mut().enumerate() {
                *yv += xv * w.get(p, j);
            }
        }
        y
    }

    /// The 4-row k-unroll reassociates the k-sum; the reference sums
    /// sequentially. With N(0,1) entries the drift is ~√k·ε, so the bound
    /// is 1e-5 scaled by the reduction depth.
    fn reassoc_tol(k: usize) -> f32 {
        1e-5 * (1.0 + k as f32 / 64.0)
    }

    #[test]
    fn matmul_matches_naive() {
        use crate::util::Rng;
        let mut r = Rng::new(1);
        for _ in 0..20 {
            let (m, k, n) = (r.range(1, 17), r.range(1, 17), r.range(1, 17));
            let a = Matrix::from_fn(m, k, |_, _| r.normal());
            let b = Matrix::from_fn(k, n, |_, _| r.normal());
            let c1 = matmul(&a, &b);
            let c2 = naive_matmul(&a, &b);
            assert!(c1.max_abs_diff(&c2) < 1e-4);
        }
    }

    #[test]
    fn tiled_matmul_matches_naive_at_ragged_shapes() {
        use crate::util::Rng;
        let mut r = Rng::new(7);
        // Every boundary case of the tiling: k not a multiple of the
        // 4-row unroll, n not a multiple of N_TILE (64), both straddling
        // one and two tiles, plus degenerate 1-sized dims.
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, 8, 64),
            (5, 63, 65),
            (7, 64, 64),
            (2, 129, 31),
            (17, 96, 130),
            (9, 130, 129),
        ];
        for &(m, k, n) in &shapes {
            let a = Matrix::from_fn(m, k, |_, _| r.normal());
            let b = Matrix::from_fn(k, n, |_, _| r.normal());
            let c1 = matmul(&a, &b);
            let c2 = naive_matmul(&a, &b);
            let d = c1.max_abs_diff(&c2);
            assert!(d < reassoc_tol(k), "({m},{k},{n}): diff {d}");
        }
        // And a randomized sweep for shapes nobody thought of.
        for _ in 0..12 {
            let (m, k, n) = (r.range(1, 20), r.range(1, 70), r.range(1, 70));
            let a = Matrix::from_fn(m, k, |_, _| r.normal());
            let b = Matrix::from_fn(k, n, |_, _| r.normal());
            let d = matmul(&a, &b).max_abs_diff(&naive_matmul(&a, &b));
            assert!(d < reassoc_tol(k), "({m},{k},{n}): diff {d}");
        }
    }

    #[test]
    fn tiled_vec_matmul_matches_naive_at_ragged_shapes() {
        use crate::util::Rng;
        let mut r = Rng::new(8);
        for &(k, n) in &[
            (1usize, 1usize),
            (5, 3),
            (63, 65),
            (64, 64),
            (129, 100),
            (130, 131),
        ] {
            let w = Matrix::from_fn(k, n, |_, _| r.normal());
            let x: Vec<f32> = (0..k).map(|_| r.normal()).collect();
            let mut y = vec![0.0; n];
            vec_matmul_into(&x, &w, &mut y);
            let yref = naive_vec_matmul(&x, &w);
            for (j, (a, b)) in y.iter().zip(&yref).enumerate() {
                assert!((a - b).abs() < reassoc_tol(k), "({k},{n}) col {j}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn vec_matmul_matches_matmul() {
        use crate::util::Rng;
        let mut r = Rng::new(2);
        let w = Matrix::from_fn(8, 5, |_, _| r.normal());
        let x: Vec<f32> = (0..8).map(|_| r.normal()).collect();
        let a = Matrix::from_vec(1, 8, x.clone());
        let full = matmul(&a, &w);
        let mut y = vec![0.0; 5];
        vec_matmul_into(&x, &w, &mut y);
        // Both run the same tiled core, but keep fp slack for safety.
        for (a, b) in full.data.iter().zip(&y) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn zero_rows_skipped_without_changing_result() {
        use crate::util::Rng;
        let mut r = Rng::new(9);
        let k = 23;
        let w = Matrix::from_fn(k, 40, |_, _| r.normal());
        let mut x: Vec<f32> = (0..k).map(|_| r.normal()).collect();
        for i in (0..k).step_by(3) {
            x[i] = 0.0; // exercise the sparse fast path
        }
        let mut y = vec![0.0; 40];
        vec_matmul_into(&x, &w, &mut y);
        let yref = naive_vec_matmul(&x, &w);
        for (a, b) in y.iter().zip(&yref) {
            assert!((a - b).abs() < reassoc_tol(k), "{a} vs {b}");
        }
    }

    /// The load-bearing property behind cross-session batching: a stacked
    /// GEMM equals the per-row GEMVs at the BIT level, not within an fp
    /// tolerance. If tiling/unrolling ever makes the batched core
    /// accumulate in a different order than the single-row core, this must
    /// fail.
    #[test]
    fn batched_gemm_rows_bitwise_equal_gemv() {
        use crate::util::Rng;
        let mut r = Rng::new(12);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 63, 65),
            (5, 64, 64),
            (4, 130, 129),
            (2, 8, 256),
            (7, 128, 512),
        ] {
            let a = Matrix::from_fn(m, k, |_, _| r.normal());
            let w = Matrix::from_fn(k, n, |_, _| r.normal());
            let mut c = Matrix::zeros(m, n);
            matmul_into(&a, &w, &mut c);
            let mut y = vec![0.0; n];
            for i in 0..m {
                vec_matmul_into(a.row(i), &w, &mut y);
                for (j, (cv, yv)) in c.row(i).iter().zip(&y).enumerate() {
                    assert_eq!(
                        cv.to_bits(),
                        yv.to_bits(),
                        "({m},{k},{n}) row {i} col {j}: batched {cv} vs gemv {yv}"
                    );
                }
            }
        }
    }

    #[test]
    fn row_batched_elementwise_kernels_bitwise_equal_per_row() {
        use crate::util::Rng;
        let mut r = Rng::new(13);
        for &(m, n) in &[(1usize, 1usize), (3, 7), (5, 64), (2, 130)] {
            let x = Matrix::from_fn(m, n, |_, _| r.normal());
            let gamma: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let beta: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let mut batched = Matrix::zeros(m, n);
            layernorm_rows_into(&x, &gamma, &beta, 1e-5, &mut batched);
            let mut row = vec![0.0; n];
            for i in 0..m {
                layernorm_into(x.row(i), &gamma, &beta, 1e-5, &mut row);
                for (a, b) in batched.row(i).iter().zip(&row) {
                    assert_eq!(a.to_bits(), b.to_bits(), "layernorm row {i}");
                }
            }
            let bias: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let mut bg = x.clone();
            bias_gelu_rows(&mut bg, &bias);
            for i in 0..m {
                let mut single = x.row(i).to_vec();
                bias_gelu(&mut single, &bias);
                for (a, b) in bg.row(i).iter().zip(&single) {
                    assert_eq!(a.to_bits(), b.to_bits(), "bias_gelu row {i}");
                }
            }
        }
    }

    #[test]
    fn bias_gelu_matches_unfused_exactly() {
        use crate::util::Rng;
        let mut r = Rng::new(10);
        for n in [1usize, 7, 64, 130] {
            let bias: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let xs: Vec<f32> = (0..n).map(|_| r.normal() * 2.0).collect();
            let mut fused = xs.clone();
            bias_gelu(&mut fused, &bias);
            let mut unfused = xs.clone();
            for (x, &b) in unfused.iter_mut().zip(&bias) {
                *x += b;
            }
            gelu_slice(&mut unfused);
            // Same scalar ops in the same order ⇒ bitwise equal.
            assert_eq!(fused, unfused, "n={n}");
        }
    }

    #[test]
    fn dot_matches_sequential_reference() {
        use crate::util::Rng;
        let mut r = Rng::new(11);
        for k in [1usize, 4, 7, 8, 9, 15, 16, 64, 129] {
            let a: Vec<f32> = (0..k).map(|_| r.normal()).collect();
            let b: Vec<f32> = (0..k).map(|_| r.normal()).collect();
            let refv: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - refv).abs() < reassoc_tol(k), "k={k}");
        }
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0, 2.0, 3.0, -5.0];
        softmax_row(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn softmax_stable_for_large_inputs() {
        let mut xs = vec![1000.0, 1001.0];
        softmax_row(&mut xs);
        assert!(xs.iter().all(|x| x.is_finite()));
    }

    /// Streaming-softmax aggregates built one term at a time must match
    /// the batch `softmax_row` result — including the boundary shapes the
    /// engine hits: a single key (seq_len 1) and a wide context.
    #[test]
    fn sm_aggregates_match_softmax_row() {
        use crate::util::Rng;
        let mut r = Rng::new(21);
        for &(ctx, dh) in &[(1usize, 1usize), (1, 8), (5, 4), (37, 16)] {
            let scores: Vec<f32> = (0..ctx).map(|_| r.normal()).collect();
            let vals: Vec<Vec<f32>> = (0..ctx)
                .map(|_| (0..dh).map(|_| r.normal()).collect())
                .collect();
            // Reference: batch softmax then weighted sum.
            let mut p = scores.clone();
            softmax_row(&mut p);
            let mut want = vec![0.0f32; dh];
            for (j, v) in vals.iter().enumerate() {
                axpy(p[j], v, &mut want);
            }
            // Streaming: frozen shift = max score, add term by term.
            let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut num = vec![0.0f32; dh];
            let mut den = 0.0f32;
            for (j, v) in vals.iter().enumerate() {
                sm_add_term(&mut num, &mut den, (scores[j] - m).exp(), v);
            }
            let mut got = vec![0.0f32; dh];
            sm_renorm_into(&num, den, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-5, "ctx {ctx} dh {dh}: {a} vs {b}");
            }
        }
    }

    /// Subtracting a term with the bit-identical weight cancels the add up
    /// to one rounding step per element — the per-term bound the §12
    /// drift-refresh policy multiplies by the refresh interval.
    #[test]
    fn sm_sub_cancels_add_to_rounding() {
        use crate::util::Rng;
        let mut r = Rng::new(22);
        for &dh in &[1usize, 4, 16] {
            let base: Vec<f32> = (0..dh).map(|_| r.normal()).collect();
            let val: Vec<f32> = (0..dh).map(|_| r.normal()).collect();
            let mut num = base.clone();
            let mut den = 2.5f32;
            let w = 0.731f32;
            sm_add_term(&mut num, &mut den, w, &val);
            sm_sub_term(&mut num, &mut den, w, &val);
            for (a, b) in num.iter().zip(&base) {
                assert!((a - b).abs() <= 2.0 * f32::EPSILON * (1.0 + b.abs() + w), "{a} vs {b}");
            }
            assert!((den - 2.5).abs() <= 4.0 * f32::EPSILON);
        }
    }

    /// Replacing every key (all terms subtracted and re-added) still lands
    /// on the batch result — the "all keys changed" boundary where the
    /// engine's decision rule would normally pick a full recompute.
    #[test]
    fn sm_full_turnover_matches_rebuild() {
        use crate::util::Rng;
        let mut r = Rng::new(23);
        let (ctx, dh) = (9usize, 8usize);
        let s_old: Vec<f32> = (0..ctx).map(|_| r.normal()).collect();
        let v_old: Vec<Vec<f32>> = (0..ctx).map(|_| (0..dh).map(|_| r.normal()).collect()).collect();
        let s_new: Vec<f32> = (0..ctx).map(|_| r.normal() * 0.5).collect();
        let v_new: Vec<Vec<f32>> = (0..ctx).map(|_| (0..dh).map(|_| r.normal()).collect()).collect();
        let m = s_old.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut num = vec![0.0f32; dh];
        let mut den = 0.0f32;
        for j in 0..ctx {
            sm_add_term(&mut num, &mut den, (s_old[j] - m).exp(), &v_old[j]);
        }
        for j in 0..ctx {
            sm_sub_term(&mut num, &mut den, (s_old[j] - m).exp(), &v_old[j]);
            sm_add_term(&mut num, &mut den, (s_new[j] - m).exp(), &v_new[j]);
        }
        let mut got = vec![0.0f32; dh];
        sm_renorm_into(&num, den, &mut got);
        // Reference under the same (stale) shift — shift cancels in the
        // ratio, so compare against a fresh softmax of the new scores.
        let mut p = s_new.clone();
        softmax_row(&mut p);
        let mut want = vec![0.0f32; dh];
        for (j, v) in v_new.iter().enumerate() {
            axpy(p[j], v, &mut want);
        }
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn gelu_known_values() {
        assert!((gelu_scalar(0.0)).abs() < 1e-7);
        assert!((gelu_scalar(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu_scalar(-10.0).abs() < 1e-4);
        // gelu(1) ≈ 0.841192 (tanh approx)
        assert!((gelu_scalar(1.0) - 0.841192).abs() < 1e-4);
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let gamma = vec![1.0; 4];
        let beta = vec![0.0; 4];
        let mut out = vec![0.0; 4];
        layernorm_into(&x, &gamma, &beta, 1e-5, &mut out);
        let mean: f32 = out.iter().sum::<f32>() / 4.0;
        let var: f32 = out.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn dot_and_axpy() {
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let b = vec![5.0, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(dot(&a, &b), 35.0);
        let mut y = vec![1.0; 5];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0, 9.0, 11.0]);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }
}
