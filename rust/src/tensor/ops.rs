//! Dense f32 primitives used by the oracle forward pass and the incremental
//! engine's hot path. All routines are allocation-conscious: the hot-path
//! variants write into caller-provided buffers.

use super::Matrix;

/// `C = A · B` — blocked row-major matmul. `A: (m,k)`, `B: (k,n)`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// `C = A · B` into an existing buffer (zeroed here).
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    let (m, k, n) = (a.rows, a.cols, b.cols);
    c.data.iter_mut().for_each(|x| *x = 0.0);
    // i-k-j loop order: unit-stride access on B and C rows; the inner loop
    // auto-vectorizes.
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut c.data[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// `y = x · W` for a single row vector. `x: (k)`, `w: (k,n)`, `y: (n)`.
#[inline]
pub fn vec_matmul_into(x: &[f32], w: &Matrix, y: &mut [f32]) {
    assert_eq!(x.len(), w.rows);
    assert_eq!(y.len(), w.cols);
    y.iter_mut().for_each(|v| *v = 0.0);
    let cols = w.cols;
    // Two-row unrolling halves the passes over `y` (the write stream is
    // the bottleneck for 128-512-wide rows; measured best vs 1- and 4-row
    // variants on this host — measured on this host).
    let pairs = x.len() / 2;
    for pp in 0..pairs {
        let p = pp * 2;
        let (x0, x1) = (x[p], x[p + 1]);
        let w0 = &w.data[p * cols..(p + 1) * cols];
        let w1 = &w.data[(p + 1) * cols..(p + 2) * cols];
        for ((yv, &a), &b) in y.iter_mut().zip(w0).zip(w1) {
            *yv += x0 * a + x1 * b;
        }
    }
    if x.len() % 2 == 1 {
        let p = x.len() - 1;
        let xv = x[p];
        let wrow = &w.data[p * cols..(p + 1) * cols];
        for (yv, &wv) in y.iter_mut().zip(wrow) {
            *yv += xv * wv;
        }
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulators help the single-core autovectorizer.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// `y += alpha * x` (axpy).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv;
    }
}

/// In-place bias add over every row.
pub fn add_bias(m: &mut Matrix, bias: &[f32]) {
    assert_eq!(m.cols, bias.len());
    for i in 0..m.rows {
        for (v, &b) in m.row_mut(i).iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// GELU, tanh approximation — matches `jax.nn.gelu(x, approximate=True)`,
/// which is what the L2 model uses, so L2/L3 parity holds bit-for-bit at the
/// formula level.
#[inline]
pub fn gelu_scalar(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + ((C * (x + 0.044715 * x * x * x)).tanh()))
}

/// Element-wise GELU over a slice.
pub fn gelu_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = gelu_scalar(*x);
    }
}

/// Layer normalization of a single row into `out`.
#[inline]
pub fn layernorm_into(x: &[f32], gamma: &[f32], beta: &[f32], eps: f32, out: &mut [f32]) {
    let n = x.len() as f32;
    let mean = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + eps).sqrt();
    for i in 0..x.len() {
        out[i] = (x[i] - mean) * inv * gamma[i] + beta[i];
    }
}

/// Row-wise softmax in place (baseline attention only).
pub fn softmax_row(xs: &mut [f32]) {
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in xs.iter_mut() {
        *x *= inv;
    }
}

/// `out = a + b` element-wise.
pub fn add(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    Matrix {
        rows: a.rows,
        cols: a.cols,
        data: a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect(),
    }
}

/// Squared Euclidean norm.
#[inline]
pub fn norm_sq(x: &[f32]) -> f32 {
    dot(x, x)
}

/// Argmax index of a slice (first maximal element).
#[inline]
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = xs[0];
    for (i, &v) in xs.iter().enumerate().skip(1) {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for p in 0..a.cols {
                    s += a.get(i, p) * b.get(p, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        use crate::util::Rng;
        let mut r = Rng::new(1);
        for _ in 0..20 {
            let (m, k, n) = (r.range(1, 17), r.range(1, 17), r.range(1, 17));
            let a = Matrix::from_fn(m, k, |_, _| r.normal());
            let b = Matrix::from_fn(k, n, |_, _| r.normal());
            let c1 = matmul(&a, &b);
            let c2 = naive_matmul(&a, &b);
            assert!(c1.max_abs_diff(&c2) < 1e-4);
        }
    }

    #[test]
    fn vec_matmul_matches_matmul() {
        use crate::util::Rng;
        let mut r = Rng::new(2);
        let w = Matrix::from_fn(8, 5, |_, _| r.normal());
        let x: Vec<f32> = (0..8).map(|_| r.normal()).collect();
        let a = Matrix::from_vec(1, 8, x.clone());
        let full = matmul(&a, &w);
        let mut y = vec![0.0; 5];
        vec_matmul_into(&x, &w, &mut y);
        // Row-pair fusion reassociates additions: allow fp slack.
        for (a, b) in full.data.iter().zip(&y) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0, 2.0, 3.0, -5.0];
        softmax_row(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn softmax_stable_for_large_inputs() {
        let mut xs = vec![1000.0, 1001.0];
        softmax_row(&mut xs);
        assert!(xs.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn gelu_known_values() {
        assert!((gelu_scalar(0.0)).abs() < 1e-7);
        assert!((gelu_scalar(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu_scalar(-10.0).abs() < 1e-4);
        // gelu(1) ≈ 0.841192 (tanh approx)
        assert!((gelu_scalar(1.0) - 0.841192).abs() < 1e-4);
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let gamma = vec![1.0; 4];
        let beta = vec![0.0; 4];
        let mut out = vec![0.0; 4];
        layernorm_into(&x, &gamma, &beta, 1e-5, &mut out);
        let mean: f32 = out.iter().sum::<f32>() / 4.0;
        let var: f32 = out.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn dot_and_axpy() {
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let b = vec![5.0, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(dot(&a, &b), 35.0);
        let mut y = vec![1.0; 5];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0, 9.0, 11.0]);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }
}
