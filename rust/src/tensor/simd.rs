//! Kernel backend selection and explicit-SIMD implementations of the
//! `accum_row_tiled` hot-path core (AVX2 on x86_64, NEON on aarch64).
//!
//! # Bit-exactness contract
//!
//! Every backend produces **bit-identical** output to the scalar core
//! ([`ops::accum_row_tiled_scalar`]). This is not best-effort: the
//! differential suites, the golden-trace lock, and the process-global
//! codebook-product cache all compare `f32::to_bits`, so a backend that
//! reassociates sums or contracts mul+add into FMA would corrupt those
//! locks the moment dispatch picks it. The SIMD cores achieve exactness
//! by construction:
//!
//! - **Vectorize across columns, not across k.** The scalar core updates
//!   each output element as `y[j] += x0*w0[j] + x1*w1[j] + x2*w2[j] + x3*w3[j]`
//!   (left-to-right). A SIMD lane owns one output column `j`, so the
//!   per-element accumulation order is exactly the scalar order — lanes
//!   are independent columns and no reassociation ever happens.
//! - **No FMA.** The cores use separate multiply and add intrinsics
//!   (`_mm256_mul_ps`/`_mm256_add_ps`, `vmulq_f32`/`vaddq_f32`), never
//!   `_mm256_fmadd_ps`/`vfmaq_f32`: fusing would skip the intermediate
//!   rounding step the scalar expression performs. (Rust/LLVM never
//!   auto-contracts mul+add without fast-math flags, so the scalar
//!   reference is unfused even under `-C target-cpu=native`.)
//! - **Identical zero-skip semantics.** The scalar core skips a k-quad
//!   when all four `x` values compare `== 0.0` (which matches `-0.0`),
//!   so `0 * inf`/`0 * NaN` in the weight matrix never materialize. The
//!   SIMD cores perform the same scalar test before the vector inner
//!   loop, and the k-tail skips individual `x == 0.0` exactly as the
//!   scalar tail does.
//!
//! Because exactness holds by construction, no tolerance tier is needed
//! anywhere: the backend-equivalence tests below assert `to_bits`
//! equality outright. If a future backend (e.g. a k-vectorized AVX-512
//! core with horizontal reduction) must reassociate, it gets an explicit
//! tolerance tier in those tests — never a silent loosening — and must
//! be kept out of `auto` until every bit-exact consumer is audited.
//!
//! # Selection order
//!
//! 1. An explicit `scalar`/`simd` request — from `ServeConfig::kernel_backend`
//!    via [`set_kernel_backend`] at coordinator start, or a direct call —
//!    always wins.
//! 2. Otherwise (`auto`), the `VQT_KERNEL_BACKEND` env var, if set and
//!    valid, decides; this is the operator escape hatch when the config
//!    file says `auto`.
//! 3. Otherwise runtime feature detection picks the best available core:
//!    AVX2 on x86_64, NEON on aarch64, scalar elsewhere.
//!
//! A `simd` request on hardware without AVX2/NEON resolves to scalar
//! rather than failing: the request names a preference, and the scalar
//! core is always a correct implementation of the same contract.

use std::sync::atomic::{AtomicU8, Ordering};

use super::ops;
use super::Matrix;

/// Requested kernel backend (config/env/API surface).
///
/// This is the *request*; [`active_backend`] reports what dispatch
/// actually resolved it to on this machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum KernelBackend {
    /// Pick the best available core via runtime feature detection.
    Auto = 0,
    /// Force the scalar reference core.
    Scalar = 1,
    /// Prefer the explicit-SIMD core; falls back to scalar when the CPU
    /// lacks AVX2/NEON.
    Simd = 2,
}

impl KernelBackend {
    /// Parse a config/env spelling (`"auto" | "scalar" | "simd"`).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(KernelBackend::Auto),
            "scalar" => Ok(KernelBackend::Scalar),
            "simd" => Ok(KernelBackend::Simd),
            other => Err(format!(
                "unknown kernel backend {other:?} (expected \"auto\", \"scalar\", or \"simd\")"
            )),
        }
    }

    /// Canonical spelling (round-trips through [`Self::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Auto => "auto",
            KernelBackend::Scalar => "scalar",
            KernelBackend::Simd => "simd",
        }
    }

    fn from_u8(v: u8) -> Self {
        match v {
            1 => KernelBackend::Scalar,
            2 => KernelBackend::Simd,
            _ => KernelBackend::Auto,
        }
    }
}

/// The concrete core dispatch resolved to on this machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResolvedBackend {
    /// Portable scalar core (the correctness reference).
    Scalar,
    /// 8-wide f32 core via `core::arch::x86_64` AVX2 intrinsics.
    Avx2,
    /// 4-wide f32 core via `core::arch::aarch64` NEON intrinsics.
    Neon,
}

impl ResolvedBackend {
    /// Human/Stats-JSON name of the resolved core.
    pub fn name(self) -> &'static str {
        match self {
            ResolvedBackend::Scalar => "scalar",
            ResolvedBackend::Avx2 => "avx2",
            ResolvedBackend::Neon => "neon",
        }
    }
}

/// Best SIMD core the running CPU supports, if any.
fn simd_available() -> Option<ResolvedBackend> {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Some(ResolvedBackend::Avx2);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Some(ResolvedBackend::Neon);
        }
    }
    None
}

/// Sentinel meaning "not yet initialized from the environment".
const UNSET: u8 = u8::MAX;

/// Process-global requested backend. Kernel dispatch is process-global
/// (the codebook-product cache is too, and mixing backends across
/// workers would be pointless: they are bit-identical anyway), so one
/// atomic suffices.
static REQUESTED: AtomicU8 = AtomicU8::new(UNSET);

fn env_request() -> Option<KernelBackend> {
    let v = std::env::var("VQT_KERNEL_BACKEND").ok()?;
    match KernelBackend::parse(&v) {
        Ok(b) => Some(b),
        Err(e) => {
            log::warn!("ignoring VQT_KERNEL_BACKEND: {e}");
            None
        }
    }
}

/// The backend currently requested (config/env/default), before
/// hardware resolution.
pub fn requested_backend() -> KernelBackend {
    match REQUESTED.load(Ordering::Acquire) {
        UNSET => {
            let b = env_request().unwrap_or(KernelBackend::Auto);
            // First initializer wins; a concurrent explicit set keeps
            // its value.
            let _ = REQUESTED.compare_exchange(
                UNSET,
                b as u8,
                Ordering::AcqRel,
                Ordering::Acquire,
            );
            KernelBackend::from_u8(REQUESTED.load(Ordering::Acquire))
        }
        v => KernelBackend::from_u8(v),
    }
}

/// Set the process-global kernel backend and return what actually took
/// effect. An explicit `Scalar`/`Simd` request always wins; an `Auto`
/// request defers to the `VQT_KERNEL_BACKEND` env var when set (the
/// operator escape hatch for configs that say `auto`).
pub fn set_kernel_backend(req: KernelBackend) -> KernelBackend {
    let effective = match req {
        KernelBackend::Auto => env_request().unwrap_or(KernelBackend::Auto),
        explicit => explicit,
    };
    REQUESTED.store(effective as u8, Ordering::Release);
    effective
}

/// The concrete core the current request resolves to on this machine.
pub fn active_backend() -> ResolvedBackend {
    match requested_backend() {
        KernelBackend::Scalar => ResolvedBackend::Scalar,
        KernelBackend::Auto | KernelBackend::Simd => {
            simd_available().unwrap_or(ResolvedBackend::Scalar)
        }
    }
}

/// Backend-pinned entry point (equivalence tests and benchmarks): same
/// contract as the scalar core, with dispatch forced to `backend`.
pub(crate) fn accum_row_tiled_with(
    backend: ResolvedBackend,
    x: &[f32],
    w: &Matrix,
    y: &mut [f32],
) {
    debug_assert_eq!(x.len(), w.rows);
    debug_assert_eq!(y.len(), w.cols);
    match backend {
        ResolvedBackend::Scalar => ops::accum_row_tiled_scalar(x, w, y),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: dispatch only resolves to Avx2 after
        // `is_x86_feature_detected!("avx2")` succeeded.
        ResolvedBackend::Avx2 => unsafe { avx2::accum_row_tiled(x, w, y) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: dispatch only resolves to Neon after
        // `is_aarch64_feature_detected!("neon")` succeeded.
        ResolvedBackend::Neon => unsafe { neon::accum_row_tiled(x, w, y) },
        // A Resolved variant whose core is compiled out for this arch
        // (e.g. a deserialized/forced Neon on x86_64): the scalar core
        // is always a correct implementation of the same contract.
        #[allow(unreachable_patterns)]
        _ => ops::accum_row_tiled_scalar(x, w, y),
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::super::ops::N_TILE;
    use super::Matrix;
    use core::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
    };

    /// AVX2 mirror of `accum_row_tiled_scalar`: 8 output columns per
    /// vector, mul+add (never FMA), scalar-identical zero-quad skip.
    /// Column-tail (`jw % 8`) and k-tail (`k % 4`) fall back to the
    /// exact scalar expressions.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn accum_row_tiled(x: &[f32], w: &Matrix, y: &mut [f32]) {
        let n = w.cols;
        let k = x.len();
        let k4 = k - k % 4;
        let mut j0 = 0;
        while j0 < n {
            let jw = (n - j0).min(N_TILE);
            let jw8 = jw - jw % 8;
            let ytile = &mut y[j0..j0 + jw];
            let mut p = 0;
            while p < k4 {
                let (x0, x1, x2, x3) = (x[p], x[p + 1], x[p + 2], x[p + 3]);
                if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                    p += 4;
                    continue;
                }
                let w0 = &w.data[p * n + j0..p * n + j0 + jw];
                let w1 = &w.data[(p + 1) * n + j0..(p + 1) * n + j0 + jw];
                let w2 = &w.data[(p + 2) * n + j0..(p + 2) * n + j0 + jw];
                let w3 = &w.data[(p + 3) * n + j0..(p + 3) * n + j0 + jw];
                let (xv0, xv1) = (_mm256_set1_ps(x0), _mm256_set1_ps(x1));
                let (xv2, xv3) = (_mm256_set1_ps(x2), _mm256_set1_ps(x3));
                let mut j = 0;
                while j < jw8 {
                    // Per lane: y + (((x0*a0 + x1*a1) + x2*a2) + x3*a3)
                    // — the exact scalar evaluation order.
                    let s01 = _mm256_add_ps(
                        _mm256_mul_ps(xv0, _mm256_loadu_ps(w0.as_ptr().add(j))),
                        _mm256_mul_ps(xv1, _mm256_loadu_ps(w1.as_ptr().add(j))),
                    );
                    let s012 =
                        _mm256_add_ps(s01, _mm256_mul_ps(xv2, _mm256_loadu_ps(w2.as_ptr().add(j))));
                    let s = _mm256_add_ps(
                        s012,
                        _mm256_mul_ps(xv3, _mm256_loadu_ps(w3.as_ptr().add(j))),
                    );
                    let yp = ytile.as_mut_ptr().add(j);
                    _mm256_storeu_ps(yp, _mm256_add_ps(_mm256_loadu_ps(yp), s));
                    j += 8;
                }
                for ((((yv, &a0), &a1), &a2), &a3) in ytile[jw8..]
                    .iter_mut()
                    .zip(&w0[jw8..])
                    .zip(&w1[jw8..])
                    .zip(&w2[jw8..])
                    .zip(&w3[jw8..])
                {
                    *yv += x0 * a0 + x1 * a1 + x2 * a2 + x3 * a3;
                }
                p += 4;
            }
            for (pp, &xv) in x.iter().enumerate().skip(k4) {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w.data[pp * n + j0..pp * n + j0 + jw];
                let xvv = _mm256_set1_ps(xv);
                let mut j = 0;
                while j < jw8 {
                    let yp = ytile.as_mut_ptr().add(j);
                    let s = _mm256_mul_ps(xvv, _mm256_loadu_ps(wrow.as_ptr().add(j)));
                    _mm256_storeu_ps(yp, _mm256_add_ps(_mm256_loadu_ps(yp), s));
                    j += 8;
                }
                for (yv, &wv) in ytile[jw8..].iter_mut().zip(&wrow[jw8..]) {
                    *yv += xv * wv;
                }
            }
            j0 += jw;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::super::ops::N_TILE;
    use super::Matrix;
    use core::arch::aarch64::{vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vst1q_f32};

    /// NEON mirror of `accum_row_tiled_scalar`: 4 output columns per
    /// vector, mul+add (never `vfmaq_f32`), scalar-identical zero-quad
    /// skip; tails fall back to the exact scalar expressions.
    ///
    /// # Safety
    /// Caller must have verified NEON support at runtime.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn accum_row_tiled(x: &[f32], w: &Matrix, y: &mut [f32]) {
        let n = w.cols;
        let k = x.len();
        let k4 = k - k % 4;
        let mut j0 = 0;
        while j0 < n {
            let jw = (n - j0).min(N_TILE);
            let jw4 = jw - jw % 4;
            let ytile = &mut y[j0..j0 + jw];
            let mut p = 0;
            while p < k4 {
                let (x0, x1, x2, x3) = (x[p], x[p + 1], x[p + 2], x[p + 3]);
                if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                    p += 4;
                    continue;
                }
                let w0 = &w.data[p * n + j0..p * n + j0 + jw];
                let w1 = &w.data[(p + 1) * n + j0..(p + 1) * n + j0 + jw];
                let w2 = &w.data[(p + 2) * n + j0..(p + 2) * n + j0 + jw];
                let w3 = &w.data[(p + 3) * n + j0..(p + 3) * n + j0 + jw];
                let (xv0, xv1) = (vdupq_n_f32(x0), vdupq_n_f32(x1));
                let (xv2, xv3) = (vdupq_n_f32(x2), vdupq_n_f32(x3));
                let mut j = 0;
                while j < jw4 {
                    // Per lane: y + (((x0*a0 + x1*a1) + x2*a2) + x3*a3)
                    // — the exact scalar evaluation order.
                    let s01 = vaddq_f32(
                        vmulq_f32(xv0, vld1q_f32(w0.as_ptr().add(j))),
                        vmulq_f32(xv1, vld1q_f32(w1.as_ptr().add(j))),
                    );
                    let s012 = vaddq_f32(s01, vmulq_f32(xv2, vld1q_f32(w2.as_ptr().add(j))));
                    let s = vaddq_f32(s012, vmulq_f32(xv3, vld1q_f32(w3.as_ptr().add(j))));
                    let yp = ytile.as_mut_ptr().add(j);
                    vst1q_f32(yp, vaddq_f32(vld1q_f32(yp), s));
                    j += 4;
                }
                for ((((yv, &a0), &a1), &a2), &a3) in ytile[jw4..]
                    .iter_mut()
                    .zip(&w0[jw4..])
                    .zip(&w1[jw4..])
                    .zip(&w2[jw4..])
                    .zip(&w3[jw4..])
                {
                    *yv += x0 * a0 + x1 * a1 + x2 * a2 + x3 * a3;
                }
                p += 4;
            }
            for (pp, &xv) in x.iter().enumerate().skip(k4) {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w.data[pp * n + j0..pp * n + j0 + jw];
                let xvv = vdupq_n_f32(xv);
                let mut j = 0;
                while j < jw4 {
                    let yp = ytile.as_mut_ptr().add(j);
                    let s = vmulq_f32(xvv, vld1q_f32(wrow.as_ptr().add(j)));
                    vst1q_f32(yp, vaddq_f32(vld1q_f32(yp), s));
                    j += 4;
                }
                for (yv, &wv) in ytile[jw4..].iter_mut().zip(&wrow[jw4..]) {
                    *yv += xv * wv;
                }
            }
            j0 += jw;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Every backend available on this machine, scalar first. On a CPU
    /// without AVX2/NEON this is just `[Scalar]` and the equivalence
    /// tests degenerate to scalar-vs-scalar (still a valid smoke).
    fn backends_under_test() -> Vec<ResolvedBackend> {
        let mut v = vec![ResolvedBackend::Scalar];
        if let Some(b) = simd_available() {
            v.push(b);
        }
        v
    }

    fn rand_vec(r: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| r.f32() * 2.0 - 1.0).collect()
    }

    fn rand_mat(r: &mut Rng, rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: rand_vec(r, rows * cols),
        }
    }

    fn run_with(b: ResolvedBackend, x: &[f32], w: &Matrix, y0: &[f32]) -> Vec<u32> {
        let mut y = y0.to_vec();
        accum_row_tiled_with(b, x, w, &mut y);
        y.iter().map(|v| v.to_bits()).collect()
    }

    /// Tile-boundary (k, n) shapes: below/at/above N_TILE and the SIMD
    /// widths, plus k-tail remainders.
    const SHAPES: &[(usize, usize)] = &[
        (1, 1),
        (3, 7),
        (5, 8),
        (4, 9),
        (7, 63),
        (8, 64),
        (9, 65),
        (63, 65),
        (64, 64),
        (129, 100),
        (130, 131),
        (16, 257),
    ];

    #[test]
    fn simd_backends_bitwise_equal_scalar_at_tile_boundaries() {
        let mut r = Rng::new(0x51D0);
        for &(k, n) in SHAPES {
            let x = rand_vec(&mut r, k);
            let w = rand_mat(&mut r, k, n);
            // Non-zero starting accumulator: the core must *add into* y.
            let y0 = rand_vec(&mut r, n);
            let want = run_with(ResolvedBackend::Scalar, &x, &w, &y0);
            for b in backends_under_test() {
                let got = run_with(b, &x, &w, &y0);
                assert_eq!(got, want, "backend {} diverged at (k={k}, n={n})", b.name());
            }
        }
    }

    #[test]
    fn zero_skip_shields_nonfinite_weights_on_every_backend() {
        // Quads of exact zeros (mixing -0.0) must skip the weight rows
        // entirely, so inf/NaN planted there never reach the output.
        let mut r = Rng::new(0xDEAD);
        for &(k, n) in &[(8usize, 65usize), (12, 64), (9, 31)] {
            let mut x = rand_vec(&mut r, k);
            let mut w = rand_mat(&mut r, k, n);
            for p in 0..4.min(k) {
                x[p] = if p % 2 == 0 { 0.0 } else { -0.0 };
                for j in 0..n {
                    w.data[p * n + j] = if j % 2 == 0 { f32::INFINITY } else { f32::NAN };
                }
            }
            if k > 4 {
                // k-tail zero (k=9 case): shields its row the same way.
                x[k - 1] = -0.0;
                for j in 0..n {
                    w.data[(k - 1) * n + j] = f32::NAN;
                }
            }
            let y0 = vec![0.0; n];
            let want = run_with(ResolvedBackend::Scalar, &x, &w, &y0);
            assert!(
                want.iter().all(|b| f32::from_bits(*b).is_finite()),
                "scalar reference must skip the poisoned rows (k={k}, n={n})"
            );
            for b in backends_under_test() {
                let got = run_with(b, &x, &w, &y0);
                assert_eq!(got, want, "backend {} diverged at (k={k}, n={n})", b.name());
            }
        }
    }

    #[test]
    fn partially_zero_quads_are_not_skipped_on_any_backend() {
        // One non-zero in the quad ⇒ the quad runs; denormal-free random
        // data keeps the comparison meaningful, and bit equality must
        // still hold including any NaN/inf the math produces.
        let mut r = Rng::new(0xBEEF);
        let (k, n) = (8usize, 70usize);
        let mut x = rand_vec(&mut r, k);
        x[0] = 0.0;
        x[1] = -0.0;
        x[2] = 0.0;
        // x[3] stays non-zero: the quad must execute.
        let mut w = rand_mat(&mut r, k, n);
        w.data[3 * n + 5] = f32::INFINITY;
        let y0 = vec![0.0; n];
        let want = run_with(ResolvedBackend::Scalar, &x, &w, &y0);
        assert!(f32::from_bits(want[5]).is_infinite());
        for b in backends_under_test() {
            assert_eq!(run_with(b, &x, &w, &y0), want, "backend {}", b.name());
        }
    }

    #[test]
    fn auto_dispatch_is_bitwise_equal_to_scalar() {
        // Whatever `auto` resolves to on this machine (and whatever the
        // test environment pinned via VQT_KERNEL_BACKEND), the dispatched
        // entry point must match the scalar reference bit-for-bit.
        let mut r = Rng::new(7);
        let (k, n) = (130usize, 129usize);
        let x = rand_vec(&mut r, k);
        let w = rand_mat(&mut r, k, n);
        let y0 = rand_vec(&mut r, n);
        let want = run_with(ResolvedBackend::Scalar, &x, &w, &y0);
        let mut y = y0.clone();
        accum_row_tiled_with(active_backend(), &x, &w, &mut y);
        let got: Vec<u32> = y.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want, "auto resolved to {}", active_backend().name());
    }

    #[test]
    fn backend_parse_round_trips_and_rejects_garbage() {
        for b in [KernelBackend::Auto, KernelBackend::Scalar, KernelBackend::Simd] {
            assert_eq!(KernelBackend::parse(b.name()), Ok(b));
        }
        assert_eq!(KernelBackend::parse(" SIMD "), Ok(KernelBackend::Simd));
        let err = KernelBackend::parse("avx512").unwrap_err();
        assert!(err.contains("avx512"), "{err}");
    }

    #[test]
    fn explicit_requests_resolve_sensibly() {
        // Pure resolution logic — no global/env mutation (unit tests run
        // in parallel threads).
        assert_eq!(KernelBackend::from_u8(KernelBackend::Scalar as u8), KernelBackend::Scalar);
        assert_eq!(KernelBackend::from_u8(KernelBackend::Simd as u8), KernelBackend::Simd);
        assert_eq!(KernelBackend::from_u8(UNSET), KernelBackend::Auto);
        // `simd` on a machine without SIMD must fall back, not fail.
        let resolved = simd_available().unwrap_or(ResolvedBackend::Scalar);
        assert!(matches!(
            resolved,
            ResolvedBackend::Scalar | ResolvedBackend::Avx2 | ResolvedBackend::Neon
        ));
    }
}
