//! Zero-dependency request tracing: per-stage monotonic timings folded
//! into one record per request.
//!
//! The design is built around the serving topology: every request is
//! executed start-to-finish on exactly one shard worker thread, so the
//! active trace lives in a thread-local and the per-shard ring buffers
//! are owned single-threaded by their worker — no locks, no atomics on
//! the hot path. The *only* cost a stage guard pays while tracing is
//! disabled is one thread-local `Cell<bool>` load (cheaper than the
//! "at most one atomic load per stage" contract in ARCHITECTURE.md §11,
//! which micro_hotpath's overhead table enforces at ≤2% per edit).
//!
//! Lifecycle per traced request:
//!
//! 1. the worker calls [`begin`] with the request's enqueue instant (the
//!    trace epoch — every stage timestamp is microseconds since then);
//! 2. instrumented code creates RAII [`stage`] guards (engine, cache
//!    lookup, wave gather/GEMM/scatter, session fault-in, …); repeated
//!    guards with the same name *aggregate* (busy sum + hit count)
//!    instead of appending, so a 128-row wave doesn't emit 128 spans;
//! 3. the worker calls [`finish`] to detach the [`TraceRecord`], stamps
//!    kind/session/shard, and either keeps it in its own [`TraceRing`]
//!    (synchronous replies) or ships it with the completion so the async
//!    front end can append the `reply_write` stage after the bytes hit
//!    the socket.
//!
//! Requests that are *not* traced call [`ensure_off`] instead of
//! [`begin`], which also makes a panic-unwound predecessor's stale state
//! harmless.

use crate::util::Json;
use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::time::Instant;

thread_local! {
    /// Fast-path flag: is a trace active on this thread? Kept separate
    /// from `CURRENT` so the disabled guard never touches the RefCell.
    static ENABLED: Cell<bool> = const { Cell::new(false) };
    static CURRENT: RefCell<Option<Active>> = const { RefCell::new(None) };
}

struct Active {
    epoch: Instant,
    stages: Vec<Stage>,
}

impl Active {
    fn fold(&mut self, name: &'static str, start_us: u64, end_us: u64) {
        if let Some(s) = self.stages.iter_mut().find(|s| s.name == name) {
            s.last_end_us = s.last_end_us.max(end_us);
            s.busy_us += end_us - start_us;
            s.count += 1;
        } else {
            self.stages.push(Stage {
                name,
                first_start_us: start_us,
                last_end_us: end_us,
                busy_us: end_us - start_us,
                count: 1,
            });
        }
    }
}

/// One named stage of a request, aggregated across repeat entries.
/// Timestamps are microseconds relative to the request's enqueue epoch;
/// `busy_us` is the summed in-stage time (≤ `last_end_us -
/// first_start_us` when the stage was entered more than once with other
/// work in between).
#[derive(Clone, Debug)]
pub struct Stage {
    pub name: &'static str,
    pub first_start_us: u64,
    pub last_end_us: u64,
    pub busy_us: u64,
    pub count: u64,
}

impl Stage {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name)),
            ("start_us", Json::num(self.first_start_us as f64)),
            ("end_us", Json::num(self.last_end_us as f64)),
            ("busy_us", Json::num(self.busy_us as f64)),
            ("count", Json::num(self.count as f64)),
        ])
    }
}

/// A completed request trace. `total_us` is the latest stage end seen —
/// it grows when the async front end appends `reply_write` after the
/// reply bytes are flushed.
#[derive(Clone, Debug)]
pub struct TraceRecord {
    /// The enqueue instant every `*_us` field is relative to. Not
    /// serialized; kept so later stages (reply write) share the epoch.
    pub epoch: Instant,
    pub kind: &'static str,
    pub session: Option<String>,
    pub shard: usize,
    pub total_us: u64,
    pub stages: Vec<Stage>,
}

impl TraceRecord {
    /// Microseconds from this record's epoch to `t` (0 if `t` precedes it).
    pub fn rel_us(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Append a single-entry stage measured by absolute instants (the
    /// async front end's `reply_write`, the worker's `queue_wait`).
    pub fn push_span(&mut self, name: &'static str, start: Instant, end: Instant) {
        let s = self.rel_us(start);
        let e = self.rel_us(end).max(s);
        self.stages.push(Stage {
            name,
            first_start_us: s,
            last_end_us: e,
            busy_us: e - s,
            count: 1,
        });
        self.total_us = self.total_us.max(e);
    }

    /// Re-express this record against a later epoch (a pooled wave is
    /// traced once against the *earliest* enqueue in the wave; each
    /// member job's copy is rebased to its own enqueue instant so its
    /// timeline starts at 0).
    pub fn rebased(&self, new_epoch: Instant) -> TraceRecord {
        let delta = new_epoch.saturating_duration_since(self.epoch).as_micros() as u64;
        let stages: Vec<Stage> = self
            .stages
            .iter()
            .map(|s| Stage {
                name: s.name,
                first_start_us: s.first_start_us.saturating_sub(delta),
                last_end_us: s.last_end_us.saturating_sub(delta),
                busy_us: s.busy_us,
                count: s.count,
            })
            .collect();
        TraceRecord {
            epoch: new_epoch,
            kind: self.kind,
            session: self.session.clone(),
            shard: self.shard,
            total_us: stages.iter().map(|s| s.last_end_us).max().unwrap_or(0),
            stages,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str(self.kind)),
            (
                "session",
                match &self.session {
                    Some(s) => Json::str(s.clone()),
                    None => Json::Null,
                },
            ),
            ("shard", Json::num(self.shard as f64)),
            ("total_us", Json::num(self.total_us as f64)),
            (
                "stages",
                Json::Arr(self.stages.iter().map(|s| s.to_json()).collect()),
            ),
        ])
    }
}

/// Start tracing the current request on this thread. `epoch` should be
/// the request's enqueue instant so queue wait shows up at offset 0.
pub fn begin(epoch: Instant) {
    CURRENT.with(|c| {
        *c.borrow_mut() = Some(Active {
            epoch,
            stages: Vec::with_capacity(8),
        })
    });
    ENABLED.with(|e| e.set(true));
}

/// Clear any active trace (the untraced-request entry point; also
/// neutralizes state left behind by a panic-unwound predecessor).
pub fn ensure_off() {
    ENABLED.with(|e| {
        if e.get() {
            e.set(false);
            CURRENT.with(|c| c.borrow_mut().take());
        }
    });
}

/// Is a trace active on this thread? (One thread-local load.)
pub fn active() -> bool {
    ENABLED.with(|e| e.get())
}

/// RAII stage guard: folds `(name, enter..drop)` into the active trace.
/// Inert — no clock read, no RefCell — when tracing is off.
#[must_use = "the stage ends when the guard drops"]
pub struct StageGuard {
    name: &'static str,
    start: Option<Instant>,
}

impl Drop for StageGuard {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let end = Instant::now();
        CURRENT.with(|c| {
            if let Some(t) = c.borrow_mut().as_mut() {
                let s = start.saturating_duration_since(t.epoch).as_micros() as u64;
                let e = end.saturating_duration_since(t.epoch).as_micros() as u64;
                t.fold(self.name, s, e.max(s));
            }
        });
    }
}

/// Enter a named stage of the active trace. `name` must be `'static`
/// (stage identity is pointer-free string equality on literals).
#[inline]
pub fn stage(name: &'static str) -> StageGuard {
    StageGuard {
        name,
        start: if active() { Some(Instant::now()) } else { None },
    }
}

/// Fold an explicitly-measured span into the active trace (used where
/// the boundaries are pre-existing instants, e.g. enqueue→dequeue).
pub fn record_span(name: &'static str, start: Instant, end: Instant) {
    if !active() {
        return;
    }
    CURRENT.with(|c| {
        if let Some(t) = c.borrow_mut().as_mut() {
            let s = start.saturating_duration_since(t.epoch).as_micros() as u64;
            let e = end.saturating_duration_since(t.epoch).as_micros() as u64;
            t.fold(name, s, e.max(s));
        }
    });
}

/// End the active trace and detach its record (kind/session/shard are
/// stamped by the caller, which knows the request). `None` if no trace
/// was active.
pub fn finish() -> Option<TraceRecord> {
    ENABLED.with(|e| e.set(false));
    let active = CURRENT.with(|c| c.borrow_mut().take())?;
    let total_us = active.stages.iter().map(|s| s.last_end_us).max().unwrap_or(0);
    Some(TraceRecord {
        epoch: active.epoch,
        kind: "",
        session: None,
        shard: 0,
        total_us,
        stages: active.stages,
    })
}

/// Bounded FIFO of completed traces. Each shard worker (and the async
/// front end) owns one; single-owner access is what makes it lock-free.
#[derive(Debug, Default)]
pub struct TraceRing {
    cap: usize,
    buf: VecDeque<TraceRecord>,
}

impl TraceRing {
    pub fn new(cap: usize) -> Self {
        TraceRing {
            cap,
            buf: VecDeque::with_capacity(cap.min(1024)),
        }
    }

    /// Retain `r` as one of the last `cap` completed traces (dropped
    /// outright when the ring is configured off, `cap == 0`).
    pub fn push(&mut self, r: TraceRecord) {
        if self.cap == 0 {
            return;
        }
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(r);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Oldest-first JSON array of the retained records.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.buf.iter().map(|r| r.to_json()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn disabled_guard_is_inert() {
        ensure_off();
        {
            let _g = stage("nothing");
        }
        assert!(!active());
        assert!(finish().is_none());
    }

    #[test]
    fn stages_aggregate_and_finish_detaches() {
        let epoch = Instant::now();
        begin(epoch);
        assert!(active());
        for _ in 0..3 {
            let _g = stage("work");
            std::hint::black_box(());
        }
        record_span("queue_wait", epoch, epoch + Duration::from_micros(40));
        let rec = finish().expect("active trace");
        assert!(!active());
        assert!(finish().is_none(), "finish detaches");
        assert_eq!(rec.stages.len(), 2, "repeat guards aggregate");
        let work = rec.stages.iter().find(|s| s.name == "work").unwrap();
        assert_eq!(work.count, 3);
        assert!(work.first_start_us <= work.last_end_us);
        assert!(work.busy_us <= work.last_end_us.saturating_sub(work.first_start_us) + 1);
        let qw = rec.stages.iter().find(|s| s.name == "queue_wait").unwrap();
        assert_eq!((qw.first_start_us, qw.last_end_us), (0, 40));
        assert!(rec.total_us >= 40);
    }

    #[test]
    fn push_span_extends_total() {
        begin(Instant::now());
        let mut rec = finish().unwrap();
        let s = rec.epoch + Duration::from_micros(100);
        rec.push_span("reply_write", s, s + Duration::from_micros(25));
        assert_eq!(rec.total_us, 125);
        let st = rec.stages.last().unwrap();
        assert_eq!((st.first_start_us, st.last_end_us, st.busy_us), (100, 125, 25));
    }

    #[test]
    fn rebase_shifts_timeline() {
        let epoch = Instant::now();
        begin(epoch);
        record_span(
            "engine",
            epoch + Duration::from_micros(50),
            epoch + Duration::from_micros(90),
        );
        let rec = finish().unwrap();
        let shifted = rec.rebased(epoch + Duration::from_micros(30));
        let st = &shifted.stages[0];
        assert_eq!((st.first_start_us, st.last_end_us), (20, 60));
        assert_eq!(shifted.total_us, 60);
        assert_eq!(st.busy_us, 40, "durations survive rebasing");
    }

    #[test]
    fn ring_bounds_and_zero_cap() {
        let mk = |kind| TraceRecord {
            epoch: Instant::now(),
            kind,
            session: None,
            shard: 0,
            total_us: 1,
            stages: Vec::new(),
        };
        let mut off = TraceRing::new(0);
        off.push(mk("a"));
        assert!(off.is_empty());
        let mut ring = TraceRing::new(2);
        ring.push(mk("a"));
        ring.push(mk("b"));
        ring.push(mk("c"));
        assert_eq!(ring.len(), 2);
        let arr = ring.to_json();
        let kinds: Vec<&str> = arr
            .as_arr()
            .unwrap()
            .iter()
            .map(|r| r.get("kind").as_str().unwrap())
            .collect();
        assert_eq!(kinds, vec!["b", "c"], "oldest evicted first");
    }

    #[test]
    fn record_json_shape() {
        begin(Instant::now());
        {
            let _g = stage("engine");
        }
        let mut rec = finish().unwrap();
        rec.kind = "edit";
        rec.session = Some("s1".into());
        rec.shard = 3;
        let j = rec.to_json();
        assert_eq!(j.get("kind").as_str(), Some("edit"));
        assert_eq!(j.get("session").as_str(), Some("s1"));
        assert_eq!(j.get("shard").as_usize(), Some(3));
        let stages = j.get("stages").as_arr().unwrap();
        assert_eq!(stages.len(), 1);
        for key in ["name", "start_us", "end_us", "busy_us", "count"] {
            assert!(!matches!(stages[0].get(key), Json::Null), "missing {key}");
        }
    }
}
