//! Deterministic PRNG utilities.
//!
//! The offline crate set has no `rand`; we implement SplitMix64 (for seeding)
//! and xoshiro256++ (for the main stream), which are small, fast, and have
//! well-understood statistical quality. Everything downstream of a seed is
//! fully deterministic, which the reproduction relies on: the Python data
//! generator and the Rust workload generator share protocols via fixed seeds.

/// SplitMix64 step: used to expand a single u64 seed into stream state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Deterministic, seedable, `Clone` for forked streams.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Fork an independent stream (e.g. per worker / per document).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform u64 in `[0, n)` (Lemire-style rejection-free for our sizes).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply trick: unbiased enough for workload generation,
        // and exactly reproducible.
        let x = self.next_u64();
        (((x as u128) * (n as u128)) >> 64) as usize
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller (pairs discarded for simplicity).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Geometric-ish heavy-tailed positive count with mean roughly `mean`
    /// (used by the edit-trace generator for span counts/lengths).
    pub fn heavy_count(&mut self, mean: f64) -> usize {
        // Sample from a mixture: mostly geometric, occasionally a long tail.
        let p = 1.0 / mean.max(1.0);
        let mut k = 1usize;
        while !self.chance(p) && k < 10_000 {
            k += 1;
        }
        if self.chance(0.05) {
            k *= self.range(2, 6);
        }
        k
    }

    /// Sample `k` distinct sorted indices from `[0, n)` (reservoir-free,
    /// suitable for k ≪ n and k ≈ n alike).
    pub fn sorted_subset(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "subset size {k} exceeds population {n}");
        if k == 0 {
            return vec![];
        }
        // Floyd's algorithm.
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element reference.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.below(13);
            assert!(x < 13);
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sorted_subset_properties() {
        let mut r = Rng::new(5);
        for _ in 0..200 {
            let n = r.range(1, 50);
            let k = r.range(0, n);
            let s = r.sorted_subset(n, k);
            assert_eq!(s.len(), k);
            assert!(s.windows(2).all(|w| w[0] < w[1]), "strictly sorted");
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn sorted_subset_full_population() {
        let mut r = Rng::new(9);
        let s = r.sorted_subset(10, 10);
        assert_eq!(s, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
