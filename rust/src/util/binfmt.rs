//! Binary tensor container — the weight/data interchange format between the
//! Python build path (`python/compile/binfmt.py`, written by
//! `python/compile/aot.py` via `make artifacts`) and the Rust runtime.
//!
//! Layout (little-endian):
//! ```text
//! magic  "VQTB"            4 bytes
//! version u32              (currently 1)
//! count   u32              number of entries
//! entries:
//!   name_len u32, name utf-8 bytes
//!   dtype    u8            0 = f32, 1 = i32
//!   ndim     u8
//!   dims     u32 × ndim
//!   data     dtype × prod(dims)
//! ```
//! Deliberately simple: no alignment games, no compression — the artifacts
//! are built once per `make artifacts` and loaded once at startup.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"VQTB";
const VERSION: u32 = 1;

/// One named tensor.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::F32 { dims, .. } | Tensor::I32 { dims, .. } => dims,
        }
    }

    pub fn f32(dims: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor::F32 { dims, data }
    }

    pub fn i32(dims: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor::I32 { dims, data }
    }

    pub fn as_f32(&self) -> Result<(&[usize], &[f32])> {
        match self {
            Tensor::F32 { dims, data } => Ok((dims, data)),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<(&[usize], &[i32])> {
        match self {
            Tensor::I32 { dims, data } => Ok((dims, data)),
            _ => bail!("tensor is not i32"),
        }
    }
}

/// A named collection of tensors (deterministic iteration order).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TensorFile {
    pub entries: BTreeMap<String, Tensor>,
}

impl TensorFile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        self.entries.insert(name.into(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.entries
            .get(name)
            .with_context(|| format!("missing tensor '{name}'"))
    }

    /// Fetch an f32 tensor, checking its shape.
    pub fn f32_shaped(&self, name: &str, dims: &[usize]) -> Result<&[f32]> {
        let (d, data) = self.get(name)?.as_f32()?;
        if d != dims {
            bail!("tensor '{name}' has dims {d:?}, expected {dims:?}");
        }
        Ok(data)
    }

    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(self.entries.len() as u32).to_le_bytes())?;
        for (name, t) in &self.entries {
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            match t {
                Tensor::F32 { dims, data } => {
                    w.write_all(&[0u8, dims.len() as u8])?;
                    for &d in dims {
                        w.write_all(&(d as u32).to_le_bytes())?;
                    }
                    for &x in data {
                        w.write_all(&x.to_le_bytes())?;
                    }
                }
                Tensor::I32 { dims, data } => {
                    w.write_all(&[1u8, dims.len() as u8])?;
                    for &d in dims {
                        w.write_all(&(d as u32).to_le_bytes())?;
                    }
                    for &x in data {
                        w.write_all(&x.to_le_bytes())?;
                    }
                }
            }
        }
        Ok(())
    }

    pub fn read_from(r: &mut impl Read) -> Result<TensorFile> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic).context("reading magic")?;
        if &magic != MAGIC {
            bail!("bad magic {magic:?}: not a VQTB tensor file");
        }
        let version = read_u32(r)?;
        if version != VERSION {
            bail!("unsupported VQTB version {version}");
        }
        let count = read_u32(r)? as usize;
        if count > 1_000_000 {
            bail!("implausible entry count {count}");
        }
        let mut entries = BTreeMap::new();
        for _ in 0..count {
            let name_len = read_u32(r)? as usize;
            if name_len > 4096 {
                bail!("implausible name length {name_len}");
            }
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("tensor name not utf-8")?;
            let mut hdr = [0u8; 2];
            r.read_exact(&mut hdr)?;
            let (dtype, ndim) = (hdr[0], hdr[1] as usize);
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u32(r)? as usize);
            }
            let n: usize = dims.iter().product();
            if n > 1 << 30 {
                bail!("implausible tensor size {n} for '{name}'");
            }
            let t = match dtype {
                0 => {
                    let mut buf = vec![0u8; n * 4];
                    r.read_exact(&mut buf)
                        .with_context(|| format!("reading data of '{name}'"))?;
                    let data = buf
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    Tensor::F32 { dims, data }
                }
                1 => {
                    let mut buf = vec![0u8; n * 4];
                    r.read_exact(&mut buf)
                        .with_context(|| format!("reading data of '{name}'"))?;
                    let data = buf
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    Tensor::I32 { dims, data }
                }
                d => bail!("unknown dtype {d} for '{name}'"),
            };
            entries.insert(name, t);
        }
        Ok(TensorFile { entries })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path.as_ref())
                .with_context(|| format!("creating {}", path.as_ref().display()))?,
        );
        self.write_to(&mut f)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<TensorFile> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path.as_ref())
                .with_context(|| format!("opening {}", path.as_ref().display()))?,
        );
        Self::read_from(&mut f)
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut tf = TensorFile::new();
        tf.insert("w1", Tensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
        tf.insert("ids", Tensor::i32(vec![4], vec![-1, 0, 7, 42]));
        tf.insert("scalar", Tensor::f32(vec![], vec![3.5]));
        let mut buf = Vec::new();
        tf.write_to(&mut buf).unwrap();
        let back = TensorFile::read_from(&mut &buf[..]).unwrap();
        assert_eq!(back, tf);
    }

    #[test]
    fn shaped_accessor() {
        let mut tf = TensorFile::new();
        tf.insert("w", Tensor::f32(vec![2, 2], vec![1.0; 4]));
        assert!(tf.f32_shaped("w", &[2, 2]).is_ok());
        assert!(tf.f32_shaped("w", &[4]).is_err());
        assert!(tf.f32_shaped("nope", &[2, 2]).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(TensorFile::read_from(&mut &b"NOPE"[..]).is_err());
        let mut buf = Vec::new();
        TensorFile::new().write_to(&mut buf).unwrap();
        buf[4] = 9; // version
        assert!(TensorFile::read_from(&mut &buf[..]).is_err());
    }

    #[test]
    fn empty_file_ok() {
        let mut buf = Vec::new();
        TensorFile::new().write_to(&mut buf).unwrap();
        let back = TensorFile::read_from(&mut &buf[..]).unwrap();
        assert!(back.entries.is_empty());
    }
}
