//! Shared substrate utilities: deterministic PRNG, mini-JSON, binary tensor
//! format, and a tiny leveled logger. These replace crates unavailable in the
//! offline build environment (`rand`, `serde_json`, `env_logger`).

pub mod binfmt;
pub mod json;
pub mod logging;
pub mod rng;

pub use binfmt::{Tensor, TensorFile};
pub use json::Json;
pub use rng::Rng;

/// Compute the median of a slice (copies + sorts; fine for reporting paths).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "median of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[7.0]), 7.0);
    }

    #[test]
    fn percentile_bounds() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn mean_simple() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
    }
}
