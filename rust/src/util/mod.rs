//! Shared substrate utilities: deterministic PRNG, mini-JSON, binary tensor
//! format, and a tiny leveled logger. These replace crates unavailable in the
//! offline build environment (`rand`, `serde_json`, `env_logger`).

pub mod binfmt;
pub mod json;
pub mod logging;
pub mod rng;
pub mod trace;

pub use binfmt::{Tensor, TensorFile};
pub use json::Json;
pub use rng::Rng;

/// FNV-1a 64-bit hash — the repo's one stable hash, shared by session→shard
/// routing ([`crate::coordinator::batcher::shard_of`]), snapshot checksums
/// ([`crate::incremental::snapshot`]), and spill-file naming. Deterministic
/// and platform-independent, so routing and on-disk formats are stable
/// across restarts and architectures.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Compute the median of a slice (copies + sorts; fine for reporting paths).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "median of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[7.0]), 7.0);
    }

    #[test]
    fn percentile_bounds() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn mean_simple() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
    }

    #[test]
    fn fnv1a64_known_vectors() {
        // Standard FNV-1a 64 test vectors — pins the constants so routing
        // and snapshot checksums never silently change.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
        assert_ne!(fnv1a64(b"session-1"), fnv1a64(b"session-2"));
    }
}
