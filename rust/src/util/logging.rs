//! Tiny leveled logger backing the `log` crate facade.
//!
//! `env_logger` is not in the offline crate set; this is a minimal stderr
//! logger honouring `VQT_LOG` (off|none|error|warn|info|debug|trace,
//! default info). An unrecognized value still defaults to info but warns
//! once — a typo like `VQT_LOG=inf` must not silently change verbosity.

use log::{Level, LevelFilter, Metadata, Record};
use std::time::Instant;

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, _m: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let t = self.start.elapsed();
            let lvl = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            eprintln!(
                "[{:>8.3}s {} {}] {}",
                t.as_secs_f64(),
                lvl,
                record.target(),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

/// Install the global logger (idempotent; later calls are no-ops).
pub fn init() {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| {
        let var = std::env::var("VQT_LOG");
        let (level, unknown) = match var.as_deref() {
            Ok("off") | Ok("none") => (LevelFilter::Off, None),
            Ok("error") => (LevelFilter::Error, None),
            Ok("warn") => (LevelFilter::Warn, None),
            Ok("info") => (LevelFilter::Info, None),
            Ok("debug") => (LevelFilter::Debug, None),
            Ok("trace") => (LevelFilter::Trace, None),
            // Unrecognized values keep the info default but must say so
            // (once — this runs under `Once`): a typo'd `VQT_LOG=inf`
            // silently meaning "info" hid real intent for too long.
            Ok(other) => (LevelFilter::Info, Some(other.to_string())),
            Err(_) => (LevelFilter::Info, None),
        };
        let logger = Box::leak(Box::new(StderrLogger {
            start: Instant::now(),
        }));
        let _ = log::set_logger(logger);
        log::set_max_level(level);
        if let Some(bad) = unknown {
            log::warn!(
                "VQT_LOG={bad:?} is not a recognized level \
                 (off|none|error|warn|info|debug|trace); defaulting to info"
            );
        }
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
