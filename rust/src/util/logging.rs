//! Tiny leveled logger backing the `log` crate facade.
//!
//! `env_logger` is not in the offline crate set; this is a minimal stderr
//! logger honouring `VQT_LOG` (error|warn|info|debug|trace, default info).

use log::{Level, LevelFilter, Metadata, Record};
use std::time::Instant;

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, _m: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let t = self.start.elapsed();
            let lvl = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            eprintln!(
                "[{:>8.3}s {} {}] {}",
                t.as_secs_f64(),
                lvl,
                record.target(),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

/// Install the global logger (idempotent; later calls are no-ops).
pub fn init() {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| {
        let level = match std::env::var("VQT_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            _ => LevelFilter::Info,
        };
        let logger = Box::leak(Box::new(StderrLogger {
            start: Instant::now(),
        }));
        let _ = log::set_logger(logger);
        log::set_max_level(level);
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
