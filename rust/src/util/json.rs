//! Minimal JSON value model, parser, and serializer.
//!
//! The offline crate set has no `serde`/`serde_json`, so the config system and
//! the TCP wire protocol use this self-contained implementation. It supports
//! the full JSON grammar (objects, arrays, strings with escapes, numbers,
//! booleans, null) with precise error positions.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are ordered (BTreeMap) so serialization
/// is deterministic — useful for golden tests and reproducible artifacts.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset into the input.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Builder helper: object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization (deterministic key order); `to_string()` comes
/// from the blanket `ToString` impl.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

/// Maximum container nesting depth. The parser recurses per `[`/`{`, so
/// unbounded depth would let a hostile wire payload (`[[[[…`) overflow the
/// stack — an abort, not a catchable error. 128 is far beyond any legitimate
/// config or protocol message.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => {
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => {
                    self.depth -= 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                        };
                        s.push(c);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences from the raw input.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "line\n\ttab \"quote\" \\ back π 🦀";
        let j = Json::Str(s.to_string());
        let round = Json::parse(&j.to_string()).unwrap();
        assert_eq!(round.as_str(), Some(s));
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""🦀""#).unwrap();
        assert_eq!(v.as_str(), Some("🦀"));
    }

    #[test]
    fn error_positions() {
        let e = Json::parse("[1, 2,]").unwrap_err();
        assert!(e.pos >= 6, "pos {}", e.pos);
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn deep_nesting_rejected_cleanly() {
        // Within the limit: fine.
        let ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
        // Far past the limit: a clean error, not a stack overflow.
        let deep = format!("{}1{}", "[".repeat(100_000), "]".repeat(100_000));
        let e = Json::parse(&deep).unwrap_err();
        assert!(e.msg.contains("nesting"), "{e}");
        let deep_obj = format!("{}\"k\":1{}", "{\"k\":".repeat(50_000), "}".repeat(50_000));
        assert!(Json::parse(&deep_obj).is_err());
    }

    #[test]
    fn serialize_deterministic() {
        let v = Json::parse(r#"{"z": 1, "a": 2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn roundtrip_random_structures() {
        // Deterministic fuzz: build random values, serialize, reparse, compare.
        use crate::util::rng::Rng;
        fn gen(r: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { r.below(4) } else { r.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(r.chance(0.5)),
                2 => Json::Num((r.below(1_000_000) as f64) / 64.0),
                3 => Json::Str(format!("s{}", r.below(1000))),
                4 => Json::Arr((0..r.below(4)).map(|_| gen(r, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..r.below(4))
                        .map(|i| (format!("k{i}"), gen(r, depth - 1)))
                        .collect(),
                ),
            }
        }
        let mut r = Rng::new(99);
        for _ in 0..300 {
            let v = gen(&mut r, 3);
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }
}
