//! Multi-head vector quantization (paper §3, §4 and App. A.2).
//!
//! Each d-dimensional vector is split into `heads` contiguous chunks; each
//! chunk is matched against that head's codebook of `codes` vectors. The
//! effective codebook is therefore `codes^heads` without the storage cost.
//!
//! Assignment uses the inner-product form from App. A.2:
//! `argmin_i ‖x − c_i‖² = argmax_i (x·c_i + b_i)` with `b_i = −‖c_i‖²/2` —
//! a matmul + argmax, which is also how the L1 Pallas kernel formulates it
//! for the MXU (see `python/compile/kernels/vq_assign.py`).

use crate::flops::{Cat, FlopLedger, MULADD};
use crate::tensor::{argmax, dot, Matrix};

/// Maximum supported VQ heads (codes are stored inline in `CodeTuple`).
pub const MAX_VQ_HEADS: usize = 8;

/// A per-head code index.
pub type Code = u16;

/// The joint code of one vector across all VQ heads. Compact, hashable —
/// used as the identity of quantized activations everywhere downstream.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct CodeTuple {
    len: u8,
    codes: [Code; MAX_VQ_HEADS],
}

impl CodeTuple {
    pub fn new(codes: &[Code]) -> CodeTuple {
        assert!(codes.len() <= MAX_VQ_HEADS, "too many VQ heads");
        let mut arr = [0; MAX_VQ_HEADS];
        arr[..codes.len()].copy_from_slice(codes);
        CodeTuple {
            len: codes.len() as u8,
            codes: arr,
        }
    }

    #[inline]
    pub fn as_slice(&self) -> &[Code] {
        &self.codes[..self.len as usize]
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len as usize
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Pack into a u64 for fast interner keys (supports ≤ 4 heads of ≤ 2^16
    /// codes, or up to 8 heads of ≤ 256 codes; asserts on overflow).
    ///
    /// The packing is injective over the supported shapes, so the u64 also
    /// serves directly as the codebook-product cache key
    /// (`incremental/codecache.rs`, keyed `(layer, pack())`): equal packed
    /// values imply equal code tuples imply equal `decode(code)·w_mix`
    /// products under one set of weights.
    pub fn pack(&self) -> u64 {
        let mut v: u64 = self.len as u64;
        if self.len <= 4 {
            for &c in self.as_slice() {
                v = (v << 15) | ((c as u64) & 0x7FFF);
            }
        } else {
            for &c in self.as_slice() {
                assert!(c < 256, "code {} too large to pack with {} heads", c, self.len);
                v = (v << 7) | ((c as u64) & 0x7F);
            }
        }
        v
    }
}

/// The per-layer multi-head codebooks.
#[derive(Clone, Debug)]
pub struct VqCodebooks {
    pub heads: usize,
    pub codes: usize,
    pub dim: usize,
    /// One `(codes, dim/heads)` matrix per head.
    pub books: Vec<Matrix>,
    /// `b_i = −‖c_i‖²/2` per head, per code (App. A.2).
    pub bias: Vec<Vec<f32>>,
}

impl VqCodebooks {
    /// Build from per-head codebook matrices; computes biases.
    pub fn new(books: Vec<Matrix>, dim: usize) -> VqCodebooks {
        assert!(!books.is_empty() && books.len() <= MAX_VQ_HEADS);
        let heads = books.len();
        let codes = books[0].rows;
        let chunk = dim / heads;
        for b in &books {
            assert_eq!(b.rows, codes, "uneven codebook sizes");
            assert_eq!(b.cols, chunk, "codebook chunk width mismatch");
        }
        let bias = books
            .iter()
            .map(|b| {
                (0..b.rows)
                    .map(|i| -0.5 * dot(b.row(i), b.row(i)))
                    .collect()
            })
            .collect();
        VqCodebooks {
            heads,
            codes,
            dim,
            books,
            bias,
        }
    }

    /// Deterministic random codebooks (tests / random-weight models).
    pub fn random(heads: usize, codes: usize, dim: usize, rng: &mut crate::util::Rng) -> Self {
        let chunk = dim / heads;
        let scale = 1.0 / (chunk as f32).sqrt();
        let books = (0..heads)
            .map(|_| Matrix::from_fn(codes, chunk, |_, _| rng.normal() * scale))
            .collect();
        VqCodebooks::new(books, dim)
    }

    #[inline]
    pub fn chunk(&self) -> usize {
        self.dim / self.heads
    }

    /// Total score-vector width (`heads × codes`).
    #[inline]
    pub fn score_width(&self) -> usize {
        self.heads * self.codes
    }

    /// Compute the full score vector `s[h·codes + i] = x_h · c_i + b_i` for
    /// one input vector. `out` must have `score_width()` elements.
    pub fn scores_into(&self, x: &[f32], out: &mut [f32], ledger: &mut FlopLedger) {
        assert_eq!(x.len(), self.dim);
        assert_eq!(out.len(), self.score_width());
        let chunk = self.chunk();
        for h in 0..self.heads {
            let xh = &x[h * chunk..(h + 1) * chunk];
            let book = &self.books[h];
            let bias = &self.bias[h];
            let so = &mut out[h * self.codes..(h + 1) * self.codes];
            for i in 0..self.codes {
                so[i] = dot(xh, book.row(i)) + bias[i];
            }
        }
        ledger.add(Cat::Vq, MULADD * (self.dim * self.codes) as u64 + self.score_width() as u64);
    }

    /// Argmax each head's score segment into a `CodeTuple`.
    pub fn codes_from_scores(&self, scores: &[f32], ledger: &mut FlopLedger) -> CodeTuple {
        assert_eq!(scores.len(), self.score_width());
        let mut cs = [0 as Code; MAX_VQ_HEADS];
        for h in 0..self.heads {
            cs[h] = argmax(&scores[h * self.codes..(h + 1) * self.codes]) as Code;
        }
        ledger.add(Cat::Vq, self.score_width() as u64);
        CodeTuple::new(&cs[..self.heads])
    }

    /// Full assignment: scores + argmax.
    pub fn assign(&self, x: &[f32], ledger: &mut FlopLedger) -> CodeTuple {
        let mut s = vec![0.0; self.score_width()];
        self.scores_into(x, &mut s, ledger);
        self.codes_from_scores(&s, ledger)
    }

    /// Decode a code tuple into `out` (concatenated per-head codewords).
    pub fn decode_into(&self, code: CodeTuple, out: &mut [f32]) {
        assert_eq!(code.len(), self.heads);
        assert_eq!(out.len(), self.dim);
        let chunk = self.chunk();
        for (h, &c) in code.as_slice().iter().enumerate() {
            out[h * chunk..(h + 1) * chunk].copy_from_slice(self.books[h].row(c as usize));
        }
    }

    /// Decode into a fresh vector.
    pub fn decode(&self, code: CodeTuple) -> Vec<f32> {
        let mut out = vec![0.0; self.dim];
        self.decode_into(code, &mut out);
        out
    }

    /// Project a value vector onto all codebooks: `vc[h·codes+i] = v_h · c_i`
    /// — the ⟨v, C⟩ precomputation of App. A.2 that lets attention
    /// corrections update VQ *scores* directly instead of touching the
    /// d-dimensional accumulator.
    pub fn project_into(&self, v: &[f32], out: &mut [f32], ledger: &mut FlopLedger) {
        assert_eq!(v.len(), self.dim);
        assert_eq!(out.len(), self.score_width());
        let chunk = self.chunk();
        for h in 0..self.heads {
            let vh = &v[h * chunk..(h + 1) * chunk];
            let book = &self.books[h];
            let so = &mut out[h * self.codes..(h + 1) * self.codes];
            for i in 0..self.codes {
                so[i] = dot(vh, book.row(i));
            }
        }
        ledger.add(Cat::Vq, MULADD * (self.dim * self.codes) as u64);
    }

    /// Quantize: assignment followed by decode — `VQ(x)` in eq. (1).
    pub fn quantize_into(
        &self,
        x: &[f32],
        out: &mut [f32],
        ledger: &mut FlopLedger,
    ) -> CodeTuple {
        let code = self.assign(x, ledger);
        self.decode_into(code, out);
        code
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn books(seed: u64) -> VqCodebooks {
        let mut r = Rng::new(seed);
        VqCodebooks::random(2, 16, 32, &mut r)
    }

    /// Brute-force nearest-codeword per head by Euclidean distance.
    fn brute_assign(vq: &VqCodebooks, x: &[f32]) -> Vec<usize> {
        let chunk = vq.chunk();
        (0..vq.heads)
            .map(|h| {
                let xh = &x[h * chunk..(h + 1) * chunk];
                let mut best = 0;
                let mut bd = f32::INFINITY;
                for i in 0..vq.codes {
                    let c = vq.books[h].row(i);
                    let d: f32 = xh.iter().zip(c).map(|(a, b)| (a - b) * (a - b)).sum();
                    if d < bd {
                        bd = d;
                        best = i;
                    }
                }
                best
            })
            .collect()
    }

    #[test]
    fn inner_product_form_matches_euclidean_nearest() {
        let vq = books(1);
        let mut r = Rng::new(2);
        let mut led = FlopLedger::new();
        for _ in 0..200 {
            let x: Vec<f32> = (0..32).map(|_| r.normal()).collect();
            let code = vq.assign(&x, &mut led);
            let brute = brute_assign(&vq, &x);
            let got: Vec<usize> = code.as_slice().iter().map(|&c| c as usize).collect();
            assert_eq!(got, brute);
        }
    }

    #[test]
    fn decode_roundtrip_of_codewords() {
        // Quantizing an exact codeword must return that codeword.
        let vq = books(3);
        let mut led = FlopLedger::new();
        for c0 in [0u16, 5, 15] {
            for c1 in [1u16, 7, 14] {
                let code = CodeTuple::new(&[c0, c1]);
                let x = vq.decode(code);
                let back = vq.assign(&x, &mut led);
                assert_eq!(back, code);
            }
        }
    }

    #[test]
    fn scores_are_linear_in_input() {
        // s(x + y) + b = s(x) + s(y) + 2b − wait: s(x) = x·c + b, so
        // s(x+y) − b = (s(x) − b) + (s(y) − b). Verify linearity of x·c.
        let vq = books(4);
        let mut r = Rng::new(5);
        let mut led = FlopLedger::new();
        let x: Vec<f32> = (0..32).map(|_| r.normal()).collect();
        let y: Vec<f32> = (0..32).map(|_| r.normal()).collect();
        let xy: Vec<f32> = x.iter().zip(&y).map(|(a, b)| a + b).collect();
        let w = vq.score_width();
        let (mut sx, mut sy, mut sxy) = (vec![0.0; w], vec![0.0; w], vec![0.0; w]);
        vq.scores_into(&x, &mut sx, &mut led);
        vq.scores_into(&y, &mut sy, &mut led);
        vq.scores_into(&xy, &mut sxy, &mut led);
        for h in 0..vq.heads {
            for i in 0..vq.codes {
                let k = h * vq.codes + i;
                let b = vq.bias[h][i];
                assert!(
                    ((sxy[k] - b) - ((sx[k] - b) + (sy[k] - b))).abs() < 1e-4,
                    "score linearity violated"
                );
            }
        }
    }

    #[test]
    fn project_matches_scores_minus_bias() {
        let vq = books(6);
        let mut r = Rng::new(7);
        let mut led = FlopLedger::new();
        let v: Vec<f32> = (0..32).map(|_| r.normal()).collect();
        let w = vq.score_width();
        let (mut s, mut p) = (vec![0.0; w], vec![0.0; w]);
        vq.scores_into(&v, &mut s, &mut led);
        vq.project_into(&v, &mut p, &mut led);
        for h in 0..vq.heads {
            for i in 0..vq.codes {
                let k = h * vq.codes + i;
                assert!((s[k] - vq.bias[h][i] - p[k]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn code_tuple_pack_unique() {
        use std::collections::HashSet;
        let mut seen = HashSet::new();
        for a in 0..16u16 {
            for b in 0..16u16 {
                assert!(seen.insert(CodeTuple::new(&[a, b]).pack()));
            }
        }
        // Different lengths never collide.
        assert_ne!(
            CodeTuple::new(&[3]).pack(),
            CodeTuple::new(&[3, 0]).pack()
        );
    }

    #[test]
    fn ledger_counts_vq_work() {
        let vq = books(8);
        let mut led = FlopLedger::new();
        let x = vec![0.5; 32];
        vq.assign(&x, &mut led);
        // dim × codes muladds = 32 × 16 × 2 ops minimum.
        assert!(led.vq >= 1024);
        assert_eq!(led.linear, 0);
    }
}
