//! Session lifecycle: one incremental engine per live document, with
//! byte-accounted LRU **spill-to-disk** under a memory budget.
//!
//! Each coordinator shard owns one `SessionStore` for the sessions
//! hash-routed to it — single-threaded access by construction, so no
//! interior locking is needed. A session moves through three states:
//!
//! ```text
//!            open / Restore                  suspend (LRU, budget, verb)
//!   (none) ───────────────▶ RESIDENT ─────────────────────▶ SUSPENDED
//!                              ▲                                │
//!                              └── resume (next request / verb) ┘
//!            close / global-LRU drop: either state ─▶ (none)
//! ```
//!
//! *Resident* sessions are charged their measured
//! [`IncrementalEngine::resident_bytes`]. Whenever the shard is over its
//! resident-count cap or its byte budget, least-recently-used sessions are
//! **suspended**: snapshotted to the spill directory (the versioned,
//! checksummed [`crate::incremental::snapshot`] format) and dropped from
//! RAM. The next request addressed to a suspended session transparently
//! resumes it — bit-exact, counters included, so the caller cannot tell the
//! session ever left memory. With no spill directory configured, eviction
//! falls back to dropping sessions outright (the pre-lifecycle behavior).

use crate::incremental::{CacheHandle, EngineOptions, IncrementalEngine};
use crate::model::ModelWeights;
use crate::util::fnv1a64;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

/// One live editing session.
pub struct Session {
    pub engine: IncrementalEngine,
    /// Monotonic access stamp for LRU.
    pub last_access: u64,
    /// Total edits served.
    pub edits: u64,
    /// Bytes this session is currently charged for (recomputed by
    /// [`SessionStore::reaccount`] after each mutating request).
    bytes: usize,
}

/// A suspended session: its snapshot lives on disk, not in RAM.
struct SpillEntry {
    path: PathBuf,
    /// Snapshot file size (reported via [`SessionInfo`]).
    file_bytes: u64,
    last_access: u64,
    edits: u64,
    doc_len: usize,
}

/// Store limits and spill policy (per shard — the coordinator divides the
/// pool-wide `ServeConfig` knobs across shards).
#[derive(Clone, Debug)]
pub struct StorePolicy {
    /// Max sessions in RAM (≥ 1).
    pub max_resident: usize,
    /// Max sessions total, resident + suspended (≥ max_resident).
    pub max_total: usize,
    /// Resident-state byte budget; 0 ⇒ unlimited.
    pub memory_budget_bytes: usize,
    /// Where snapshots spill; `None` ⇒ eviction drops sessions.
    pub spill_dir: Option<PathBuf>,
}

/// Outcome of [`SessionStore::prepare`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Prepared {
    /// Already in RAM.
    Resident,
    /// Was suspended; has been restored from its spill snapshot.
    Resumed,
    /// Not known to this store.
    Missing,
}

/// Point-in-time description of one session (the `SessionInfo` verb).
#[derive(Clone, Debug)]
pub struct SessionInfo {
    /// "resident" or "suspended".
    pub state: &'static str,
    /// Bytes charged against the memory budget (0 while suspended).
    pub resident_bytes: usize,
    /// Snapshot file size on disk (0 while resident).
    pub spill_bytes: u64,
    pub edits: u64,
    pub doc_len: usize,
}

/// Session store with byte-accounted LRU suspension.
pub struct SessionStore {
    resident: HashMap<String, Session>,
    spilled: HashMap<String, SpillEntry>,
    clock: u64,
    policy: StorePolicy,
    weights: Arc<ModelWeights>,
    engine_opts: EngineOptions,
    /// Shared codebook-product cache to re-attach on resume. Snapshots
    /// exclude the cache by design, so a restored engine comes back
    /// detached; the store is the single place that knows the shard's
    /// handle and can make resume transparent.
    cache: Option<CacheHandle>,
    resident_bytes: usize,
    /// Sessions dropped outright (no spill dir, or global-LRU total-cap
    /// eviction, or spill failure).
    pub evictions: u64,
    /// Sessions snapshotted to disk.
    pub suspends: u64,
    /// Sessions restored from disk.
    pub resumes: u64,
}

/// Spill file name: a short sanitized prefix of the session id (debugging
/// aid) plus the full FNV-1a 64 of the id (uniqueness), so arbitrary
/// client-chosen ids — path separators, unicode, 4 KiB monsters — map to
/// safe, distinct file names.
fn spill_filename(id: &str) -> String {
    let prefix: String = id
        .chars()
        .take(32)
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' { c } else { '_' })
        .collect();
    format!("{prefix}-{:016x}.vqss", fnv1a64(id.as_bytes()))
}

impl SessionStore {
    pub fn new(
        weights: Arc<ModelWeights>,
        engine_opts: EngineOptions,
        policy: StorePolicy,
        cache: Option<CacheHandle>,
    ) -> SessionStore {
        assert!(policy.max_resident > 0, "resident capacity must be ≥ 1");
        assert!(
            policy.max_total >= policy.max_resident,
            "total capacity below resident capacity"
        );
        SessionStore {
            resident: HashMap::new(),
            spilled: HashMap::new(),
            clock: 0,
            policy,
            weights,
            engine_opts,
            cache,
            resident_bytes: 0,
            evictions: 0,
            suspends: 0,
            resumes: 0,
        }
    }

    // -- introspection ----------------------------------------------------

    pub fn resident_len(&self) -> usize {
        self.resident.len()
    }

    pub fn spilled_len(&self) -> usize {
        self.spilled.len()
    }

    pub fn len(&self) -> usize {
        self.resident.len() + self.spilled.len()
    }

    pub fn is_empty(&self) -> bool {
        self.resident.is_empty() && self.spilled.is_empty()
    }

    pub fn contains(&self, id: &str) -> bool {
        self.resident.contains_key(id) || self.spilled.contains_key(id)
    }

    pub fn is_resident(&self, id: &str) -> bool {
        self.resident.contains_key(id)
    }

    pub fn is_suspended(&self, id: &str) -> bool {
        self.spilled.contains_key(id)
    }

    /// Measured bytes of resident session state (the budget gauge).
    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    /// All known session ids (resident and suspended), sorted.
    pub fn ids(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .resident
            .keys()
            .chain(self.spilled.keys())
            .cloned()
            .collect();
        v.sort();
        v
    }

    pub fn info(&self, id: &str) -> Option<SessionInfo> {
        if let Some(s) = self.resident.get(id) {
            return Some(SessionInfo {
                state: "resident",
                resident_bytes: s.bytes,
                spill_bytes: 0,
                edits: s.edits,
                doc_len: s.engine.len(),
            });
        }
        self.spilled.get(id).map(|e| SessionInfo {
            state: "suspended",
            resident_bytes: 0,
            spill_bytes: e.file_bytes,
            edits: e.edits,
            doc_len: e.doc_len,
        })
    }

    // -- lifecycle operations ---------------------------------------------

    /// Insert (or replace) a resident session, then enforce capacity and
    /// budget. Returns the id of a session *dropped* to make room under the
    /// total cap, if any (suspensions are not drops and are only counted).
    pub fn insert(&mut self, id: String, engine: IncrementalEngine) -> Option<String> {
        self.clock += 1;
        if let Some(old) = self.resident.remove(&id) {
            self.resident_bytes -= old.bytes;
        }
        if let Some(old) = self.spilled.remove(&id) {
            let _ = std::fs::remove_file(&old.path);
        }
        // Total cap: drop the globally least-recently-used session.
        let mut dropped = None;
        if self.len() >= self.policy.max_total {
            if let Some(oldest) = self.global_lru() {
                self.drop_session(&oldest);
                self.evictions += 1;
                dropped = Some(oldest);
            }
        }
        let bytes = engine.resident_bytes();
        self.resident_bytes += bytes;
        self.resident.insert(
            id.clone(),
            Session {
                engine,
                last_access: self.clock,
                edits: 0,
                bytes,
            },
        );
        self.enforce(Some(&id));
        dropped
    }

    /// Make `id` resident (resuming from its spill snapshot if suspended),
    /// so a following [`Self::get_mut`] succeeds. Transparent
    /// resume-on-next-request is this method called on the request path.
    pub fn prepare(&mut self, id: &str) -> Result<Prepared> {
        if self.resident.contains_key(id) {
            return Ok(Prepared::Resident);
        }
        let Some(entry) = self.spilled.remove(id) else {
            return Ok(Prepared::Missing);
        };
        let _span = crate::util::trace::stage("fault_in");
        let restored = IncrementalEngine::restore_from_file(
            self.weights.clone(),
            &entry.path,
            self.engine_opts,
        )
        .with_context(|| format!("resuming suspended session '{id}'"));
        // Whether or not the restore succeeds, the snapshot file is
        // consumed: a corrupt spill must not be retried forever.
        let _ = std::fs::remove_file(&entry.path);
        let mut engine = restored?;
        // Snapshots exclude the cache; re-attach the shard's handle so a
        // resumed session rewarms lazily instead of staying cold forever.
        engine.set_code_cache(self.cache.clone());
        self.clock += 1;
        let bytes = engine.resident_bytes();
        self.resident_bytes += bytes;
        self.resident.insert(
            id.to_string(),
            Session {
                engine,
                last_access: self.clock,
                edits: entry.edits,
                bytes,
            },
        );
        self.resumes += 1;
        self.enforce(Some(id));
        Ok(Prepared::Resumed)
    }

    /// Mutable access to a *resident* session, refreshing LRU recency.
    /// (Call [`Self::prepare`] first to fault a suspended session in.)
    pub fn get_mut(&mut self, id: &str) -> Option<&mut Session> {
        self.clock += 1;
        let clock = self.clock;
        self.resident.get_mut(id).map(|s| {
            s.last_access = clock;
            s
        })
    }

    /// Re-measure a session after a mutating request (edits grow and shrink
    /// engine state) and re-enforce the budget against the new total.
    pub fn reaccount(&mut self, id: &str) {
        if let Some(s) = self.resident.get_mut(id) {
            let bytes = s.engine.resident_bytes();
            self.resident_bytes = self.resident_bytes - s.bytes + bytes;
            s.bytes = bytes;
        }
        self.enforce(Some(id));
    }

    /// Explicitly suspend a session (the `Suspend` verb). Idempotent for
    /// already-suspended sessions; `Ok(false)` for unknown ids; an error if
    /// no spill directory is configured.
    pub fn suspend(&mut self, id: &str) -> Result<bool> {
        if self.spilled.contains_key(id) {
            return Ok(true);
        }
        if !self.resident.contains_key(id) {
            return Ok(false);
        }
        anyhow::ensure!(
            self.policy.spill_dir.is_some(),
            "suspend requires a configured spill_dir"
        );
        self.spill_one(id)?;
        Ok(true)
    }

    /// Temporarily remove a *resident* session for externally-driven
    /// execution (the cross-session batched path needs simultaneous
    /// ownership of several engines). The engine stays live in RAM while
    /// checked out, so its bytes STAY charged against the budget — a
    /// resume faulting in mid-wave must make its spill decisions against
    /// the true resident total, not one understated by the whole wave.
    /// [`Self::checkin`] settles the charge against the re-measured size;
    /// a session that will never be returned must release its charge via
    /// [`Self::discard`].
    pub fn checkout(&mut self, id: &str) -> Option<Session> {
        self.clock += 1;
        self.resident.remove(id)
    }

    /// Return a checked-out session: re-measures its bytes (the batch may
    /// have grown or shrunk engine state), refreshes LRU recency, and
    /// re-enforces capacity and budget against the new total.
    pub fn checkin(&mut self, id: String, mut s: Session) {
        self.clock += 1;
        s.last_access = self.clock;
        let charged = s.bytes;
        s.bytes = s.engine.resident_bytes();
        self.resident_bytes = self.resident_bytes - charged + s.bytes;
        self.resident.insert(id.clone(), s);
        self.enforce(Some(&id));
    }

    /// Drop a checked-out session without returning it (panic recovery
    /// discards a wave's engines rather than serving possibly-corrupt
    /// state), releasing the byte charge [`Self::checkout`] kept.
    pub fn discard(&mut self, s: Session) {
        self.resident_bytes -= s.bytes;
    }

    /// Close a session in either state. Returns whether it existed.
    pub fn remove(&mut self, id: &str) -> bool {
        if let Some(s) = self.resident.remove(id) {
            self.resident_bytes -= s.bytes;
            return true;
        }
        if let Some(e) = self.spilled.remove(id) {
            let _ = std::fs::remove_file(&e.path);
            return true;
        }
        false
    }

    // -- internals --------------------------------------------------------

    /// Id of the globally least-recently-used session across both states.
    fn global_lru(&self) -> Option<String> {
        let r = self
            .resident
            .iter()
            .map(|(k, s)| (s.last_access, k))
            .min();
        let sp = self
            .spilled
            .iter()
            .map(|(k, e)| (e.last_access, k))
            .min();
        match (r, sp) {
            (Some(a), Some(b)) => Some(if a.0 <= b.0 { a.1.clone() } else { b.1.clone() }),
            (Some(a), None) => Some(a.1.clone()),
            (None, Some(b)) => Some(b.1.clone()),
            (None, None) => None,
        }
    }

    fn drop_session(&mut self, id: &str) {
        if let Some(s) = self.resident.remove(id) {
            self.resident_bytes -= s.bytes;
        }
        if let Some(e) = self.spilled.remove(id) {
            let _ = std::fs::remove_file(&e.path);
        }
    }

    /// Suspend (or, without a spill dir, drop) LRU residents until both the
    /// resident-count cap and the byte budget hold. `keep` — normally the
    /// session the current request addresses — is never chosen, so a
    /// session larger than the whole budget still serves (the budget then
    /// holds all-but-this-session; there is nothing left to evict).
    fn enforce(&mut self, keep: Option<&str>) {
        loop {
            let over_count = self.resident.len() > self.policy.max_resident;
            let over_bytes = self.policy.memory_budget_bytes > 0
                && self.resident_bytes > self.policy.memory_budget_bytes;
            if !over_count && !over_bytes {
                return;
            }
            let Some(victim) = self
                .resident
                .iter()
                .filter(|(k, _)| Some(k.as_str()) != keep)
                .min_by_key(|(_, s)| s.last_access)
                .map(|(k, _)| k.clone())
            else {
                return; // only `keep` remains — nothing more to shed
            };
            if self.policy.spill_dir.is_some() {
                if let Err(e) = self.spill_one(&victim) {
                    // A failed spill (disk full, permissions) must not wedge
                    // the shard: fall back to dropping the victim.
                    log::warn!("spill of session '{victim}' failed ({e:#}); dropping it");
                    self.drop_session(&victim);
                    self.evictions += 1;
                }
            } else {
                self.drop_session(&victim);
                self.evictions += 1;
            }
        }
    }

    /// Snapshot one resident session to disk and forget its RAM state.
    fn spill_one(&mut self, id: &str) -> Result<()> {
        let dir = self
            .policy
            .spill_dir
            .as_ref()
            .context("no spill_dir configured")?
            .clone();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating spill dir {}", dir.display()))?;
        let s = self.resident.get(id).context("session not resident")?;
        let path = dir.join(spill_filename(id));
        s.engine.snapshot_to_file(&path)?;
        let file_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        let s = self.resident.remove(id).expect("checked above");
        self.resident_bytes -= s.bytes;
        self.spilled.insert(
            id.to_string(),
            SpillEntry {
                path,
                file_bytes,
                last_access: s.last_access,
                edits: s.edits,
                doc_len: s.engine.len(),
            },
        );
        self.suspends += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::incremental::EngineOptions;
    use crate::model::ModelWeights;
    use std::sync::Arc;

    fn engine(w: &Arc<ModelWeights>, seed: u64) -> IncrementalEngine {
        let tokens: Vec<u32> = (0..6).map(|i| ((seed + i) % 60) as u32).collect();
        IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default())
    }

    fn store(w: &Arc<ModelWeights>, policy: StorePolicy) -> SessionStore {
        SessionStore::new(w.clone(), EngineOptions::default(), policy, None)
    }

    fn drop_policy(max_resident: usize) -> StorePolicy {
        StorePolicy {
            max_resident,
            max_total: max_resident,
            memory_budget_bytes: 0,
            spill_dir: None,
        }
    }

    fn tempdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("vqt_spill_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn lru_eviction_order_without_spill() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 1));
        let mut store = store(&w, drop_policy(2));
        assert_eq!(store.insert("a".into(), engine(&w, 1)), None);
        assert_eq!(store.insert("b".into(), engine(&w, 2)), None);
        // Touch "a" so "b" is the LRU.
        store.get_mut("a").unwrap();
        let evicted = store.insert("c".into(), engine(&w, 3));
        assert_eq!(evicted.as_deref(), Some("b"));
        assert!(store.contains("a") && store.contains("c"));
        assert_eq!(store.evictions, 1);
        assert_eq!(store.suspends, 0, "no spill dir ⇒ drops, not suspensions");
    }

    #[test]
    fn replace_does_not_evict() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 1));
        let mut store = store(&w, drop_policy(1));
        store.insert("a".into(), engine(&w, 1));
        assert_eq!(store.insert("a".into(), engine(&w, 2)), None);
        assert_eq!(store.len(), 1);
        assert_eq!(store.evictions, 0);
    }

    #[test]
    fn remove_and_ids() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 1));
        let mut store = store(&w, drop_policy(4));
        store.insert("x".into(), engine(&w, 1));
        store.insert("y".into(), engine(&w, 2));
        assert_eq!(store.ids(), vec!["x".to_string(), "y".to_string()]);
        assert!(store.remove("x"));
        assert!(!store.remove("x"));
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn count_pressure_spills_and_resumes_bit_exact() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 2));
        let dir = tempdir("count");
        let mut store = store(
            &w,
            StorePolicy {
                max_resident: 1,
                max_total: 8,
                memory_budget_bytes: 0,
                spill_dir: Some(dir.clone()),
            },
        );
        store.insert("a".into(), engine(&w, 1));
        let logits_a: Vec<u32> = store.get_mut("a").unwrap().engine.logits()
            .iter().map(|x| x.to_bits()).collect();
        store.insert("b".into(), engine(&w, 2));
        // "a" was suspended, not dropped.
        assert!(store.is_suspended("a") && store.is_resident("b"));
        assert_eq!(store.suspends, 1);
        assert_eq!(store.evictions, 0);
        assert_eq!(store.info("a").unwrap().state, "suspended");
        assert!(store.info("a").unwrap().spill_bytes > 0);
        // Transparent resume restores bit-identical state (and suspends
        // "b" in turn under the resident cap of 1).
        assert_eq!(store.prepare("a").unwrap(), Prepared::Resumed);
        assert_eq!(store.resumes, 1);
        let back: Vec<u32> = store.get_mut("a").unwrap().engine.logits()
            .iter().map(|x| x.to_bits()).collect();
        assert_eq!(back, logits_a);
        assert!(store.is_suspended("b"));
        let _ = std::fs::remove_dir_all(dir);
    }

    /// A resumed session comes back with the shard's cache handle attached
    /// (snapshots exclude the cache, so without this re-attach a suspended
    /// session would stay cold for the rest of its life).
    #[test]
    fn resume_reattaches_the_code_cache() {
        use crate::incremental::{CacheHandle, CodeCache};
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 9));
        let handle = CacheHandle::new(Arc::new(CodeCache::new(1 << 20)), &w);
        let dir = tempdir("reattach");
        let mut store = SessionStore::new(
            w.clone(),
            EngineOptions::default(),
            StorePolicy {
                max_resident: 4,
                max_total: 8,
                memory_budget_bytes: 0,
                spill_dir: Some(dir.clone()),
            },
            Some(handle.clone()),
        );
        store.insert("a".into(), engine(&w, 1));
        assert!(
            store.get_mut("a").unwrap().engine.code_cache().is_none(),
            "insert does not attach; the coordinator's Open handler does"
        );
        store.suspend("a").unwrap();
        assert_eq!(store.prepare("a").unwrap(), Prepared::Resumed);
        let got = store.get_mut("a").unwrap().engine.code_cache().cloned();
        let got = got.expect("resumed session re-attached");
        assert!(Arc::ptr_eq(&got.cache, &handle.cache));
        assert_eq!(got.fp, handle.fp);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn byte_budget_keeps_resident_bytes_bounded() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 3));
        let one = engine(&w, 1).resident_bytes();
        let dir = tempdir("budget");
        // Budget for about two engines.
        let budget = one * 2 + one / 2;
        let mut store = store(
            &w,
            StorePolicy {
                max_resident: 64,
                max_total: 64,
                memory_budget_bytes: budget,
                spill_dir: Some(dir.clone()),
            },
        );
        for i in 0..6 {
            store.insert(format!("s{i}"), engine(&w, i));
            assert!(
                store.resident_bytes() <= budget,
                "after insert {i}: {} > budget {budget}",
                store.resident_bytes()
            );
        }
        assert_eq!(store.len(), 6, "budget suspends, never loses sessions");
        assert!(store.suspends >= 4);
        // Every session remains reachable.
        for i in 0..6 {
            assert_ne!(store.prepare(&format!("s{i}")).unwrap(), Prepared::Missing);
            assert!(store.resident_bytes() <= budget);
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn explicit_suspend_is_idempotent_and_needs_spill_dir() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 4));
        let mut no_spill = store(&w, drop_policy(4));
        no_spill.insert("a".into(), engine(&w, 1));
        assert!(no_spill.suspend("a").is_err(), "no spill dir configured");
        let dir = tempdir("suspend");
        let mut s = store(
            &w,
            StorePolicy {
                max_resident: 4,
                max_total: 8,
                memory_budget_bytes: 0,
                spill_dir: Some(dir.clone()),
            },
        );
        s.insert("a".into(), engine(&w, 1));
        assert!(s.suspend("a").unwrap());
        assert!(s.suspend("a").unwrap(), "idempotent");
        assert!(!s.suspend("ghost").unwrap());
        assert_eq!(s.suspends, 1);
        // Closing a suspended session deletes its snapshot file.
        let path = dir.join(spill_filename("a"));
        assert!(path.exists());
        assert!(s.remove("a"));
        assert!(!path.exists());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn total_cap_drops_global_lru_even_if_suspended() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 5));
        let dir = tempdir("total");
        let mut s = store(
            &w,
            StorePolicy {
                max_resident: 1,
                max_total: 2,
                memory_budget_bytes: 0,
                spill_dir: Some(dir.clone()),
            },
        );
        s.insert("a".into(), engine(&w, 1)); // a resident
        s.insert("b".into(), engine(&w, 2)); // a suspended, b resident
        assert_eq!(s.len(), 2);
        let dropped = s.insert("c".into(), engine(&w, 3));
        assert_eq!(dropped.as_deref(), Some("a"), "oldest (suspended) dropped");
        assert_eq!(s.len(), 2);
        assert!(!s.contains("a"));
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn checkout_checkin_keeps_byte_accounting_and_lru() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 7));
        let mut store = store(&w, drop_policy(4));
        store.insert("a".into(), engine(&w, 1));
        store.insert("b".into(), engine(&w, 2));
        let before = store.resident_bytes();
        let sess = store.checkout("a").expect("resident");
        assert_eq!(store.resident_bytes(), before, "checked-out stays charged");
        assert!(!store.contains("a"), "checked-out session is absent");
        assert!(store.checkout("a").is_none(), "double checkout");
        assert!(store.checkout("ghost").is_none());
        store.checkin("a".into(), sess);
        assert_eq!(store.resident_bytes(), before, "charge settled");
        assert!(store.is_resident("a"));
        // Discard releases the charge of a never-returned checkout.
        let sess = store.checkout("b").expect("resident");
        store.discard(sess);
        assert!(store.resident_bytes() < before, "discard released charge");
        // Check-in refreshed recency: "b" is now the LRU victim.
        let mut capped = store(&w, drop_policy(2));
        capped.insert("a".into(), engine(&w, 1));
        capped.insert("b".into(), engine(&w, 2));
        let s = capped.checkout("a").unwrap();
        capped.checkin("a".into(), s);
        let evicted = capped.insert("c".into(), engine(&w, 3));
        assert_eq!(evicted.as_deref(), Some("b"));
    }

    #[test]
    fn checkin_reenforces_budget() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 8));
        let one = engine(&w, 1).resident_bytes();
        let dir = tempdir("checkin");
        let mut store = store(
            &w,
            StorePolicy {
                max_resident: 64,
                max_total: 64,
                memory_budget_bytes: one + one / 2,
                spill_dir: Some(dir.clone()),
            },
        );
        store.insert("a".into(), engine(&w, 1));
        let sess = store.checkout("a").unwrap();
        store.insert("b".into(), engine(&w, 2));
        // Returning "a" puts the store over budget; the LRU ("b") spills,
        // the just-returned session is protected.
        store.checkin("a".into(), sess);
        assert!(store.is_resident("a"));
        assert!(store.is_suspended("b"));
        assert!(store.resident_bytes() <= one + one / 2);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn spill_filenames_are_safe_and_distinct() {
        let a = spill_filename("user/../../etc/passwd");
        assert!(!a.contains('/') && !a.contains(".."));
        assert_ne!(spill_filename("s1"), spill_filename("s2"));
        let long = "x".repeat(4096);
        assert!(spill_filename(&long).len() < 64);
    }

    #[test]
    fn corrupt_spill_surfaces_error_and_forgets_session() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 6));
        let dir = tempdir("corrupt");
        let mut s = store(
            &w,
            StorePolicy {
                max_resident: 4,
                max_total: 8,
                memory_budget_bytes: 0,
                spill_dir: Some(dir.clone()),
            },
        );
        s.insert("a".into(), engine(&w, 1));
        s.suspend("a").unwrap();
        let path = dir.join(spill_filename("a"));
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, bytes).unwrap();
        assert!(s.prepare("a").is_err(), "corrupt snapshot must error");
        // The broken session is gone — a retry reports Missing, not a hang.
        assert_eq!(s.prepare("a").unwrap(), Prepared::Missing);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn sanitized_prefix_check() {
        // Spaces and non-ASCII map to '_'; the FNV suffix disambiguates.
        assert!(spill_filename("weird id ☃").starts_with("weird_id__"));
    }
}
