//! Document sessions: one incremental engine per live document, with LRU
//! eviction. Each coordinator shard owns one `SessionStore` for the
//! sessions hash-routed to it — single-threaded access by construction,
//! so no interior locking is needed.

use crate::incremental::IncrementalEngine;
use std::collections::HashMap;

/// One live editing session.
pub struct Session {
    pub engine: IncrementalEngine,
    /// Monotonic access stamp for LRU.
    pub last_access: u64,
    /// Total edits served.
    pub edits: u64,
}

/// Session store with capacity-bounded LRU eviction.
pub struct SessionStore {
    map: HashMap<String, Session>,
    clock: u64,
    capacity: usize,
    pub evictions: u64,
}

impl SessionStore {
    pub fn new(capacity: usize) -> SessionStore {
        assert!(capacity > 0);
        SessionStore {
            map: HashMap::new(),
            clock: 0,
            capacity,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, id: &str) -> bool {
        self.map.contains_key(id)
    }

    /// Insert (or replace) a session; evicts the least-recently-used entry
    /// when at capacity. Returns the evicted session id, if any.
    pub fn insert(&mut self, id: String, engine: IncrementalEngine) -> Option<String> {
        self.clock += 1;
        let mut evicted = None;
        if !self.map.contains_key(&id) && self.map.len() >= self.capacity {
            if let Some(oldest) = self
                .map
                .iter()
                .min_by_key(|(_, s)| s.last_access)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&oldest);
                self.evictions += 1;
                evicted = Some(oldest);
            }
        }
        self.map.insert(
            id,
            Session {
                engine,
                last_access: self.clock,
                edits: 0,
            },
        );
        evicted
    }

    /// Mutable access, refreshing LRU recency.
    pub fn get_mut(&mut self, id: &str) -> Option<&mut Session> {
        self.clock += 1;
        let clock = self.clock;
        self.map.get_mut(id).map(|s| {
            s.last_access = clock;
            s
        })
    }

    pub fn remove(&mut self, id: &str) -> Option<Session> {
        self.map.remove(id)
    }

    pub fn ids(&self) -> Vec<String> {
        let mut v: Vec<String> = self.map.keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::incremental::EngineOptions;
    use crate::model::ModelWeights;
    use std::sync::Arc;

    fn engine(w: &Arc<ModelWeights>, seed: u64) -> IncrementalEngine {
        let tokens: Vec<u32> = (0..6).map(|i| ((seed + i) % 60) as u32).collect();
        IncrementalEngine::new(w.clone(), &tokens, EngineOptions::default())
    }

    #[test]
    fn lru_eviction_order() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 1));
        let mut store = SessionStore::new(2);
        assert_eq!(store.insert("a".into(), engine(&w, 1)), None);
        assert_eq!(store.insert("b".into(), engine(&w, 2)), None);
        // Touch "a" so "b" is the LRU.
        store.get_mut("a").unwrap();
        let evicted = store.insert("c".into(), engine(&w, 3));
        assert_eq!(evicted.as_deref(), Some("b"));
        assert!(store.contains("a") && store.contains("c"));
        assert_eq!(store.evictions, 1);
    }

    #[test]
    fn replace_does_not_evict() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 1));
        let mut store = SessionStore::new(1);
        store.insert("a".into(), engine(&w, 1));
        assert_eq!(store.insert("a".into(), engine(&w, 2)), None);
        assert_eq!(store.len(), 1);
        assert_eq!(store.evictions, 0);
    }

    #[test]
    fn remove_and_ids() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 1));
        let mut store = SessionStore::new(4);
        store.insert("x".into(), engine(&w, 1));
        store.insert("y".into(), engine(&w, 2));
        assert_eq!(store.ids(), vec!["x".to_string(), "y".to_string()]);
        assert!(store.remove("x").is_some());
        assert!(store.remove("x").is_none());
        assert_eq!(store.len(), 1);
    }
}
