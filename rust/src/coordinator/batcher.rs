//! Request-planning policy for the shard pool.
//!
//! Two layers of planning keep the hot path lock-free and cache-friendly:
//!
//! 1. **Routing** ([`shard_of`]): a session id is hashed to a fixed shard,
//!    so exactly one worker thread ever touches that session's
//!    `IncrementalEngine` — single-threaded ownership, no locks.
//! 2. **Batching** ([`plan`]): each shard drains its queue up to
//!    `max_batch` jobs (bounded by a deadline) and reorders them for
//!    session locality before execution.
//!
//! Invariant (property-tested): the relative order of jobs belonging to
//! the same session is preserved — reordering across sessions is free,
//! reordering within a session would corrupt edit scripts. Routing
//! preserves the same invariant globally because a session's jobs all
//! land in one shard's FIFO queue.

/// Shard index a session id is pinned to: FNV-1a 64-bit over the id bytes,
/// reduced mod the shard count. Deterministic and platform-independent, so
/// routing is stable across restarts and the tests can predict placement.
pub fn shard_of(session: &str, shards: usize) -> usize {
    debug_assert!(shards > 0);
    (crate::util::fnv1a64(session.as_bytes()) % shards as u64) as usize
}

/// Minimal view of a queued job for planning purposes.
pub trait SessionKeyed {
    /// Session key; `None` for session-less ops (dense calls, stats).
    fn session_key(&self) -> Option<&str>;
}

/// Stable-group jobs by session key: all jobs of the first-seen session
/// first (in arrival order), then the next session, etc. Session-less jobs
/// keep their arrival positions relative to their own kind at the end.
pub fn plan<J: SessionKeyed>(jobs: Vec<J>) -> Vec<J> {
    if jobs.len() <= 1 {
        return jobs;
    }
    // Assign each job a (group_rank, arrival) sort key.
    let mut group_rank: Vec<(Option<String>, usize)> = Vec::new();
    let mut keys = Vec::with_capacity(jobs.len());
    for (arrival, j) in jobs.iter().enumerate() {
        let k = j.session_key().map(|s| s.to_string());
        let rank = match group_rank.iter().position(|(g, _)| *g == k) {
            Some(i) => i,
            None => {
                group_rank.push((k.clone(), arrival));
                group_rank.len() - 1
            }
        };
        keys.push((rank, arrival));
    }
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by_key(|&i| keys[i]);
    // Permute.
    let mut slots: Vec<Option<J>> = jobs.into_iter().map(Some).collect();
    order
        .into_iter()
        .map(|i| slots[i].take().expect("each slot moved once"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq, Clone)]
    struct J(Option<&'static str>, u32);

    impl SessionKeyed for J {
        fn session_key(&self) -> Option<&str> {
            self.0
        }
    }

    #[test]
    fn groups_by_session_preserving_intra_order() {
        let jobs = vec![
            J(Some("a"), 0),
            J(Some("b"), 1),
            J(Some("a"), 2),
            J(None, 3),
            J(Some("b"), 4),
        ];
        let planned = plan(jobs);
        assert_eq!(
            planned,
            vec![
                J(Some("a"), 0),
                J(Some("a"), 2),
                J(Some("b"), 1),
                J(Some("b"), 4),
                J(None, 3),
            ]
        );
    }

    #[test]
    fn single_job_untouched() {
        let planned = plan(vec![J(Some("x"), 9)]);
        assert_eq!(planned, vec![J(Some("x"), 9)]);
    }

    #[test]
    fn shard_of_is_deterministic_and_in_range() {
        for shards in 1..9 {
            for i in 0..64 {
                let sid = format!("session-{i}");
                let s = shard_of(&sid, shards);
                assert!(s < shards);
                assert_eq!(s, shard_of(&sid, shards), "stable for {sid}");
            }
        }
        // Single shard: everything routes to 0.
        assert_eq!(shard_of("anything", 1), 0);
    }

    #[test]
    fn shard_of_spreads_sessions() {
        // Not a statistical test — just pin that FNV doesn't collapse a
        // realistic id population onto one shard.
        let shards = 4;
        let mut hit = [false; 4];
        for i in 0..64 {
            hit[shard_of(&format!("user-{i}-doc"), shards)] = true;
        }
        assert!(hit.iter().all(|&h| h), "all shards used: {hit:?}");
    }
}
