//! Batching policy: the worker drains the request queue up to
//! `max_batch` jobs (bounded by a deadline) and reorders them for session
//! locality before execution.
//!
//! Invariant (property-tested): the relative order of jobs belonging to
//! the same session is preserved — reordering across sessions is free,
//! reordering within a session would corrupt edit scripts.

/// Minimal view of a queued job for planning purposes.
pub trait SessionKeyed {
    /// Session key; `None` for session-less ops (dense calls, stats).
    fn session_key(&self) -> Option<&str>;
}

/// Stable-group jobs by session key: all jobs of the first-seen session
/// first (in arrival order), then the next session, etc. Session-less jobs
/// keep their arrival positions relative to their own kind at the end.
pub fn plan<J: SessionKeyed>(jobs: Vec<J>) -> Vec<J> {
    if jobs.len() <= 1 {
        return jobs;
    }
    // Assign each job a (group_rank, arrival) sort key.
    let mut group_rank: Vec<(Option<String>, usize)> = Vec::new();
    let mut keys = Vec::with_capacity(jobs.len());
    for (arrival, j) in jobs.iter().enumerate() {
        let k = j.session_key().map(|s| s.to_string());
        let rank = match group_rank.iter().position(|(g, _)| *g == k) {
            Some(i) => i,
            None => {
                group_rank.push((k.clone(), arrival));
                group_rank.len() - 1
            }
        };
        keys.push((rank, arrival));
    }
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by_key(|&i| keys[i]);
    // Permute.
    let mut slots: Vec<Option<J>> = jobs.into_iter().map(Some).collect();
    order
        .into_iter()
        .map(|i| slots[i].take().expect("each slot moved once"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq, Clone)]
    struct J(Option<&'static str>, u32);

    impl SessionKeyed for J {
        fn session_key(&self) -> Option<&str> {
            self.0
        }
    }

    #[test]
    fn groups_by_session_preserving_intra_order() {
        let jobs = vec![
            J(Some("a"), 0),
            J(Some("b"), 1),
            J(Some("a"), 2),
            J(None, 3),
            J(Some("b"), 4),
        ];
        let planned = plan(jobs);
        assert_eq!(
            planned,
            vec![
                J(Some("a"), 0),
                J(Some("a"), 2),
                J(Some("b"), 1),
                J(Some("b"), 4),
                J(None, 3),
            ]
        );
    }

    #[test]
    fn single_job_untouched() {
        let planned = plan(vec![J(Some("x"), 9)]);
        assert_eq!(planned, vec![J(Some("x"), 9)]);
    }
}
