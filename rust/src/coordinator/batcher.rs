//! Request-planning policy for the shard pool.
//!
//! Two layers of planning keep the hot path lock-free and cache-friendly:
//!
//! 1. **Routing** ([`shard_of`]): a session id is hashed to a fixed shard,
//!    so exactly one worker thread ever touches that session's
//!    `IncrementalEngine` — single-threaded ownership, no locks.
//! 2. **Batching** ([`plan`]): each shard drains its queue up to
//!    `max_batch` jobs (bounded by a deadline) and reorders them for
//!    session locality before execution.
//!
//! Invariant (property-tested): the relative order of jobs belonging to
//! the same session is preserved — reordering across sessions is free,
//! reordering within a session would corrupt edit scripts. Routing
//! preserves the same invariant globally because a session's jobs all
//! land in one shard's FIFO queue.

/// Shard index a session id is pinned to: FNV-1a 64-bit over the id bytes,
/// reduced mod the shard count. Deterministic and platform-independent, so
/// routing is stable across restarts and the tests can predict placement.
pub fn shard_of(session: &str, shards: usize) -> usize {
    debug_assert!(shards > 0);
    (crate::util::fnv1a64(session.as_bytes()) % shards as u64) as usize
}

/// Minimal view of a queued job for planning purposes.
pub trait SessionKeyed {
    /// Session key; `None` for session-less ops (dense calls, stats).
    fn session_key(&self) -> Option<&str>;
}

/// Size-or-timeout queue drain: after `first` arrives, keep pulling jobs
/// off the shard queue until `max` jobs are collected or `window` elapses
/// (whichever first — a full batch closes the window early, so a loaded
/// shard never waits). This is the adaptive gathering step in front of
/// [`plan`] and the cross-session pooled-GEMM executor: the window is the
/// wait a request may pay to share a weight traversal with its neighbors.
pub fn drain<J>(
    rx: &std::sync::mpsc::Receiver<J>,
    first: J,
    max: usize,
    window: std::time::Duration,
) -> Vec<J> {
    use std::sync::mpsc::TryRecvError;
    let mut batch = vec![first];
    let deadline = std::time::Instant::now() + window;
    while batch.len() < max {
        match rx.try_recv() {
            Ok(j) => batch.push(j),
            Err(TryRecvError::Empty) => {
                if std::time::Instant::now() >= deadline {
                    break;
                }
                std::thread::yield_now();
            }
            Err(TryRecvError::Disconnected) => break,
        }
    }
    batch
}

/// Stable-group jobs by session key: all jobs of the first-seen session
/// first (in arrival order), then the next session, etc. Session-less jobs
/// keep their arrival positions relative to their own kind at the end.
pub fn plan<J: SessionKeyed>(jobs: Vec<J>) -> Vec<J> {
    if jobs.len() <= 1 {
        return jobs;
    }
    // Assign each job a (group_rank, arrival) sort key.
    let mut group_rank: Vec<(Option<String>, usize)> = Vec::new();
    let mut keys = Vec::with_capacity(jobs.len());
    for (arrival, j) in jobs.iter().enumerate() {
        let k = j.session_key().map(|s| s.to_string());
        let rank = match group_rank.iter().position(|(g, _)| *g == k) {
            Some(i) => i,
            None => {
                group_rank.push((k.clone(), arrival));
                group_rank.len() - 1
            }
        };
        keys.push((rank, arrival));
    }
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by_key(|&i| keys[i]);
    // Permute.
    let mut slots: Vec<Option<J>> = jobs.into_iter().map(Some).collect();
    order
        .into_iter()
        .map(|i| slots[i].take().expect("each slot moved once"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq, Clone)]
    struct J(Option<&'static str>, u32);

    impl SessionKeyed for J {
        fn session_key(&self) -> Option<&str> {
            self.0
        }
    }

    #[test]
    fn groups_by_session_preserving_intra_order() {
        let jobs = vec![
            J(Some("a"), 0),
            J(Some("b"), 1),
            J(Some("a"), 2),
            J(None, 3),
            J(Some("b"), 4),
        ];
        let planned = plan(jobs);
        assert_eq!(
            planned,
            vec![
                J(Some("a"), 0),
                J(Some("a"), 2),
                J(Some("b"), 1),
                J(Some("b"), 4),
                J(None, 3),
            ]
        );
    }

    #[test]
    fn single_job_untouched() {
        let planned = plan(vec![J(Some("x"), 9)]);
        assert_eq!(planned, vec![J(Some("x"), 9)]);
    }

    #[test]
    fn drain_is_size_capped_and_keeps_order() {
        let (tx, rx) = std::sync::mpsc::channel::<u32>();
        for i in 1..6 {
            tx.send(i).unwrap();
        }
        // Size cap closes the window immediately — no timeout wait.
        let batch = drain(&rx, 0, 4, std::time::Duration::from_secs(60));
        assert_eq!(batch, vec![0, 1, 2, 3]);
        // Remaining jobs are still queued, in order.
        let rest = drain(&rx, rx.recv().unwrap(), 8, std::time::Duration::ZERO);
        assert_eq!(rest, vec![4, 5]);
    }

    #[test]
    fn drain_returns_at_least_first_on_empty_queue() {
        let (tx, rx) = std::sync::mpsc::channel::<u32>();
        let batch = drain(&rx, 9, 8, std::time::Duration::from_micros(50));
        assert_eq!(batch, vec![9]);
        drop(tx);
        // Disconnected sender: returns what it has, never hangs.
        let batch = drain(&rx, 7, 8, std::time::Duration::from_secs(60));
        assert_eq!(batch, vec![7]);
    }

    #[test]
    fn shard_of_is_deterministic_and_in_range() {
        for shards in 1..9 {
            for i in 0..64 {
                let sid = format!("session-{i}");
                let s = shard_of(&sid, shards);
                assert!(s < shards);
                assert_eq!(s, shard_of(&sid, shards), "stable for {sid}");
            }
        }
        // Single shard: everything routes to 0.
        assert_eq!(shard_of("anything", 1), 0);
    }

    /// Satellite coverage: adversarial session-id shapes must stay in
    /// range, hash distinctly where it matters, and not collapse realistic
    /// id families onto one shard.
    #[test]
    fn shard_of_sane_over_adversarial_id_shapes() {
        let shards = 4;
        // Degenerate and hostile shapes: all in range, all deterministic.
        let nasty = [
            "",
            " ",
            "\n",
            "a",
            "☃ unicode ☃",
            "../../etc/passwd",
            "\u{0}\u{1}\u{2}",
            "🦀🦀🦀🦀",
        ];
        for id in nasty {
            let s = shard_of(id, shards);
            assert!(s < shards, "{id:?}");
            assert_eq!(s, shard_of(id, shards), "{id:?} unstable");
            assert_eq!(shard_of(id, 1), 0, "{id:?} single shard");
        }
        // 4 KiB monster ids: in range, and a one-byte difference at the
        // END still lands distinct hash inputs (FNV folds every byte).
        let long_a = format!("{}a", "x".repeat(4096));
        let long_b = format!("{}b", "x".repeat(4096));
        assert!(shard_of(&long_a, shards) < shards);
        assert_ne!(
            crate::util::fnv1a64(long_a.as_bytes()),
            crate::util::fnv1a64(long_b.as_bytes()),
            "trailing-byte difference ignored"
        );
        // Realistic adversarial families (shared long prefixes, sequential
        // suffixes — the worst case for weak hashes): every shard used,
        // and no shard starved below a loose floor.
        for family in [
            |i: usize| format!("user-{i}-doc"),
            |i: usize| format!("{}{i}", "tenant-0000000000000000-session-"),
            |i: usize| format!("s{i:064}"),
        ] {
            let mut counts = [0usize; 4];
            for i in 0..256 {
                counts[shard_of(&family(i), shards)] += 1;
            }
            // Loose floor (fair share is 64): catches collapse, not skew.
            assert!(
                counts.iter().all(|&c| c >= 8),
                "family collapsed: {counts:?}"
            );
        }
    }

    #[test]
    fn shard_of_spreads_sessions() {
        // Not a statistical test — just pin that FNV doesn't collapse a
        // realistic id population onto one shard.
        let shards = 4;
        let mut hit = [false; 4];
        for i in 0..64 {
            hit[shard_of(&format!("user-{i}-doc"), shards)] = true;
        }
        assert!(hit.iter().all(|&h| h), "all shards used: {hit:?}");
    }
}
