//! Request-planning policy for the shard pool.
//!
//! Two layers of planning keep the hot path lock-free and cache-friendly:
//!
//! 1. **Routing** ([`shard_of`]): a session id is hashed to a fixed shard,
//!    so exactly one worker thread ever touches that session's
//!    `IncrementalEngine` — single-threaded ownership, no locks.
//! 2. **Batching** ([`plan`]): each shard drains its queue up to
//!    `max_batch` jobs (bounded by a deadline) and reorders them for
//!    session locality before execution.
//!
//! Invariant (property-tested): the relative order of jobs belonging to
//! the same session is preserved — reordering across sessions is free,
//! reordering within a session would corrupt edit scripts. Routing
//! preserves the same invariant globally because a session's jobs all
//! land in one shard's FIFO queue.

/// Shard index a session id is pinned to: FNV-1a 64-bit over the id bytes,
/// reduced mod the shard count. Deterministic and platform-independent, so
/// routing is stable across restarts and the tests can predict placement.
pub fn shard_of(session: &str, shards: usize) -> usize {
    debug_assert!(shards > 0);
    (crate::util::fnv1a64(session.as_bytes()) % shards as u64) as usize
}

/// Minimal view of a queued job for planning purposes.
pub trait SessionKeyed {
    /// Session key; `None` for session-less ops (dense calls, stats).
    fn session_key(&self) -> Option<&str>;
}

/// Size-or-timeout queue drain: after `first` arrives, keep pulling jobs
/// off the shard queue until `max` jobs are collected or `window` elapses
/// (whichever first — a full batch closes the window early, so a loaded
/// shard never waits). This is the adaptive gathering step in front of
/// [`plan`] and the cross-session pooled-GEMM executor: the window is the
/// wait a request may pay to share a weight traversal with its neighbors.
///
/// The wait is a *blocking* `recv_timeout` on the remaining deadline, not
/// a `yield_now` spin: an idle shard with an open window sleeps in the
/// channel's futex until a job arrives or the window closes, instead of
/// burning a full core re-polling an empty queue (regression-tested by
/// `empty_queue_drain_sleeps_instead_of_spinning`). Queued jobs are still
/// drained eagerly via `try_recv` first, so a `Duration::ZERO` window
/// collects everything already in the queue without sleeping at all.
///
/// Observability note: the window wait is charged to the *queue_wait*
/// stage, not to the wave itself — per-job queue wait is measured on the
/// worker from enqueue to the moment wave execution starts (after this
/// drain and [`plan`]), so `util::trace` needs no hook here and an
/// untraced drain stays zero-cost.
pub fn drain<J>(
    rx: &std::sync::mpsc::Receiver<J>,
    first: J,
    max: usize,
    window: std::time::Duration,
) -> Vec<J> {
    use std::sync::mpsc::{RecvTimeoutError, TryRecvError};
    let mut batch = vec![first];
    let deadline = std::time::Instant::now() + window;
    while batch.len() < max {
        match rx.try_recv() {
            Ok(j) => {
                batch.push(j);
                continue;
            }
            Err(TryRecvError::Disconnected) => break,
            Err(TryRecvError::Empty) => {}
        }
        let now = std::time::Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(j) => batch.push(j),
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    batch
}

/// Stable-group jobs by session key: all jobs of the first-seen session
/// first (in arrival order), then the next session, etc. Session-less jobs
/// keep their arrival positions relative to their own kind at the end.
pub fn plan<J: SessionKeyed>(jobs: Vec<J>) -> Vec<J> {
    if jobs.len() <= 1 {
        return jobs;
    }
    // Assign each job a (group_rank, arrival) sort key.
    let mut group_rank: Vec<(Option<String>, usize)> = Vec::new();
    let mut keys = Vec::with_capacity(jobs.len());
    for (arrival, j) in jobs.iter().enumerate() {
        let k = j.session_key().map(|s| s.to_string());
        let rank = match group_rank.iter().position(|(g, _)| *g == k) {
            Some(i) => i,
            None => {
                group_rank.push((k.clone(), arrival));
                group_rank.len() - 1
            }
        };
        keys.push((rank, arrival));
    }
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by_key(|&i| keys[i]);
    // Permute.
    let mut slots: Vec<Option<J>> = jobs.into_iter().map(Some).collect();
    order
        .into_iter()
        .map(|i| slots[i].take().expect("each slot moved once"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq, Clone)]
    struct J(Option<&'static str>, u32);

    impl SessionKeyed for J {
        fn session_key(&self) -> Option<&str> {
            self.0
        }
    }

    #[test]
    fn groups_by_session_preserving_intra_order() {
        let jobs = vec![
            J(Some("a"), 0),
            J(Some("b"), 1),
            J(Some("a"), 2),
            J(None, 3),
            J(Some("b"), 4),
        ];
        let planned = plan(jobs);
        assert_eq!(
            planned,
            vec![
                J(Some("a"), 0),
                J(Some("a"), 2),
                J(Some("b"), 1),
                J(Some("b"), 4),
                J(None, 3),
            ]
        );
    }

    #[test]
    fn single_job_untouched() {
        let planned = plan(vec![J(Some("x"), 9)]);
        assert_eq!(planned, vec![J(Some("x"), 9)]);
    }

    #[test]
    fn drain_is_size_capped_and_keeps_order() {
        let (tx, rx) = std::sync::mpsc::channel::<u32>();
        for i in 1..6 {
            tx.send(i).unwrap();
        }
        // Size cap closes the window immediately — no timeout wait.
        let batch = drain(&rx, 0, 4, std::time::Duration::from_secs(60));
        assert_eq!(batch, vec![0, 1, 2, 3]);
        // Remaining jobs are still queued, in order.
        let rest = drain(&rx, rx.recv().unwrap(), 8, std::time::Duration::ZERO);
        assert_eq!(rest, vec![4, 5]);
    }

    #[test]
    fn drain_returns_at_least_first_on_empty_queue() {
        let (tx, rx) = std::sync::mpsc::channel::<u32>();
        let batch = drain(&rx, 9, 8, std::time::Duration::from_micros(50));
        assert_eq!(batch, vec![9]);
        drop(tx);
        // Disconnected sender: returns what it has, never hangs.
        let batch = drain(&rx, 7, 8, std::time::Duration::from_secs(60));
        assert_eq!(batch, vec![7]);
    }

    /// Thread CPU time (user + system) in milliseconds, from
    /// `/proc/thread-self/stat` fields 14/15 (utime/stime, USER_HZ
    /// ticks — 100/s on every mainstream Linux).
    #[cfg(target_os = "linux")]
    fn thread_cpu_ms() -> u64 {
        let stat = std::fs::read_to_string("/proc/thread-self/stat").unwrap();
        // comm (field 2) may contain spaces/parens; split after it.
        let rest = &stat[stat.rfind(')').unwrap() + 2..];
        let f: Vec<&str> = rest.split_whitespace().collect();
        // rest starts at field 3, so utime (14) and stime (15) are at
        // indices 11 and 12.
        let ticks: u64 = f[11].parse::<u64>().unwrap() + f[12].parse::<u64>().unwrap();
        ticks * 10
    }

    /// Regression for the idle-spin bug: an empty-queue drain used to
    /// busy-loop `yield_now()` for the whole window, burning a full core.
    /// It must now *sleep* in `recv_timeout`: wall time covers the window
    /// while thread CPU time stays near zero.
    #[test]
    #[cfg(target_os = "linux")]
    fn empty_queue_drain_sleeps_instead_of_spinning() {
        let (tx, rx) = std::sync::mpsc::channel::<u32>();
        // Warm up lazy init (channel internals, /proc read) off the clock.
        let _ = drain(&rx, 0, 8, std::time::Duration::ZERO);
        let _ = thread_cpu_ms();
        let window = std::time::Duration::from_millis(400);
        let cpu0 = thread_cpu_ms();
        let t0 = std::time::Instant::now();
        let batch = drain(&rx, 1, 8, window);
        let wall = t0.elapsed();
        let cpu = thread_cpu_ms() - cpu0;
        drop(tx);
        assert_eq!(batch, vec![1]);
        assert!(wall >= std::time::Duration::from_millis(300), "window honored: {wall:?}");
        // The spin version burns ~400 ms of CPU here; the sleeping version
        // a few scheduler ticks. 100 ms is a generous CI-safe ceiling.
        assert!(cpu <= 100, "drain burned {cpu} ms CPU over a {wall:?} idle window");
    }

    /// The blocking wait must still wake for jobs that arrive mid-window
    /// (size-or-timeout semantics, not sleep-the-whole-window).
    #[test]
    fn drain_wakes_for_late_arrivals_within_window() {
        let (tx, rx) = std::sync::mpsc::channel::<u32>();
        let sender = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            tx.send(1).unwrap();
            tx.send(2).unwrap();
        });
        let t0 = std::time::Instant::now();
        // Size cap 3 closes the window as soon as both arrivals land.
        let batch = drain(&rx, 0, 3, std::time::Duration::from_secs(5));
        let wall = t0.elapsed();
        sender.join().unwrap();
        assert_eq!(batch, vec![0, 1, 2]);
        assert!(
            wall < std::time::Duration::from_secs(4),
            "size cap must close the window early, took {wall:?}"
        );
    }

    #[test]
    fn shard_of_is_deterministic_and_in_range() {
        for shards in 1..9 {
            for i in 0..64 {
                let sid = format!("session-{i}");
                let s = shard_of(&sid, shards);
                assert!(s < shards);
                assert_eq!(s, shard_of(&sid, shards), "stable for {sid}");
            }
        }
        // Single shard: everything routes to 0.
        assert_eq!(shard_of("anything", 1), 0);
    }

    /// Satellite coverage: adversarial session-id shapes must stay in
    /// range, hash distinctly where it matters, and not collapse realistic
    /// id families onto one shard.
    #[test]
    fn shard_of_sane_over_adversarial_id_shapes() {
        let shards = 4;
        // Degenerate and hostile shapes: all in range, all deterministic.
        let nasty = [
            "",
            " ",
            "\n",
            "a",
            "☃ unicode ☃",
            "../../etc/passwd",
            "\u{0}\u{1}\u{2}",
            "🦀🦀🦀🦀",
        ];
        for id in nasty {
            let s = shard_of(id, shards);
            assert!(s < shards, "{id:?}");
            assert_eq!(s, shard_of(id, shards), "{id:?} unstable");
            assert_eq!(shard_of(id, 1), 0, "{id:?} single shard");
        }
        // 4 KiB monster ids: in range, and a one-byte difference at the
        // END still lands distinct hash inputs (FNV folds every byte).
        let long_a = format!("{}a", "x".repeat(4096));
        let long_b = format!("{}b", "x".repeat(4096));
        assert!(shard_of(&long_a, shards) < shards);
        assert_ne!(
            crate::util::fnv1a64(long_a.as_bytes()),
            crate::util::fnv1a64(long_b.as_bytes()),
            "trailing-byte difference ignored"
        );
        // Realistic adversarial families (shared long prefixes, sequential
        // suffixes — the worst case for weak hashes): every shard used,
        // and no shard starved below a loose floor.
        for family in [
            |i: usize| format!("user-{i}-doc"),
            |i: usize| format!("{}{i}", "tenant-0000000000000000-session-"),
            |i: usize| format!("s{i:064}"),
        ] {
            let mut counts = [0usize; 4];
            for i in 0..256 {
                counts[shard_of(&family(i), shards)] += 1;
            }
            // Loose floor (fair share is 64): catches collapse, not skew.
            assert!(
                counts.iter().all(|&c| c >= 8),
                "family collapsed: {counts:?}"
            );
        }
    }

    #[test]
    fn shard_of_spreads_sessions() {
        // Not a statistical test — just pin that FNV doesn't collapse a
        // realistic id population onto one shard.
        let shards = 4;
        let mut hit = [false; 4];
        for i in 0..64 {
            hit[shard_of(&format!("user-{i}-doc"), shards)] = true;
        }
        assert!(hit.iter().all(|&h| h), "all shards used: {hit:?}");
    }
}
