//! The coordinator service: a worker thread owning all inference state
//! (sessions, engines, PJRT runtime — none of which are `Send`-friendly or
//! cheap to share), fronted by a bounded channel. Clients are cheap
//! clonable handles.

use crate::compressed::CompressedBatch;
use crate::config::ServeConfig;
use crate::edits::{diff_tokens, Edit};
use crate::flops::{dense_forward_flops, FlopLedger};
use crate::incremental::{EngineOptions, IncrementalEngine};
use crate::model::{dense_forward, ModelWeights};
use crate::runtime::ArtifactRuntime;
use crate::util::Json;
use anyhow::{bail, Result};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use super::batcher::{plan, SessionKeyed};
use super::metrics::Metrics;
use super::session::SessionStore;

/// Requests accepted by the coordinator.
#[derive(Clone, Debug)]
pub enum Request {
    /// Open (or reset) a session with an initial document.
    Open { session: String, tokens: Vec<u32> },
    /// Apply one edit to a session (the online path).
    Edit { session: String, edit: Edit },
    /// Apply an edit script to a session.
    EditScript { session: String, edits: Vec<Edit> },
    /// Submit a whole new revision; the coordinator diffs and applies
    /// incrementally (the offline path).
    Revision { session: String, tokens: Vec<u32> },
    /// Process a batch of revisions sharing one base document (offline
    /// batch; §3.1 compressed storage is measured and reported).
    BatchRevisions {
        base: Vec<u32>,
        revisions: Vec<Vec<u32>>,
    },
    /// Dense forward via the AOT L2 artifact (baseline / fallback path).
    Dense { tokens: Vec<u32> },
    /// Top-k next-token suggestions for a session (the writing-assistant
    /// payload; tied-embedding LM head over the last row).
    Suggest { session: String, k: usize },
    /// Persist a session's full state to a checkpoint file.
    Checkpoint { session: String, path: String },
    /// Restore a session from a checkpoint file (no recompute).
    Restore { session: String, path: String },
    /// Close a session.
    Close { session: String },
    /// Metrics snapshot.
    Stats,
}

impl Request {
    fn kind(&self) -> &'static str {
        match self {
            Request::Open { .. } => "open",
            Request::Edit { .. } => "edit",
            Request::EditScript { .. } => "edit_script",
            Request::Revision { .. } => "revision",
            Request::BatchRevisions { .. } => "batch_revisions",
            Request::Dense { .. } => "dense",
            Request::Suggest { .. } => "suggest",
            Request::Checkpoint { .. } => "checkpoint",
            Request::Restore { .. } => "restore",
            Request::Close { .. } => "close",
            Request::Stats => "stats",
        }
    }
}

/// Responses produced by the coordinator.
#[derive(Clone, Debug)]
pub enum Response {
    Logits {
        logits: Vec<f32>,
        predicted: usize,
        /// Arithmetic ops actually spent on this request.
        flops: u64,
        /// What a from-scratch dense pass would have cost.
        dense_equiv_flops: u64,
        defragged: bool,
    },
    BatchLogits {
        each: Vec<Vec<f32>>,
        flops: u64,
        dense_equiv_flops: u64,
        /// (compressed floats, dense floats) for the batch code state
        /// across layers — the §3.1 storage claim, measured.
        storage: (usize, usize),
    },
    Stats(Json),
    Suggestions(Vec<(u32, f32)>),
    Done,
    Closed {
        existed: bool,
    },
    Err(String),
}

impl Response {
    pub fn logits(&self) -> Result<&[f32]> {
        match self {
            Response::Logits { logits, .. } => Ok(logits),
            Response::Err(e) => bail!("coordinator error: {e}"),
            other => bail!("unexpected response {other:?}"),
        }
    }
}

struct Job {
    req: Request,
    reply: mpsc::Sender<Response>,
    enqueued: Instant,
}

impl SessionKeyed for Job {
    fn session_key(&self) -> Option<&str> {
        match &self.req {
            Request::Open { session, .. }
            | Request::Edit { session, .. }
            | Request::EditScript { session, .. }
            | Request::Revision { session, .. }
            | Request::Suggest { session, .. }
            | Request::Checkpoint { session, .. }
            | Request::Restore { session, .. }
            | Request::Close { session } => Some(session),
            _ => None,
        }
    }
}

/// Clonable client handle to a running coordinator.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::SyncSender<Job>,
}

impl Client {
    /// Blocking request (waits for queue space — natural backpressure).
    pub fn request(&self, req: Request) -> Result<Response> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Job {
                req,
                reply: rtx,
                enqueued: Instant::now(),
            })
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        Ok(rrx.recv()?)
    }

    /// Non-blocking request: fails fast when the queue is full
    /// (backpressure surfaces to the caller).
    pub fn try_request(&self, req: Request) -> Result<Response> {
        let (rtx, rrx) = mpsc::channel();
        match self.tx.try_send(Job {
            req,
            reply: rtx,
            enqueued: Instant::now(),
        }) {
            Ok(()) => Ok(rrx.recv()?),
            Err(mpsc::TrySendError::Full(_)) => bail!("queue full (backpressure)"),
            Err(mpsc::TrySendError::Disconnected(_)) => bail!("coordinator stopped"),
        }
    }
}

/// Running coordinator (worker thread + client factory). The worker exits
/// when every `Client` handle (including the coordinator's own) is gone.
pub struct Coordinator {
    client: Option<Client>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// What the worker serves from.
pub struct Backend {
    pub weights: Arc<ModelWeights>,
    /// AOT artifacts (None ⇒ dense requests run on the in-process oracle).
    pub artifacts_dir: Option<std::path::PathBuf>,
    pub engine_opts: EngineOptions,
}

impl Coordinator {
    /// Spawn the worker thread and return the handle.
    pub fn start(backend: Backend, cfg: ServeConfig) -> Coordinator {
        let (tx, rx) = mpsc::sync_channel::<Job>(cfg.queue_capacity);
        let client = Client { tx: tx.clone() };
        let handle = std::thread::Builder::new()
            .name("vqt-coordinator".into())
            .spawn(move || worker_loop(backend, cfg, rx))
            .expect("spawn coordinator");
        Coordinator {
            client: Some(client),
            handle: Some(handle),
        }
    }

    pub fn client(&self) -> Client {
        self.client.as_ref().expect("coordinator running").clone()
    }

    /// Drop our client handle and wait for the worker to drain and exit.
    /// (Outstanding client clones keep the worker alive until dropped.)
    pub fn shutdown(mut self) {
        self.client = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.client = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn worker_loop(backend: Backend, cfg: ServeConfig, rx: mpsc::Receiver<Job>) {
    let runtime = backend.artifacts_dir.as_ref().and_then(|d| {
        match ArtifactRuntime::open(d) {
            Ok(rt) => Some(rt),
            Err(e) => {
                log::warn!("artifact runtime unavailable ({e:#}); dense requests use the in-process oracle");
                None
            }
        }
    });
    let mut state = Worker {
        weights: backend.weights,
        engine_opts: backend.engine_opts,
        runtime,
        sessions: SessionStore::new(cfg.max_sessions),
        metrics: Metrics::default(),
        verify_every: cfg.verify_every,
    };
    loop {
        // Block for the first job, then drain up to max_batch more within
        // the deadline.
        let first = match rx.recv() {
            Ok(j) => j,
            Err(_) => break, // all clients gone
        };
        let mut batch = vec![first];
        let deadline =
            Instant::now() + std::time::Duration::from_millis(cfg.batch_deadline_ms);
        while batch.len() < cfg.max_batch {
            match rx.try_recv() {
                Ok(j) => batch.push(j),
                Err(mpsc::TryRecvError::Empty) => {
                    if Instant::now() >= deadline {
                        break;
                    }
                    std::thread::yield_now();
                }
                Err(mpsc::TryRecvError::Disconnected) => break,
            }
        }
        for job in plan(batch) {
            let kind = job.req.kind();
            let t0 = Instant::now();
            let resp = state.handle(job.req);
            let wait_us = job.enqueued.elapsed().as_micros() as f64;
            let us = t0.elapsed().as_micros() as f64;
            match kind {
                "edit" | "edit_script" => state.metrics.lat_edit_us.record(us),
                "revision" | "batch_revisions" => state.metrics.lat_revision_us.record(us),
                "dense" => state.metrics.lat_dense_us.record(us),
                _ => {}
            }
            log::debug!("{kind}: {us:.0}µs (+{wait_us:.0}µs queued)");
            if matches!(resp, Response::Err(_)) {
                state.metrics.errors += 1;
            }
            let _ = job.reply.send(resp);
        }
    }
    log::info!("coordinator worker exiting");
}

struct Worker {
    weights: Arc<ModelWeights>,
    engine_opts: EngineOptions,
    runtime: Option<ArtifactRuntime>,
    sessions: SessionStore,
    metrics: Metrics,
    verify_every: usize,
}

impl Worker {
    fn handle(&mut self, req: Request) -> Response {
        match self.handle_inner(req) {
            Ok(r) => r,
            Err(e) => Response::Err(format!("{e:#}")),
        }
    }

    fn dense_equiv(&self, n: usize) -> u64 {
        dense_forward_flops(&self.weights.cfg, n)
    }

    fn handle_inner(&mut self, req: Request) -> Result<Response> {
        match req {
            Request::Open { session, tokens } => {
                anyhow::ensure!(!tokens.is_empty(), "empty document");
                anyhow::ensure!(
                    tokens.len() <= self.weights.cfg.max_seq,
                    "document too long"
                );
                let mut opts = self.engine_opts;
                opts.verify_every = self.verify_every;
                let engine = IncrementalEngine::new(self.weights.clone(), &tokens, opts);
                let flops = engine.ledger.total();
                let logits = engine.logits().to_vec();
                let predicted = engine.predict();
                if self.sessions.insert(session, engine).is_some() {
                    self.metrics.sessions_evicted += 1;
                }
                self.metrics.sessions_opened += 1;
                let n = tokens.len();
                self.metrics.flops_incremental += flops;
                self.metrics.flops_dense_equiv += self.dense_equiv(n);
                Ok(Response::Logits {
                    logits,
                    predicted,
                    flops,
                    dense_equiv_flops: self.dense_equiv(n),
                    defragged: false,
                })
            }
            Request::Edit { session, edit } => self.apply_edits(&session, &[edit]),
            Request::EditScript { session, edits } => self.apply_edits(&session, &edits),
            Request::Revision { session, tokens } => {
                let s = self
                    .sessions
                    .get_mut(&session)
                    .ok_or_else(|| anyhow::anyhow!("unknown session '{session}'"))?;
                let script = diff_tokens(s.engine.tokens(), &tokens);
                let rep = s.engine.apply_revision(&script);
                s.edits += script.len() as u64;
                let n = s.engine.len();
                let predicted = s.engine.predict();
                self.metrics.revisions += 1;
                self.metrics.edits += script.len() as u64;
                self.metrics.flops_incremental += rep.flops;
                let dense_equiv = self.dense_equiv(n);
                self.metrics.flops_dense_equiv += dense_equiv;
                Ok(Response::Logits {
                    logits: rep.logits,
                    predicted,
                    flops: rep.flops,
                    dense_equiv_flops: dense_equiv,
                    defragged: rep.defragged,
                })
            }
            Request::BatchRevisions { base, revisions } => self.batch_revisions(base, revisions),
            Request::Dense { tokens } => {
                self.metrics.dense_calls += 1;
                let n = tokens.len();
                let logits = match &self.runtime {
                    Some(rt) => {
                        // Deterministic spread positions (same protocol as
                        // the engine's initial assignment).
                        let pool = rt.manifest.config.pos_pool;
                        let pos: Vec<u32> = (0..n)
                            .map(|i| (((2 * i + 1) * pool) / (2 * n)) as u32)
                            .collect();
                        rt.dense_logits(&tokens, &pos)?
                    }
                    None => {
                        let pool = self.weights.cfg.pos_pool;
                        let pos: Vec<u32> = (0..n)
                            .map(|i| (((2 * i + 1) * pool) / (2 * n)) as u32)
                            .collect();
                        let mut led = FlopLedger::new();
                        dense_forward(&self.weights, &tokens, &pos, &mut led).logits
                    }
                };
                let predicted = crate::tensor::argmax(&logits);
                Ok(Response::Logits {
                    logits,
                    predicted,
                    flops: self.dense_equiv(n),
                    dense_equiv_flops: self.dense_equiv(n),
                    defragged: false,
                })
            }
            Request::Suggest { session, k } => {
                let s = self
                    .sessions
                    .get_mut(&session)
                    .ok_or_else(|| anyhow::anyhow!("unknown session '{session}'"))?;
                Ok(Response::Suggestions(s.engine.suggest_topk(k.clamp(1, 64))))
            }
            Request::Checkpoint { session, path } => {
                anyhow::ensure!(
                    !path.contains(".."),
                    "checkpoint path must not contain '..'"
                );
                let s = self
                    .sessions
                    .get_mut(&session)
                    .ok_or_else(|| anyhow::anyhow!("unknown session '{session}'"))?;
                s.engine.to_tensor_file().save(&path)?;
                Ok(Response::Done)
            }
            Request::Restore { session, path } => {
                anyhow::ensure!(!path.contains(".."), "checkpoint path must not contain '..'");
                let tf = crate::util::TensorFile::load(&path)?;
                let mut opts = self.engine_opts;
                opts.verify_every = self.verify_every;
                let engine =
                    IncrementalEngine::from_tensor_file(self.weights.clone(), &tf, opts)?;
                if self.sessions.insert(session, engine).is_some() {
                    self.metrics.sessions_evicted += 1;
                }
                self.metrics.sessions_opened += 1;
                Ok(Response::Done)
            }
            Request::Close { session } => {
                let existed = self.sessions.remove(&session).is_some();
                Ok(Response::Closed { existed })
            }
            Request::Stats => {
                let mut j = self.metrics.to_json();
                if let Json::Obj(map) = &mut j {
                    map.insert(
                        "live_sessions".into(),
                        Json::num(self.sessions.len() as f64),
                    );
                }
                Ok(Response::Stats(j))
            }
        }
    }

    fn apply_edits(&mut self, session: &str, edits: &[Edit]) -> Result<Response> {
        let s = self
            .sessions
            .get_mut(session)
            .ok_or_else(|| anyhow::anyhow!("unknown session '{session}'"))?;
        let rep = s.engine.apply_edits(edits);
        s.edits += edits.len() as u64;
        let n = s.engine.len();
        let predicted = s.engine.predict();
        let defrags = s.engine.stats.defrags;
        self.metrics.edits += edits.len() as u64;
        self.metrics.defrags = self.metrics.defrags.max(defrags);
        self.metrics.flops_incremental += rep.flops;
        // Dense equivalent: one from-scratch pass per edit (the online
        // comparison the paper makes for atomic edits).
        let dense_equiv = self.dense_equiv(n) * edits.len().max(1) as u64;
        self.metrics.flops_dense_equiv += dense_equiv;
        Ok(Response::Logits {
            logits: rep.logits,
            predicted,
            flops: rep.flops,
            dense_equiv_flops: dense_equiv,
            defragged: rep.defragged,
        })
    }

    /// Offline batch: process the base once, fork per revision, apply each
    /// diff incrementally; measure the §3.1 compressed storage of the VQ
    /// code state across the batch.
    fn batch_revisions(&mut self, base: Vec<u32>, revisions: Vec<Vec<u32>>) -> Result<Response> {
        anyhow::ensure!(!base.is_empty(), "empty base document");
        let mut opts = self.engine_opts;
        opts.verify_every = 0;
        let base_engine = IncrementalEngine::new(self.weights.clone(), &base, opts);
        let mut flops = base_engine.ledger.total();
        let mut dense_equiv = self.dense_equiv(base.len());
        let mut each = Vec::with_capacity(revisions.len());
        let mut forks = Vec::with_capacity(revisions.len());
        for rev in &revisions {
            let mut fork = base_engine.fork();
            let script = diff_tokens(&base, rev);
            let rep = fork.apply_revision(&script);
            flops += rep.flops;
            dense_equiv += self.dense_equiv(rev.len());
            each.push(rep.logits);
            forks.push(fork);
        }
        self.metrics.revisions += revisions.len() as u64;
        self.metrics.flops_incremental += flops;
        self.metrics.flops_dense_equiv += dense_equiv;
        // §3.1 storage measurement over the final layer's code state:
        // members must share geometry, so measure on the shortest length.
        let min_len = forks
            .iter()
            .map(|f| f.len())
            .chain(std::iter::once(base_engine.len()))
            .min()
            .unwrap_or(0);
        let cfg = &self.weights.cfg;
        let mut storage = (0usize, 0usize);
        if min_len > 0 && cfg.vq_heads > 0 {
            let li = cfg.n_layers - 1;
            let mut lut = std::collections::HashMap::new();
            let mut codebook: Vec<Vec<f32>> = Vec::new();
            let vq = self.weights.layers[li].vq.as_ref().unwrap();
            let mut p: Vec<Vec<u32>> = Vec::new();
            for eng in std::iter::once(&base_engine).chain(forks.iter()) {
                let row: Vec<u32> = eng.layer_codes(li)[..min_len]
                    .iter()
                    .map(|&c| {
                        *lut.entry(c.pack()).or_insert_with(|| {
                            codebook.push(vq.decode(c));
                            (codebook.len() - 1) as u32
                        })
                    })
                    .collect();
                p.push(row);
            }
            let cb = CompressedBatch::from_index_matrix(min_len, p.len(), cfg.d_model, codebook, &p);
            storage = (cb.storage_floats(), cb.dense_floats());
        }
        Ok(Response::BatchLogits {
            each,
            flops,
            dense_equiv_flops: dense_equiv,
            storage,
        })
    }
}
