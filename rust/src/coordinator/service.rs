//! The coordinator service: a **sharded worker pool**. `ServeConfig::
//! workers` threads each own a disjoint set of sessions (engines, metrics,
//! PJRT runtime — none of which are cheap to share), fronted by one
//! bounded channel per shard. Sessions are hash-routed to a fixed shard
//! ([`super::batcher::shard_of`]), so the engine hot path stays
//! single-threaded and lock-free while throughput scales with cores.
//! Clients are cheap clonable handles that route by session id:
//!
//! - session-addressed requests go to the owning shard's FIFO queue;
//! - session-less work (`Dense`, `BatchRevisions`) is spread round-robin;
//! - `Stats` fans out to every shard and merges the per-shard
//!   [`Metrics`] snapshots into one pool-wide view.
//!
//! A request that panics inside a shard is caught, the (possibly
//! half-updated) session is dropped, and the caller gets an error — a
//! poisoned session never takes down the shard, the pool, or a blocked
//! caller.

use crate::compressed::CompressedBatch;
use crate::config::ServeConfig;
use crate::edits::{diff_tokens, Edit};
use crate::flops::{dense_forward_flops, FlopLedger};
use crate::incremental::{CacheHandle, CodeCache, EngineOptions, IncrementalEngine};
use crate::model::{dense_forward, ModelWeights};
use crate::runtime::ArtifactRuntime;
use crate::tensor;
use crate::util::trace::{self, TraceRecord, TraceRing};
use crate::util::Json;
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use super::batcher::{plan, shard_of, SessionKeyed};
use super::metrics::Metrics;
use super::session::{Prepared, Session, SessionStore, StorePolicy};

/// Requests accepted by the coordinator.
#[derive(Clone, Debug)]
pub enum Request {
    /// Open (or reset) a session with an initial document.
    Open { session: String, tokens: Vec<u32> },
    /// Apply one edit to a session (the online path).
    Edit { session: String, edit: Edit },
    /// Apply an edit script to a session.
    EditScript { session: String, edits: Vec<Edit> },
    /// Submit a whole new revision; the coordinator diffs and applies
    /// incrementally (the offline path).
    Revision { session: String, tokens: Vec<u32> },
    /// Process a batch of revisions sharing one base document (offline
    /// batch; §3.1 compressed storage is measured and reported).
    BatchRevisions {
        base: Vec<u32>,
        revisions: Vec<Vec<u32>>,
    },
    /// Dense forward via the AOT L2 artifact (baseline / fallback path).
    Dense { tokens: Vec<u32> },
    /// Top-k next-token suggestions for a session (the writing-assistant
    /// payload; tied-embedding LM head over the last row).
    Suggest { session: String, k: usize },
    /// Persist a session's full state to a snapshot file (the versioned,
    /// checksummed `VQSS` format — counters included).
    Checkpoint { session: String, path: String },
    /// Restore a session from a snapshot file (no recompute).
    Restore { session: String, path: String },
    /// Suspend a session: snapshot it to the spill dir and release its RAM.
    /// Its next request resumes it transparently.
    Suspend { session: String },
    /// Eagerly resume a suspended session (requests do this lazily anyway).
    Resume { session: String },
    /// Lifecycle introspection: state, measured bytes, edits, length.
    SessionInfo { session: String },
    /// Close a session.
    Close { session: String },
    /// Metrics snapshot.
    Stats,
    /// Last-N completed request traces (per-shard rings + the async
    /// front end's reply-write ring, concatenated).
    TraceDump,
    /// Prometheus-style text exposition of every counter/histogram.
    Metrics,
}

impl Request {
    /// Session key this request is pinned to. `None` ⇒ not
    /// session-addressed: routed round-robin (`Dense`, `BatchRevisions`)
    /// or fanned out to every shard (`Stats`).
    pub fn session(&self) -> Option<&str> {
        match self {
            Request::Open { session, .. }
            | Request::Edit { session, .. }
            | Request::EditScript { session, .. }
            | Request::Revision { session, .. }
            | Request::Suggest { session, .. }
            | Request::Checkpoint { session, .. }
            | Request::Restore { session, .. }
            | Request::Suspend { session }
            | Request::Resume { session }
            | Request::SessionInfo { session }
            | Request::Close { session } => Some(session),
            Request::BatchRevisions { .. }
            | Request::Dense { .. }
            | Request::Stats
            | Request::TraceDump
            | Request::Metrics => None,
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            Request::Open { .. } => "open",
            Request::Edit { .. } => "edit",
            Request::EditScript { .. } => "edit_script",
            Request::Revision { .. } => "revision",
            Request::BatchRevisions { .. } => "batch_revisions",
            Request::Dense { .. } => "dense",
            Request::Suggest { .. } => "suggest",
            Request::Checkpoint { .. } => "checkpoint",
            Request::Restore { .. } => "restore",
            Request::Suspend { .. } => "suspend",
            Request::Resume { .. } => "resume",
            Request::SessionInfo { .. } => "session_info",
            Request::Close { .. } => "close",
            Request::Stats => "stats",
            Request::TraceDump => "trace",
            Request::Metrics => "metrics",
        }
    }

    /// Monitoring verbs are never traced themselves (a `trace` dump that
    /// recorded itself would pollute the very rings it reads).
    fn is_admin(&self) -> bool {
        matches!(
            self,
            Request::Stats | Request::TraceDump | Request::Metrics
        )
    }
}

/// Responses produced by the coordinator.
#[derive(Clone, Debug)]
pub enum Response {
    Logits {
        logits: Vec<f32>,
        predicted: usize,
        /// Arithmetic ops actually spent on this request.
        flops: u64,
        /// What a from-scratch dense pass would have cost.
        dense_equiv_flops: u64,
        defragged: bool,
    },
    BatchLogits {
        each: Vec<Vec<f32>>,
        flops: u64,
        dense_equiv_flops: u64,
        /// (compressed floats, dense floats) for the batch code state
        /// across layers — the §3.1 storage claim, measured.
        storage: (usize, usize),
    },
    Stats(Json),
    /// One shard's raw metrics snapshot. Internal plumbing: the client
    /// fans a `Stats` request out to every shard and merges these into a
    /// single [`Response::Stats`] before the caller sees anything.
    ShardStats {
        metrics: Box<Metrics>,
        live_sessions: usize,
        /// Suspended (spilled-to-disk) sessions on this shard — a gauge.
        spilled_sessions: usize,
        /// Measured bytes of resident session state — the budget gauge.
        resident_bytes: u64,
    },
    /// Lifecycle introspection for one session.
    SessionInfo {
        state: &'static str,
        resident_bytes: u64,
        spill_bytes: u64,
        edits: u64,
        doc_len: usize,
    },
    Suggestions(Vec<(u32, f32)>),
    /// JSON array of completed [`TraceRecord`]s (the `trace` verb).
    Traces(Json),
    /// Prometheus text exposition (the `metrics` verb).
    MetricsText(String),
    /// A reply with its request's span breakdown attached — produced only
    /// when the client sent `"trace": true`, so replies stay byte-identical
    /// for everyone else.
    Traced {
        inner: Box<Response>,
        trace: Json,
    },
    Done,
    Closed {
        existed: bool,
    },
    Err(String),
}

impl Response {
    pub fn logits(&self) -> Result<&[f32]> {
        match self {
            Response::Logits { logits, .. } => Ok(logits),
            Response::Err(e) => bail!("coordinator error: {e}"),
            other => bail!("unexpected response {other:?}"),
        }
    }
}

/// A finished async request on its way back to the event loop: which
/// connection it belongs to and its per-connection sequence number, so the
/// front end can release replies in request order even when shards finish
/// out of order.
#[derive(Debug)]
pub struct Completion {
    pub conn: u64,
    pub seq: u64,
    pub resp: Response,
    /// Span breakdown of the request that produced this reply (traced
    /// requests only). The IO thread appends the `reply_write` stage once
    /// the bytes are flushed, then retires the record to its ring.
    pub trace: Option<TraceRecord>,
}

/// Where a shard delivers a job's reply.
///
/// The blocking server parks each caller thread on a fresh per-request
/// channel ([`ReplyTo::Sync`]). The readiness-driven front end cannot park
/// anything, so its jobs carry [`ReplyTo::Async`]: the shard pushes a
/// [`Completion`] onto the owning IO thread's queue and rings its waker
/// (an opaque `Fn` — an `eventfd` write in practice — so the coordinator
/// stays free of server types).
#[derive(Clone)]
pub enum ReplyTo {
    Sync(mpsc::Sender<Response>),
    Async {
        tx: mpsc::Sender<Completion>,
        conn: u64,
        seq: u64,
        wake: Arc<dyn Fn() + Send + Sync>,
    },
}

impl ReplyTo {
    /// Deliver the reply. A vanished receiver (caller gone, event loop
    /// shut down) is not an error for the shard — it just drops the reply,
    /// same contract the old raw `Sender` had.
    pub fn send(&self, resp: Response) {
        let _ = self.send_traced(resp, None);
    }

    /// Deliver the reply along with its trace record, if any. Async
    /// replies ship the record inside the [`Completion`] (the IO thread
    /// appends `reply_write` and owns its retirement); synchronous replies
    /// have no further stages, so the record is handed BACK to the caller
    /// — the shard worker — to retire into its own ring.
    pub fn send_traced(&self, resp: Response, rec: Option<TraceRecord>) -> Option<TraceRecord> {
        match self {
            ReplyTo::Sync(tx) => {
                let _ = tx.send(resp);
                rec
            }
            ReplyTo::Async {
                tx,
                conn,
                seq,
                wake,
            } => {
                let _ = tx.send(Completion {
                    conn: *conn,
                    seq: *seq,
                    resp,
                    trace: rec,
                });
                wake();
                None
            }
        }
    }
}

struct Job {
    req: Request,
    reply: ReplyTo,
    enqueued: Instant,
    /// Client asked for the span breakdown in its reply (`"trace": true`).
    trace: bool,
}

impl SessionKeyed for Job {
    fn session_key(&self) -> Option<&str> {
        self.req.session()
    }
}

/// Where a request goes in the pool.
enum Route {
    /// Session-addressed: the owning shard.
    Pinned(usize),
    /// Session-less one-shot work: any shard (round-robin).
    Any,
    /// Pool-wide snapshot: every shard, merged by the client.
    FanOut,
}

fn route(req: &Request, shards: usize) -> Route {
    match req.session() {
        Some(s) => Route::Pinned(shard_of(s, shards)),
        None if req.is_admin() => Route::FanOut,
        None => Route::Any,
    }
}

/// Why a non-blocking [`Client::submit`] could not enqueue a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The target shard's queue is full. The front end sheds the request
    /// with a typed `Busy` reply instead of queueing unboundedly.
    Busy,
    /// The coordinator has stopped; the connection should be closed.
    Closed,
}

/// Clonable client handle to a running coordinator pool. Routing happens
/// here: one bounded sender per shard, shared by all clones.
#[derive(Clone)]
pub struct Client {
    shards: Arc<[mpsc::SyncSender<Job>]>,
    /// Round-robin cursor for session-less requests.
    rr: Arc<AtomicUsize>,
}

impl Client {
    /// Number of worker shards behind this client.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Blocking request (waits for queue space — natural backpressure).
    pub fn request(&self, req: Request) -> Result<Response> {
        self.dispatch(req, true, false)
    }

    /// Blocking request with the client's per-request trace flag: the
    /// reply comes back wrapped in [`Response::Traced`] when set.
    pub fn request_traced(&self, req: Request, trace: bool) -> Result<Response> {
        self.dispatch(req, true, trace)
    }

    /// Non-blocking request: fails fast when the target shard's queue is
    /// full (backpressure surfaces to the caller).
    pub fn try_request(&self, req: Request) -> Result<Response> {
        self.dispatch(req, false, false)
    }

    /// Non-blocking submit for the readiness-driven front end: route the
    /// request and `try_send` it — the event loop must never park on a
    /// full shard queue. `Stats` (a pool-wide fan-out that has to park on
    /// every shard's snapshot) is serviced on a short-lived helper thread;
    /// it is a rare monitoring verb, so the thread cost is off the hot
    /// path by construction.
    pub fn submit(&self, req: Request, reply: ReplyTo) -> std::result::Result<(), SubmitError> {
        self.submit_traced(req, reply, false)
    }

    /// [`Client::submit`] with the client's per-request trace flag.
    pub fn submit_traced(
        &self,
        req: Request,
        reply: ReplyTo,
        trace: bool,
    ) -> std::result::Result<(), SubmitError> {
        let shard = match route(&req, self.shards.len()) {
            Route::Pinned(s) => s,
            Route::Any => self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len(),
            Route::FanOut => {
                let client = self.clone();
                let spawned = std::thread::Builder::new()
                    .name("vqt-fanout".into())
                    .spawn(move || {
                        let resp = client
                            .dispatch(req, true, false)
                            .unwrap_or_else(|e| Response::Err(format!("{e:#}")));
                        reply.send(resp);
                    });
                return spawned.map(|_| ()).map_err(|_| SubmitError::Closed);
            }
        };
        let job = Job {
            req,
            reply,
            enqueued: Instant::now(),
            trace,
        };
        match self.shards[shard].try_send(job) {
            Ok(()) => Ok(()),
            Err(mpsc::TrySendError::Full(_)) => Err(SubmitError::Busy),
            Err(mpsc::TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    fn enqueue(
        &self,
        shard: usize,
        req: Request,
        blocking: bool,
        trace: bool,
    ) -> Result<mpsc::Receiver<Response>> {
        let (rtx, rrx) = mpsc::channel();
        let job = Job {
            req,
            reply: ReplyTo::Sync(rtx),
            enqueued: Instant::now(),
            trace,
        };
        if blocking {
            self.shards[shard]
                .send(job)
                .map_err(|_| anyhow!("coordinator stopped"))?;
        } else {
            match self.shards[shard].try_send(job) {
                Ok(()) => {}
                Err(mpsc::TrySendError::Full(_)) => bail!("queue full (backpressure)"),
                Err(mpsc::TrySendError::Disconnected(_)) => bail!("coordinator stopped"),
            }
        }
        Ok(rrx)
    }

    /// Wait for a shard's reply. A dropped reply channel means the shard
    /// died before answering — surfaced as an error, never a hang.
    fn recv(rrx: mpsc::Receiver<Response>) -> Result<Response> {
        rrx.recv()
            .map_err(|_| anyhow!("coordinator shard terminated before replying"))
    }

    fn dispatch(&self, req: Request, blocking: bool, trace: bool) -> Result<Response> {
        match route(&req, self.shards.len()) {
            Route::Pinned(s) => Self::recv(self.enqueue(s, req, blocking, trace)?),
            Route::Any => {
                let s = self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len();
                Self::recv(self.enqueue(s, req, blocking, trace)?)
            }
            Route::FanOut => {
                // Enqueue on every shard first, then collect, so the
                // snapshots are taken concurrently.
                let want_prometheus = matches!(req, Request::Metrics);
                let rxs: Vec<_> = (0..self.shards.len())
                    .map(|s| self.enqueue(s, req.clone(), blocking, false))
                    .collect::<Result<_>>()?;
                if matches!(req, Request::TraceDump) {
                    // Shard rings in shard order, oldest-first within each.
                    // (The async front end grafts its own reply-write ring
                    // on top before serializing.)
                    let mut all = Vec::new();
                    for rrx in rxs {
                        match Self::recv(rrx)? {
                            Response::Traces(Json::Arr(mut v)) => all.append(&mut v),
                            Response::Err(e) => bail!("trace fan-out failed: {e}"),
                            other => bail!("unexpected shard trace response {other:?}"),
                        }
                    }
                    return Ok(Response::Traces(Json::Arr(all)));
                }
                let mut merged = Metrics::default();
                let mut live = 0usize;
                let mut spilled = 0usize;
                let mut res_bytes = 0u64;
                let mut per_shard = Vec::with_capacity(self.shards.len());
                for rrx in rxs {
                    match Self::recv(rrx)? {
                        Response::ShardStats {
                            metrics,
                            live_sessions,
                            spilled_sessions,
                            resident_bytes,
                        } => {
                            // Compact per-shard breakdown (shard order):
                            // makes routing spread observable — load skew
                            // and the round-robin path are testable and
                            // debuggable from one snapshot.
                            per_shard.push(Json::obj(vec![
                                ("live_sessions", Json::num(live_sessions as f64)),
                                ("spilled_sessions", Json::num(spilled_sessions as f64)),
                                ("resident_bytes", Json::num(resident_bytes as f64)),
                                ("edits", Json::num(metrics.edits as f64)),
                                ("dense_calls", Json::num(metrics.dense_calls as f64)),
                                ("errors", Json::num(metrics.errors as f64)),
                                ("panics", Json::num(metrics.panics as f64)),
                                ("batched_rows", Json::num(metrics.batched_rows as f64)),
                                ("cache_hits", Json::num(metrics.cache_hits as f64)),
                                ("cache_misses", Json::num(metrics.cache_misses as f64)),
                                (
                                    "cache_evictions",
                                    Json::num(metrics.cache_evictions as f64),
                                ),
                                ("cache_bytes", Json::num(metrics.cache_bytes as f64)),
                                (
                                    "attn_delta_rows",
                                    Json::num(metrics.attn_delta_rows as f64),
                                ),
                                (
                                    "attn_full_rows",
                                    Json::num(metrics.attn_full_rows as f64),
                                ),
                                (
                                    "attn_refreshes",
                                    Json::num(metrics.attn_refreshes as f64),
                                ),
                                (
                                    "attn_saved_flops",
                                    Json::num(metrics.attn_saved_flops as f64),
                                ),
                                (
                                    "queue_wait_p99_us",
                                    Json::num(metrics.queue_wait_us.percentile(99.0)),
                                ),
                                (
                                    "traces_recorded",
                                    Json::num(metrics.traces_recorded as f64),
                                ),
                                ("slow_requests", Json::num(metrics.slow_requests as f64)),
                            ]));
                            merged.merge(&metrics);
                            live += live_sessions;
                            spilled += spilled_sessions;
                            res_bytes += resident_bytes;
                        }
                        Response::Err(e) => bail!("stats fan-out failed: {e}"),
                        other => bail!("unexpected shard stats response {other:?}"),
                    }
                }
                if want_prometheus {
                    // The text exposition renders the merged counters; the
                    // pool-wide gauges ride along as plain gauges. (The
                    // async front end appends its connection gauges before
                    // the text leaves the process.)
                    return Ok(Response::MetricsText(merged.to_prometheus(&[
                        ("live_sessions", live as f64),
                        ("spilled_sessions", spilled as f64),
                        ("resident_bytes", res_bytes as f64),
                        ("shards", self.shards.len() as f64),
                    ])));
                }
                let mut j = merged.to_json();
                if let Json::Obj(map) = &mut j {
                    map.insert("live_sessions".into(), Json::num(live as f64));
                    map.insert("spilled_sessions".into(), Json::num(spilled as f64));
                    map.insert("resident_bytes".into(), Json::num(res_bytes as f64));
                    map.insert("shards".into(), Json::num(self.shards.len() as f64));
                    // Resolved kernel backend (process-global): lets an
                    // operator confirm from one Stats call which core the
                    // pool's dense work actually runs on.
                    map.insert(
                        "kernel_backend".into(),
                        Json::str(tensor::active_backend().name()),
                    );
                    map.insert("per_shard".into(), Json::Arr(per_shard));
                }
                Ok(Response::Stats(j))
            }
        }
    }
}

/// Running coordinator pool (N shard threads + client factory). The shards
/// exit when every `Client` handle (including the coordinator's own) is
/// gone; each drains the jobs already in its queue before exiting, so
/// shutdown never abandons an in-flight caller.
pub struct Coordinator {
    client: Option<Client>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

/// What the worker serves from.
pub struct Backend {
    pub weights: Arc<ModelWeights>,
    /// AOT artifacts (None ⇒ dense requests run on the in-process oracle).
    pub artifacts_dir: Option<std::path::PathBuf>,
    pub engine_opts: EngineOptions,
}

impl Coordinator {
    /// Spawn `cfg.workers` shard threads and return the pool handle.
    /// `queue_capacity` and `max_sessions` are split evenly across shards
    /// (ceil division), so the config keeps its pool-wide meaning.
    pub fn start(backend: Backend, cfg: ServeConfig) -> Coordinator {
        // Kernel backend selection is process-global (the codebook-product
        // cache shares rows across shards, so every shard must produce the
        // same bits — which all backends do by contract). An explicit
        // scalar/simd config wins; "auto" defers to VQT_KERNEL_BACKEND and
        // then to runtime feature detection. Config validation already
        // rejected typos; hand-built ServeConfigs with garbage fall back
        // to auto rather than panicking a server start.
        let requested = tensor::KernelBackend::parse(&cfg.kernel_backend)
            .unwrap_or(tensor::KernelBackend::Auto);
        tensor::set_kernel_backend(requested);
        log::info!(
            "kernel backend: requested {} → active {}",
            requested.name(),
            tensor::active_backend().name()
        );
        let shards = cfg.workers.max(1);
        let queue_cap = cfg.queue_capacity.div_ceil(shards).max(1);
        let sessions_cap = cfg.max_sessions.div_ceil(shards).max(1);
        // Lifecycle policy, split across shards like the other pool-wide
        // knobs. `max_resident_sessions == 0` means "no count pressure"
        // (resident cap = total cap); `memory_budget_mb == 0` disables the
        // byte budget; an empty spill dir means eviction drops sessions.
        let resident_cap = if cfg.max_resident_sessions == 0 {
            sessions_cap
        } else {
            cfg.max_resident_sessions
                .div_ceil(shards)
                .clamp(1, sessions_cap)
        };
        let budget_bytes = (cfg.memory_budget_mb * (1 << 20)) / shards;
        // Spill into a per-instance subdirectory: spill files are keyed by
        // session id, so two coordinators sharing the shipped spill_dir
        // would otherwise overwrite (and on resume, consume) each other's
        // suspended sessions. Clearing the subdirectory up front also
        // prevents a recycled pid from resuming stale snapshots of a dead
        // instance. (Suspended sessions intentionally do not outlive the
        // coordinator: the store's index is in-memory; `checkpoint` is the
        // durable-persistence verb.)
        let spill_dir = (!cfg.spill_dir.is_empty()).then(|| {
            let dir = std::path::Path::new(&cfg.spill_dir)
                .join(format!("instance-{}", std::process::id()));
            let _ = std::fs::remove_dir_all(&dir);
            dir
        });
        let policy = StorePolicy {
            max_resident: resident_cap,
            max_total: sessions_cap,
            memory_budget_bytes: budget_bytes,
            spill_dir,
        };
        // One PROCESS-GLOBAL codebook-product cache for the whole pool,
        // not one per shard: `code → decode·w_mix` products depend only on
        // the weights, so sessions hash-routed to different shards that
        // touch the same codes share warm entries. The handle carries the
        // weights fingerprint; every engine attaches a clone, and the
        // `code_cache_mb = 0` default keeps the classic uncached serving
        // numerics (and stat series) byte-for-byte.
        let code_cache = (cfg.code_cache_mb > 0).then(|| {
            CacheHandle::new(
                Arc::new(CodeCache::from_mb(cfg.code_cache_mb)),
                &backend.weights,
            )
        });
        let mut txs = Vec::with_capacity(shards);
        let mut handles = Vec::with_capacity(shards);
        for shard in 0..shards {
            let (tx, rx) = mpsc::sync_channel::<Job>(queue_cap);
            let seed = ShardSeed {
                weights: backend.weights.clone(),
                artifacts_dir: backend.artifacts_dir.clone(),
                engine_opts: backend.engine_opts,
                cfg: cfg.clone(),
                policy: policy.clone(),
                code_cache: code_cache.clone(),
            };
            let handle = std::thread::Builder::new()
                .name(format!("vqt-shard-{shard}"))
                .spawn(move || worker_loop(shard, seed, rx))
                .expect("spawn coordinator shard");
            txs.push(tx);
            handles.push(handle);
        }
        Coordinator {
            client: Some(Client {
                shards: txs.into(),
                rr: Arc::new(AtomicUsize::new(0)),
            }),
            handles,
        }
    }

    pub fn client(&self) -> Client {
        self.client.as_ref().expect("coordinator running").clone()
    }

    /// Drop our client handle and wait for every shard to drain and exit.
    /// (Outstanding client clones keep the shards alive until dropped.)
    pub fn shutdown(mut self) {
        self.join_all();
    }

    fn join_all(&mut self) {
        self.client = None;
        for h in self.handles.drain(..) {
            if h.join().is_err() {
                log::error!("coordinator shard panicked during shutdown");
            }
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.join_all();
    }
}

/// Best-effort panic payload stringification for the per-request guard.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&'static str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

/// Everything one shard thread serves from — bundled so the spawn site
/// stays one clone-per-field block as the pool grows knobs.
struct ShardSeed {
    weights: Arc<ModelWeights>,
    artifacts_dir: Option<std::path::PathBuf>,
    engine_opts: EngineOptions,
    cfg: ServeConfig,
    policy: StorePolicy,
    /// Pool-shared codebook-product cache (None ⇒ caching disabled).
    code_cache: Option<CacheHandle>,
}

fn worker_loop(shard: usize, seed: ShardSeed, rx: mpsc::Receiver<Job>) {
    let ShardSeed {
        weights,
        artifacts_dir,
        engine_opts,
        cfg,
        policy,
        code_cache,
    } = seed;
    let runtime = artifacts_dir.as_ref().and_then(|d| {
        match ArtifactRuntime::open(d) {
            Ok(rt) => Some(rt),
            Err(e) => {
                // One warning for the pool, not one per shard.
                if shard == 0 {
                    log::warn!("artifact runtime unavailable ({e:#}); dense requests use the in-process oracle");
                }
                None
            }
        }
    });
    // The store restores spilled sessions itself, so it owns the same
    // (weights, effective engine options) the Open path constructs with.
    let mut effective_opts = engine_opts;
    effective_opts.verify_every = cfg.verify_every;
    let mut state = Worker {
        weights: weights.clone(),
        engine_opts,
        runtime,
        sessions: SessionStore::new(weights, effective_opts, policy, code_cache.clone()),
        cache: code_cache,
        metrics: Metrics::default(),
        verify_every: cfg.verify_every,
        checkpoint_dir: cfg.checkpoint_dir.clone(),
        trace_all: cfg.trace_buffer > 0 || cfg.slow_request_us > 0,
        slow_request_us: cfg.slow_request_us,
        ring: TraceRing::new(cfg.trace_buffer),
    };
    // Size-or-timeout drain window: `batch_window_us` when set, else the
    // legacy ms-granular deadline.
    let window = if cfg.batch_window_us > 0 {
        std::time::Duration::from_micros(cfg.batch_window_us)
    } else {
        std::time::Duration::from_millis(cfg.batch_deadline_ms)
    };
    loop {
        // Block for the first job, then drain up to max_batch more within
        // the window (batcher::drain), and group by session (plan).
        let first = match rx.recv() {
            Ok(j) => j,
            Err(_) => break, // all clients gone
        };
        let jobs = plan(super::batcher::drain(&rx, first, cfg.max_batch, window));
        // Cross-session pooled execution for the leading edit jobs of
        // every session in the drain; everything else runs classically.
        let (entries, rest) = split_rounds(jobs, cfg.max_batch_rows > 0);
        if !entries.is_empty() {
            state.run_batched(shard, entries, cfg.max_batch_rows);
        }
        for job in rest {
            state.execute_job(shard, job);
        }
    }
    log::debug!("coordinator shard {shard} exiting");
}

/// A session's leading run of poolable (`Edit`/`EditScript`) jobs from
/// one queue drain — the unit the cross-session batcher consumes.
struct BatchEntry {
    session: String,
    jobs: std::collections::VecDeque<Job>,
}

/// Split a planned batch into cross-session poolable prefixes and the
/// rest. Jobs arrive grouped by session (see [`plan`]); each session
/// contributes its LEADING run of edit jobs. Later jobs — and anything
/// after a non-edit job — stay on the classic path, so per-session order
/// is preserved exactly. Pooling needs at least two sessions with edit
/// heads; otherwise everything keeps the classic path in plan order.
fn split_rounds(jobs: Vec<Job>, enabled: bool) -> (Vec<BatchEntry>, Vec<Job>) {
    let is_edit = |r: &Request| matches!(r, Request::Edit { .. } | Request::EditScript { .. });
    if !enabled {
        return (Vec::new(), jobs);
    }
    // First pass: how many sessions lead with an edit job?
    let mut heads = 0;
    let mut prev: Option<&str> = None;
    for job in &jobs {
        let s = job.req.session();
        if let Some(s) = s {
            if prev != Some(s) && is_edit(&job.req) {
                heads += 1;
            }
        }
        prev = s;
    }
    if heads < 2 {
        return (Vec::new(), jobs);
    }
    let mut entries: Vec<BatchEntry> = Vec::new();
    let mut rest: Vec<Job> = Vec::new();
    // (current session group, whether its poolable prefix has ended)
    let mut cur: Option<(String, bool)> = None;
    for job in jobs {
        match job.req.session().map(str::to_string) {
            Some(s) => {
                let broken = match &mut cur {
                    Some((cs, b)) if *cs == s => *b,
                    _ => {
                        cur = Some((s.clone(), false));
                        false
                    }
                };
                if !broken && is_edit(&job.req) {
                    match entries.iter_mut().find(|e| e.session == s) {
                        Some(e) => e.jobs.push_back(job),
                        None => entries.push(BatchEntry {
                            session: s,
                            jobs: std::iter::once(job).collect(),
                        }),
                    }
                } else {
                    if let Some((_, b)) = &mut cur {
                        *b = true;
                    }
                    rest.push(job);
                }
            }
            None => {
                cur = None;
                rest.push(job);
            }
        }
    }
    (entries, rest)
}

/// Validate an edit script against the engine's document invariants
/// WITHOUT touching engine state, by simulating the document length across
/// the script. These are exactly the conditions `stage_edit` asserts on;
/// checking them up front turns a malformed client script into a typed
/// error instead of a panic that costs the whole session (or, on the
/// pooled path, every session in the wave).
fn validate_edits(edits: &[Edit], mut len: usize, max_seq: usize) -> Result<()> {
    for e in edits {
        match *e {
            Edit::Replace { at, .. } => {
                anyhow::ensure!(at < len, "replace at {at} out of bounds (document length {len})");
            }
            Edit::Insert { at, .. } => {
                anyhow::ensure!(at <= len, "insert at {at} out of bounds (document length {len})");
                anyhow::ensure!(len < max_seq, "document full ({max_seq} tokens)");
                len += 1;
            }
            Edit::Delete { at } => {
                anyhow::ensure!(at < len, "delete at {at} out of bounds (document length {len})");
                anyhow::ensure!(len > 1, "cannot delete the last token");
                len -= 1;
            }
        }
    }
    Ok(())
}

struct Worker {
    weights: Arc<ModelWeights>,
    engine_opts: EngineOptions,
    runtime: Option<ArtifactRuntime>,
    sessions: SessionStore,
    /// Pool-shared codebook-product cache, attached to every engine this
    /// shard constructs (`None` ⇒ `code_cache_mb = 0`, classic serving).
    cache: Option<CacheHandle>,
    metrics: Metrics,
    verify_every: usize,
    /// Directory snapshot verbs are confined to (empty ⇒ verbs disabled).
    checkpoint_dir: String,
    /// Trace every request (`trace_buffer > 0` or `slow_request_us > 0`),
    /// not just the ones that asked with `"trace": true`.
    trace_all: bool,
    /// WARN with the full span breakdown when a traced request's total
    /// exceeds this many microseconds (0 ⇒ off).
    slow_request_us: u64,
    /// Last-N completed traces on this shard (sync-reply requests; async
    /// replies retire into the front end's ring after `reply_write`).
    ring: TraceRing,
}

/// Snapshot of one engine's cache counters — subtracted around each
/// request to attribute hit/miss/eviction/byte activity to the serving
/// shard (same additive-delta protocol the `defrags` counter uses, so the
/// cross-shard merge stays a plain sum regardless of session placement).
fn cache_counters(e: &IncrementalEngine) -> (u64, u64, u64, u64) {
    (
        e.stats.cache_hits,
        e.stats.cache_misses,
        e.stats.cache_evictions,
        e.stats.cache_bytes_inserted,
    )
}

/// Snapshot of one engine's semi-naive attention counters — same
/// additive-delta protocol as [`cache_counters`], so delta-row/full-row/
/// refresh/saved-FLOP activity sums correctly across shards. All four stay
/// zero on gelu-series engines (no aggregates, no softmax recompute path).
fn attn_counters(e: &IncrementalEngine) -> (u64, u64, u64, u64) {
    (
        e.stats.attn_delta_rows,
        e.stats.attn_full_rows,
        e.stats.attn_refreshes,
        e.stats.attn_delta_saved_flops,
    )
}

impl Worker {
    fn handle(&mut self, req: Request) -> Response {
        match self.handle_inner(req) {
            Ok(r) => r,
            Err(e) => Response::Err(format!("{e:#}")),
        }
    }

    /// Shared trace bookkeeping: count the record, and WARN with the full
    /// span breakdown when it crossed the slow-request threshold.
    fn note_trace(&mut self, rec: &TraceRecord) {
        self.metrics.traces_recorded += 1;
        if self.slow_request_us > 0 && rec.total_us >= self.slow_request_us {
            self.metrics.slow_requests += 1;
            log::warn!(
                "slow request on shard {}: '{}' took {}µs (threshold {}µs) {}",
                rec.shard,
                rec.kind,
                rec.total_us,
                self.slow_request_us,
                rec.to_json()
            );
        }
    }

    /// Execute one job on the classic per-session path: panic-guarded
    /// handle, latency/error accounting, optional span trace, reply.
    fn execute_job(&mut self, shard: usize, job: Job) {
        let Job {
            req,
            reply,
            enqueued,
            trace: trace_requested,
        } = job;
        let kind = req.kind();
        let session = req.session().map(str::to_string);
        // Admin verbs are exempt from tracing: a `trace` dump that traced
        // itself would pollute the very rings it reads.
        let traced = (self.trace_all || trace_requested) && !req.is_admin();
        // Queue wait is measured AT dequeue so service time cannot leak
        // into it (the old `enqueued.elapsed()` taken after handle() made
        // the "queued" debug figure include the request's own service).
        let t0 = Instant::now();
        let wait_us = t0.saturating_duration_since(enqueued).as_micros() as f64;
        self.metrics.queue_wait_us.record(wait_us);
        if traced {
            trace::begin(enqueued);
            trace::record_span("queue_wait", enqueued, t0);
        } else {
            // Also neutralizes state a panic-unwound request left behind.
            trace::ensure_off();
        }
        let guarded = std::panic::AssertUnwindSafe(|| self.handle(req));
        let resp = match std::panic::catch_unwind(guarded) {
            Ok(r) => r,
            Err(payload) => {
                // A panicking request must not take the shard (or a
                // blocked caller) down with it. The session that
                // panicked mid-update may hold half-applied state, so
                // it is dropped rather than served corrupt.
                if let Some(s) = &session {
                    self.sessions.remove(s);
                }
                self.metrics.panics += 1;
                Response::Err(format!(
                    "request '{kind}' panicked: {} (session dropped)",
                    panic_message(payload.as_ref())
                ))
            }
        };
        let us = t0.elapsed().as_micros() as f64;
        match kind {
            "edit" | "edit_script" => self.metrics.lat_edit_us.record(us),
            "revision" | "batch_revisions" => self.metrics.lat_revision_us.record(us),
            "dense" => self.metrics.lat_dense_us.record(us),
            _ => {}
        }
        log::debug!("shard {shard} {kind}: {us:.0}µs (+{wait_us:.0}µs queued)");
        if matches!(resp, Response::Err(_)) {
            self.metrics.errors += 1;
        }
        match trace::finish() {
            None => reply.send(resp),
            Some(mut rec) => {
                rec.kind = kind;
                rec.session = session;
                rec.shard = shard;
                self.note_trace(&rec);
                let resp = if trace_requested {
                    Response::Traced {
                        inner: Box::new(resp),
                        trace: rec.to_json(),
                    }
                } else {
                    resp
                };
                // Sync replies hand the record back for this shard's ring;
                // async replies retire it in the IO thread after the
                // `reply_write` stage is appended.
                if let Some(r) = reply.send_traced(resp, Some(rec)) {
                    self.ring.push(r);
                }
            }
        }
    }

    /// Cross-session pooled execution over the batchable prefixes of one
    /// queue drain. Wave by wave, the next queued edit job of every
    /// session runs concurrently: each engine's per-layer block tails are
    /// pooled into stacked GEMMs of at most `max_batch_rows` rows
    /// ([`crate::incremental::batch`]), so the layer weights are streamed
    /// once per pooled wave instead of once per session. Bit-exact with
    /// the classic path — locked by the unit tests below and
    /// `tests/differential_batch.rs`.
    fn run_batched(&mut self, shard: usize, mut entries: Vec<BatchEntry>, max_batch_rows: usize) {
        loop {
            let live = entries.iter().filter(|e| !e.jobs.is_empty()).count();
            if live == 0 {
                break;
            }
            if live < 2 {
                // A single session's tail cannot pool with anyone — run
                // its remaining jobs on the classic path directly instead
                // of paying checkout/checkin (byte re-measure + budget
                // enforcement) per job for zero batching benefit.
                for e in entries.iter_mut() {
                    while let Some(job) = e.jobs.pop_front() {
                        self.execute_job(shard, job);
                    }
                }
                break;
            }
            // Assemble the wave: the next job of every session.
            let mut wave: Vec<(String, Job)> = Vec::new();
            for e in entries.iter_mut() {
                if let Some(job) = e.jobs.pop_front() {
                    wave.push((e.session.clone(), job));
                }
            }
            // Fault in and check out every wave session. Unknown sessions
            // fall back to the classic path, which reports the canonical
            // error. A failed resume must be reported HERE: prepare()
            // consumes the spill entry on failure, so by the time the
            // classic path retried, the cause (e.g. a corrupt snapshot)
            // would have degraded to 'unknown session'. Fault-in time
            // counts toward the wave's recorded service time, exactly as
            // ensure_resident's resume does inside the classic path's
            // latency measurement.
            let t_prep = Instant::now();
            let mut pool: Vec<(String, Session, Job)> = Vec::new();
            let mut fallback: Vec<Job> = Vec::new();
            for (s, job) in wave {
                match self.sessions.prepare(&s) {
                    Ok(Prepared::Resident | Prepared::Resumed) => {
                        if let Some(sess) = self.sessions.checkout(&s) {
                            pool.push((s, sess, job));
                        } else {
                            fallback.push(job);
                        }
                    }
                    Ok(Prepared::Missing) => fallback.push(job),
                    Err(e) => {
                        self.metrics.errors += 1;
                        job.reply.send(Response::Err(format!("{e:#}")));
                    }
                }
            }
            // Typed pre-validation against each session's CURRENT document
            // (earlier waves already applied): a malformed script gets an
            // error reply and leaves its session intact, exactly like the
            // classic path — and it never reaches the pooled kernel, where
            // a panic would cost every session in the wave.
            let mut valid: Vec<(String, Session, Job)> = Vec::new();
            for (s, sess, job) in pool {
                let checked = match &job.req {
                    Request::Edit { edit, .. } => validate_edits(
                        std::slice::from_ref(edit),
                        sess.engine.len(),
                        self.weights.cfg.max_seq,
                    ),
                    Request::EditScript { edits, .. } => {
                        validate_edits(edits, sess.engine.len(), self.weights.cfg.max_seq)
                    }
                    other => unreachable!("non-edit request {other:?} in batch pool"),
                };
                match checked {
                    Ok(()) => valid.push((s, sess, job)),
                    Err(e) => {
                        self.sessions.checkin(s, sess);
                        self.metrics.errors += 1;
                        job.reply.send(Response::Err(format!("{e:#}")));
                    }
                }
            }
            let mut pool = valid;
            if pool.len() < 2 {
                // Nothing to pool across sessions — classic path.
                for (s, sess, job) in pool {
                    self.sessions.checkin(s, sess);
                    fallback.push(job);
                }
                for job in fallback {
                    self.execute_job(shard, job);
                }
                continue;
            }
            let prep_us = t_prep.elapsed().as_micros() as f64;
            for job in fallback {
                self.execute_job(shard, job);
            }
            // Trace the wave ONCE against its earliest enqueue (the pooled
            // stages are shared work, so per-job guards would lie); each
            // member's record is rebased to its own enqueue instant in the
            // reply loop so every timeline starts at 0. This must begin
            // after the fallback jobs above — execute_job manages the
            // thread-local trace itself and would clobber an open wave.
            let wave_traced = self.trace_all || pool.iter().any(|(_, _, j)| j.trace);
            if wave_traced {
                let epoch = pool
                    .iter()
                    .map(|(_, _, j)| j.enqueued)
                    .min()
                    .expect("pooled wave has >=2 jobs");
                trace::begin(epoch);
            } else {
                trace::ensure_off();
            }
            // Pooled execution of the wave.
            let t0 = Instant::now();
            let scripts: Vec<Vec<Edit>> = pool
                .iter()
                .map(|(_, _, job)| match &job.req {
                    Request::Edit { edit, .. } => vec![*edit],
                    Request::EditScript { edits, .. } => edits.clone(),
                    other => unreachable!("non-edit request {other:?} in batch pool"),
                })
                .collect();
            let defrags_before: Vec<u64> = pool
                .iter()
                .map(|(_, s, _)| s.engine.stats.defrags)
                .collect();
            let cache_before: Vec<(u64, u64, u64, u64)> = pool
                .iter()
                .map(|(_, s, _)| cache_counters(&s.engine))
                .collect();
            let attn_before: Vec<(u64, u64, u64, u64)> = pool
                .iter()
                .map(|(_, s, _)| attn_counters(&s.engine))
                .collect();
            let outcome = {
                let script_refs: Vec<&[Edit]> = scripts.iter().map(|s| s.as_slice()).collect();
                let mut engines: Vec<&mut crate::incremental::IncrementalEngine> =
                    pool.iter_mut().map(|(_, s, _)| &mut s.engine).collect();
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    crate::incremental::batch::apply_scripts_batched(
                        &mut engines,
                        &script_refs,
                        max_batch_rows,
                    )
                }))
            };
            match outcome {
                Err(payload) => {
                    // Any engine in the wave may hold half-applied state —
                    // drop them all rather than serve corrupt sessions.
                    // (Their queued follow-up jobs will get the canonical
                    // unknown-session error on later waves.)
                    trace::ensure_off();
                    self.metrics.panics += 1;
                    let msg = panic_message(payload.as_ref()).to_string();
                    for (s, sess, job) in pool {
                        self.sessions.discard(sess);
                        self.metrics.errors += 1;
                        job.reply.send(Response::Err(format!(
                            "batched edit panicked: {msg} (session '{s}' dropped)"
                        )));
                    }
                }
                Ok(out) => {
                    let wave_rec = trace::finish();
                    self.metrics.batched_rows += out.batched_rows;
                    for &f in &out.gemm_fills {
                        self.metrics.batch_fill.record(f as f64);
                    }
                    // One service-time measurement for the whole wave
                    // (fault-in + pooled execution), taken before the
                    // reply loop: every pooled session received the same
                    // service, so recording a value inflated by earlier
                    // sessions' reply work would skew the histogram by
                    // reply order.
                    let us = prep_us + t0.elapsed().as_micros() as f64;
                    for (i, ((s, mut sess, job), rep)) in
                        pool.into_iter().zip(out.reports).enumerate()
                    {
                        // Identical accounting to the classic apply_edits
                        // path: per-session edit counters, FLOP ledgers,
                        // byte re-measurement on check-in.
                        let nedits = scripts[i].len();
                        sess.edits += nedits as u64;
                        let n = sess.engine.len();
                        let predicted = sess.engine.predict();
                        let defrag_delta = sess.engine.stats.defrags - defrags_before[i];
                        let cache_after = cache_counters(&sess.engine);
                        let attn_after = attn_counters(&sess.engine);
                        self.sessions.checkin(s, sess);
                        self.metrics.edits += nedits as u64;
                        self.metrics.defrags += defrag_delta;
                        self.charge_cache_delta(cache_before[i], cache_after);
                        self.charge_attn_delta(attn_before[i], attn_after);
                        self.metrics.flops_incremental += rep.flops;
                        let dense_equiv = self.dense_equiv(n) * nedits.max(1) as u64;
                        self.metrics.flops_dense_equiv += dense_equiv;
                        self.metrics.lat_edit_us.record(us);
                        // Per-job queue wait, measured at the wave's
                        // dequeue/prepare point (service time excluded,
                        // same fix as the classic path).
                        let wait_us =
                            t_prep.saturating_duration_since(job.enqueued).as_micros() as f64;
                        self.metrics.queue_wait_us.record(wait_us);
                        log::debug!(
                            "shard {shard} batched {}: {us:.0}µs (+{wait_us:.0}µs queued)",
                            job.req.kind()
                        );
                        let resp = Response::Logits {
                            logits: rep.logits,
                            predicted,
                            flops: rep.flops,
                            dense_equiv_flops: dense_equiv,
                            defragged: rep.defragged,
                        };
                        let rec = wave_rec
                            .as_ref()
                            .filter(|_| self.trace_all || job.trace)
                            .map(|w| {
                                let mut r = w.rebased(job.enqueued);
                                r.kind = job.req.kind();
                                r.session = job.req.session().map(str::to_string);
                                r.shard = shard;
                                r.push_span("queue_wait", job.enqueued, t_prep);
                                r
                            });
                        match rec {
                            None => job.reply.send(resp),
                            Some(rec) => {
                                self.note_trace(&rec);
                                let resp = if job.trace {
                                    Response::Traced {
                                        inner: Box::new(resp),
                                        trace: rec.to_json(),
                                    }
                                } else {
                                    resp
                                };
                                if let Some(r) = job.reply.send_traced(resp, Some(rec)) {
                                    self.ring.push(r);
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    fn dense_equiv(&self, n: usize) -> u64 {
        dense_forward_flops(&self.weights.cfg, n)
    }

    /// Fold an engine's cache-counter delta into this shard's metrics.
    fn charge_cache_delta(&mut self, before: (u64, u64, u64, u64), after: (u64, u64, u64, u64)) {
        self.metrics.cache_hits += after.0 - before.0;
        self.metrics.cache_misses += after.1 - before.1;
        self.metrics.cache_evictions += after.2 - before.2;
        self.metrics.cache_bytes += after.3 - before.3;
    }

    /// Fold an engine's attention-counter delta into this shard's metrics.
    fn charge_attn_delta(&mut self, before: (u64, u64, u64, u64), after: (u64, u64, u64, u64)) {
        self.metrics.attn_delta_rows += after.0 - before.0;
        self.metrics.attn_full_rows += after.1 - before.1;
        self.metrics.attn_refreshes += after.2 - before.2;
        self.metrics.attn_saved_flops += after.3 - before.3;
    }

    /// Resolve a client-supplied snapshot name inside the configured
    /// checkpoint directory. The name must be a bare filename: absolute
    /// paths, path separators, and dot components are rejected with typed
    /// errors, so no client-controlled string can make the server read or
    /// write outside `checkpoint_dir`. An empty `checkpoint_dir` keeps the
    /// verbs disabled (the secure default).
    fn checkpoint_path(&self, name: &str) -> Result<std::path::PathBuf> {
        anyhow::ensure!(
            !self.checkpoint_dir.is_empty(),
            "checkpoint/restore disabled: no checkpoint_dir configured"
        );
        anyhow::ensure!(!name.is_empty(), "empty checkpoint name");
        anyhow::ensure!(
            !name.contains('/') && !name.contains('\\'),
            "checkpoint name must be a bare filename inside checkpoint_dir \
             (path separators rejected)"
        );
        anyhow::ensure!(
            name != "." && name != "..",
            "checkpoint name must be a bare filename inside checkpoint_dir"
        );
        // Separators are rejected above, so the name is one normal path
        // component and the join cannot escape the directory.
        Ok(std::path::Path::new(&self.checkpoint_dir).join(name))
    }

    /// Fault a session in (transparently resuming it from its spill
    /// snapshot if suspended) or fail with the canonical unknown-session
    /// error. Every session-state-touching verb funnels through here.
    fn ensure_resident(&mut self, session: &str) -> Result<()> {
        match self.sessions.prepare(session)? {
            Prepared::Resident | Prepared::Resumed => Ok(()),
            Prepared::Missing => anyhow::bail!("unknown session '{session}'"),
        }
    }

    fn handle_inner(&mut self, req: Request) -> Result<Response> {
        match req {
            Request::Open { session, tokens } => {
                anyhow::ensure!(!tokens.is_empty(), "empty document");
                anyhow::ensure!(
                    tokens.len() <= self.weights.cfg.max_seq,
                    "document too long"
                );
                let mut opts = self.engine_opts;
                opts.verify_every = self.verify_every;
                let mut engine = IncrementalEngine::try_new(self.weights.clone(), &tokens, opts)?;
                // Attach AFTER the initial build: an Open processes every
                // row of a fresh document, and warming the shared cache
                // with a whole document's worth of products would let one
                // large open evict the hot working set of every live
                // session. Steady-state edits are the hit population that
                // matters; they attach here and warm it row by row.
                engine.set_code_cache(self.cache.clone());
                let flops = engine.ledger.total();
                let logits = engine.logits().to_vec();
                let predicted = engine.predict();
                self.sessions.insert(session, engine);
                self.metrics.sessions_opened += 1;
                let n = tokens.len();
                self.metrics.flops_incremental += flops;
                self.metrics.flops_dense_equiv += self.dense_equiv(n);
                Ok(Response::Logits {
                    logits,
                    predicted,
                    flops,
                    dense_equiv_flops: self.dense_equiv(n),
                    defragged: false,
                })
            }
            Request::Edit { session, edit } => self.apply_edits(&session, &[edit]),
            Request::EditScript { session, edits } => self.apply_edits(&session, &edits),
            Request::Revision { session, tokens } => {
                // A revision is a whole replacement document, so it obeys
                // the same bounds as Open — diffing toward an empty or
                // oversized document would walk the engine into the
                // delete-last/document-full panics.
                anyhow::ensure!(!tokens.is_empty(), "empty revision");
                anyhow::ensure!(
                    tokens.len() <= self.weights.cfg.max_seq,
                    "revision too long"
                );
                self.ensure_resident(&session)?;
                let s = self.sessions.get_mut(&session).expect("resident");
                let script = diff_tokens(s.engine.tokens(), &tokens);
                let defrags_before = s.engine.stats.defrags;
                let cache_before = cache_counters(&s.engine);
                let attn_before = attn_counters(&s.engine);
                let rep = s.engine.apply_revision(&script);
                s.edits += script.len() as u64;
                let n = s.engine.len();
                let predicted = s.engine.predict();
                let defrags_after = s.engine.stats.defrags;
                let cache_after = cache_counters(&s.engine);
                let attn_after = attn_counters(&s.engine);
                self.sessions.reaccount(&session);
                self.metrics.revisions += 1;
                self.metrics.edits += script.len() as u64;
                self.metrics.defrags += defrags_after - defrags_before;
                self.charge_cache_delta(cache_before, cache_after);
                self.charge_attn_delta(attn_before, attn_after);
                self.metrics.flops_incremental += rep.flops;
                let dense_equiv = self.dense_equiv(n);
                self.metrics.flops_dense_equiv += dense_equiv;
                Ok(Response::Logits {
                    logits: rep.logits,
                    predicted,
                    flops: rep.flops,
                    dense_equiv_flops: dense_equiv,
                    defragged: rep.defragged,
                })
            }
            Request::BatchRevisions { base, revisions } => self.batch_revisions(base, revisions),
            Request::Dense { tokens } => {
                anyhow::ensure!(!tokens.is_empty(), "empty document");
                anyhow::ensure!(
                    tokens.len() <= self.weights.cfg.max_seq,
                    "document too long"
                );
                self.metrics.dense_calls += 1;
                let n = tokens.len();
                let logits = match &self.runtime {
                    Some(rt) => {
                        // Deterministic spread positions (same protocol as
                        // the engine's initial assignment).
                        let pool = rt.manifest.config.pos_pool;
                        let pos: Vec<u32> = (0..n)
                            .map(|i| (((2 * i + 1) * pool) / (2 * n)) as u32)
                            .collect();
                        rt.dense_logits(&tokens, &pos)?
                    }
                    None => {
                        let pool = self.weights.cfg.pos_pool;
                        let pos: Vec<u32> = (0..n)
                            .map(|i| (((2 * i + 1) * pool) / (2 * n)) as u32)
                            .collect();
                        let mut led = FlopLedger::new();
                        dense_forward(&self.weights, &tokens, &pos, &mut led).logits
                    }
                };
                let predicted = crate::tensor::argmax(&logits);
                Ok(Response::Logits {
                    logits,
                    predicted,
                    flops: self.dense_equiv(n),
                    dense_equiv_flops: self.dense_equiv(n),
                    defragged: false,
                })
            }
            Request::Suggest { session, k } => {
                self.ensure_resident(&session)?;
                let s = self.sessions.get_mut(&session).expect("resident");
                Ok(Response::Suggestions(s.engine.suggest_topk(k.clamp(1, 64))))
            }
            Request::Checkpoint { session, path } => {
                let file = self.checkpoint_path(&path)?;
                self.ensure_resident(&session)?;
                std::fs::create_dir_all(&self.checkpoint_dir)?;
                let s = self.sessions.get_mut(&session).expect("resident");
                s.engine.snapshot_to_file(file)?;
                Ok(Response::Done)
            }
            Request::Restore { session, path } => {
                let file = self.checkpoint_path(&path)?;
                let mut opts = self.engine_opts;
                opts.verify_every = self.verify_every;
                let mut engine =
                    IncrementalEngine::restore_from_file(self.weights.clone(), &file, opts)?;
                // Snapshots exclude the cache by design; re-attach so the
                // restored session rewarms lazily.
                engine.set_code_cache(self.cache.clone());
                // Restoring over a live id replaces the old incarnation:
                // remove it first so a suspended predecessor's spill file
                // is reclaimed instead of leaking, and count the verb in
                // its own gauge — a restore is not a fresh open, and
                // double-counting the id would inflate `sessions_opened`.
                self.sessions.remove(&session);
                self.sessions.insert(session, engine);
                self.metrics.sessions_restored += 1;
                Ok(Response::Done)
            }
            Request::Suspend { session } => {
                let known = self.sessions.suspend(&session)?;
                anyhow::ensure!(known, "unknown session '{session}'");
                Ok(Response::Done)
            }
            Request::Resume { session } => {
                self.ensure_resident(&session)?;
                Ok(Response::Done)
            }
            Request::SessionInfo { session } => {
                let info = self
                    .sessions
                    .info(&session)
                    .ok_or_else(|| anyhow::anyhow!("unknown session '{session}'"))?;
                Ok(Response::SessionInfo {
                    state: info.state,
                    resident_bytes: info.resident_bytes as u64,
                    spill_bytes: info.spill_bytes,
                    edits: info.edits,
                    doc_len: info.doc_len,
                })
            }
            Request::Close { session } => {
                let existed = self.sessions.remove(&session);
                Ok(Response::Closed { existed })
            }
            Request::TraceDump => Ok(Response::Traces(self.ring.to_json())),
            Request::Stats | Request::Metrics => {
                // Both verbs read the same per-shard snapshot; the client
                // merges and renders (JSON for `stats`, Prometheus text
                // for `metrics`).
                // Lifecycle counters live in the store (the single writer);
                // surface them through the shard's metrics snapshot so the
                // cross-shard merge sums them like every other counter.
                let mut m = self.metrics.clone();
                m.sessions_evicted = self.sessions.evictions;
                m.suspends = self.sessions.suspends;
                m.resumes = self.sessions.resumes;
                Ok(Response::ShardStats {
                    metrics: Box::new(m),
                    live_sessions: self.sessions.resident_len(),
                    spilled_sessions: self.sessions.spilled_len(),
                    resident_bytes: self.sessions.resident_bytes() as u64,
                })
            }
        }
    }

    fn apply_edits(&mut self, session: &str, edits: &[Edit]) -> Result<Response> {
        self.ensure_resident(session)?;
        let s = self.sessions.get_mut(session).expect("resident");
        validate_edits(edits, s.engine.len(), self.weights.cfg.max_seq)?;
        let defrags_before = s.engine.stats.defrags;
        let cache_before = cache_counters(&s.engine);
        let attn_before = attn_counters(&s.engine);
        let rep = s.engine.apply_edits(edits);
        s.edits += edits.len() as u64;
        let n = s.engine.len();
        let predicted = s.engine.predict();
        let defrags_after = s.engine.stats.defrags;
        let cache_after = cache_counters(&s.engine);
        let attn_after = attn_counters(&s.engine);
        self.sessions.reaccount(session);
        self.metrics.edits += edits.len() as u64;
        // Additive counter (not a gauge) so the cross-shard merge sums
        // correctly regardless of session placement.
        self.metrics.defrags += defrags_after - defrags_before;
        self.charge_cache_delta(cache_before, cache_after);
        self.charge_attn_delta(attn_before, attn_after);
        self.metrics.flops_incremental += rep.flops;
        // Dense equivalent: one from-scratch pass per edit (the online
        // comparison the paper makes for atomic edits).
        let dense_equiv = self.dense_equiv(n) * edits.len().max(1) as u64;
        self.metrics.flops_dense_equiv += dense_equiv;
        Ok(Response::Logits {
            logits: rep.logits,
            predicted,
            flops: rep.flops,
            dense_equiv_flops: dense_equiv,
            defragged: rep.defragged,
        })
    }

    /// Offline batch: process the base once, fork per revision, apply each
    /// diff incrementally; measure the §3.1 compressed storage of the VQ
    /// code state across the batch.
    fn batch_revisions(&mut self, base: Vec<u32>, revisions: Vec<Vec<u32>>) -> Result<Response> {
        anyhow::ensure!(!base.is_empty(), "empty base document");
        anyhow::ensure!(
            base.len() <= self.weights.cfg.max_seq,
            "base document too long"
        );
        for (i, rev) in revisions.iter().enumerate() {
            anyhow::ensure!(!rev.is_empty(), "empty revision (index {i})");
            anyhow::ensure!(
                rev.len() <= self.weights.cfg.max_seq,
                "revision {i} too long"
            );
        }
        let mut opts = self.engine_opts;
        opts.verify_every = 0;
        let mut base_engine = IncrementalEngine::try_new(self.weights.clone(), &base, opts)?;
        // Same attach-after-build rule as Open; the forks inherit the
        // handle, so revision diffs hit products warmed by live sessions.
        base_engine.set_code_cache(self.cache.clone());
        let mut flops = base_engine.ledger.total();
        let mut dense_equiv = self.dense_equiv(base.len());
        let mut each = Vec::with_capacity(revisions.len());
        let mut forks = Vec::with_capacity(revisions.len());
        for rev in &revisions {
            let mut fork = base_engine.fork();
            let script = diff_tokens(&base, rev);
            let rep = fork.apply_revision(&script);
            flops += rep.flops;
            dense_equiv += self.dense_equiv(rev.len());
            each.push(rep.logits);
            // `fork` zeroes the stat counters, so the fork's totals ARE
            // the delta this revision contributed.
            self.charge_cache_delta((0, 0, 0, 0), cache_counters(&fork));
            self.charge_attn_delta((0, 0, 0, 0), attn_counters(&fork));
            forks.push(fork);
        }
        self.metrics.revisions += revisions.len() as u64;
        self.metrics.flops_incremental += flops;
        self.metrics.flops_dense_equiv += dense_equiv;
        // §3.1 storage measurement over the final layer's code state:
        // members must share geometry, so measure on the shortest length.
        let min_len = forks
            .iter()
            .map(|f| f.len())
            .chain(std::iter::once(base_engine.len()))
            .min()
            .unwrap_or(0);
        let cfg = &self.weights.cfg;
        let mut storage = (0usize, 0usize);
        if min_len > 0 && cfg.vq_heads > 0 {
            let li = cfg.n_layers - 1;
            let mut lut = std::collections::HashMap::new();
            let mut codebook: Vec<Vec<f32>> = Vec::new();
            let vq = self.weights.layer_vq(li)?;
            let mut p: Vec<Vec<u32>> = Vec::new();
            for eng in std::iter::once(&base_engine).chain(forks.iter()) {
                let row: Vec<u32> = eng.layer_codes(li)[..min_len]
                    .iter()
                    .map(|&c| {
                        *lut.entry(c.pack()).or_insert_with(|| {
                            codebook.push(vq.decode(c));
                            (codebook.len() - 1) as u32
                        })
                    })
                    .collect();
                p.push(row);
            }
            let cb = CompressedBatch::from_index_matrix(min_len, p.len(), cfg.d_model, codebook, &p);
            storage = (cb.storage_floats(), cb.dense_floats());
        }
        Ok(Response::BatchLogits {
            each,
            flops,
            dense_equiv_flops: dense_equiv,
            storage,
        })
    }
}

#[cfg(test)]
mod batched_round_tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::testutil::gen_edit;
    use crate::util::Rng;

    fn mk_worker(w: &Arc<ModelWeights>) -> Worker {
        let policy = StorePolicy {
            max_resident: 64,
            max_total: 64,
            memory_budget_bytes: 0,
            spill_dir: None,
        };
        Worker {
            weights: w.clone(),
            engine_opts: EngineOptions::default(),
            runtime: None,
            sessions: SessionStore::new(w.clone(), EngineOptions::default(), policy, None),
            cache: None,
            metrics: Metrics::default(),
            verify_every: 0,
            checkpoint_dir: String::new(),
            trace_all: false,
            slow_request_us: 0,
            ring: TraceRing::new(0),
        }
    }

    fn job(req: Request) -> (Job, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Job {
                req,
                reply: ReplyTo::Sync(tx),
                enqueued: Instant::now(),
                trace: false,
            },
            rx,
        )
    }

    fn entry(session: &str, jobs: Vec<Job>) -> BatchEntry {
        BatchEntry {
            session: session.to_string(),
            jobs: jobs.into_iter().collect(),
        }
    }

    /// The coordinator-level lock: one pooled round produces the same
    /// replies — logits bits, flops, dense-equivalents, predictions — and
    /// the same counters as the classic per-session worker.
    #[test]
    fn batched_round_bit_exact_vs_classic_worker() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 41));
        let mut batched = mk_worker(&w);
        let mut classic = mk_worker(&w);
        let mut r = Rng::new(9);
        let docs: Vec<Vec<u32>> = (0..3)
            .map(|i| {
                (0..(8 + i))
                    .map(|_| r.below(cfg.vocab_size) as u32)
                    .collect()
            })
            .collect();
        for (i, d) in docs.iter().enumerate() {
            for wk in [&mut batched, &mut classic] {
                let resp = wk.handle(Request::Open {
                    session: format!("s{i}"),
                    tokens: d.clone(),
                });
                assert!(matches!(resp, Response::Logits { .. }), "{resp:?}");
            }
        }
        let mut entries = Vec::new();
        let mut rxs = Vec::new();
        let mut classic_resps = Vec::new();
        let mut lens: Vec<usize> = docs.iter().map(Vec::len).collect();
        for i in 0..3 {
            let mut edits = Vec::new();
            for _ in 0..3 {
                let e = gen_edit(&mut r, lens[i], cfg.vocab_size, cfg.max_seq);
                lens[i] = (lens[i] as isize + e.len_delta()) as usize;
                edits.push(e);
            }
            let req = Request::EditScript {
                session: format!("s{i}"),
                edits,
            };
            classic_resps.push(classic.handle(req.clone()));
            let (j, rx) = job(req);
            entries.push(entry(&format!("s{i}"), vec![j]));
            rxs.push(rx);
        }
        batched.run_batched(0, entries, 4);
        assert!(batched.metrics.batched_rows > 0, "pooled path must run");
        assert!(batched.metrics.batch_fill.count() > 0);
        for (i, (rx, want)) in rxs.iter().zip(&classic_resps).enumerate() {
            let got = rx.try_recv().expect("reply sent");
            match (got, want) {
                (
                    Response::Logits {
                        logits: a,
                        predicted: pa,
                        flops: fa,
                        dense_equiv_flops: da,
                        defragged: ga,
                    },
                    Response::Logits {
                        logits: b,
                        predicted: pb,
                        flops: fb,
                        dense_equiv_flops: db,
                        defragged: gb,
                    },
                ) => {
                    let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
                    let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(ab, bb, "session {i} logits bits");
                    assert_eq!(pa, *pb, "session {i} prediction");
                    assert_eq!(fa, *fb, "session {i} flops");
                    assert_eq!(da, *db, "session {i} dense equiv");
                    assert_eq!(ga, *gb, "session {i} defragged");
                }
                other => panic!("session {i}: {other:?}"),
            }
        }
        assert_eq!(batched.metrics.edits, classic.metrics.edits);
        assert_eq!(
            batched.metrics.flops_incremental,
            classic.metrics.flops_incremental
        );
        assert_eq!(
            batched.metrics.flops_dense_equiv,
            classic.metrics.flops_dense_equiv
        );
        assert_eq!(batched.metrics.errors, 0);
    }

    /// An out-of-bounds edit in a wave is rejected with a typed error
    /// BEFORE the pooled kernel runs: the bad job's session survives, the
    /// rest of the wave still pools, and no panic is recorded.
    #[test]
    fn batched_round_rejects_invalid_edit_without_panicking() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 43));
        let mut wk = mk_worker(&w);
        let doc: Vec<u32> = (0..10).map(|i| (i % 50) as u32).collect();
        for s in ["a", "b", "c"] {
            wk.handle(Request::Open {
                session: s.into(),
                tokens: doc.clone(),
            });
        }
        let (ja, rxa) = job(Request::Edit {
            session: "a".into(),
            edit: Edit::Replace { at: 2, tok: 3 },
        });
        let (jb, rxb) = job(Request::Edit {
            session: "b".into(),
            edit: Edit::Replace { at: 9999, tok: 3 }, // out of bounds ⇒ typed reject
        });
        let (jc, rxc) = job(Request::Edit {
            session: "c".into(),
            edit: Edit::Replace { at: 5, tok: 4 },
        });
        wk.run_batched(
            0,
            vec![
                entry("a", vec![ja]),
                entry("b", vec![jb]),
                entry("c", vec![jc]),
            ],
            8,
        );
        assert!(matches!(rxa.try_recv(), Ok(Response::Logits { .. })));
        match rxb.try_recv() {
            Ok(Response::Err(e)) => assert!(e.contains("out of bounds"), "{e}"),
            other => panic!("{other:?}"),
        }
        assert!(matches!(rxc.try_recv(), Ok(Response::Logits { .. })));
        assert_eq!(wk.metrics.panics, 0, "validation fires before the kernel");
        assert_eq!(wk.metrics.errors, 1);
        assert!(wk.metrics.batched_rows > 0, "survivors still pool");
        // Every session — including the one whose edit was rejected —
        // stays alive and serviceable.
        for s in ["a", "b", "c"] {
            let resp = wk.handle(Request::Edit {
                session: s.into(),
                edit: Edit::Replace { at: 0, tok: 1 },
            });
            assert!(matches!(resp, Response::Logits { .. }), "{s}: {resp:?}");
        }
    }

    /// Classic-path sweep of the malformed-script space: out-of-bounds
    /// replace/insert/delete, delete-to-empty, and document-full all come
    /// back as typed errors with the session intact and `panics == 0`.
    #[test]
    fn classic_path_rejects_malformed_scripts_without_panicking() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 59));
        let mut wk = mk_worker(&w);
        wk.handle(Request::Open {
            session: "s".into(),
            tokens: vec![1, 2, 3],
        });
        let bad: Vec<(Vec<Edit>, &str)> = vec![
            (vec![Edit::Replace { at: 3, tok: 0 }], "out of bounds"),
            (vec![Edit::Insert { at: 4, tok: 0 }], "out of bounds"),
            (vec![Edit::Delete { at: 7 }], "out of bounds"),
            (
                // Delete-to-empty: the THIRD delete (simulated len 1) trips.
                vec![
                    Edit::Delete { at: 0 },
                    Edit::Delete { at: 0 },
                    Edit::Delete { at: 0 },
                ],
                "cannot delete the last token",
            ),
            (
                (0..cfg.max_seq).map(|_| Edit::Insert { at: 0, tok: 1 }).collect(),
                "document full",
            ),
        ];
        for (edits, want) in bad {
            match wk.handle(Request::EditScript {
                session: "s".into(),
                edits,
            }) {
                Response::Err(e) => assert!(e.contains(want), "{want}: {e}"),
                other => panic!("{want}: {other:?}"),
            }
        }
        assert_eq!(wk.metrics.panics, 0);
        // The session never lost state: a valid edit still lands.
        let resp = wk.handle(Request::Edit {
            session: "s".into(),
            edit: Edit::Replace { at: 0, tok: 9 },
        });
        assert!(matches!(resp, Response::Logits { .. }), "{resp:?}");
    }

    /// A wave with fewer than two poolable sessions falls back to the
    /// classic path (same replies, no pooled GEMMs recorded).
    #[test]
    fn single_session_wave_falls_back_to_classic() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 47));
        let mut wk = mk_worker(&w);
        let doc: Vec<u32> = (0..12).map(|i| (i % 50) as u32).collect();
        wk.handle(Request::Open {
            session: "solo".into(),
            tokens: doc,
        });
        let (j, rx) = job(Request::Edit {
            session: "solo".into(),
            edit: Edit::Replace { at: 3, tok: 7 },
        });
        // Second entry is an unknown session: it errs via the classic
        // path, leaving only one poolable session.
        let (jg, rxg) = job(Request::Edit {
            session: "ghost".into(),
            edit: Edit::Replace { at: 0, tok: 1 },
        });
        wk.run_batched(0, vec![entry("solo", vec![j]), entry("ghost", vec![jg])], 8);
        assert!(matches!(rx.try_recv(), Ok(Response::Logits { .. })));
        match rxg.try_recv() {
            Ok(Response::Err(e)) => assert!(e.contains("unknown session"), "{e}"),
            other => panic!("{other:?}"),
        }
        assert_eq!(wk.metrics.batched_rows, 0, "no pooled GEMMs for a solo wave");
        assert_eq!(wk.metrics.edits, 1);
    }

    /// With a cache attached, sessions editing the same document share
    /// products: the first session's edit misses (and warms the cache),
    /// later sessions hit, and the worker attributes both to its metrics.
    /// Opens contribute nothing — the attach happens after the build.
    #[test]
    fn cached_worker_attributes_cross_session_hits() {
        use crate::incremental::{CacheHandle, CodeCache};
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 53));
        let mut wk = mk_worker(&w);
        wk.cache = Some(CacheHandle::new(Arc::new(CodeCache::new(1 << 22)), &w));
        let doc: Vec<u32> = (0..10).map(|i| (i % 50) as u32).collect();
        for i in 0..3 {
            wk.handle(Request::Open {
                session: format!("s{i}"),
                tokens: doc.clone(),
            });
        }
        assert_eq!(
            wk.metrics.cache_hits + wk.metrics.cache_misses,
            0,
            "initial builds stay uncached"
        );
        for i in 0..3 {
            let resp = wk.handle(Request::Edit {
                session: format!("s{i}"),
                edit: Edit::Replace { at: 4, tok: 9 },
            });
            assert!(matches!(resp, Response::Logits { .. }), "{resp:?}");
        }
        assert!(wk.metrics.cache_misses > 0, "first session warms the cache");
        assert!(wk.metrics.cache_hits > 0, "identical edits hit cross-session");
        assert!(wk.metrics.cache_bytes > 0, "insert bytes attributed");
    }

    /// A trace-enabled worker measures queue wait at dequeue, stamps the
    /// span breakdown, retires sync-reply traces into its own ring, and
    /// wraps the reply only when the client asked for it.
    #[test]
    fn traced_worker_records_spans_and_ring() {
        let cfg = ModelConfig::vqt_tiny();
        let w = Arc::new(ModelWeights::random(&cfg, 61));
        let mut wk = mk_worker(&w);
        wk.trace_all = true;
        wk.slow_request_us = 1;
        wk.ring = TraceRing::new(8);
        wk.handle(Request::Open {
            session: "s".into(),
            tokens: vec![1, 2, 3],
        });
        // trace_all without the per-request flag: the reply stays plain,
        // the record retires into the shard ring.
        let (j, rx) = job(Request::Edit {
            session: "s".into(),
            edit: Edit::Replace { at: 0, tok: 5 },
        });
        // Make the queue wait unambiguous (and trip the 1µs slow bar).
        std::thread::sleep(std::time::Duration::from_millis(2));
        wk.execute_job(2, j);
        assert!(matches!(rx.try_recv(), Ok(Response::Logits { .. })));
        assert_eq!(wk.ring.len(), 1, "sync trace retires into the ring");
        assert_eq!(wk.metrics.traces_recorded, 1);
        assert_eq!(wk.metrics.slow_requests, 1, "2ms wait trips a 1µs bar");
        assert!(wk.metrics.queue_wait_us.count() >= 1);
        assert!(
            wk.metrics.queue_wait_us.max() >= 2_000.0,
            "queue wait measured at dequeue: {}",
            wk.metrics.queue_wait_us.max()
        );
        // Per-request flag: the reply arrives wrapped with the breakdown.
        let (mut j2, rx2) = job(Request::Edit {
            session: "s".into(),
            edit: Edit::Replace { at: 1, tok: 6 },
        });
        j2.trace = true;
        wk.execute_job(2, j2);
        match rx2.try_recv() {
            Ok(Response::Traced { inner, trace }) => {
                assert!(matches!(*inner, Response::Logits { .. }), "{inner:?}");
                assert_eq!(trace.get("kind").as_str(), Some("edit"));
                assert_eq!(trace.get("shard").as_usize(), Some(2));
                let names: Vec<&str> = trace
                    .get("stages")
                    .as_arr()
                    .expect("stages array")
                    .iter()
                    .map(|s| s.get("name").as_str().unwrap())
                    .collect();
                assert!(names.contains(&"queue_wait"), "{names:?}");
                assert!(names.contains(&"engine"), "{names:?}");
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(wk.ring.len(), 2);
        // The trace verb serves this shard's ring.
        match wk.handle(Request::TraceDump) {
            Response::Traces(j) => assert_eq!(j.as_arr().unwrap().len(), 2),
            other => panic!("{other:?}"),
        }
        // Tracing off: no ring growth, no wrapper, queue wait still lands.
        let mut quiet = mk_worker(&w);
        quiet.handle(Request::Open {
            session: "q".into(),
            tokens: vec![4, 5],
        });
        let (j3, rx3) = job(Request::Edit {
            session: "q".into(),
            edit: Edit::Replace { at: 0, tok: 1 },
        });
        quiet.execute_job(0, j3);
        assert!(matches!(rx3.try_recv(), Ok(Response::Logits { .. })));
        assert!(quiet.ring.is_empty());
        assert_eq!(quiet.metrics.traces_recorded, 0);
        assert_eq!(quiet.metrics.queue_wait_us.count(), 1);
    }

    /// split_rounds takes only each session's LEADING run of edit jobs and
    /// preserves everything else (order included) for the classic path.
    #[test]
    fn split_rounds_takes_leading_edit_runs_only() {
        let mk = |req: Request| job(req).0;
        let e = |s: &str| {
            mk(Request::Edit {
                session: s.into(),
                edit: Edit::Replace { at: 0, tok: 1 },
            })
        };
        // Plan order: s1 group [edit, edit, open, edit], s2 group [edit],
        // then a session-less dense job.
        let jobs = vec![
            e("s1"),
            e("s1"),
            mk(Request::Open {
                session: "s1".into(),
                tokens: vec![1],
            }),
            e("s1"),
            e("s2"),
            mk(Request::Dense { tokens: vec![1] }),
        ];
        let (entries, rest) = split_rounds(jobs, true);
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].session, "s1");
        assert_eq!(entries[0].jobs.len(), 2, "leading run only");
        assert_eq!(entries[1].session, "s2");
        assert_eq!(entries[1].jobs.len(), 1);
        // Rest: open(s1), edit(s1) after the break, dense — in order.
        assert_eq!(rest.len(), 3);
        assert!(matches!(rest[0].req, Request::Open { .. }));
        assert!(matches!(rest[1].req, Request::Edit { .. }));
        assert!(matches!(rest[2].req, Request::Dense { .. }));
        // Disabled or single-headed batches stay classic, order intact.
        let jobs = vec![e("s1"), e("s1")];
        let (entries, rest) = split_rounds(jobs, true);
        assert!(entries.is_empty(), "one session ⇒ no pooling");
        assert_eq!(rest.len(), 2);
        let jobs = vec![e("s1"), e("s2")];
        let (entries, _) = split_rounds(jobs, false);
        assert!(entries.is_empty(), "max_batch_rows = 0 disables pooling");
    }
}
