//! Serving metrics: counters, log-bucket latency histograms, FLOP savings.

use crate::util::Json;

/// Log-bucketed histogram (µs-scale friendly: buckets are powers of 2).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// bucket i counts values in [2^i, 2^(i+1)).
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: vec![0; 48],
            count: 0,
            sum: 0.0,
            max: 0.0,
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        let b = if v < 1.0 {
            0
        } else {
            (v.log2() as usize).min(self.buckets.len() - 1)
        };
        self.buckets[b] += 1;
        self.count += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    /// Fold another histogram into this one (shard-snapshot merge: bucket
    /// counts and sums add, the max is the max of maxes — percentiles of
    /// the merge are percentiles of the union).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, &b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        if other.max > self.max {
            self.max = other.max;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Raw bucket counts (bucket i holds values in [2^i, 2^(i+1))) — the
    /// Prometheus exposition renders the full distribution from these,
    /// not just the point percentiles `to_json` reports.
    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Approximate percentile from the buckets (upper bound of bucket).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return (1u64 << (i + 1)) as f64;
            }
        }
        self.max
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean", Json::num(self.mean())),
            ("p50", Json::num(self.percentile(50.0))),
            ("p99", Json::num(self.percentile(99.0))),
            ("p999", Json::num(self.percentile(99.9))),
            ("max", Json::num(self.max)),
        ])
    }
}

/// Aggregated serving metrics. Each coordinator shard owns its own
/// `Metrics` (no locks on the hot path); a `Stats` request snapshots every
/// shard and merges them with [`Metrics::merge`].
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Per-request wall latency in microseconds, by op kind. Measured
    /// from dequeue — queue time is `queue_wait_us`, not folded in here.
    pub lat_edit_us: Histogram,
    pub lat_revision_us: Histogram,
    pub lat_dense_us: Histogram,
    /// Shard-queue wait (enqueue→dequeue) in microseconds, recorded for
    /// every job on both the classic and batched paths. Split out of the
    /// `lat_*` histograms so queueing delay is visible instead of hiding
    /// inside request latency.
    pub queue_wait_us: Histogram,
    /// Completed request traces retained (ring pushes + completions
    /// shipped to the async front end for the reply-write stage).
    pub traces_recorded: u64,
    /// Requests whose end-to-end trace exceeded `slow_request_us` (each
    /// logs its full span breakdown at WARN).
    pub slow_requests: u64,
    /// FLOPs actually spent by incremental processing.
    pub flops_incremental: u64,
    /// FLOPs a dense recompute would have spent for the same requests.
    pub flops_dense_equiv: u64,
    pub edits: u64,
    pub revisions: u64,
    pub dense_calls: u64,
    /// Total defragmentations (position-pool rebuilds) served — additive
    /// across edits, sessions, and shards.
    pub defrags: u64,
    pub sessions_opened: u64,
    /// Sessions (re)created from a client-supplied checkpoint via the
    /// `Restore` verb. Counted separately from `sessions_opened` so a
    /// restore over an already-known id doesn't double-count the session.
    pub sessions_restored: u64,
    /// Sessions dropped outright (no spill dir, total-cap eviction, or a
    /// failed spill write).
    pub sessions_evicted: u64,
    /// Sessions suspended: snapshotted to the spill dir and released from
    /// RAM (LRU pressure, byte budget, or the `Suspend` verb).
    pub suspends: u64,
    /// Suspended sessions restored from disk (explicitly or transparently
    /// on their next request).
    pub resumes: u64,
    pub rejected_backpressure: u64,
    pub errors: u64,
    /// Requests that panicked inside a shard (caught; the session was
    /// dropped and an error surfaced to the caller).
    pub panics: u64,
    /// Rows executed through pooled cross-session block-tail GEMMs (the
    /// batched execution path; 0 means every edit ran per-session).
    pub batched_rows: u64,
    /// Block tails served from the shared codebook-product cache (the
    /// decode→mix GEMV was skipped). Additive across sessions and shards;
    /// 0 when `code_cache_mb` is 0.
    pub cache_hits: u64,
    /// Block tails that consulted the cache and had to compute (the miss
    /// inserts the product for future hits).
    pub cache_misses: u64,
    /// Cache entries evicted under the `code_cache_mb` byte budget.
    pub cache_evictions: u64,
    /// Bytes inserted into the cache (cumulative, not resident — the
    /// resident gauge lives in the cache itself and is bounded by config).
    pub cache_bytes: u64,
    /// Batch occupancy: rows per pooled GEMM issued. A mean near 1 means
    /// the window rarely catches concurrent sessions; a high p50 means the
    /// weight traversal is being amortized well.
    pub batch_fill: Histogram,
    /// Softmax engines: consumer rows updated via streaming-softmax
    /// aggregate deltas (semi-naive recompute). 0 for element-wise models,
    /// whose per-column corrections are exact and tracked by the engine's
    /// own `corrections` counter instead.
    pub attn_delta_rows: u64,
    /// Softmax engines: consumer rows that fell back to a full attention
    /// recompute (cost rule, numeric guard, or drift refresh).
    pub attn_full_rows: u64,
    /// Drift-counter-triggered full refreshes (subset of `attn_full_rows`).
    pub attn_refreshes: u64,
    /// FLOPs the delta rows saved vs pricing them as full recomputes.
    pub attn_saved_flops: u64,
}

impl Metrics {
    /// Fold another shard's metrics into this one — the pool-wide snapshot
    /// a `Stats` request reports.
    pub fn merge(&mut self, o: &Metrics) {
        self.lat_edit_us.merge(&o.lat_edit_us);
        self.lat_revision_us.merge(&o.lat_revision_us);
        self.lat_dense_us.merge(&o.lat_dense_us);
        self.queue_wait_us.merge(&o.queue_wait_us);
        self.traces_recorded += o.traces_recorded;
        self.slow_requests += o.slow_requests;
        self.flops_incremental += o.flops_incremental;
        self.flops_dense_equiv += o.flops_dense_equiv;
        self.edits += o.edits;
        self.revisions += o.revisions;
        self.dense_calls += o.dense_calls;
        self.defrags += o.defrags;
        self.sessions_opened += o.sessions_opened;
        self.sessions_restored += o.sessions_restored;
        self.sessions_evicted += o.sessions_evicted;
        self.suspends += o.suspends;
        self.resumes += o.resumes;
        self.rejected_backpressure += o.rejected_backpressure;
        self.errors += o.errors;
        self.panics += o.panics;
        self.batched_rows += o.batched_rows;
        self.batch_fill.merge(&o.batch_fill);
        self.cache_hits += o.cache_hits;
        self.cache_misses += o.cache_misses;
        self.cache_evictions += o.cache_evictions;
        self.cache_bytes += o.cache_bytes;
        self.attn_delta_rows += o.attn_delta_rows;
        self.attn_full_rows += o.attn_full_rows;
        self.attn_refreshes += o.attn_refreshes;
        self.attn_saved_flops += o.attn_saved_flops;
    }
    /// The aggregate speedup the engine achieved (paper's headline ratio).
    pub fn speedup(&self) -> f64 {
        if self.flops_incremental == 0 {
            0.0
        } else {
            self.flops_dense_equiv as f64 / self.flops_incremental as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lat_edit_us", self.lat_edit_us.to_json()),
            ("lat_revision_us", self.lat_revision_us.to_json()),
            ("lat_dense_us", self.lat_dense_us.to_json()),
            ("queue_wait_us", self.queue_wait_us.to_json()),
            ("traces_recorded", Json::num(self.traces_recorded as f64)),
            ("slow_requests", Json::num(self.slow_requests as f64)),
            ("flops_incremental", Json::num(self.flops_incremental as f64)),
            ("flops_dense_equiv", Json::num(self.flops_dense_equiv as f64)),
            ("speedup", Json::num(self.speedup())),
            ("edits", Json::num(self.edits as f64)),
            ("revisions", Json::num(self.revisions as f64)),
            ("dense_calls", Json::num(self.dense_calls as f64)),
            ("defrags", Json::num(self.defrags as f64)),
            ("sessions_opened", Json::num(self.sessions_opened as f64)),
            ("sessions_restored", Json::num(self.sessions_restored as f64)),
            ("sessions_evicted", Json::num(self.sessions_evicted as f64)),
            ("suspends", Json::num(self.suspends as f64)),
            ("resumes", Json::num(self.resumes as f64)),
            (
                "rejected_backpressure",
                Json::num(self.rejected_backpressure as f64),
            ),
            ("errors", Json::num(self.errors as f64)),
            ("panics", Json::num(self.panics as f64)),
            ("batched_rows", Json::num(self.batched_rows as f64)),
            ("batch_fill", self.batch_fill.to_json()),
            ("cache_hits", Json::num(self.cache_hits as f64)),
            ("cache_misses", Json::num(self.cache_misses as f64)),
            ("cache_evictions", Json::num(self.cache_evictions as f64)),
            ("cache_bytes", Json::num(self.cache_bytes as f64)),
            ("attn_delta_rows", Json::num(self.attn_delta_rows as f64)),
            ("attn_full_rows", Json::num(self.attn_full_rows as f64)),
            ("attn_refreshes", Json::num(self.attn_refreshes as f64)),
            ("attn_saved_flops", Json::num(self.attn_saved_flops as f64)),
        ])
    }

    /// Render every counter and histogram in Prometheus text exposition
    /// format (`# HELP`/`# TYPE`, cumulative `_bucket{le="…"}` lines with
    /// the histograms' explicit power-of-2 bounds — the full distribution,
    /// not the point percentiles `to_json` reports). `gauges` carries
    /// point-in-time values owned by the caller (live sessions, resident
    /// bytes, shard count, front-end connection gauges, …), emitted as
    /// `vqt_<name>` gauge lines in the given order.
    pub fn to_prometheus(&self, gauges: &[(&str, f64)]) -> String {
        let mut out = String::with_capacity(6 * 1024);
        let hists: [(&str, &str, &Histogram); 5] = [
            (
                "vqt_lat_edit_us",
                "Edit latency from shard dequeue, microseconds",
                &self.lat_edit_us,
            ),
            (
                "vqt_lat_revision_us",
                "Revision latency from shard dequeue, microseconds",
                &self.lat_revision_us,
            ),
            (
                "vqt_lat_dense_us",
                "Dense-call latency from shard dequeue, microseconds",
                &self.lat_dense_us,
            ),
            (
                "vqt_queue_wait_us",
                "Shard-queue wait enqueue to dequeue, microseconds",
                &self.queue_wait_us,
            ),
            (
                "vqt_batch_fill_rows",
                "Rows per pooled cross-session GEMM wave",
                &self.batch_fill,
            ),
        ];
        for (name, help, h) in hists {
            prometheus_histogram(&mut out, name, help, h);
        }
        let counters: [(&str, &str, u64); 25] = [
            ("vqt_edits_total", "Edit requests served", self.edits),
            ("vqt_revisions_total", "Revision requests served", self.revisions),
            ("vqt_dense_calls_total", "Dense forward calls served", self.dense_calls),
            ("vqt_defrags_total", "Position-pool defragmentations", self.defrags),
            ("vqt_sessions_opened_total", "Sessions opened", self.sessions_opened),
            (
                "vqt_sessions_restored_total",
                "Sessions restored from client checkpoints",
                self.sessions_restored,
            ),
            ("vqt_sessions_evicted_total", "Sessions dropped outright", self.sessions_evicted),
            ("vqt_suspends_total", "Sessions suspended to the spill dir", self.suspends),
            ("vqt_resumes_total", "Suspended sessions resumed", self.resumes),
            (
                "vqt_rejected_backpressure_total",
                "Requests rejected by shard-queue backpressure",
                self.rejected_backpressure,
            ),
            ("vqt_errors_total", "Requests answered with a typed error", self.errors),
            ("vqt_panics_total", "Requests that panicked inside a shard", self.panics),
            (
                "vqt_batched_rows_total",
                "Rows executed through pooled GEMM waves",
                self.batched_rows,
            ),
            ("vqt_cache_hits_total", "Codebook-product cache hits", self.cache_hits),
            ("vqt_cache_misses_total", "Codebook-product cache misses", self.cache_misses),
            (
                "vqt_cache_evictions_total",
                "Codebook-product cache evictions",
                self.cache_evictions,
            ),
            (
                "vqt_cache_bytes_total",
                "Bytes inserted into the codebook-product cache",
                self.cache_bytes,
            ),
            (
                "vqt_flops_incremental_total",
                "FLOPs spent by incremental processing",
                self.flops_incremental,
            ),
            (
                "vqt_flops_dense_equiv_total",
                "FLOPs a dense recompute would have spent",
                self.flops_dense_equiv,
            ),
            ("vqt_traces_recorded_total", "Completed request traces retained", self.traces_recorded),
            (
                "vqt_slow_requests_total",
                "Requests exceeding slow_request_us",
                self.slow_requests,
            ),
            (
                "vqt_attn_delta_rows_total",
                "Consumer rows updated via streaming-softmax aggregate deltas",
                self.attn_delta_rows,
            ),
            (
                "vqt_attn_full_rows_total",
                "Consumer rows that fell back to full attention recompute",
                self.attn_full_rows,
            ),
            (
                "vqt_attn_refreshes_total",
                "Drift-counter-triggered full attention refreshes",
                self.attn_refreshes,
            ),
            (
                "vqt_attn_saved_flops_total",
                "FLOPs saved by attention delta updates vs full recompute",
                self.attn_saved_flops,
            ),
        ];
        for (name, help, v) in counters {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"
            ));
        }
        out.push_str(&format!(
            "# HELP vqt_speedup_ratio Dense-equivalent over incremental FLOPs\n\
             # TYPE vqt_speedup_ratio gauge\nvqt_speedup_ratio {}\n",
            self.speedup()
        ));
        for (name, v) in gauges {
            out.push_str(&format!(
                "# TYPE vqt_{name} gauge\nvqt_{name} {v}\n"
            ));
        }
        out
    }
}

/// One histogram in exposition format: cumulative buckets up to the last
/// non-empty bound, then the mandatory `+Inf`/`_sum`/`_count` triple.
fn prometheus_histogram(out: &mut String, name: &str, help: &str, h: &Histogram) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
    let buckets = h.buckets();
    let live = buckets.iter().rposition(|&c| c != 0).map_or(0, |i| i + 1);
    let mut cum = 0u64;
    for (i, &c) in buckets.iter().take(live).enumerate() {
        cum += c;
        out.push_str(&format!(
            "{name}_bucket{{le=\"{}\"}} {cum}\n",
            1u64 << (i + 1)
        ));
    }
    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
    out.push_str(&format!("{name}_sum {}\n", h.sum()));
    out.push_str(&format!("{name}_count {}\n", h.count()));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_basics() {
        let mut h = Histogram::new();
        for v in [1.0, 2.0, 4.0, 8.0, 1000.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() > 200.0);
        assert_eq!(h.max(), 1000.0);
        assert!(h.percentile(50.0) >= 4.0);
        assert!(h.percentile(99.0) >= 1000.0);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.percentile(99.0), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn histogram_merge_is_union() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for v in [1.0, 8.0] {
            a.record(v);
        }
        for v in [2.0, 4.0, 1000.0] {
            b.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), 5);
        assert_eq!(a.max(), 1000.0);
        assert!((a.mean() - 203.0).abs() < 1e-9);
        assert!(a.percentile(99.0) >= 1000.0);
    }

    #[test]
    fn metrics_merge_adds_counters() {
        let mut a = Metrics {
            edits: 3,
            flops_incremental: 10,
            flops_dense_equiv: 100,
            cache_hits: 2,
            cache_bytes: 64,
            ..Default::default()
        };
        a.lat_edit_us.record(4.0);
        let mut b = Metrics {
            edits: 5,
            flops_incremental: 10,
            flops_dense_equiv: 300,
            panics: 1,
            suspends: 2,
            resumes: 1,
            cache_hits: 3,
            cache_misses: 4,
            cache_evictions: 1,
            cache_bytes: 128,
            attn_delta_rows: 7,
            attn_full_rows: 2,
            attn_refreshes: 1,
            attn_saved_flops: 900,
            ..Default::default()
        };
        b.lat_edit_us.record(16.0);
        a.merge(&b);
        assert_eq!(a.edits, 8);
        assert_eq!(a.panics, 1);
        assert_eq!((a.suspends, a.resumes), (2, 1));
        assert_eq!(
            (a.cache_hits, a.cache_misses, a.cache_evictions, a.cache_bytes),
            (5, 4, 1, 192)
        );
        assert_eq!(
            (a.attn_delta_rows, a.attn_full_rows, a.attn_refreshes, a.attn_saved_flops),
            (7, 2, 1, 900)
        );
        assert_eq!(a.speedup(), 20.0);
        assert_eq!(a.lat_edit_us.count(), 2);
    }

    #[test]
    fn merge_folds_batch_occupancy() {
        let mut a = Metrics {
            batched_rows: 10,
            ..Default::default()
        };
        a.batch_fill.record(2.0);
        let mut b = Metrics {
            batched_rows: 5,
            ..Default::default()
        };
        b.batch_fill.record(8.0);
        b.batch_fill.record(8.0);
        a.merge(&b);
        assert_eq!(a.batched_rows, 15);
        assert_eq!(a.batch_fill.count(), 3);
        assert_eq!(a.batch_fill.max(), 8.0);
        let j = a.to_json();
        assert_eq!(j.get("batched_rows").as_usize(), Some(15));
        assert!(j.get("batch_fill").get("p50").as_f64().is_some());
    }

    #[test]
    fn merge_folds_queue_wait_and_trace_counters() {
        let mut a = Metrics {
            traces_recorded: 2,
            slow_requests: 1,
            ..Default::default()
        };
        a.queue_wait_us.record(3.0);
        let mut b = Metrics {
            traces_recorded: 5,
            slow_requests: 0,
            ..Default::default()
        };
        b.queue_wait_us.record(100.0);
        b.queue_wait_us.record(7.0);
        a.merge(&b);
        assert_eq!(a.traces_recorded, 7);
        assert_eq!(a.slow_requests, 1);
        assert_eq!(a.queue_wait_us.count(), 3);
        assert_eq!(a.queue_wait_us.max(), 100.0);
        let j = a.to_json();
        assert_eq!(j.get("queue_wait_us").get("count").as_usize(), Some(3));
        assert_eq!(j.get("traces_recorded").as_usize(), Some(7));
        assert_eq!(j.get("slow_requests").as_usize(), Some(1));
    }

    #[test]
    fn prometheus_exposition_shape() {
        let mut m = Metrics {
            edits: 9,
            cache_hits: 4,
            ..Default::default()
        };
        m.lat_edit_us.record(5.0);
        m.lat_edit_us.record(300.0);
        m.queue_wait_us.record(12.0);
        let text = m.to_prometheus(&[("live_sessions", 3.0), ("shards", 2.0)]);
        // Histograms: TYPE line, explicit cumulative buckets, +Inf triple.
        assert!(text.contains("# TYPE vqt_lat_edit_us histogram"), "{text}");
        assert!(text.contains("vqt_lat_edit_us_bucket{le=\"8\"} 1"));
        assert!(text.contains("vqt_lat_edit_us_bucket{le=\"512\"} 2"));
        assert!(text.contains("vqt_lat_edit_us_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("vqt_lat_edit_us_sum 305"));
        assert!(text.contains("vqt_lat_edit_us_count 2"));
        assert!(text.contains("# TYPE vqt_queue_wait_us histogram"));
        // Counters and caller-supplied gauges.
        assert!(text.contains("# TYPE vqt_edits_total counter\nvqt_edits_total 9"));
        assert!(text.contains("vqt_cache_hits_total 4"));
        assert!(text.contains("vqt_traces_recorded_total 0"));
        assert!(text.contains("# TYPE vqt_attn_delta_rows_total counter"));
        assert!(text.contains("vqt_attn_saved_flops_total 0"));
        assert!(text.contains("# TYPE vqt_live_sessions gauge\nvqt_live_sessions 3"));
        assert!(text.contains("vqt_shards 2"));
        // Empty histograms still expose a valid +Inf/sum/count triple.
        assert!(text.contains("vqt_lat_dense_us_bucket{le=\"+Inf\"} 0"));
    }

    #[test]
    fn speedup_ratio() {
        let mut m = Metrics::default();
        m.flops_dense_equiv = 1000;
        m.flops_incremental = 100;
        assert_eq!(m.speedup(), 10.0);
    }

    #[test]
    fn metrics_json_shape() {
        let m = Metrics::default();
        let j = m.to_json();
        assert!(j.get("speedup").as_f64().is_some());
        assert!(j.get("lat_edit_us").get("p99").as_f64().is_some());
        assert!(j.get("lat_edit_us").get("p999").as_f64().is_some());
        assert_eq!(j.get("sessions_restored").as_usize(), Some(0));
        for k in [
            "cache_hits",
            "cache_misses",
            "cache_evictions",
            "cache_bytes",
            "attn_delta_rows",
            "attn_full_rows",
            "attn_refreshes",
            "attn_saved_flops",
        ] {
            assert_eq!(j.get(k).as_usize(), Some(0), "{k}");
        }
    }
}
