//! Serving metrics: counters, log-bucket latency histograms, FLOP savings.

use crate::util::Json;

/// Log-bucketed histogram (µs-scale friendly: buckets are powers of 2).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// bucket i counts values in [2^i, 2^(i+1)).
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: vec![0; 48],
            count: 0,
            sum: 0.0,
            max: 0.0,
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        let b = if v < 1.0 {
            0
        } else {
            (v.log2() as usize).min(self.buckets.len() - 1)
        };
        self.buckets[b] += 1;
        self.count += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    /// Fold another histogram into this one (shard-snapshot merge: bucket
    /// counts and sums add, the max is the max of maxes — percentiles of
    /// the merge are percentiles of the union).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, &b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        if other.max > self.max {
            self.max = other.max;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Approximate percentile from the buckets (upper bound of bucket).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return (1u64 << (i + 1)) as f64;
            }
        }
        self.max
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean", Json::num(self.mean())),
            ("p50", Json::num(self.percentile(50.0))),
            ("p99", Json::num(self.percentile(99.0))),
            ("p999", Json::num(self.percentile(99.9))),
            ("max", Json::num(self.max)),
        ])
    }
}

/// Aggregated serving metrics. Each coordinator shard owns its own
/// `Metrics` (no locks on the hot path); a `Stats` request snapshots every
/// shard and merges them with [`Metrics::merge`].
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Per-request wall latency in microseconds, by op kind.
    pub lat_edit_us: Histogram,
    pub lat_revision_us: Histogram,
    pub lat_dense_us: Histogram,
    /// FLOPs actually spent by incremental processing.
    pub flops_incremental: u64,
    /// FLOPs a dense recompute would have spent for the same requests.
    pub flops_dense_equiv: u64,
    pub edits: u64,
    pub revisions: u64,
    pub dense_calls: u64,
    /// Total defragmentations (position-pool rebuilds) served — additive
    /// across edits, sessions, and shards.
    pub defrags: u64,
    pub sessions_opened: u64,
    /// Sessions (re)created from a client-supplied checkpoint via the
    /// `Restore` verb. Counted separately from `sessions_opened` so a
    /// restore over an already-known id doesn't double-count the session.
    pub sessions_restored: u64,
    /// Sessions dropped outright (no spill dir, total-cap eviction, or a
    /// failed spill write).
    pub sessions_evicted: u64,
    /// Sessions suspended: snapshotted to the spill dir and released from
    /// RAM (LRU pressure, byte budget, or the `Suspend` verb).
    pub suspends: u64,
    /// Suspended sessions restored from disk (explicitly or transparently
    /// on their next request).
    pub resumes: u64,
    pub rejected_backpressure: u64,
    pub errors: u64,
    /// Requests that panicked inside a shard (caught; the session was
    /// dropped and an error surfaced to the caller).
    pub panics: u64,
    /// Rows executed through pooled cross-session block-tail GEMMs (the
    /// batched execution path; 0 means every edit ran per-session).
    pub batched_rows: u64,
    /// Block tails served from the shared codebook-product cache (the
    /// decode→mix GEMV was skipped). Additive across sessions and shards;
    /// 0 when `code_cache_mb` is 0.
    pub cache_hits: u64,
    /// Block tails that consulted the cache and had to compute (the miss
    /// inserts the product for future hits).
    pub cache_misses: u64,
    /// Cache entries evicted under the `code_cache_mb` byte budget.
    pub cache_evictions: u64,
    /// Bytes inserted into the cache (cumulative, not resident — the
    /// resident gauge lives in the cache itself and is bounded by config).
    pub cache_bytes: u64,
    /// Batch occupancy: rows per pooled GEMM issued. A mean near 1 means
    /// the window rarely catches concurrent sessions; a high p50 means the
    /// weight traversal is being amortized well.
    pub batch_fill: Histogram,
}

impl Metrics {
    /// Fold another shard's metrics into this one — the pool-wide snapshot
    /// a `Stats` request reports.
    pub fn merge(&mut self, o: &Metrics) {
        self.lat_edit_us.merge(&o.lat_edit_us);
        self.lat_revision_us.merge(&o.lat_revision_us);
        self.lat_dense_us.merge(&o.lat_dense_us);
        self.flops_incremental += o.flops_incremental;
        self.flops_dense_equiv += o.flops_dense_equiv;
        self.edits += o.edits;
        self.revisions += o.revisions;
        self.dense_calls += o.dense_calls;
        self.defrags += o.defrags;
        self.sessions_opened += o.sessions_opened;
        self.sessions_restored += o.sessions_restored;
        self.sessions_evicted += o.sessions_evicted;
        self.suspends += o.suspends;
        self.resumes += o.resumes;
        self.rejected_backpressure += o.rejected_backpressure;
        self.errors += o.errors;
        self.panics += o.panics;
        self.batched_rows += o.batched_rows;
        self.batch_fill.merge(&o.batch_fill);
        self.cache_hits += o.cache_hits;
        self.cache_misses += o.cache_misses;
        self.cache_evictions += o.cache_evictions;
        self.cache_bytes += o.cache_bytes;
    }
    /// The aggregate speedup the engine achieved (paper's headline ratio).
    pub fn speedup(&self) -> f64 {
        if self.flops_incremental == 0 {
            0.0
        } else {
            self.flops_dense_equiv as f64 / self.flops_incremental as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lat_edit_us", self.lat_edit_us.to_json()),
            ("lat_revision_us", self.lat_revision_us.to_json()),
            ("lat_dense_us", self.lat_dense_us.to_json()),
            ("flops_incremental", Json::num(self.flops_incremental as f64)),
            ("flops_dense_equiv", Json::num(self.flops_dense_equiv as f64)),
            ("speedup", Json::num(self.speedup())),
            ("edits", Json::num(self.edits as f64)),
            ("revisions", Json::num(self.revisions as f64)),
            ("dense_calls", Json::num(self.dense_calls as f64)),
            ("defrags", Json::num(self.defrags as f64)),
            ("sessions_opened", Json::num(self.sessions_opened as f64)),
            ("sessions_restored", Json::num(self.sessions_restored as f64)),
            ("sessions_evicted", Json::num(self.sessions_evicted as f64)),
            ("suspends", Json::num(self.suspends as f64)),
            ("resumes", Json::num(self.resumes as f64)),
            (
                "rejected_backpressure",
                Json::num(self.rejected_backpressure as f64),
            ),
            ("errors", Json::num(self.errors as f64)),
            ("panics", Json::num(self.panics as f64)),
            ("batched_rows", Json::num(self.batched_rows as f64)),
            ("batch_fill", self.batch_fill.to_json()),
            ("cache_hits", Json::num(self.cache_hits as f64)),
            ("cache_misses", Json::num(self.cache_misses as f64)),
            ("cache_evictions", Json::num(self.cache_evictions as f64)),
            ("cache_bytes", Json::num(self.cache_bytes as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_basics() {
        let mut h = Histogram::new();
        for v in [1.0, 2.0, 4.0, 8.0, 1000.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() > 200.0);
        assert_eq!(h.max(), 1000.0);
        assert!(h.percentile(50.0) >= 4.0);
        assert!(h.percentile(99.0) >= 1000.0);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.percentile(99.0), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn histogram_merge_is_union() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for v in [1.0, 8.0] {
            a.record(v);
        }
        for v in [2.0, 4.0, 1000.0] {
            b.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), 5);
        assert_eq!(a.max(), 1000.0);
        assert!((a.mean() - 203.0).abs() < 1e-9);
        assert!(a.percentile(99.0) >= 1000.0);
    }

    #[test]
    fn metrics_merge_adds_counters() {
        let mut a = Metrics {
            edits: 3,
            flops_incremental: 10,
            flops_dense_equiv: 100,
            cache_hits: 2,
            cache_bytes: 64,
            ..Default::default()
        };
        a.lat_edit_us.record(4.0);
        let mut b = Metrics {
            edits: 5,
            flops_incremental: 10,
            flops_dense_equiv: 300,
            panics: 1,
            suspends: 2,
            resumes: 1,
            cache_hits: 3,
            cache_misses: 4,
            cache_evictions: 1,
            cache_bytes: 128,
            ..Default::default()
        };
        b.lat_edit_us.record(16.0);
        a.merge(&b);
        assert_eq!(a.edits, 8);
        assert_eq!(a.panics, 1);
        assert_eq!((a.suspends, a.resumes), (2, 1));
        assert_eq!(
            (a.cache_hits, a.cache_misses, a.cache_evictions, a.cache_bytes),
            (5, 4, 1, 192)
        );
        assert_eq!(a.speedup(), 20.0);
        assert_eq!(a.lat_edit_us.count(), 2);
    }

    #[test]
    fn merge_folds_batch_occupancy() {
        let mut a = Metrics {
            batched_rows: 10,
            ..Default::default()
        };
        a.batch_fill.record(2.0);
        let mut b = Metrics {
            batched_rows: 5,
            ..Default::default()
        };
        b.batch_fill.record(8.0);
        b.batch_fill.record(8.0);
        a.merge(&b);
        assert_eq!(a.batched_rows, 15);
        assert_eq!(a.batch_fill.count(), 3);
        assert_eq!(a.batch_fill.max(), 8.0);
        let j = a.to_json();
        assert_eq!(j.get("batched_rows").as_usize(), Some(15));
        assert!(j.get("batch_fill").get("p50").as_f64().is_some());
    }

    #[test]
    fn speedup_ratio() {
        let mut m = Metrics::default();
        m.flops_dense_equiv = 1000;
        m.flops_incremental = 100;
        assert_eq!(m.speedup(), 10.0);
    }

    #[test]
    fn metrics_json_shape() {
        let m = Metrics::default();
        let j = m.to_json();
        assert!(j.get("speedup").as_f64().is_some());
        assert!(j.get("lat_edit_us").get("p99").as_f64().is_some());
        assert!(j.get("lat_edit_us").get("p999").as_f64().is_some());
        assert_eq!(j.get("sessions_restored").as_usize(), Some(0));
        for k in ["cache_hits", "cache_misses", "cache_evictions", "cache_bytes"] {
            assert_eq!(j.get(k).as_usize(), Some(0), "{k}");
        }
    }
}
