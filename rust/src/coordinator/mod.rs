//! The serving coordinator (L3): a sharded worker pool with hash-routed
//! session ownership, byte-accounted session lifecycle (LRU spill-to-disk
//! under a memory budget, transparent resume), per-shard batching and
//! metrics (merged on snapshot), backpressure, and panic isolation. The
//! paper's incremental engine is the execution backend; the AOT L2
//! artifact is the dense baseline path. See `docs/ARCHITECTURE.md` §5
//! (shard model) and §6 (session lifecycle).

pub mod batcher;
pub mod metrics;
pub mod service;
pub mod session;

pub use metrics::{Histogram, Metrics};
pub use service::{
    Backend, Client, Completion, Coordinator, ReplyTo, Request, Response, SubmitError,
};
pub use session::{Prepared, SessionInfo, SessionStore, StorePolicy};
