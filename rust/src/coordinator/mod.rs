//! The serving coordinator (L3): session management, request routing,
//! batching, metrics, backpressure. The paper's incremental engine is the
//! execution backend; the AOT L2 artifact is the dense baseline path.

pub mod batcher;
pub mod metrics;
pub mod service;
pub mod session;

pub use metrics::{Histogram, Metrics};
pub use service::{Backend, Client, Coordinator, Request, Response};
pub use session::SessionStore;
